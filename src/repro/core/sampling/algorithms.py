"""Sampling primitives: Vitter's Algorithm D (uniform without replacement,
sequential/streaming) and Efraimidis–Spirakis Algorithm A-ES (weighted without
replacement via exponential-race scores), as used by the paper's Gather ops.
"""
from __future__ import annotations

import numpy as np

__all__ = ["algorithm_d", "algorithm_a_es", "uniform_sample"]


def algorithm_d(n: int, k: int, rng: np.random.Generator) -> np.ndarray:
    """Vitter's Algorithm D: k uniform indices without replacement from
    range(n), emitted in increasing order, O(k) time and O(1) extra space.

    Faithful implementation of the skip-distance method (Vitter 1987, with the
    Algorithm A fallback for small n/k ratios)."""
    if k <= 0:
        return np.zeros(0, dtype=np.int64)
    if k >= n:
        return np.arange(n, dtype=np.int64)
    out = np.empty(k, dtype=np.int64)
    i = 0  # next candidate index
    j = 0  # number selected
    n_rem, k_rem = n, k
    alpha = 13  # switch to Algorithm A when n_rem <= alpha * k_rem
    while k_rem > 1:
        if n_rem <= alpha * k_rem:
            # Algorithm A: simple sequential scan
            top = n_rem - k_rem
            while k_rem > 1:
                v = rng.random()
                s = 0
                quot = top / n_rem
                while quot > v:
                    s += 1
                    top -= 1
                    n_rem -= 1
                    quot *= top / n_rem
                i += s
                out[j] = i
                j += 1
                i += 1
                n_rem -= 1
                k_rem -= 1
            break
        # Algorithm D skip generation
        vprime = rng.random() ** (1.0 / k_rem)
        qu1 = n_rem - k_rem + 1
        while True:
            # generate U and X
            while True:
                x = n_rem * (1.0 - vprime)
                s = int(x)
                if s < qu1:
                    break
                vprime = rng.random() ** (1.0 / k_rem)
            u = rng.random()
            # acceptance test (simplified exact rejection via f(s))
            y1 = (u * n_rem / qu1) ** (1.0 / (k_rem - 1))
            vprime = y1 * (1.0 - x / n_rem) ** -1 * (qu1 / (qu1 - s))
            if vprime <= 1.0:
                break  # accept by squeeze
            # full test
            y2 = 1.0
            top2 = n_rem - 1.0
            if k_rem - 1 > s:
                bottom = n_rem - k_rem
                limit = n_rem - s
            else:
                bottom = n_rem - s - 1.0
                limit = qu1
            t = n_rem - 1.0
            while t >= limit:
                y2 *= top2 / bottom
                top2 -= 1.0
                bottom -= 1.0
                t -= 1.0
            if n_rem / (n_rem - x) >= y1 * (y2 ** (1.0 / (k_rem - 1))):
                vprime = rng.random() ** (1.0 / (k_rem - 1))
                break
            vprime = rng.random() ** (1.0 / k_rem)
        i += s
        out[j] = i
        j += 1
        i += 1
        n_rem -= s + 1
        k_rem -= 1
    # last record: uniform over the remainder
    if k_rem == 1:
        s = int(n_rem * rng.random())
        i += s
        out[j] = i
        j += 1
    return out[:j]


def uniform_sample(
    n: int, k: int, rng: np.random.Generator, use_vitter: bool = False
) -> np.ndarray:
    """k uniform indices from range(n) without replacement.  The vectorized
    numpy path is distribution-identical to Algorithm D; ``use_vitter=True``
    runs the faithful streaming implementation (validated equivalent in
    tests/test_sampling_algorithms.py)."""
    if k <= 0:
        return np.zeros(0, dtype=np.int64)
    if k >= n:
        return np.arange(n, dtype=np.int64)
    if use_vitter:
        return algorithm_d(n, k, rng)
    if k * 4 >= n:
        return np.sort(rng.permutation(n)[:k]).astype(np.int64)
    # rejection-free for k << n: Floyd's algorithm vectorized-ish
    return np.sort(rng.choice(n, size=k, replace=False)).astype(np.int64)


def algorithm_a_es(
    weights: np.ndarray, k: int, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray]:
    """Efraimidis–Spirakis A-ES: weighted sampling without replacement.

    Returns (indices, scores) of the top-k items by score u_i^{1/w_i}.
    Items with zero/negative weight are never selected (score 0).
    The *scores* are what make the algorithm distributable: global top-k of
    per-server top-k equals single-machine top-k (Gather/Apply, paper Alg 3/4).
    """
    n = weights.shape[0]
    if n == 0 or k <= 0:
        return np.zeros(0, np.int64), np.zeros(0, np.float64)
    u = rng.random(n)
    w = np.asarray(weights, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        scores = np.where(w > 0, u ** (1.0 / np.maximum(w, 1e-300)), 0.0)
    # never pad the draw with zero-weight items (P ∝ w means P = 0)
    k = min(k, int((w > 0).sum()))
    if k <= 0:
        return np.zeros(0, np.int64), np.zeros(0, np.float64)
    if k == n:
        idx = np.argsort(-scores, kind="stable")
    else:
        part = np.argpartition(-scores, k - 1)[:k]
        idx = part[np.argsort(-scores[part], kind="stable")]
    return idx.astype(np.int64), scores[idx]
