from repro.core.sampling.algorithms import algorithm_d, algorithm_a_es, uniform_sample
from repro.core.sampling.service import (
    DEFAULT_DIRECTION,
    MAX_PARTS,
    SamplingServer,
    VertexRouter,
    GatherApplyClient,
    EdgeCutClient,
    SampledHop,
    SampledSubgraph,
)

__all__ = [
    "algorithm_d",
    "algorithm_a_es",
    "uniform_sample",
    "DEFAULT_DIRECTION",
    "MAX_PARTS",
    "SamplingServer",
    "VertexRouter",
    "GatherApplyClient",
    "EdgeCutClient",
    "SampledHop",
    "SampledSubgraph",
]
