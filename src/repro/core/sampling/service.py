"""Gather-Apply distributed K-hop neighbor sampling (paper §III-C, Alg. 1-4).

The P logical sampling servers (one per vertex-cut partition) are simulated
in-process.  The client routes each one-hop request to *every* server hosting
the seed (the vertex-cut property), gathers partial samples and applies the
merge:

  uniform  — server p draws r = f · local_deg/global_deg edges via Algorithm D
             (UniformGatherOp, Alg. 2); Apply joins and trims to f.
  weighted — server p computes A-ES scores u^{1/w} for its local neighbors and
             returns its top-f with scores (WeightedGatherOp, Alg. 3); Apply
             takes the global top-f by score (WeightedApplyOp, Alg. 4).

Per-server workload counters model the paper's Fig.-10 measurement: work is
dominated by edges touched (weighted scans all local neighbor weights; uniform
is O(k) thanks to Algorithm D) plus a per-seed request overhead.

``EdgeCutClient`` emulates the DistDGL-style baseline: an edge-cut partitioned
graph where the one-hop request of a vertex is answered ONLY by its owner
server (halo edges make it local) — the hotspot's entire neighborhood burdens
a single server, which is precisely the imbalance GLISP removes.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.graph.graph import GraphPartition, HeteroGraph

__all__ = [
    "DEFAULT_DIRECTION",
    "MAX_PARTS",
    "VertexRouter",
    "SamplingServer",
    "GatherApplyClient",
    "EdgeCutClient",
    "SampledHop",
    "SampledSubgraph",
]

# One shared default for every sampler surface (clients, trainer, inference
# engine).  GLISP samples along OUT edges; baselines must use the same
# direction or comparisons silently skew.
DEFAULT_DIRECTION = "out"

# The router packs hosting sets into a uint64 bitmask; more partitions than
# bits silently alias (1 << p wraps), corrupting routing.
MAX_PARTS = 64


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


class VertexRouter:
    """Vertex -> set of partitions (bitmask), built from the edge assignment."""

    def __init__(self, g: HeteroGraph, edge_parts: np.ndarray, num_parts: int):
        if num_parts > MAX_PARTS:
            raise ValueError(
                f"VertexRouter supports at most {MAX_PARTS} partitions "
                f"(uint64 hosting bitmask), got num_parts={num_parts}"
            )
        mask = np.zeros(g.num_vertices, dtype=np.uint64)
        for p in range(num_parts):
            sel = edge_parts == p
            bit = np.uint64(1 << p)
            verts = np.union1d(g.src[sel], g.dst[sel])
            mask[verts] |= bit
        self.mask = mask
        self.num_parts = num_parts

    def servers_of(self, gids: np.ndarray) -> list[np.ndarray]:
        """For each partition p, the subset of ``gids`` hosted on p."""
        out = []
        for p in range(self.num_parts):
            bit = np.uint64(1 << p)
            out.append(gids[(self.mask[gids] & bit) != 0])
        return out


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------


@dataclass
class ServerStats:
    requests: int = 0
    seeds: int = 0
    work_units: float = 0.0  # modeled work: edges scanned + samples drawn
    edges_returned: int = 0
    bytes_out: int = 0

    def merge(self, other: "ServerStats") -> None:
        self.requests += other.requests
        self.seeds += other.seeds
        self.work_units += other.work_units
        self.edges_returned += other.edges_returned
        self.bytes_out += other.bytes_out


class SamplingServer:
    def __init__(
        self, part: GraphPartition, seed: int = 0, cost_model: str = "algd"
    ):
        """cost_model:
        "algd" — GLISP: Vitter's Algorithm D, O(k) work per uniform request
                 (the paper's design);
        "scan" — baseline systems whose uniform neighbor sampling walks the
                 local adjacency slice, O(local_deg) per request (DGL-style
                 permutation/reservoir implementations)."""
        self.part = part
        self.rng = np.random.default_rng(seed * 7919 + part.part_id)
        self.stats = ServerStats()
        self.cost_model = cost_model

    # -- helpers -----------------------------------------------------------
    def _slices(self, lids: np.ndarray, direction: str):
        p = self.part
        if direction == "out":
            indptr, nbr = p.out_indptr, p.out_dst
            eid_of_slot = None  # slot index IS the edge local id
        else:
            indptr, nbr = p.in_indptr, p.in_src
            eid_of_slot = p.in_edge_id
        starts, ends = indptr[lids], indptr[lids + 1]
        return starts, ends, nbr, eid_of_slot

    def _global_degree(self, lids: np.ndarray, direction: str) -> np.ndarray:
        return (
            self.part.out_degrees[lids]
            if direction == "out"
            else self.part.in_degrees[lids]
        )

    @staticmethod
    def _flatten_slices(starts: np.ndarray, lens: np.ndarray):
        """(slots, seg): concatenated ``arange(starts[i], starts[i]+lens[i])``
        plus the owning seed index per slot — one vectorized pass, no Python
        loop (the sampling hot path runs on the prefetch thread and must not
        hog the GIL)."""
        total = int(lens.sum())
        if total == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        cum = np.cumsum(lens) - lens
        ranges = np.arange(total, dtype=np.int64) - np.repeat(cum, lens)
        slots = np.repeat(starts, lens) + ranges
        seg = np.repeat(np.arange(lens.shape[0], dtype=np.int64), lens)
        return slots, seg

    def _eid_global(self, eids_local: np.ndarray) -> np.ndarray:
        """Local edge ids -> global edge ids (identity if the partition was
        built before ``edge_global_id`` existed)."""
        eg = self.part.edge_global_id
        return eids_local if eg is None else eg[eids_local].astype(np.int64)

    # -- UniformGatherOp (Alg. 2) -------------------------------------------
    def uniform_gather(
        self, seeds_gid: np.ndarray, fanout: int, direction: str = DEFAULT_DIRECTION
    ):
        p = self.part
        lids = p.global_to_local(seeds_gid)
        ok = lids >= 0
        seeds_gid, lids = seeds_gid[ok], lids[ok]
        if seeds_gid.shape[0] == 0:
            return (np.zeros(0, np.int64),) * 2 + (np.zeros(0, np.int64),)
        starts, ends, nbr, eid_of_slot = self._slices(lids, direction)
        local_deg = (ends - starts).astype(np.int64)
        global_deg = np.maximum(1, self._global_degree(lids, direction))
        r = fanout * local_deg / global_deg
        k = np.floor(r).astype(np.int64)
        k += self.rng.random(k.shape[0]) < (r - k)  # randomized rounding
        k = np.minimum(k, local_deg)

        self.stats.requests += 1
        self.stats.seeds += int(seeds_gid.shape[0])
        if self.cost_model == "algd":
            # Algorithm D: O(k) work per seed + request handling overhead
            self.stats.work_units += float(k.sum()) + seeds_gid.shape[0]
        else:
            # adjacency-slice walk: O(local_deg) per seed
            self.stats.work_units += float(local_deg.sum()) + seeds_gid.shape[0]

        # vectorized k-of-n per seed: draw one uniform key per local edge
        # slot, keep each seed's k smallest — distribution-identical to
        # Algorithm D (uniform without replacement); the *cost model* above
        # still charges O(k) per the paper's design
        sel = k > 0
        if not sel.any():
            return (np.zeros(0, np.int64),) * 3
        slots, seg = self._flatten_slices(starts[sel], local_deg[sel])
        u = self.rng.random(slots.shape[0])
        order = np.lexsort((u, seg))
        seg_s, slots_s = seg[order], slots[order]
        keep = _group_rank(seg_s) < k[sel][seg_s]
        seg_k, slots_k = seg_s[keep], slots_s[keep]
        s = seeds_gid[sel][seg_k]
        n = p.local_to_global(nbr[slots_k])
        e = self._eid_global(
            slots_k if eid_of_slot is None else eid_of_slot[slots_k]
        )
        self.stats.edges_returned += s.shape[0]
        self.stats.bytes_out += s.nbytes + n.nbytes
        return s, n, e

    # -- WeightedGatherOp (Alg. 3) -------------------------------------------
    def weighted_gather(
        self, seeds_gid: np.ndarray, fanout: int, direction: str = DEFAULT_DIRECTION
    ):
        p = self.part
        assert p.edge_weights is not None, "graph has no edge weights"
        lids = p.global_to_local(seeds_gid)
        ok = lids >= 0
        seeds_gid, lids = seeds_gid[ok], lids[ok]
        if seeds_gid.shape[0] == 0:
            return (np.zeros(0, np.int64),) * 2 + (
                np.zeros(0, np.float64),
                np.zeros(0, np.int64),
            )
        starts, ends, nbr, eid_of_slot = self._slices(lids, direction)
        local_deg = (ends - starts).astype(np.int64)

        self.stats.requests += 1
        self.stats.seeds += int(seeds_gid.shape[0])
        # A-ES scans every local neighbor weight: O(local_deg) per seed
        self.stats.work_units += float(local_deg.sum()) + seeds_gid.shape[0]

        # vectorized A-ES (Efraimidis–Spirakis): score u^{1/w} per local
        # edge, per-seed top-f by score — one lexsort over the flattened
        # neighbor slices instead of a Python loop per seed
        slots, seg = self._flatten_slices(starts, local_deg)
        if slots.shape[0] == 0:
            return (np.zeros(0, np.int64),) * 2 + (
                np.zeros(0, np.float64),
                np.zeros(0, np.int64),
            )
        eids = slots if eid_of_slot is None else eid_of_slot[slots]
        w = p.edge_weights[eids].astype(np.float64)
        u = self.rng.random(slots.shape[0])
        with np.errstate(divide="ignore", invalid="ignore"):
            scores = np.where(w > 0, u ** (1.0 / np.maximum(w, 1e-300)), 0.0)
        order = np.lexsort((-scores, seg))
        seg_s = seg[order]
        # P(select) ∝ weight: zero/negative-weight edges are never returned,
        # even when a seed has fewer than `fanout` positive-weight neighbors
        keep = (_group_rank(seg_s) < fanout) & (scores[order] > 0)
        kept = order[keep]
        seg_k = seg[kept]
        s = seeds_gid[seg_k]
        n = p.local_to_global(nbr[slots[kept]])
        sc = scores[kept]
        e = self._eid_global(eids[kept])
        self.stats.edges_returned += s.shape[0]
        self.stats.bytes_out += s.nbytes + n.nbytes + sc.nbytes
        return s, n, sc, e


# ---------------------------------------------------------------------------
# Sampled output
# ---------------------------------------------------------------------------


@dataclass
class SampledHop:
    src: np.ndarray  # seed gids, repeated per sampled edge
    dst: np.ndarray  # sampled neighbor gids
    # global edge id per sampled edge (None for partitions built before
    # edge_global_id existed); lets consumers read edge types/weights directly
    eid: np.ndarray | None = None


@dataclass
class SampledSubgraph:
    seeds: np.ndarray
    hops: list[SampledHop] = field(default_factory=list)

    def all_vertices(self) -> np.ndarray:
        arrs = [self.seeds] + [h.src for h in self.hops] + [h.dst for h in self.hops]
        return np.unique(np.concatenate(arrs))

    @property
    def num_edges(self) -> int:
        return sum(h.src.shape[0] for h in self.hops)


# ---------------------------------------------------------------------------
# Clients
# ---------------------------------------------------------------------------


def _group_rank(seed_arr: np.ndarray) -> np.ndarray:
    """Rank of each element within its (sorted, contiguous) seed group."""
    change = np.empty(seed_arr.shape[0], dtype=bool)
    change[0] = True
    change[1:] = seed_arr[1:] != seed_arr[:-1]
    group_start = np.maximum.accumulate(
        np.where(change, np.arange(seed_arr.shape[0]), 0)
    )
    return np.arange(seed_arr.shape[0]) - group_start


def _trim_uniform(
    seed_arr: np.ndarray,
    nbr_arr: np.ndarray,
    eid_arr: np.ndarray,
    fanout: int,
    rng: np.random.Generator,
):
    """UniformApplyOp: join per-server results; trim any seed's surplus
    (randomized rounding can overshoot f by a draw or two) uniformly."""
    if seed_arr.shape[0] == 0:
        return seed_arr, nbr_arr, eid_arr
    # random permutation then stable-sort by seed => random order within seed
    perm = rng.permutation(seed_arr.shape[0])
    order = perm[np.argsort(seed_arr[perm], kind="stable")]
    seed_arr, nbr_arr, eid_arr = seed_arr[order], nbr_arr[order], eid_arr[order]
    keep = _group_rank(seed_arr) < fanout
    return seed_arr[keep], nbr_arr[keep], eid_arr[keep]


def _topk_by_score(
    seed_arr: np.ndarray,
    nbr_arr: np.ndarray,
    eid_arr: np.ndarray,
    score_arr: np.ndarray,
    fanout: int,
):
    """WeightedApplyOp: global top-f per seed by A-ES score (Alg. 4)."""
    if seed_arr.shape[0] == 0:
        return seed_arr, nbr_arr, eid_arr
    order = np.lexsort((-score_arr, seed_arr))
    seed_arr, nbr_arr, eid_arr = seed_arr[order], nbr_arr[order], eid_arr[order]
    keep = _group_rank(seed_arr) < fanout
    return seed_arr[keep], nbr_arr[keep], eid_arr[keep]


class GatherApplyClient:
    """GLISP client: Gather from all hosting servers, Apply merge (Alg. 1)."""

    def __init__(
        self,
        servers: list[SamplingServer],
        router: VertexRouter,
        seed: int = 0,
    ):
        self.servers = servers
        self.router = router
        self.rng = np.random.default_rng(seed)
        # eids are only meaningful when EVERY server can map to global ids
        # (partitions persisted before edge_global_id existed return local
        # slots, which must not be mistaken for global edge ids)
        self.has_global_eids = all(
            s.part.edge_global_id is not None for s in servers
        )
        # modeled wall-clock work: servers run in parallel, so a hop costs the
        # MAX of the per-server work deltas (the in-process simulation is
        # serial; benchmarks use this to report parallel-cluster latency)
        self.parallel_work = 0.0
        self.total_work = 0.0

    def sample_khop(
        self,
        seeds: np.ndarray,
        fanouts: list[int],
        weighted: bool = False,
        direction: str = DEFAULT_DIRECTION,
    ) -> SampledSubgraph:
        seeds = np.unique(np.asarray(seeds, dtype=np.int64))
        result = SampledSubgraph(seeds=seeds)
        frontier = seeds
        for f in fanouts:
            routed = self.router.servers_of(frontier)
            parts_s, parts_n, parts_x, parts_e = [], [], [], []
            w0 = [srv.stats.work_units for srv in self.servers]
            for srv, sub in zip(self.servers, routed):
                if sub.shape[0] == 0:
                    continue
                if weighted:
                    s, n, sc, e = srv.weighted_gather(sub, f, direction)
                    parts_x.append(sc)
                else:
                    s, n, e = srv.uniform_gather(sub, f, direction)
                parts_s.append(s)
                parts_n.append(n)
                parts_e.append(e)
            deltas = [
                srv.stats.work_units - w for srv, w in zip(self.servers, w0)
            ]
            self.parallel_work += max(deltas) if deltas else 0.0
            self.total_work += sum(deltas)
            if parts_s:
                s = np.concatenate(parts_s)
                n = np.concatenate(parts_n)
                e = np.concatenate(parts_e)
                if weighted:
                    sc = np.concatenate(parts_x)
                    s, n, e = _topk_by_score(s, n, e, sc, f)
                else:
                    s, n, e = _trim_uniform(s, n, e, f, self.rng)
            else:
                s = n = e = np.zeros(0, np.int64)
            result.hops.append(
                SampledHop(src=s, dst=n, eid=e if self.has_global_eids else None)
            )
            frontier = np.unique(n)  # GetSeedsOfNextHop
            if frontier.shape[0] == 0:
                break
        return result

    def server_workloads(self) -> np.ndarray:
        return np.array([s.stats.work_units for s in self.servers])

    def reset_stats(self) -> None:
        for s in self.servers:
            s.stats = ServerStats()


class EdgeCutClient(GatherApplyClient):
    """DistDGL-style baseline: one-hop request of v is answered ONLY by
    owner(v); the halo (replicated cut edges) makes it local.  Built over the
    same server implementation, but routing is by vertex owner, the local
    partition holds the vertex's FULL one-hop, and the sample is complete
    without a merge step (local_deg == global_deg on the owner)."""

    def __init__(
        self,
        servers: list[SamplingServer],
        vertex_owner: np.ndarray,
        seed: int = 0,
    ):
        self.servers = servers
        self.owner = vertex_owner
        self.rng = np.random.default_rng(seed)
        self.has_global_eids = all(
            s.part.edge_global_id is not None for s in servers
        )
        self.parallel_work = 0.0
        self.total_work = 0.0

    def sample_khop(
        self,
        seeds: np.ndarray,
        fanouts: list[int],
        weighted: bool = False,
        direction: str = DEFAULT_DIRECTION,
    ) -> SampledSubgraph:
        seeds = np.unique(np.asarray(seeds, dtype=np.int64))
        result = SampledSubgraph(seeds=seeds)
        frontier = seeds
        for f in fanouts:
            parts_s, parts_n, parts_e = [], [], []
            owners = self.owner[frontier]
            w0 = [srv.stats.work_units for srv in self.servers]
            for p, srv in enumerate(self.servers):
                sub = frontier[owners == p]
                if sub.shape[0] == 0:
                    continue
                if weighted:
                    s, n, sc, e = srv.weighted_gather(sub, f, direction)
                    s, n, e = _topk_by_score(s, n, e, sc, f)
                else:
                    s, n, e = srv.uniform_gather(sub, f, direction)
                parts_s.append(s)
                parts_n.append(n)
                parts_e.append(e)
            deltas = [
                srv.stats.work_units - w for srv, w in zip(self.servers, w0)
            ]
            self.parallel_work += max(deltas) if deltas else 0.0
            self.total_work += sum(deltas)
            s = np.concatenate(parts_s) if parts_s else np.zeros(0, np.int64)
            n = np.concatenate(parts_n) if parts_n else np.zeros(0, np.int64)
            e = np.concatenate(parts_e) if parts_e else np.zeros(0, np.int64)
            result.hops.append(
                SampledHop(src=s, dst=n, eid=e if self.has_global_eids else None)
            )
            frontier = np.unique(n)
            if frontier.shape[0] == 0:
                break
        return result
