"""Gather-Apply distributed K-hop neighbor sampling (paper §III-C, Alg. 1-4).

The P logical sampling servers (one per vertex-cut partition) are simulated
in-process.  One-hop requests are routed to servers by a *routing strategy*,
partial samples are gathered and (for the vertex-cut layout) merged:

  uniform  — server p draws r = f · local_deg/global_deg edges via Algorithm D
             (UniformGatherOp, Alg. 2); Apply joins and trims to f.
  weighted — server p computes A-ES scores u^{1/w} for its local neighbors and
             returns its top-f with scores (WeightedGatherOp, Alg. 3); Apply
             takes the global top-f by score (WeightedApplyOp, Alg. 4).

Two routing strategies cover the paper's system and the baseline:

``GatherApplyRouting`` — GLISP: every server hosting the seed (the vertex-cut
    property) answers with its local portion; the client-side Apply merges.
``OwnerRouting`` — the DistDGL-style baseline: one-hop requests are answered
    ONLY by the seed's owner (halo edges make the full neighborhood local);
    no cross-server merge — the hotspot's entire neighborhood burdens a
    single server, precisely the imbalance GLISP removes.

Per-server workload counters model the paper's Fig.-10 measurement: work is
dominated by edges touched (weighted scans all local neighbor weights; uniform
is O(k) thanks to Algorithm D) plus a per-seed request overhead.

Two consumption surfaces share the same servers, routing, and hop executor:

``SamplingService`` (preferred) — the asynchronous request-plan API.  Clients
    ``submit(SampleRequest) -> SampleTicket`` and read ``ticket.result()``;
    the service advances every in-flight request one hop per scheduling
    round, so concurrent requests overlap hop levels (request k's hop-2 runs
    beside request k+1's hop-1), duplicate frontier seeds across in-flight
    requests are coalesced into one dispatch, and oversized per-server
    batches are split.  Randomness is keyed per ``(service seed, request
    key, hop, server, chunk)``, so a request's result is bit-identical
    regardless of prefetch depth, submission interleaving, or how many
    concurrent clients share the service.

``GatherApplyClient`` / ``EdgeCutClient`` (legacy, blocking) — thin
    synchronous wrappers over the same routing strategies + hop executor,
    drawing from shared per-server RNG streams (results depend on call
    order).  Kept for raw single-consumer use; new code should go through
    ``SamplingService``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.faults import CircuitBreaker, InjectedFault, RetryPolicy, as_injector
from repro.graph.graph import GraphPartition, HeteroGraph

__all__ = [
    "DEFAULT_DIRECTION",
    "MAX_PARTS",
    "VertexRouter",
    "SamplingServer",
    "ServerStats",
    "SamplingSpec",
    "SampleRequest",
    "SampleTicket",
    "SampleTimeout",
    "SamplingService",
    "ServiceStats",
    "request_rng",
    "GatherApplyRouting",
    "OwnerRouting",
    "GatherApplyClient",
    "EdgeCutClient",
    "SampledHop",
    "SampledSubgraph",
]

# One shared default for every sampler surface (clients, trainer, inference
# engine).  GLISP samples along OUT edges; baselines must use the same
# direction or comparisons silently skew.
DEFAULT_DIRECTION = "out"

# The router packs hosting sets into a uint64 bitmask; more partitions than
# bits silently alias (1 << p wraps), corrupting routing.
MAX_PARTS = 64

_KEY_MASK = (1 << 64) - 1
# domain-separation tags for the per-request RNG streams (gather draws vs
# the client-side Apply trim) so the two never alias
_GATHER_TAG = 0x6A7

_TRIM_TAG = 0x7213


def request_rng(seed: int, key: tuple, hop: int, *tail: int) -> np.random.Generator:
    """The deterministic RNG stream for ``(service seed, request key, hop,
    *tail)`` — length-prefixed entropy, so keys of different lengths never
    alias.  Module-level rather than a service method because remote
    sampling workers (``repro.dist.worker``) must re-derive the very same
    streams from wire-carried key material; this function is the single
    definition both deployments share."""
    seq = np.random.SeedSequence(
        (int(seed) & _KEY_MASK, len(key), *key, hop, *tail)
    )
    return np.random.default_rng(seq)


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


class VertexRouter:
    """Vertex -> set of partitions (bitmask), built from the edge assignment."""

    def __init__(self, g: HeteroGraph, edge_parts: np.ndarray, num_parts: int):
        if num_parts > MAX_PARTS:
            raise ValueError(
                f"VertexRouter supports at most {MAX_PARTS} partitions "
                f"(uint64 hosting bitmask), got num_parts={num_parts}"
            )
        mask = np.zeros(g.num_vertices, dtype=np.uint64)
        for p in range(num_parts):
            sel = edge_parts == p
            bit = np.uint64(1 << p)
            verts = np.union1d(g.src[sel], g.dst[sel])
            mask[verts] |= bit
        self.mask = mask
        self.num_parts = num_parts

    def servers_of(self, gids: np.ndarray) -> list[np.ndarray]:
        """For each partition p, the subset of ``gids`` hosted on p."""
        out = []
        for p in range(self.num_parts):
            bit = np.uint64(1 << p)
            out.append(gids[(self.mask[gids] & bit) != 0])
        return out


class GatherApplyRouting:
    """GLISP routing: every server hosting a seed answers; Apply merges."""

    merge = True

    def __init__(self, router: VertexRouter):
        self.router = router

    def route(self, frontier: np.ndarray) -> list[np.ndarray]:
        return self.router.servers_of(frontier)


class OwnerRouting:
    """DistDGL-style routing: only the seed's owner answers; no merge (the
    owner's halo holds the FULL one-hop, so local_deg == global_deg)."""

    merge = False

    def __init__(self, owner: np.ndarray, num_parts: int):
        self.owner = owner
        self.num_parts = num_parts

    def route(self, frontier: np.ndarray) -> list[np.ndarray]:
        owners = self.owner[frontier]
        return [frontier[owners == p] for p in range(self.num_parts)]


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------


@dataclass
class ServerStats:
    requests: int = 0
    seeds: int = 0
    work_units: float = 0.0  # modeled work: edges scanned + samples drawn
    edges_returned: int = 0
    bytes_out: int = 0
    # fault-tolerance counters: extra gather attempts after an injected
    # failure, dispatches served by a non-primary replica, and dispatches
    # lost entirely (every replica exhausted -> degraded partial fanout)
    retries: int = 0
    failovers: int = 0
    degraded: int = 0

    def merge(self, other: "ServerStats") -> None:
        self.requests += other.requests
        self.seeds += other.seeds
        self.work_units += other.work_units
        self.edges_returned += other.edges_returned
        self.bytes_out += other.bytes_out
        self.retries += other.retries
        self.failovers += other.failovers
        self.degraded += other.degraded


@dataclass
class ServiceStats(ServerStats):
    """``SamplingService.stats()``: the merged per-server counters plus the
    service-level work accounting, with the *modeled* numbers explicitly
    named as such so benchmarks can no longer conflate them with the
    *measured* wall-clock per-round time reported alongside."""

    # the Fig.-10 work model (edges touched + per-seed overhead), NOT a
    # measurement: per-round MAX across servers / per-round SUM
    modeled_parallel_work: float = 0.0
    modeled_total_work: float = 0.0
    # measured: scheduling rounds driven and their wall-clock total
    rounds: int = 0
    measured_round_seconds: float = 0.0

    @property
    def parallel_work(self) -> float:
        """DEPRECATED alias for :attr:`modeled_parallel_work`."""
        return self.modeled_parallel_work

    @property
    def total_work(self) -> float:
        """DEPRECATED alias for :attr:`modeled_total_work`."""
        return self.modeled_total_work


class SamplingServer:
    def __init__(
        self,
        part: GraphPartition,
        seed: int = 0,
        cost_model: str = "algd",
        *,
        replica_id: int = 0,
        faults=None,
    ):
        """cost_model:
        "algd" — GLISP: Vitter's Algorithm D, O(k) work per uniform request
                 (the paper's design);
        "scan" — baseline systems whose uniform neighbor sampling walks the
                 local adjacency slice, O(local_deg) per request (DGL-style
                 permutation/reservoir implementations).

        ``replica_id`` distinguishes replica servers of the same partition
        (the service's failover targets); ``faults`` is an optional
        ``FaultInjector`` fired at the top of every gather, BEFORE any RNG
        consumption or stats accounting, so a failed attempt leaves no
        trace in the sample stream and a retry redraws bit-identically."""
        self.part = part
        self.rng = np.random.default_rng(seed * 7919 + part.part_id)
        self.stats = ServerStats()
        self.cost_model = cost_model
        self.replica_id = replica_id
        self.faults = faults
        self.breaker = CircuitBreaker()
        self.site = f"server.{part.part_id}.{replica_id}"

    @property
    def health(self) -> str:
        """"up" or "quarantined" (circuit breaker open)."""
        return "quarantined" if self.breaker.state == "open" else "up"

    def _maybe_fail(self) -> None:
        if self.faults is not None:
            self.faults.fire(self.site)

    # -- helpers -----------------------------------------------------------
    def _slices(self, lids: np.ndarray, direction: str):
        p = self.part
        if direction == "out":
            indptr, nbr = p.out_indptr, p.out_dst
            eid_of_slot = None  # slot index IS the edge local id
        else:
            indptr, nbr = p.in_indptr, p.in_src
            eid_of_slot = p.in_edge_id
        starts, ends = indptr[lids], indptr[lids + 1]
        return starts, ends, nbr, eid_of_slot

    def _global_degree(self, lids: np.ndarray, direction: str) -> np.ndarray:
        return (
            self.part.out_degrees[lids]
            if direction == "out"
            else self.part.in_degrees[lids]
        )

    @staticmethod
    def _flatten_slices(starts: np.ndarray, lens: np.ndarray):
        """(slots, seg): concatenated ``arange(starts[i], starts[i]+lens[i])``
        plus the owning seed index per slot — one vectorized pass, no Python
        loop (the sampling hot path runs on the prefetch thread and must not
        hog the GIL)."""
        total = int(lens.sum())
        if total == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        cum = np.cumsum(lens) - lens
        ranges = np.arange(total, dtype=np.int64) - np.repeat(cum, lens)
        slots = np.repeat(starts, lens) + ranges
        seg = np.repeat(np.arange(lens.shape[0], dtype=np.int64), lens)
        return slots, seg

    def _eid_global(self, eids_local: np.ndarray) -> np.ndarray:
        """Local edge ids -> global edge ids (identity if the partition was
        built before ``edge_global_id`` existed)."""
        eg = self.part.edge_global_id
        return eids_local if eg is None else eg[eids_local].astype(np.int64)

    # -- UniformGatherOp (Alg. 2) -------------------------------------------
    def uniform_gather(
        self,
        seeds_gid: np.ndarray,
        fanout: int,
        direction: str = DEFAULT_DIRECTION,
        *,
        rng: np.random.Generator | None = None,
        replace: bool = False,
    ):
        """``rng=None`` draws from the server's shared stream (legacy blocking
        clients); the service passes a per-request stream so results are
        independent of request interleaving.  ``replace=True`` draws each of
        the r slots independently (with replacement)."""
        self._maybe_fail()
        rng = self.rng if rng is None else rng
        p = self.part
        lids = p.global_to_local(seeds_gid)
        ok = lids >= 0
        seeds_gid, lids = seeds_gid[ok], lids[ok]
        if seeds_gid.shape[0] == 0:
            return (np.zeros(0, np.int64),) * 2 + (np.zeros(0, np.int64),)
        starts, ends, nbr, eid_of_slot = self._slices(lids, direction)
        local_deg = (ends - starts).astype(np.int64)
        global_deg = np.maximum(1, self._global_degree(lids, direction))
        r = fanout * local_deg / global_deg
        k = np.floor(r).astype(np.int64)
        k += rng.random(k.shape[0]) < (r - k)  # randomized rounding
        if replace:
            k = np.where(local_deg > 0, k, 0)
        else:
            k = np.minimum(k, local_deg)

        self.stats.requests += 1
        self.stats.seeds += int(seeds_gid.shape[0])
        if self.cost_model == "algd":
            # Algorithm D: O(k) work per seed + request handling overhead
            self.stats.work_units += float(k.sum()) + seeds_gid.shape[0]
        else:
            # adjacency-slice walk: O(local_deg) per seed
            self.stats.work_units += float(local_deg.sum()) + seeds_gid.shape[0]

        sel = k > 0
        if not sel.any():
            return (np.zeros(0, np.int64),) * 3
        if replace:
            # each slot an independent uniform draw over the local neighbors
            ksel = k[sel]
            seg_k = np.repeat(np.arange(ksel.shape[0], dtype=np.int64), ksel)
            ld = local_deg[sel][seg_k]
            offs = np.minimum(
                (rng.random(seg_k.shape[0]) * ld).astype(np.int64), ld - 1
            )
            slots_k = starts[sel][seg_k] + offs
        else:
            # vectorized k-of-n per seed: draw one uniform key per local edge
            # slot, keep each seed's k smallest — distribution-identical to
            # Algorithm D (uniform without replacement); the *cost model*
            # above still charges O(k) per the paper's design
            slots, seg = self._flatten_slices(starts[sel], local_deg[sel])
            u = rng.random(slots.shape[0])
            order = np.lexsort((u, seg))
            seg_s, slots_s = seg[order], slots[order]
            keep = _group_rank(seg_s) < k[sel][seg_s]
            seg_k, slots_k = seg_s[keep], slots_s[keep]
        s = seeds_gid[sel][seg_k]
        n = p.local_to_global(nbr[slots_k])
        e = self._eid_global(
            slots_k if eid_of_slot is None else eid_of_slot[slots_k]
        )
        self.stats.edges_returned += s.shape[0]
        self.stats.bytes_out += s.nbytes + n.nbytes
        return s, n, e

    # -- WeightedGatherOp (Alg. 3) -------------------------------------------
    def weighted_gather(
        self,
        seeds_gid: np.ndarray,
        fanout: int,
        direction: str = DEFAULT_DIRECTION,
        *,
        rng: np.random.Generator | None = None,
    ):
        self._maybe_fail()
        rng = self.rng if rng is None else rng
        p = self.part
        assert p.edge_weights is not None, "graph has no edge weights"
        lids = p.global_to_local(seeds_gid)
        ok = lids >= 0
        seeds_gid, lids = seeds_gid[ok], lids[ok]
        if seeds_gid.shape[0] == 0:
            return (np.zeros(0, np.int64),) * 2 + (
                np.zeros(0, np.float64),
                np.zeros(0, np.int64),
            )
        starts, ends, nbr, eid_of_slot = self._slices(lids, direction)
        local_deg = (ends - starts).astype(np.int64)

        self.stats.requests += 1
        self.stats.seeds += int(seeds_gid.shape[0])
        # A-ES scans every local neighbor weight: O(local_deg) per seed
        self.stats.work_units += float(local_deg.sum()) + seeds_gid.shape[0]

        # vectorized A-ES (Efraimidis–Spirakis): score u^{1/w} per local
        # edge, per-seed top-f by score — one lexsort over the flattened
        # neighbor slices instead of a Python loop per seed
        slots, seg = self._flatten_slices(starts, local_deg)
        if slots.shape[0] == 0:
            return (np.zeros(0, np.int64),) * 2 + (
                np.zeros(0, np.float64),
                np.zeros(0, np.int64),
            )
        eids = slots if eid_of_slot is None else eid_of_slot[slots]
        w = p.edge_weights[eids].astype(np.float64)
        u = rng.random(slots.shape[0])
        with np.errstate(divide="ignore", invalid="ignore"):
            scores = np.where(w > 0, u ** (1.0 / np.maximum(w, 1e-300)), 0.0)
        order = np.lexsort((-scores, seg))
        seg_s = seg[order]
        # P(select) ∝ weight: zero/negative-weight edges are never returned,
        # even when a seed has fewer than `fanout` positive-weight neighbors
        keep = (_group_rank(seg_s) < fanout) & (scores[order] > 0)
        kept = order[keep]
        seg_k = seg[kept]
        s = seeds_gid[seg_k]
        n = p.local_to_global(nbr[slots[kept]])
        sc = scores[kept]
        e = self._eid_global(eids[kept])
        self.stats.edges_returned += s.shape[0]
        self.stats.bytes_out += s.nbytes + n.nbytes + sc.nbytes
        return s, n, sc, e


# ---------------------------------------------------------------------------
# Sampled output
# ---------------------------------------------------------------------------


@dataclass
class SampledHop:
    src: np.ndarray  # seed gids, repeated per sampled edge
    dst: np.ndarray  # sampled neighbor gids
    # global edge id per sampled edge (None for partitions built before
    # edge_global_id existed); lets consumers read edge types/weights directly
    eid: np.ndarray | None = None


@dataclass
class SampledSubgraph:
    seeds: np.ndarray
    hops: list[SampledHop] = field(default_factory=list)
    # True when at least one dispatch was lost to failures (every replica
    # exhausted or quarantined): the sample is a partial fanout.  Degraded
    # results are flagged, never silent — consumers decide whether partial
    # neighborhoods are acceptable (training often tolerates them; a
    # determinism-sensitive consumer must drop or resample them).
    degraded: bool = False
    lost_dispatches: int = 0

    def all_vertices(self) -> np.ndarray:
        arrs = [self.seeds] + [h.src for h in self.hops] + [h.dst for h in self.hops]
        return np.unique(np.concatenate(arrs))

    @property
    def num_edges(self) -> int:
        return sum(h.src.shape[0] for h in self.hops)


# ---------------------------------------------------------------------------
# Request plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SamplingSpec:
    """A validated, typed description of one K-hop sampling plan — replaces
    the ``fanouts/weighted/direction`` kwarg forest on every surface."""

    fanouts: tuple = (10, 5)
    weighted: bool = False  # A-ES weighted sampling instead of uniform
    direction: str = DEFAULT_DIRECTION
    # with-replacement uniform draws (each slot independent); weighted A-ES
    # is inherently without replacement
    replace: bool = False

    def validate(self) -> "SamplingSpec":
        if not self.fanouts or any(f <= 0 for f in self.fanouts):
            raise ValueError(f"fanouts must be positive, got {self.fanouts!r}")
        if self.direction not in ("out", "in"):
            raise ValueError(
                f"direction must be 'out' or 'in', got {self.direction!r}"
            )
        if self.weighted and self.replace:
            raise ValueError(
                "replace=True is uniform-only: weighted A-ES sampling is "
                "inherently without replacement"
            )
        return self


@dataclass(frozen=True)
class SampleRequest:
    """One K-hop request: seeds + plan + the RNG stream key.

    ``key`` (a tuple of ints) names the request's deterministic random
    stream: the result is a pure function of ``(service seed, key, seeds,
    spec)``.  Two requests MAY share a key — e.g. identically-seeded loaders
    on a shared service reuse the same key sequence and therefore reproduce
    the exact streams they would see on private services."""

    seeds: np.ndarray
    spec: SamplingSpec
    key: tuple = (0,)


def _norm_key(key) -> tuple:
    if isinstance(key, (int, np.integer)):
        key = (int(key),)
    if isinstance(key, (str, bytes)):
        raise TypeError(
            f"request key must be an int or a tuple of ints, got {key!r}"
        )
    try:
        out = tuple(int(k) & _KEY_MASK for k in key)
    except (TypeError, ValueError):
        raise TypeError(
            f"request key must be an int or a tuple of ints, got {key!r}"
        ) from None
    if not out:
        raise ValueError("request key must not be empty")
    return out


class _RequestState:
    __slots__ = ("request", "result", "frontier", "hop", "done", "cancelled")

    def __init__(self, request: SampleRequest):
        self.request = request
        self.result = SampledSubgraph(seeds=request.seeds)
        self.frontier = request.seeds
        self.hop = 0
        self.done = False
        self.cancelled = False


class SampleTimeout(TimeoutError):
    """``SampleTicket.result(timeout=)`` deadline expired before completion."""


class SampleTicket:
    """Future-like handle for a submitted request.  ``result()`` drives the
    service's cooperative scheduler until this request completes — every
    other in-flight request advances alongside it, one hop per round."""

    def __init__(self, service: "SamplingService", state: _RequestState):
        self._service = service
        self._state = state

    @property
    def request(self) -> SampleRequest:
        return self._state.request

    def done(self) -> bool:
        return self._state.done

    def cancel(self) -> None:
        """Withdraw an unfinished request so abandoned tickets stop
        consuming scheduler rounds and skewing workload counters."""
        self._service._cancel(self._state)

    def result(self, timeout: float | None = None) -> SampledSubgraph:
        """Drive rounds until done; raise :class:`SampleTimeout` past the
        deadline.  ``timeout=None`` falls back to the service's
        ``ticket_timeout`` (itself ``None`` = wait forever, an explicit
        opt-in).  The deadline is checked between rounds: a round's numpy
        work is not interruptible, so expiry is detected at the next
        round boundary — the ticket stays in flight and a later
        ``result()`` call may still complete it."""
        if timeout is None:
            timeout = self._service.ticket_timeout
        deadline = None if timeout is None else time.monotonic() + float(timeout)
        if self._state.cancelled:
            raise RuntimeError("sample request was cancelled")
        while not self._state.done:
            if deadline is not None and time.monotonic() >= deadline:
                raise SampleTimeout(
                    f"sample request key={self._state.request.key} not "
                    f"complete within {timeout}s "
                    f"({self._service.inflight()} requests in flight)"
                )
            # pass the deadline down so contended rounds wait on the
            # scheduler lock only until expiry, not indefinitely — a 10 ms
            # timeout must come back in ~10 ms even when another thread
            # holds the service mid-round
            self._service._advance_round(deadline=deadline)
        if self._state.cancelled:
            raise RuntimeError("sample request was cancelled")
        return self._state.result


# ---------------------------------------------------------------------------
# Shared hop executor
# ---------------------------------------------------------------------------


def _group_rank(seed_arr: np.ndarray) -> np.ndarray:
    """Rank of each element within its (sorted, contiguous) seed group."""
    change = np.empty(seed_arr.shape[0], dtype=bool)
    change[0] = True
    change[1:] = seed_arr[1:] != seed_arr[:-1]
    group_start = np.maximum.accumulate(
        np.where(change, np.arange(seed_arr.shape[0]), 0)
    )
    return np.arange(seed_arr.shape[0]) - group_start


def _trim_uniform(
    seed_arr: np.ndarray,
    nbr_arr: np.ndarray,
    eid_arr: np.ndarray,
    fanout: int,
    rng: np.random.Generator,
):
    """UniformApplyOp: join per-server results; trim any seed's surplus
    (randomized rounding can overshoot f by a draw or two) uniformly."""
    if seed_arr.shape[0] == 0:
        return seed_arr, nbr_arr, eid_arr
    # random permutation then stable-sort by seed => random order within seed
    perm = rng.permutation(seed_arr.shape[0])
    order = perm[np.argsort(seed_arr[perm], kind="stable")]
    seed_arr, nbr_arr, eid_arr = seed_arr[order], nbr_arr[order], eid_arr[order]
    keep = _group_rank(seed_arr) < fanout
    return seed_arr[keep], nbr_arr[keep], eid_arr[keep]


def _topk_by_score(
    seed_arr: np.ndarray,
    nbr_arr: np.ndarray,
    eid_arr: np.ndarray,
    score_arr: np.ndarray,
    fanout: int,
):
    """WeightedApplyOp: global top-f per seed by A-ES score (Alg. 4)."""
    if seed_arr.shape[0] == 0:
        return seed_arr, nbr_arr, eid_arr
    order = np.lexsort((-score_arr, seed_arr))
    seed_arr, nbr_arr, eid_arr = seed_arr[order], nbr_arr[order], eid_arr[order]
    keep = _group_rank(seed_arr) < fanout
    return seed_arr[keep], nbr_arr[keep], eid_arr[keep]


def _chunked(arr: np.ndarray, max_batch: int) -> list[np.ndarray]:
    """Split one per-server seed batch into dispatch-sized chunks.  Chunks
    partition the (unique) batch, so per-seed semantics are untouched."""
    n = arr.shape[0]
    if n == 0:
        return []
    if max_batch <= 0 or n <= max_batch:
        return [arr]
    return [arr[i : i + max_batch] for i in range(0, n, max_batch)]


def _gather_once(
    srv: SamplingServer,
    chunk: np.ndarray,
    fanout: int,
    direction: str,
    *,
    weighted: bool,
    replace: bool,
    rng: np.random.Generator | None,
):
    """One raw gather attempt against one server.  Shared by the direct
    executor path and the service's fault-tolerant dispatcher: any server
    hosting the same partition, given the same ``rng`` key material,
    returns the bit-identical draw — which is what makes retry and
    replica failover invisible in the sample stream."""
    if weighted:
        return srv.weighted_gather(chunk, fanout, direction, rng=rng)
    return srv.uniform_gather(chunk, fanout, direction, rng=rng, replace=replace)


def execute_hop(
    servers: list[SamplingServer],
    routed: list[np.ndarray],
    fanout: int,
    *,
    weighted: bool = False,
    replace: bool = False,
    direction: str = DEFAULT_DIRECTION,
    merge: bool = True,
    trim_rng: np.random.Generator | None = None,
    rng_for=None,
    max_server_batch: int = 0,
    on_dispatch=None,
    dispatch=None,
    submit_dispatch=None,
    collect_dispatch=None,
):
    """One hop for one request: per-server (chunked) gathers + optional Apply.

    The ONE gather/merge loop shared by the blocking clients and the async
    service.  ``merge=True`` is the Gather-Apply path (vertex-cut: join all
    hosts' partials, trim/top-f globally); ``merge=False`` is the owner-routed
    path, where each server's answer is already complete — weighted results
    get the per-server top-f (identical to the global top-f, since every
    neighbor is local to one server) and uniform results need no trim
    (local_deg == global_deg makes randomized rounding exact).

    ``rng_for(part_id, chunk_idx)`` supplies per-dispatch RNG streams (the
    service's per-request keying); ``None`` uses each server's shared stream.
    ``dispatch(part_id, chunk_idx, chunk)`` overrides the gather itself
    (the service's fault-tolerant retry/failover path); it returns
    ``(serving_server, raw_gather)`` or ``None`` for a lost dispatch,
    which marks the hop degraded.  ``on_dispatch(part_id, chunk, server)``
    observes every SERVED chunk (the coalescing accountant) — lost
    dispatches are not observed, so rebates never touch uncharged stats.
    ``submit_dispatch(part_id, chunk_idx, chunk) -> handle`` +
    ``collect_dispatch(handle)`` split the dispatch into two phases (the
    remote worker-pool path): every chunk is submitted before any answer
    is collected, so real worker processes overlap, and answers are
    collected in submission order — the merge sees chunks in exactly the
    sequence the single-phase loop would have produced, which is what
    keeps remote mode bit-identical to in-process mode.

    Returns ``(src, nbr, eid, lost)`` where ``lost`` counts dispatches
    that produced no answer.
    """
    jobs = [
        (p, ci, chunk, srv)
        for p, (srv, sub) in enumerate(zip(servers, routed))
        for ci, chunk in enumerate(_chunked(sub, max_server_batch))
    ]
    handles = (
        [submit_dispatch(p, ci, chunk) for p, ci, chunk, _ in jobs]
        if submit_dispatch is not None
        else None
    )
    parts_s, parts_n, parts_x, parts_e = [], [], [], []
    lost = 0
    for j, (p, ci, chunk, srv) in enumerate(jobs):
        if handles is not None:
            served = collect_dispatch(handles[j])
            if served is None:
                lost += 1
                continue
            srv_used, res = served
        elif dispatch is not None:
            served = dispatch(p, ci, chunk)
            if served is None:
                lost += 1
                continue
            srv_used, res = served
        else:
            rng = rng_for(p, ci) if rng_for is not None else None
            srv_used = srv
            res = _gather_once(
                srv, chunk, fanout, direction,
                weighted=weighted, replace=replace, rng=rng,
            )
        if on_dispatch is not None:
            on_dispatch(p, chunk, srv_used)
        if weighted:
            s, n, sc, e = res
            if merge:
                parts_x.append(sc)
            else:
                s, n, e = _topk_by_score(s, n, e, sc, fanout)
        else:
            s, n, e = res
        parts_s.append(s)
        parts_n.append(n)
        parts_e.append(e)
    if not parts_s:
        z = np.zeros(0, np.int64)
        return z, z, z, lost
    s = np.concatenate(parts_s)
    n = np.concatenate(parts_n)
    e = np.concatenate(parts_e)
    if merge:
        if weighted:
            s, n, e = _topk_by_score(s, n, e, np.concatenate(parts_x), fanout)
        else:
            s, n, e = _trim_uniform(s, n, e, fanout, trim_rng)
    return s, n, e, lost


# ---------------------------------------------------------------------------
# The asynchronous request-plan service
# ---------------------------------------------------------------------------


class SamplingService:
    """The shared, concurrent, schedulable sampling tier.

    Owns the servers and a routing strategy; clients submit requests and
    read tickets:

        service = SamplingService(servers, GatherApplyRouting(router))
        t1 = service.submit(seeds_a, spec)
        t2 = service.submit(seeds_b, spec)      # in flight alongside t1
        sub_a, sub_b = t1.result(), t2.result()

    Scheduling: each round advances EVERY in-flight request by one hop, so
    concurrent requests overlap hop levels.  Within a round the service

    - **coalesces** duplicate frontier seeds across requests: each unique
      (server, seed) pair is charged the per-seed request overhead once and
      the round's dispatch count reflects the deduplicated batches (actual
      sample draws stay per-request so results are bit-exact regardless of
      what else is in flight);
    - **splits** per-server batches larger than ``max_server_batch`` into
      separate dispatches, bounding per-dispatch response size so one huge
      request cannot monopolize a server's queue ahead of other requests'
      chunks.

    Work model: ``parallel_work`` accumulates the per-round MAX of the
    per-server work deltas (servers run concurrently; requests sharing a
    round overlap), ``total_work`` the sum.  The blocking clients charge one
    round per request-hop; overlapping in-flight requests therefore lowers
    modeled parallel latency — the request-level load-balancing win the
    paper's service design targets.

    Determinism contract: a request's result is a pure function of
    ``(service seed, request.key, seeds, spec, max_server_batch)`` —
    invariant to submission order, interleaving, coalescing, and the number
    of concurrent clients.
    """

    def __init__(
        self,
        servers: list[SamplingServer],
        routing,
        *,
        seed: int = 0,
        coalesce: bool = True,
        max_server_batch: int = 0,
        replicas: int = 1,
        fault_plan=None,
        retry_policy: RetryPolicy | None = None,
        ticket_timeout: float | None = None,
        dispatcher=None,
    ):
        """``replicas`` spawns ``replicas - 1`` extra servers per partition
        sharing the primary's ``GraphPartition`` (no data copy — the
        in-process stand-in for a replicated deployment); dispatches fail
        over to them when the primary's attempts are exhausted or its
        breaker is open.  ``fault_plan`` (a ``FaultPlan`` or shared
        ``FaultInjector``) arms injection at every server's gather site;
        ``retry_policy`` bounds per-replica attempts; ``ticket_timeout``
        is the default deadline for ``SampleTicket.result()``.

        ``dispatcher`` routes every gather to real worker processes
        instead of the in-process server objects: anything with the
        ``repro.dist.client.WorkerPool`` contract (``dispatch(p, ci,
        chunk, key, hop, spec) -> handle``, ``collect(handle)``, plus
        ``server_stats/health/workloads/reset_stats/close``).  The
        keyed per-dispatch RNG makes the two paths bit-identical; the
        local servers then only provide routing metadata and sit idle."""
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.servers = servers
        self.routing = routing
        self.seed = int(seed) & _KEY_MASK
        self.coalesce = coalesce
        self.max_server_batch = int(max_server_batch)
        self.replicas = int(replicas)
        self.faults = as_injector(fault_plan)
        self.retry_policy = retry_policy if retry_policy is not None else RetryPolicy()
        self.retry_policy.validate()
        self.ticket_timeout = ticket_timeout
        self.degraded_dispatches = 0
        self.groups: list[list[SamplingServer]] = []
        for srv in servers:
            if self.faults is not None:
                srv.faults = self.faults
            group = [srv]
            for r in range(1, self.replicas):
                group.append(
                    SamplingServer(
                        srv.part,
                        seed=int(seed) + 104729 * r,
                        cost_model=srv.cost_model,
                        replica_id=r,
                        faults=self.faults,
                    )
                )
            self.groups.append(group)
        self._all_servers = [s for group in self.groups for s in group]
        # eids are only meaningful when EVERY server can map to global ids
        # (partitions persisted before edge_global_id existed return local
        # slots, which must not be mistaken for global edge ids)
        self.has_global_eids = all(
            s.part.edge_global_id is not None for s in servers
        )
        self.dispatcher = dispatcher
        self.modeled_parallel_work = 0.0
        self.modeled_total_work = 0.0
        self.rounds = 0
        self.measured_round_seconds = 0.0
        self._inflight: list[_RequestState] = []
        self._auto_key = 0
        # rounds are serialized: concurrent consumers (e.g. a thread-mode
        # prefetch producer beside a foreground sample call) never advance
        # the same request twice; per-request RNG keys keep every result
        # bit-identical no matter which thread drives the round
        self._lock = threading.RLock()

    # -- submission ----------------------------------------------------
    def submit(
        self,
        request,
        spec: SamplingSpec | None = None,
        *,
        key=None,
    ) -> SampleTicket:
        """Submit a ``SampleRequest`` (or ``(seeds, spec)``) for sampling.

        ``key`` names the request's RNG stream (see ``SampleRequest``);
        omitted keys draw from the service's own monotonic counter."""
        if isinstance(request, SampleRequest):
            if spec is not None:
                raise ValueError("pass spec inside the SampleRequest")
            seeds, spec = request.seeds, request.spec
            key = request.key if key is None else key
        else:
            seeds = request
            if spec is None:
                raise ValueError("submit(seeds, ...) requires a SamplingSpec")
        spec.validate()
        with self._lock:
            if key is None:
                key = (self._auto_key,)
                self._auto_key += 1
            req = SampleRequest(
                seeds=np.unique(np.asarray(seeds, dtype=np.int64)),
                spec=spec,
                key=_norm_key(key),
            )
            state = _RequestState(req)
            self._inflight.append(state)
        return SampleTicket(self, state)

    def inflight(self) -> int:
        return len(self._inflight)

    def drain(self) -> None:
        """Run rounds until no request is in flight."""
        while self._inflight:
            self._advance_round()

    # -- blocking shims (one release of deprecation) -------------------
    def sample_khop(
        self,
        seeds: np.ndarray,
        fanouts,
        weighted: bool = False,
        direction: str = DEFAULT_DIRECTION,
    ) -> SampledSubgraph:
        """DEPRECATED submit-and-wait shim over :meth:`submit` (kept one
        release so legacy client call sites keep working)."""
        spec = SamplingSpec(
            fanouts=tuple(fanouts), weighted=weighted, direction=direction
        )
        # glint: disable=DET004 -- deprecated shim keeps the legacy
        # sequence-key behavior its remaining external callers rely on
        return self.submit(seeds, spec).result(timeout=self.ticket_timeout)

    # -- stats ---------------------------------------------------------
    @property
    def router(self) -> VertexRouter:
        router = getattr(self.routing, "router", None)
        if router is None:
            raise AttributeError(
                f"{type(self.routing).__name__} routing has no VertexRouter "
                "(owner-routed services expose .routing.owner instead)"
            )
        return router

    @property
    def parallel_work(self) -> float:
        """DEPRECATED alias for :attr:`modeled_parallel_work` — the name
        hid that this is the Fig.-10 *work model*, not a measurement."""
        return self.modeled_parallel_work

    @parallel_work.setter
    def parallel_work(self, value: float) -> None:
        self.modeled_parallel_work = float(value)

    @property
    def total_work(self) -> float:
        """DEPRECATED alias for :attr:`modeled_total_work`."""
        return self.modeled_total_work

    @total_work.setter
    def total_work(self, value: float) -> None:
        self.modeled_total_work = float(value)

    def stats(self) -> ServiceStats:
        """Service-level aggregate: per-server counters (primaries and
        replicas, remote workers' included) merged into one, the
        service's lost-dispatch count in ``degraded``, the explicitly
        modeled work totals, and the measured per-round wall clock."""
        merged = ServiceStats()
        if self.dispatcher is not None:
            for d in self.dispatcher.server_stats().values():
                merged.merge(ServerStats(**d))
        for srv in self._all_servers:
            merged.merge(srv.stats)
        merged.degraded += self.degraded_dispatches
        merged.modeled_parallel_work = self.modeled_parallel_work
        merged.modeled_total_work = self.modeled_total_work
        merged.rounds = self.rounds
        merged.measured_round_seconds = self.measured_round_seconds
        return merged

    def server_health(self) -> dict[str, str]:
        """Health per replica site, e.g. ``{"server.0.0": "up",
        "server.0.1": "quarantined"}`` (circuit-breaker view).  With a
        remote dispatcher the workers' breakers answer, plus a
        ``worker.<p>`` process-liveness row per worker."""
        if self.dispatcher is not None:
            return self.dispatcher.health()
        return {srv.site: srv.health for srv in self._all_servers}

    def server_workloads(self) -> np.ndarray:
        """Modeled work per partition, summed over that partition's
        replicas (shape unchanged from the replica-free layout)."""
        if self.dispatcher is not None:
            return self.dispatcher.workloads()
        return np.array(
            [sum(s.stats.work_units for s in group) for group in self.groups]
        )

    def reset_stats(self) -> None:
        if self.dispatcher is not None:
            self.dispatcher.reset_stats()
        for s in self._all_servers:
            s.stats = ServerStats()
        self.degraded_dispatches = 0
        self.modeled_parallel_work = 0.0
        self.modeled_total_work = 0.0
        self.rounds = 0
        self.measured_round_seconds = 0.0

    def close(self, timeout: float = 2.0) -> None:
        """Shut down the remote worker pool, if any (in-process services
        have nothing to release)."""
        if self.dispatcher is not None:
            self.dispatcher.close(timeout=timeout)

    def __repr__(self) -> str:
        return (
            f"SamplingService(servers={len(self.servers)}, "
            f"routing={type(self.routing).__name__}, "
            f"inflight={len(self._inflight)})"
        )

    # -- scheduler -----------------------------------------------------
    def _rng(self, key: tuple, hop: int, *tail: int) -> np.random.Generator:
        return request_rng(self.seed, key, hop, *tail)

    def _cancel(self, state: _RequestState) -> None:
        with self._lock:
            if state.done:
                return
            state.done = True
            state.cancelled = True
            if state in self._inflight:
                self._inflight.remove(state)

    def _advance_round(self, deadline: float | None = None) -> None:
        """One scheduling round: every in-flight request advances one hop.

        ``deadline`` (absolute monotonic seconds) bounds the wait for the
        scheduler lock: past it the round is skipped and the caller's own
        deadline check fires.  Without it a blocking acquire could pin a
        short ``result(timeout=)`` behind a long round on another thread."""
        if deadline is None:
            acquired = self._lock.acquire()
        else:
            remaining = deadline - time.monotonic()
            acquired = self._lock.acquire(timeout=max(0.0, min(remaining, 0.05)))
        if not acquired:
            return
        try:
            active = list(self._inflight)
            if not active:
                return
            t0 = time.perf_counter()
            # remote mode: work is booked in the worker processes; the
            # snapshots riding on collected results give per-partition
            # (= per worker host) sums with no extra round-trip.  The
            # parallel-work MAX is then over hosts rather than over
            # individual replica servers — the right granularity, since a
            # partition's replicas share one host either way.
            if self.dispatcher is not None:
                w0 = self.dispatcher.snapshot_workloads()
            else:
                w0 = [srv.stats.work_units for srv in self._all_servers]
            # dispatch log keyed by the SERVING server (primary or a
            # failover replica), so coalescing rebates hit the stats that
            # were actually charged
            log: dict[int, tuple[SamplingServer, list]] = {}

            def on_dispatch(p, chunk, srv):
                log.setdefault(id(srv), (srv, []))[1].append(chunk)

            for st in active:
                self._execute_hop(st, on_dispatch)
            if self.coalesce:
                self._coalesce_credit(log)
            if self.dispatcher is not None:
                w1 = self.dispatcher.snapshot_workloads()
            else:
                w1 = [srv.stats.work_units for srv in self._all_servers]
            deltas = [b - a for a, b in zip(w0, w1)]
            self.modeled_parallel_work += max(deltas) if deltas else 0.0
            self.modeled_total_work += sum(deltas)
            self.rounds += 1
            self.measured_round_seconds += time.perf_counter() - t0
            self._inflight = [st for st in self._inflight if not st.done]
        finally:
            self._lock.release()

    def _dispatch_gather(self, p: int, ci: int, chunk: np.ndarray, key, hop, spec):
        """Fault-tolerant dispatch of one chunk to partition ``p``.

        Tries each non-quarantined replica in order, up to
        ``retry_policy.max_attempts`` times each.  Every attempt
        re-derives the dispatch RNG stream from ``(key, hop, p, ci)`` —
        independent of attempt number and of which replica answers — so
        a retry or a failover redraws the bit-identical sample: failover
        is invisible in the result stream by construction.  Returns
        ``(serving_server, raw_gather)`` or ``None`` when every replica
        is exhausted (a degraded, partial-fanout dispatch)."""
        policy = self.retry_policy
        fanout = spec.fanouts[hop]
        for r, srv in enumerate(self.groups[p]):
            if not srv.breaker.allow():
                continue
            for attempt in range(1, policy.max_attempts + 1):
                rng = self._rng(key, hop, p, ci, _GATHER_TAG)
                try:
                    res = _gather_once(
                        srv, chunk, fanout, spec.direction,
                        weighted=spec.weighted, replace=spec.replace, rng=rng,
                    )
                except InjectedFault:
                    srv.breaker.record_failure()
                    if attempt < policy.max_attempts and srv.breaker.state != "open":
                        srv.stats.retries += 1
                        policy.sleep(attempt)
                        continue
                    break  # replica exhausted or quarantined: fail over
                srv.breaker.record_success()
                if r > 0:
                    srv.stats.failovers += 1
                return srv, res
        self.degraded_dispatches += 1
        return None

    def _execute_hop(self, st: _RequestState, on_dispatch) -> None:
        spec = st.request.spec
        key = st.request.key
        hop = st.hop
        if self.dispatcher is not None:
            # remote path: submit every chunk to the worker pool before
            # collecting any answer (real processes overlap), collect in
            # submission order (merge order identical to in-process).
            # No on_dispatch: the workers charge their own stats, so the
            # coalescing rebate has nothing local to credit; lost counts
            # land on the service here — the worker deliberately does not
            # book them (that would double-count degraded in stats()).
            s, n, e, lost = execute_hop(
                self.servers,
                self.routing.route(st.frontier),
                spec.fanouts[hop],
                weighted=spec.weighted,
                replace=spec.replace,
                direction=spec.direction,
                merge=self.routing.merge,
                trim_rng=self._rng(key, hop, _TRIM_TAG),
                max_server_batch=self.max_server_batch,
                submit_dispatch=lambda p, ci, chunk: self.dispatcher.dispatch(
                    p, ci, chunk, key, hop, spec
                ),
                collect_dispatch=self.dispatcher.collect,
            )
            self.degraded_dispatches += lost
        else:
            s, n, e, lost = execute_hop(
                self.servers,
                self.routing.route(st.frontier),
                spec.fanouts[hop],
                weighted=spec.weighted,
                replace=spec.replace,
                direction=spec.direction,
                merge=self.routing.merge,
                trim_rng=self._rng(key, hop, _TRIM_TAG),
                rng_for=lambda p, ci: self._rng(key, hop, p, ci, _GATHER_TAG),
                max_server_batch=self.max_server_batch,
                on_dispatch=on_dispatch,
                dispatch=lambda p, ci, chunk: self._dispatch_gather(
                    p, ci, chunk, key, hop, spec
                ),
            )
        if lost:
            st.result.degraded = True
            st.result.lost_dispatches += lost
        st.result.hops.append(
            SampledHop(src=s, dst=n, eid=e if self.has_global_eids else None)
        )
        st.hop += 1
        st.frontier = np.unique(n)
        if st.hop >= len(spec.fanouts) or st.frontier.shape[0] == 0:
            st.done = True

    def _coalesce_credit(self, log: dict) -> None:
        """Rebate the duplicated dispatch overhead within one round.

        Draw work stays per-request (per-request RNG streams must actually
        run), but a seed dispatched to the same server by several in-flight
        requests is one service-level request: the per-seed handling
        overhead and the dispatch count are charged for the deduplicated
        batch only.  Results are untouched — coalescing on/off is
        bit-equivalent; only the workload model changes."""
        m = self.max_server_batch
        for srv, arrs in log.values():
            if len(arrs) <= 1:
                continue
            # only seeds the server actually hosts were charged
            present = [a[srv.part.global_to_local(a) >= 0] for a in arrs]
            charged = [a for a in present if a.shape[0]]
            if len(charged) <= 1:
                continue
            total = sum(a.shape[0] for a in charged)
            uniq = int(np.unique(np.concatenate(charged)).shape[0])
            dup = total - uniq
            srv.stats.seeds -= dup
            srv.stats.work_units -= dup
            fair = 1 if m <= 0 else -(-uniq // m)  # ceil
            srv.stats.requests -= len(charged) - min(len(charged), fair)


# ---------------------------------------------------------------------------
# Legacy blocking clients (thin wrappers over the shared hop executor)
# ---------------------------------------------------------------------------


class _BlockingClient:
    """Shared K-hop loop for the legacy blocking clients: route, execute the
    hop through the one shared executor, account one scheduling round per
    request-hop (no overlap — exactly the pre-service behavior)."""

    routing = None  # set by subclasses

    def _init_common(self, servers: list[SamplingServer], seed: int) -> None:
        self.servers = servers
        self.rng = np.random.default_rng(seed)
        self.has_global_eids = all(
            s.part.edge_global_id is not None for s in servers
        )
        # modeled wall-clock work: servers run in parallel, so a hop costs the
        # MAX of the per-server work deltas (the in-process simulation is
        # serial; benchmarks use this to report parallel-cluster latency)
        self.parallel_work = 0.0
        self.total_work = 0.0

    def sample_khop(
        self,
        seeds: np.ndarray,
        fanouts: list[int],
        weighted: bool = False,
        direction: str = DEFAULT_DIRECTION,
    ) -> SampledSubgraph:
        seeds = np.unique(np.asarray(seeds, dtype=np.int64))
        result = SampledSubgraph(seeds=seeds)
        frontier = seeds
        for f in fanouts:
            w0 = [srv.stats.work_units for srv in self.servers]
            s, n, e, _ = execute_hop(
                self.servers,
                self.routing.route(frontier),
                f,
                weighted=weighted,
                direction=direction,
                merge=self.routing.merge,
                trim_rng=self.rng,
            )
            deltas = [
                srv.stats.work_units - w for srv, w in zip(self.servers, w0)
            ]
            self.parallel_work += max(deltas) if deltas else 0.0
            self.total_work += sum(deltas)
            result.hops.append(
                SampledHop(src=s, dst=n, eid=e if self.has_global_eids else None)
            )
            frontier = np.unique(n)  # GetSeedsOfNextHop
            if frontier.shape[0] == 0:
                break
        return result

    def server_workloads(self) -> np.ndarray:
        return np.array([s.stats.work_units for s in self.servers])

    def reset_stats(self) -> None:
        for s in self.servers:
            s.stats = ServerStats()
        self.parallel_work = 0.0
        self.total_work = 0.0


class GatherApplyClient(_BlockingClient):
    """GLISP client: Gather from all hosting servers, Apply merge (Alg. 1)."""

    def __init__(
        self,
        servers: list[SamplingServer],
        router: VertexRouter,
        seed: int = 0,
    ):
        self._init_common(servers, seed)
        self.routing = GatherApplyRouting(router)
        self.router = router


class EdgeCutClient(_BlockingClient):
    """DistDGL-style baseline: one-hop request of v is answered ONLY by
    owner(v); the halo (replicated cut edges) makes it local.  Built over the
    same server implementation, but routing is by vertex owner, the local
    partition holds the vertex's FULL one-hop, and the sample is complete
    without a merge step (local_deg == global_deg on the owner)."""

    def __init__(
        self,
        servers: list[SamplingServer],
        vertex_owner: np.ndarray,
        seed: int = 0,
    ):
        self._init_common(servers, seed)
        self.routing = OwnerRouting(vertex_owner, len(servers))
        self.owner = vertex_owner
