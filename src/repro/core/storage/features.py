"""``FeatureSource`` — one feature-fetch surface for the training path.

``subgraph_to_batch`` / ``BatchPipeline`` historically indexed a raw
in-memory ``[N, F]`` ndarray.  A ``FeatureSource`` abstracts that gather so
the same pipeline can serve features out-of-core through a ``HybridCache``
(AGL/GiGL-style feature stores) with zero change to batch contents:

    src = ArrayFeatureSource(g.vertex_feats)              # in-memory
    src = StoreFeatureSource.from_array(feats, workdir)   # disk-backed

Both yield bit-identical batches — the cache only changes WHERE rows come
from, never their values (property-tested in tests/test_storage.py).
"""
from __future__ import annotations

import numpy as np

from repro.core.storage.hybrid import HybridCache, build_tiers
from repro.core.storage.store import DFSTier

__all__ = [
    "ArrayFeatureSource",
    "FeatureSource",
    "StoreFeatureSource",
    "as_feature_source",
]


class FeatureSource:
    """Protocol-ish base: ``gather(rows) -> [len(rows), dim]`` float32."""

    dim: int
    num_rows: int
    dtype = np.float32

    def gather(self, rows: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    @property
    def shape(self) -> tuple:
        """ndarray-compatible view so ``feats.shape[1]`` call sites work."""
        return (self.num_rows, self.dim)


class ArrayFeatureSource(FeatureSource):
    """Zero-copy wrapper over an in-memory feature matrix."""

    def __init__(self, feats: np.ndarray):
        self.feats = feats
        self.num_rows, self.dim = feats.shape
        self.dtype = feats.dtype

    def gather(self, rows: np.ndarray) -> np.ndarray:
        return self.feats[rows]

    def __repr__(self) -> str:
        return f"ArrayFeatureSource(shape={self.feats.shape})"


class StoreFeatureSource(FeatureSource):
    """Features served through a ``HybridCache`` over a chunked store —
    out-of-core training with the same tiered accounting as inference."""

    def __init__(self, cache: HybridCache):
        self.cache = cache
        self.num_rows = cache.store.num_rows
        self.dim = cache.store.dim
        self.dtype = cache.store.dtype

    @classmethod
    def from_array(
        cls,
        feats: np.ndarray,
        path: str,
        *,
        chunk_rows: int = 4096,
        tiers=("memory", "disk"),
        tier_capacities=(),
        policy="fifo",
        dynamic_frac: float = 0.10,
        compress: bool = False,
    ) -> "StoreFeatureSource":
        """Spill an in-memory matrix into a chunked store at ``path`` and
        wrap it in a fresh tier stack (the out-of-core migration helper).
        Disk tiers in the stack get a real spill directory under ``path``
        — without one an unbounded "disk" tier would keep every chunk it
        admits as a live ndarray, defeating the out-of-core point."""
        store = DFSTier(
            path,
            feats.shape[0],
            feats.shape[1],
            chunk_rows=chunk_rows,
            compress=compress,
            dtype=feats.dtype,
        )
        store.write_rows(np.arange(feats.shape[0], dtype=np.int64), feats)
        stack = build_tiers(
            tiers,
            chunk_rows,
            feats.shape[1],
            capacities=tier_capacities,
            dtype=feats.dtype,
            disk_path=path,
        )
        return cls(HybridCache(store, stack, policy=policy,
                               dynamic_frac=dynamic_frac))

    def gather(self, rows: np.ndarray) -> np.ndarray:
        return self.cache.read_rows(np.asarray(rows, dtype=np.int64))

    @property
    def stats(self):
        return self.cache.stats

    def __repr__(self) -> str:
        return f"StoreFeatureSource({self.cache!r})"


def as_feature_source(feats) -> FeatureSource:
    """ndarray -> ``ArrayFeatureSource``; a ``FeatureSource`` passes through."""
    if isinstance(feats, FeatureSource):
        return feats
    return ArrayFeatureSource(np.asarray(feats))
