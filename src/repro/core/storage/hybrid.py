"""``HybridCache`` — the tiered storage composition (paper §III-D).

One ordered tier stack (fast→slow, e.g. ``memory`` → ``disk``) over an
authoritative ``DFSTier``.  Reads walk the stack top-down; a hit at tier i
promotes the chunk into every faster tier (admission), evicting per each
tier's pluggable policy; a full miss is a demand DFS fetch, admitted at the
slowest cache tier and served from there — exactly the historic
``TwoLevelCache`` accounting when configured as ``memory + disk`` with the
``fifo`` policy:

    fill_chunks   = HybridStats.fill_chunks   (DFS fetches: fill + demand)
    static_reads  = slowest cache tier's hits (disk-served reads)
    dynamic_hits  = fastest memory tier's hits

The fill lifecycle is explicit: ``plan_fill(rows)`` computes which chunks a
slice will need (and the fill window that locality-aware eviction keys on)
without touching storage; ``fill(plan)`` executes it; ``evict()`` releases
cache residency.  The implicit ``fill_static`` of the old two-level cache is
a shim over this pair.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.faults import InjectedFault, RetryPolicy, as_injector
from repro.core.storage.policies import EvictionPolicy, resolve_policy
from repro.core.storage.store import ChunkReadError, DFSTier, IOCost, chunk_runs
from repro.core.storage.tiers import STORAGE_TIERS, StorageTier, TierStats

__all__ = ["FillPlan", "HybridCache", "HybridStats", "build_tiers"]


@dataclass
class FillPlan:
    """What one ``fill`` will do, computed without touching storage."""

    chunks: np.ndarray  # every chunk the slice will read
    fetch: np.ndarray  # the subset that must come from the DFS tier
    focus_lo: int  # fill window in chunk ids — the locality
    focus_hi: int  # policy's eviction distance reference
    reset: bool = True  # drop current residency before filling

    def modeled_ms(self, cost: IOCost) -> float:
        return self.fetch.shape[0] * cost.dfs_ms

    def __repr__(self) -> str:
        return (
            f"FillPlan(chunks={self.chunks.shape[0]}, "
            f"fetch={self.fetch.shape[0]}, "
            f"focus=[{self.focus_lo}, {self.focus_hi}], reset={self.reset})"
        )


@dataclass
class HybridStats:
    """Rollup over the stack: DFS fetches + per-tier hit accounting."""

    fill_chunks: int = 0  # chunks fetched from the authoritative tier
    demand_reads: int = 0  # the subset of fill_chunks served on-demand
    # (a full cache miss, not a planned fill); NOT counted as tier hits
    rows_served: int = 0
    store_retries: int = 0  # authoritative-store reads retried
    tiers: list = field(default_factory=list)  # TierStats refs, fast→slow

    # -- fault-tolerance rollups ---------------------------------------------
    @property
    def retries(self) -> int:
        """All retried chunk reads, cache tiers + authoritative store."""
        return sum(t.retries for t in self.tiers) + self.store_retries

    @property
    def failovers(self) -> int:
        """Chunks a cache tier failed to serve (fell through to a slower
        tier or the authoritative store)."""
        return sum(t.failovers for t in self.tiers)

    # -- legacy two-level views ---------------------------------------------
    @property
    def dynamic_hits(self) -> int:
        """Hits at the fastest tier when it is a memory tier (level 2)."""
        if self.tiers and self.tiers[0].kind == "memory":
            return self.tiers[0].hits
        return 0

    @property
    def static_reads(self) -> int:
        """Reads NOT served by a leading memory tier: hits at every tier
        below the fastest, plus the fastest tier's own hits when it is not
        memory (e.g. a disk-only stack), plus demand faults.  The historic
        counter also charged demand-faulted chunks to the static level
        after fetching them, so that view is preserved here — but
        ``demand_reads`` stays out of ``TierStats.hits``, which count only
        chunks found resident."""
        reads = sum(t.hits for t in self.tiers[1:]) + self.demand_reads
        if self.tiers and self.tiers[0].kind != "memory":
            reads += self.tiers[0].hits
        return reads

    @property
    def total_chunk_reads(self) -> int:
        return self.static_reads

    @property
    def dynamic_hit_ratio(self) -> float:
        tot = self.static_reads + self.dynamic_hits
        return self.dynamic_hits / tot if tot else 0.0

    # -- tiered views --------------------------------------------------------
    def hit_ratios(self) -> dict[str, float]:
        """Per-tier fraction of all chunk retrievals (incl. DFS fetches)."""
        total = sum(t.hits for t in self.tiers) + self.fill_chunks
        out = {
            f"{i}:{t.kind}": (t.hits / total if total else 0.0)
            for i, t in enumerate(self.tiers)
        }
        out["dfs"] = self.fill_chunks / total if total else 0.0
        return out

    def modeled_time_ms(self, cost: IOCost) -> float:
        ms = self.fill_chunks * cost.dfs_ms
        for t in self.tiers:
            ms += t.hits * cost.per_chunk_ms(t.kind)
        return ms

    def as_dict(self) -> dict:
        return {
            "fill_chunks": self.fill_chunks,
            "demand_reads": self.demand_reads,
            "rows_served": self.rows_served,
            "retries": self.retries,
            "failovers": self.failovers,
            "tiers": [
                {
                    "kind": t.kind,
                    "hits": t.hits,
                    "admits": t.admits,
                    "evictions": t.evictions,
                    "retries": t.retries,
                    "failovers": t.failovers,
                }
                for t in self.tiers
            ],
        }


def build_tiers(
    names,
    chunk_rows: int,
    dim: int,
    *,
    capacities=(),
    dtype=np.float32,
    disk_path: str | None = None,
    faults=None,
) -> list[StorageTier]:
    """Materialize a fast→slow cache tier stack from registry names.

    ``capacities`` aligns with ``names``; missing or ``0`` entries mean
    "auto" (memory: sized from ``dynamic_frac`` by the cache; disk:
    unbounded).  ``disk_path`` makes disk tiers actually spill to files.
    ``faults`` (a ``FaultPlan`` or shared ``FaultInjector``) arms the
    per-tier ``<kind>.read`` / ``<kind>.corrupt`` injection sites."""
    injector = as_injector(faults)
    tiers: list[StorageTier] = []
    for i, name in enumerate(names):
        cls = STORAGE_TIERS.get(name)
        cap = int(capacities[i]) if i < len(capacities) else 0
        kw = {"capacity": None if cap == 0 else cap, "dtype": dtype}
        if injector is not None:
            kw["faults"] = injector
        if getattr(cls, "kind", None) == "disk" and disk_path is not None:
            kw["path"] = f"{disk_path}/tier{i}"
        tiers.append(cls(chunk_rows, dim, **kw))
    return tiers


class HybridCache:
    """An ordered tier stack over an authoritative ``DFSTier``."""

    def __init__(
        self,
        store: DFSTier,
        tiers: list[StorageTier] | None = None,
        *,
        policy="fifo",
        dynamic_frac: float = 0.10,
        retry_policy: RetryPolicy | None = None,
    ):
        """``retry_policy`` bounds per-read attempts against each level;
        a chunk a cache tier cannot serve after retries is dropped from
        that tier and transparently falls through to the next slower
        level (ultimately the authoritative store), recorded in that
        tier's ``TierStats.failovers``."""
        if tiers is None:
            tiers = build_tiers(("memory", "disk"), store.chunk_rows, store.dim,
                                dtype=store.dtype)
        if not tiers:
            raise ValueError("HybridCache needs at least one cache tier")
        for t in tiers:
            if t.chunk_rows != store.chunk_rows or t.dim != store.dim:
                raise ValueError(
                    f"tier {t!r} geometry differs from the store "
                    f"(chunk_rows={store.chunk_rows}, dim={store.dim})"
                )
        self.store = store
        self.tiers = list(tiers)
        self.dynamic_frac = dynamic_frac
        # one fresh policy instance per tier — a policy instance passed in
        # is only a template (its type is instantiated per tier), because a
        # live instance shared across tiers or caches would desynchronize
        # its tracked set from the tier contents and corrupt eviction
        if isinstance(policy, EvictionPolicy):
            policy = type(policy)
        self.policies: list[EvictionPolicy] = [
            resolve_policy(policy) for _ in self.tiers
        ]
        self.retry_policy = retry_policy if retry_policy is not None else RetryPolicy()
        self.retry_policy.validate()
        self.stats = HybridStats(tiers=[t.stats for t in self.tiers])
        self._seen_chunks: set[int] = set()  # distinct chunks ever admitted

    # -- capacity ------------------------------------------------------------
    def _effective_capacity(self, i: int) -> int | None:
        """Tier i's chunk budget.  Explicit capacities win; an unset memory
        tier is auto-sized as ``dynamic_frac`` of the tier below it (the
        fill set after a fill) and GROWS as chunks are admitted in
        fill-free use — the historic zero-capacity bug is gone."""
        t = self.tiers[i]
        if t.capacity is not None:
            return t.capacity
        if t.kind != "memory":
            return None  # disk-like tiers default to unbounded
        base = (
            len(self.tiers[i + 1])
            if i + 1 < len(self.tiers)
            else len(self._seen_chunks)
        )
        return max(1, int(self.dynamic_frac * base))

    # -- fill lifecycle ------------------------------------------------------
    def plan_fill(
        self,
        rows_needed: np.ndarray,
        *,
        focus_rows: np.ndarray | None = None,
        reset: bool = True,
    ) -> FillPlan:
        """Plan the static fill for one slice: every chunk holding a needed
        row, the subset that must be DFS-fetched, and the locality focus
        window (from ``focus_rows`` — e.g. the partition's own vertices —
        or the full fill range)."""
        rows = np.asarray(rows_needed, np.int64)
        chunks = np.unique(rows // self.store.chunk_rows)
        if reset or chunks.shape[0] == 0:
            fetch = chunks
        else:
            resident = np.zeros(chunks.shape[0], dtype=bool)
            for t in self.tiers:
                resident |= t.contains(chunks)
            fetch = chunks[~resident]
        if focus_rows is not None and np.asarray(focus_rows).shape[0]:
            fc = np.asarray(focus_rows, np.int64) // self.store.chunk_rows
            lo, hi = int(fc.min()), int(fc.max())
        elif chunks.shape[0]:
            lo, hi = int(chunks[0]), int(chunks[-1])
        else:
            lo = hi = 0
        return FillPlan(chunks=chunks, fetch=fetch, focus_lo=lo,
                        focus_hi=hi, reset=reset)

    def fill(self, plan: FillPlan) -> None:
        """Execute a fill: fetch ``plan.fetch`` from DFS into the slowest
        cache tier and point every policy's focus at the fill window.  The
        faster tiers start cold (the historic level-2 semantics)."""
        if plan.reset:
            self.evict()
        for pol in self.policies:
            pol.set_focus(plan.focus_lo, plan.focus_hi)
        base = len(self.tiers) - 1
        for c in plan.fetch:
            block = self._store_read(int(c))
            self.stats.fill_chunks += 1
            self._admit(base, int(c), block)

    def fill_for(self, rows_needed: np.ndarray, **kw) -> FillPlan:
        """Convenience: ``plan_fill`` + ``fill`` in one call."""
        plan = self.plan_fill(rows_needed, **kw)
        self.fill(plan)
        return plan

    def evict(self, chunks: np.ndarray | None = None) -> int:
        """Drop chunks (default: everything) from every cache tier.  The
        authoritative store is untouched; returns chunks released."""
        dropped = 0
        for t, pol in zip(self.tiers, self.policies):
            ids = t.chunk_ids() if chunks is None else [
                int(c) for c in np.asarray(chunks, np.int64) if int(c) in t
            ]
            for c in ids:
                t.delete_chunk(c)
                pol.forget(c)
                dropped += 1
        return dropped

    # -- chunk movement ------------------------------------------------------
    def _admit(self, i: int, c: int, block: np.ndarray) -> None:
        t, pol = self.tiers[i], self.policies[i]
        t.write_chunk(c, block)
        t.stats.admits += 1
        pol.on_admit(c)
        self._seen_chunks.add(c)
        cap = self._effective_capacity(i)
        if cap is not None:
            while len(t) > cap:
                v = pol.victim()
                pol.forget(v)
                t.delete_chunk(v)
                t.stats.evictions += 1

    def _tier_read(self, i: int, c: int) -> np.ndarray | None:
        """Read chunk ``c`` from tier ``i`` with bounded retries; ``None``
        when the tier cannot serve it (transient errors exhausted the
        retry budget, or the stored payload is corrupt/truncated)."""
        t = self.tiers[i]
        policy = self.retry_policy
        for attempt in range(1, policy.max_attempts + 1):
            try:
                return t.read_chunk(c)
            except (InjectedFault, ChunkReadError, OSError):
                if attempt < policy.max_attempts:
                    t.stats.retries += 1
                    policy.sleep(attempt)
        return None

    def _store_read(self, c: int) -> np.ndarray:
        """Authoritative-store read with bounded retries.  There is no
        slower level to fall through to: exhausting the budget propagates
        the store's descriptive error."""
        policy = self.retry_policy
        for attempt in range(1, policy.max_attempts):
            try:
                return self.store.read_chunk(c)
            except (InjectedFault, ChunkReadError, OSError):
                self.stats.store_retries += 1
                policy.sleep(attempt)
        return self.store.read_chunk(c)

    def _get_chunk(self, c: int) -> np.ndarray:
        for i, t in enumerate(self.tiers):
            if c not in t:
                continue
            block = self._tier_read(i, c)
            if block is None:
                # the tier cannot serve this chunk: drop the bad copy and
                # fall through to the next slower level — the read still
                # succeeds, it just costs a slower fetch
                t.delete_chunk(c)
                self.policies[i].forget(c)
                t.stats.failovers += 1
                continue
            t.stats.hits += 1
            self.policies[i].on_access(c)
            for j in range(i - 1, -1, -1):  # promote into faster tiers
                self._admit(j, c, block)
            return block
        # full miss: demand DFS fetch, admitted at the slowest cache tier
        # (the historic fill-free fallback, capacity included); counted as
        # demand_reads, never as a tier hit — the chunk wasn't resident
        block = self._store_read(c)
        self.stats.fill_chunks += 1
        self.stats.demand_reads += 1
        base = len(self.tiers) - 1
        self._admit(base, c, block)
        for j in range(base - 1, -1, -1):
            self._admit(j, c, block)
        return block

    # -- row interface -------------------------------------------------------
    def read_rows(self, rows: np.ndarray) -> np.ndarray:
        """Gather rows through the stack, grouped by chunk via one argsort;
        one ``_get_chunk`` per distinct chunk, so accounting is identical
        to a scalar read loop."""
        rows = np.asarray(rows, dtype=np.int64)
        out = np.empty((rows.shape[0], self.store.dim), dtype=self.store.dtype)
        for c, pos, crows in chunk_runs(rows, self.store.chunk_rows):
            block = self._get_chunk(c)
            out[pos] = block[crows - c * self.store.chunk_rows]
        self.stats.rows_served += rows.shape[0]
        return out

    def write_rows(self, rows: np.ndarray, values: np.ndarray) -> None:
        """Write-through: rows go to the authoritative store; stale cached
        copies of the touched chunks are released."""
        rows = np.asarray(rows, dtype=np.int64)
        self.store.write_rows(rows, values)
        self.evict(np.unique(rows // self.store.chunk_rows))

    def contains(self, rows: np.ndarray) -> np.ndarray:
        """Per-row cache residency (any tier, authoritative excluded)."""
        rows = np.asarray(rows, dtype=np.int64)
        out = np.zeros(rows.shape[0], dtype=bool)
        for c, pos, _ in chunk_runs(rows, self.store.chunk_rows):
            if any(c in t for t in self.tiers):
                out[pos] = True
        return out

    def __repr__(self) -> str:
        stack = " -> ".join(t.kind for t in self.tiers)
        return (
            f"HybridCache([{stack}] over {type(self.store).__name__}, "
            f"policy={self.policies[0].name})"
        )
