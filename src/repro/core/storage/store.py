"""The authoritative chunked store — the Zarr-on-DFS stand-in (paper §III-D).

The full embedding/feature matrix of one GNN layer is chunked into fixed-row
files (paper: chunk 32768 rows, Blosclz-compressed, on HDFS).  Here chunks
are .npy files (optionally zlib-compressed .npz) in a local directory, with
explicit read counters and an I/O *cost model* so benchmarks can report
modeled DFS/disk/memory retrieval times without a real HDFS cluster:

    IOCost.dfs_ms    per-chunk read from the remote store (paper: HDFS)
    IOCost.disk_ms   per-chunk read from the worker-local disk tier
    IOCost.mem_ms    per-chunk hit in the in-memory tier

``DFSTier`` is the bottom (authoritative) tier of a ``HybridCache`` stack —
it is never evicted from and always ``contains`` every chunk.  The historic
name ``ChunkedEmbeddingStore`` survives as a deprecation shim in
``repro.core.inference.store``.
"""
from __future__ import annotations

import os
import zlib
from dataclasses import dataclass, field

import numpy as np

from repro.utils import ceil_div

try:  # xxhash is faster when available; the container may not ship it
    import xxhash  # type: ignore[import-not-found]
except ModuleNotFoundError:  # pragma: no cover - depends on environment
    xxhash = None

__all__ = [
    "ChunkCorruptionError",
    "ChunkReadError",
    "DFSTier",
    "IOCost",
    "StoreStats",
    "block_checksum",
    "chunk_runs",
]


class ChunkReadError(IOError):
    """A chunk could not be read: file missing, truncated, or unparseable.

    Always names the chunk id and file path so a failed tier read is
    actionable from the message alone."""


class ChunkCorruptionError(ChunkReadError):
    """A chunk was read but failed checksum verification."""


def block_checksum(block: np.ndarray) -> int:
    """Content checksum of one chunk block (xxhash64 when available,
    else crc32).  Computed over the raw bytes of the C-contiguous array,
    so any bit flip in the stored payload is detected."""
    data = np.ascontiguousarray(block)
    if xxhash is not None:
        return xxhash.xxh64(data.tobytes()).intdigest()
    return zlib.crc32(data.tobytes())


def _corrupt_block(block: np.ndarray) -> np.ndarray:
    """Bit-flipped copy of a block — the injected-corruption payload.
    The shape/dtype are preserved so only checksum verification (not an
    earlier shape check) can catch it, which is the property under test."""
    bad = np.array(block, copy=True)
    flat = bad.view(np.uint8).reshape(-1)
    if flat.shape[0]:
        flat[0] ^= 0xFF
    return bad


def chunk_runs(rows: np.ndarray, chunk_rows: int, *, assume_sorted: bool = False):
    """Group row ids by chunk with one argsort (no O(rows) boolean mask per
    chunk).  Yields ``(chunk_id, positions, chunk_rows_sorted)`` per distinct
    chunk, where ``positions`` indexes the original ``rows`` array and
    ``chunk_rows_sorted`` are the corresponding row ids in stable order
    (ascending when the input is sorted).

    ``assume_sorted=True`` skips the argsort entirely for callers that hand
    in already-ascending rows (positions become contiguous ranges) — the
    write path's pre-sort no longer pays for a second, redundant sort."""
    chunk_ids = rows // chunk_rows
    if assume_sorted:
        uniq, run_starts = np.unique(chunk_ids, return_index=True)
        run_ends = np.append(run_starts[1:], chunk_ids.shape[0])
        for c, a, b in zip(uniq, run_starts, run_ends):
            yield int(c), np.arange(a, b, dtype=np.int64), rows[a:b]
        return
    order = np.argsort(chunk_ids, kind="stable")
    sorted_rows = rows[order]
    sorted_chunks = chunk_ids[order]
    uniq, run_starts = np.unique(sorted_chunks, return_index=True)
    run_ends = np.append(run_starts[1:], sorted_chunks.shape[0])
    for c, a, b in zip(uniq, run_starts, run_ends):
        yield int(c), order[a:b], sorted_rows[a:b]


@dataclass
class IOCost:
    # Defaults modeled on the paper's setting: HDFS round-trip ≫ local SSD ≫
    # memory.  Only *ratios* matter for speedup numbers.
    dfs_ms: float = 20.0
    disk_ms: float = 2.0
    mem_ms: float = 0.05
    # custom STORAGE_TIERS kinds price here (kind -> per-chunk ms); a kind
    # in neither map falls back to disk_ms so a registered extension tier
    # never crashes the stats rollup
    extra_ms: dict = field(default_factory=dict)

    def per_chunk_ms(self, tier_kind: str) -> float:
        """Modeled per-chunk retrieval time for one tier kind."""
        builtin = {
            "memory": self.mem_ms,
            "disk": self.disk_ms,
            "dfs": self.dfs_ms,
        }
        if tier_kind in builtin:
            return builtin[tier_kind]
        return float(self.extra_ms.get(tier_kind, self.disk_ms))


@dataclass
class StoreStats:
    chunk_writes: int = 0
    chunk_reads: int = 0  # reads that actually hit this store
    rows_read: int = 0


class DFSTier:
    """One [N, D] matrix as fixed-size row chunks — the authoritative tier.

    Rows are indexed by the *reordered* consecutive local id (paper §III-D:
    the reorder algorithm assigns the IDs; chunk = id // chunk_rows)."""

    kind = "dfs"

    def __init__(
        self,
        path: str,
        num_rows: int,
        dim: int,
        chunk_rows: int = 32768,
        compress: bool = False,
        dtype=np.float32,
        *,
        faults=None,
    ):
        """``faults`` is an optional ``FaultInjector``; reads then fire the
        ``dfs.read`` site (transient read error) and the ``dfs.corrupt``
        site (bit-flipped payload, caught by checksum verification)."""
        self.path = path
        self.num_rows = num_rows
        self.dim = dim
        self.chunk_rows = chunk_rows
        self.compress = compress
        self.dtype = dtype
        self.num_chunks = ceil_div(num_rows, chunk_rows)
        self.stats = StoreStats()
        self.faults = faults
        # checksum per chunk, recorded at write and verified at read —
        # in-memory because this process is the only writer (the DFS
        # stand-in); a real deployment would persist them beside the chunk
        self._sums: dict[int, int] = {}
        os.makedirs(path, exist_ok=True)

    # -- chunk addressing ----------------------------------------------------
    def chunk_of(self, rows: np.ndarray) -> np.ndarray:
        return np.asarray(rows) // self.chunk_rows

    def _chunk_file(self, c: int) -> str:
        return os.path.join(
            self.path, f"chunk_{c:06d}.{'npz' if self.compress else 'npy'}"
        )

    def contains(self, chunks: np.ndarray) -> np.ndarray:
        """Authoritative: every valid chunk id is present by definition."""
        chunks = np.asarray(chunks, dtype=np.int64)
        return (chunks >= 0) & (chunks < self.num_chunks)

    # -- IO -------------------------------------------------------------------
    def write_rows(self, rows: np.ndarray, values: np.ndarray) -> None:
        """Write rows (values[i] -> row rows[i]); one argsort groups by chunk
        AND pre-sorts within each chunk (``chunk_runs`` gets the
        ``assume_sorted`` hint, so nothing is sorted twice).  A write that
        covers every row of a chunk skips the read-modify-write and stores
        the values slice directly (workers write disjoint row ranges)."""
        rows = np.asarray(rows, dtype=np.int64)
        values = np.asarray(values)
        order = np.argsort(rows, kind="stable")
        rows, values = rows[order], values[order]
        for c, pos, crows in chunk_runs(rows, self.chunk_rows, assume_sorted=True):
            base = c * self.chunk_rows
            nrows = min(self.chunk_rows, self.num_rows - base)
            off = crows - base
            if off.shape[0] == nrows and np.array_equal(
                off, np.arange(nrows, dtype=np.int64)
            ):
                block = np.ascontiguousarray(values[pos], dtype=self.dtype)
            else:
                block = self._read_chunk_raw(c, allow_missing=True)
                block[off] = values[pos]
            self._write_chunk_raw(c, block)

    def write_chunk(self, c: int, block: np.ndarray) -> None:
        self._write_chunk_raw(c, np.ascontiguousarray(block, dtype=self.dtype))

    def _write_chunk_raw(self, c: int, block: np.ndarray) -> None:
        """Atomic chunk write: tmp in the same directory + fsync +
        ``os.replace``, so a crash mid-write leaves either the old chunk
        or the new one, never a truncated file; the tmp is removed on
        failure so partial writes leave no debris."""
        fn = self._chunk_file(c)
        tmp = fn + ".tmp"
        try:
            with open(tmp, "wb") as fh:
                if self.compress:
                    np.savez_compressed(fh, block=block)
                else:
                    np.save(fh, block)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, fn)
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        self._sums[c] = block_checksum(block)
        self.stats.chunk_writes += 1

    def _read_chunk_raw(self, c: int, allow_missing: bool = False) -> np.ndarray:
        fn = self._chunk_file(c)
        nrows = min(self.chunk_rows, self.num_rows - c * self.chunk_rows)
        if not os.path.exists(fn):
            if allow_missing:
                return np.zeros((nrows, self.dim), dtype=self.dtype)
            raise ChunkReadError(
                f"chunk {c} of {type(self).__name__} missing: no file at {fn}"
            )
        try:
            if self.compress:
                with np.load(fn) as z:
                    return z["block"]
            return np.load(fn)
        except (ValueError, EOFError, KeyError, OSError) as exc:
            raise ChunkReadError(
                f"chunk {c} of {type(self).__name__} unreadable "
                f"(truncated or corrupt file): {fn}: {exc}"
            ) from exc

    def _verify(self, c: int, block: np.ndarray) -> None:
        want = self._sums.get(c)
        if want is not None and block_checksum(block) != want:
            raise ChunkCorruptionError(
                f"chunk {c} of {type(self).__name__} failed checksum "
                f"verification: {self._chunk_file(c)}"
            )

    def read_chunk(self, c: int) -> np.ndarray:
        """Counted read — a 'remote DFS fetch' in the cost model."""
        if self.faults is not None:
            self.faults.fire("dfs.read")
        block = self._read_chunk_raw(c)
        if self.faults is not None and self.faults.should_fail("dfs.corrupt"):
            block = _corrupt_block(block)
        self._verify(c, block)
        self.stats.chunk_reads += 1
        self.stats.rows_read += block.shape[0]
        return block

    def read_rows(self, rows: np.ndarray) -> np.ndarray:
        """Uncached row gather (the Fig.-14a baseline: read straight from
        HDFS, one chunk fetch per distinct chunk touched); grouped by chunk
        via one argsort instead of a boolean mask scan per chunk."""
        rows = np.asarray(rows, dtype=np.int64)
        out = np.empty((rows.shape[0], self.dim), dtype=self.dtype)
        for c, pos, crows in chunk_runs(rows, self.chunk_rows):
            block = self.read_chunk(c)
            out[pos] = block[crows - c * self.chunk_rows]
        return out

    # historic spelling kept for the Fig.-14a baseline call sites
    read_rows_direct = read_rows
