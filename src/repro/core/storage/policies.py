"""Admission/eviction policies for ``HybridCache`` tiers (paper §III-D).

A policy tracks the chunks resident in ONE bounded tier and picks eviction
victims; the cache calls ``on_admit``/``on_access``/``forget`` as chunks
move.  Policies are pluggable through the ``CACHE_POLICIES`` registry (the
name ``GLISPConfig.cache_policy`` resolves):

    fifo       evict the oldest-admitted chunk (the paper's default)
    lru        evict the least-recently-used chunk
    locality   evict the chunk farthest (in reorder-chunk distance) from the
               active partition's fill window — after the PDS reorder a
               partition occupies a contiguous chunk interval, so distance
               to that interval predicts reuse: local chunks are re-read
               throughout the slice, far chunks are one-shot boundary
               neighbors.  Ties fall back to FIFO age.

``HybridCache.plan_fill`` sets the focus interval on every policy that
accepts one (``set_focus``), so the locality policy needs no extra wiring
at call sites.
"""
from __future__ import annotations

from collections import OrderedDict

from repro.utils import Registry

__all__ = [
    "CACHE_POLICIES",
    "EvictionPolicy",
    "FifoPolicy",
    "LruPolicy",
    "LocalityPolicy",
    "resolve_policy",
]


CACHE_POLICIES: Registry = Registry("cache policy")


class EvictionPolicy:
    """Base: insertion-ordered chunk tracking (FIFO semantics)."""

    name = "base"

    def __init__(self):
        self._order: OrderedDict[int, None] = OrderedDict()

    def on_admit(self, c: int) -> None:
        self._order[c] = None

    def on_access(self, c: int) -> None:  # FIFO: age is admission order
        pass

    def forget(self, c: int) -> None:
        self._order.pop(c, None)

    def victim(self) -> int:
        """The chunk to evict next (must be tracked); FIFO head by default."""
        return next(iter(self._order))

    def set_focus(self, lo: int, hi: int) -> None:
        """Hint: the active fill window [lo, hi] in chunk ids (no-op for
        access-order policies)."""

    def clear(self) -> None:
        self._order.clear()

    def __len__(self) -> int:
        return len(self._order)

    def __repr__(self) -> str:
        return f"{type(self).__name__}(tracked={len(self._order)})"


@CACHE_POLICIES.register("fifo")
class FifoPolicy(EvictionPolicy):
    name = "fifo"


@CACHE_POLICIES.register("lru")
class LruPolicy(EvictionPolicy):
    name = "lru"

    def on_access(self, c: int) -> None:
        if c in self._order:
            self._order.move_to_end(c)


@CACHE_POLICIES.register("locality")
class LocalityPolicy(EvictionPolicy):
    """Locality-aware eviction: farthest-from-the-fill-window-first.

    The PDS reorder lays each partition's vertices (hubs first) into a
    contiguous run of chunk ids, so the fill window ``[lo, hi]`` of the
    active partition is exactly the high-reuse region; chunks pulled in for
    boundary neighbors sit far outside it and are rarely touched twice.
    Eviction therefore scores every tracked chunk by its distance to the
    window and drops the farthest (FIFO age breaks ties), keeping the local
    working set hot where FIFO/LRU would cycle it out."""

    name = "locality"

    def __init__(self):
        super().__init__()
        self._lo = 0
        self._hi = 0

    def set_focus(self, lo: int, hi: int) -> None:
        self._lo, self._hi = int(lo), int(hi)

    def _distance(self, c: int) -> int:
        if c < self._lo:
            return self._lo - c
        if c > self._hi:
            return c - self._hi
        return 0

    def victim(self) -> int:
        # max distance wins; insertion (FIFO) order breaks ties, which the
        # OrderedDict iteration order provides for free
        return max(self._order, key=self._distance)


def resolve_policy(policy) -> EvictionPolicy:
    """One fresh policy instance from a name, class, instance, or the legacy
    ``CachePolicy`` str-enum (its members are plain strings underneath)."""
    if isinstance(policy, EvictionPolicy):
        return policy
    if isinstance(policy, type) and issubclass(policy, EvictionPolicy):
        return policy()
    if isinstance(policy, str):  # includes CachePolicy str-enum members
        return CACHE_POLICIES.get(policy)()
    raise TypeError(f"cannot resolve cache policy from {policy!r}")
