"""repro.core.storage — the tiered storage subsystem (paper §III-D).

One pluggable ``HybridCache`` API for layer embeddings (inference) and
input features (training):

    DFSTier          authoritative chunked store (the Zarr-on-DFS stand-in)
    MemoryTier/DiskTier  bounded cache tiers above it (STORAGE_TIERS)
    CACHE_POLICIES   fifo | lru | locality eviction policies
    HybridCache      the ordered tier stack with plan_fill()/evict()
    FeatureSource    the training-side feature-fetch surface

The historic ``ChunkedEmbeddingStore`` / ``TwoLevelCache`` names remain as
deprecation shims in ``repro.core.inference`` over this package.
"""
from repro.core.storage.store import (
    ChunkCorruptionError,
    ChunkReadError,
    DFSTier,
    IOCost,
    StoreStats,
    block_checksum,
    chunk_runs,
)
from repro.core.storage.tiers import (
    STORAGE_TIERS,
    DiskTier,
    MemoryTier,
    StorageTier,
    TierStats,
)
from repro.core.storage.policies import (
    CACHE_POLICIES,
    EvictionPolicy,
    FifoPolicy,
    LocalityPolicy,
    LruPolicy,
    resolve_policy,
)
from repro.core.storage.hybrid import FillPlan, HybridCache, HybridStats, build_tiers
from repro.core.storage.features import (
    ArrayFeatureSource,
    FeatureSource,
    StoreFeatureSource,
    as_feature_source,
)

__all__ = [
    "ArrayFeatureSource",
    "CACHE_POLICIES",
    "ChunkCorruptionError",
    "ChunkReadError",
    "DFSTier",
    "DiskTier",
    "EvictionPolicy",
    "FeatureSource",
    "FifoPolicy",
    "FillPlan",
    "HybridCache",
    "HybridStats",
    "IOCost",
    "LocalityPolicy",
    "LruPolicy",
    "MemoryTier",
    "STORAGE_TIERS",
    "StorageTier",
    "StoreFeatureSource",
    "StoreStats",
    "TierStats",
    "as_feature_source",
    "block_checksum",
    "build_tiers",
    "chunk_runs",
    "resolve_policy",
]
