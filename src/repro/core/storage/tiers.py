"""Cache tiers above the authoritative store (paper §III-D hierarchy).

A ``StorageTier`` is chunk-granular bounded storage: the ``HybridCache``
stacks tiers fast→slow (e.g. ``memory`` → ``disk``) over a ``DFSTier`` and
moves whole chunks between them.  Row-level access (``read_rows`` /
``write_rows`` / ``contains``) is batched through the shared ``chunk_runs``
argsort path, so a tier never scans per-row.

``MemoryTier``   chunk blocks held as live ndarrays (the dynamic cache).
``DiskTier``     the worker-local static cache.  By default blocks stay in
                 RAM but are *accounted* at disk cost (the historic
                 ``TwoLevelCache`` static level, and what the engine uses);
                 give it a ``path`` to actually spill chunks to .npy files
                 for out-of-core operation.

New tier kinds register in ``STORAGE_TIERS`` and become available to
``GLISPConfig.storage_tiers`` by name.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.storage.store import (
    ChunkReadError,
    _corrupt_block,
    block_checksum,
    chunk_runs,
)
from repro.utils import Registry

__all__ = [
    "STORAGE_TIERS",
    "DiskTier",
    "MemoryTier",
    "StorageTier",
    "TierStats",
]


@dataclass
class TierStats:
    """Per-tier accounting rolled up by ``HybridCache.stats``."""

    kind: str = ""
    hits: int = 0  # chunk reads served by this tier
    admits: int = 0  # chunks written into this tier
    evictions: int = 0  # chunks dropped to stay within capacity
    retries: int = 0  # chunk reads that succeeded only after retry
    failovers: int = 0  # chunks this tier failed to serve (fell through
    # to a slower tier / the authoritative store)


@runtime_checkable
class StorageTier(Protocol):
    """Chunk-granular bounded storage; one level of a ``HybridCache``.

    ``capacity`` is in chunks; ``None`` means unbounded.  Row-level calls
    are batched by chunk via ``chunk_runs`` — implementations must never
    loop per row."""

    kind: str
    chunk_rows: int
    dim: int
    capacity: int | None
    stats: TierStats

    def read_chunk(self, c: int) -> np.ndarray: ...

    def write_chunk(self, c: int, block: np.ndarray) -> None: ...

    def delete_chunk(self, c: int) -> None: ...

    def contains(self, chunks: np.ndarray) -> np.ndarray: ...

    def read_rows(self, rows: np.ndarray) -> np.ndarray: ...

    def write_rows(self, rows: np.ndarray, values: np.ndarray) -> None: ...

    def chunk_ids(self) -> list[int]: ...

    def __len__(self) -> int: ...

    def __contains__(self, c: int) -> bool: ...


class _ChunkTierBase:
    """Shared row-level plumbing: chunk addressing + batched gathers."""

    kind = "base"

    def __init__(
        self,
        chunk_rows: int,
        dim: int,
        *,
        capacity: int | None = None,
        dtype=np.float32,
        faults=None,
    ):
        self.chunk_rows = chunk_rows
        self.dim = dim
        self.capacity = capacity
        self.dtype = dtype
        self.stats = TierStats(kind=self.kind)
        # optional FaultInjector: reads fire "<kind>.read" (transient
        # error) and "<kind>.corrupt" (bit-flipped payload) sites
        self.faults = faults

    def _fire_read(self) -> None:
        if self.faults is not None:
            self.faults.fire(f"{self.kind}.read")

    def _maybe_corrupt(self, block: np.ndarray) -> np.ndarray:
        if self.faults is not None and self.faults.should_fail(
            f"{self.kind}.corrupt"
        ):
            return _corrupt_block(block)
        return block

    # chunk-level interface subclasses fill in -----------------------------
    def read_chunk(self, c: int) -> np.ndarray:
        raise NotImplementedError

    def write_chunk(self, c: int, block: np.ndarray) -> None:
        raise NotImplementedError

    def delete_chunk(self, c: int) -> None:
        raise NotImplementedError

    def chunk_ids(self) -> list[int]:
        raise NotImplementedError

    def __len__(self) -> int:
        return len(self.chunk_ids())

    def __contains__(self, c: int) -> bool:
        return bool(self.contains(np.asarray([c]))[0])

    # batched row-level interface ------------------------------------------
    def contains(self, chunks: np.ndarray) -> np.ndarray:
        held = set(self.chunk_ids())
        chunks = np.asarray(chunks, dtype=np.int64)
        return np.fromiter(
            (int(c) in held for c in chunks), dtype=bool, count=chunks.shape[0]
        )

    def read_rows(self, rows: np.ndarray) -> np.ndarray:
        """Gather rows held by this tier (caller guarantees residency),
        grouped by chunk via one argsort."""
        rows = np.asarray(rows, dtype=np.int64)
        out = np.empty((rows.shape[0], self.dim), dtype=self.dtype)
        for c, pos, crows in chunk_runs(rows, self.chunk_rows):
            out[pos] = self.read_chunk(c)[crows - c * self.chunk_rows]
        return out

    def write_rows(self, rows: np.ndarray, values: np.ndarray) -> None:
        """Scatter rows into resident chunks (read-modify-write per chunk)."""
        rows = np.asarray(rows, dtype=np.int64)
        values = np.asarray(values)
        for c, pos, crows in chunk_runs(rows, self.chunk_rows):
            block = self.read_chunk(c)
            block[crows - c * self.chunk_rows] = values[pos]
            self.write_chunk(c, block)

    def clear(self) -> None:
        for c in list(self.chunk_ids()):
            self.delete_chunk(c)

    def __repr__(self) -> str:
        cap = "inf" if self.capacity is None else self.capacity
        return f"{type(self).__name__}(chunks={len(self)}, capacity={cap})"


STORAGE_TIERS: Registry = Registry("storage tier")


@STORAGE_TIERS.register("memory")
class MemoryTier(_ChunkTierBase):
    """Chunk blocks as live ndarrays — the dynamic in-memory cache level."""

    kind = "memory"

    def __init__(self, chunk_rows: int, dim: int, **kw):
        super().__init__(chunk_rows, dim, **kw)
        self._blocks: dict[int, np.ndarray] = {}

    def read_chunk(self, c: int) -> np.ndarray:
        self._fire_read()
        return self._blocks[c]

    def write_chunk(self, c: int, block: np.ndarray) -> None:
        self._blocks[c] = block

    def delete_chunk(self, c: int) -> None:
        self._blocks.pop(c, None)

    def chunk_ids(self) -> list[int]:
        return list(self._blocks)

    def __len__(self) -> int:
        return len(self._blocks)

    def __contains__(self, c: int) -> bool:
        return c in self._blocks


@STORAGE_TIERS.register("disk")
class DiskTier(_ChunkTierBase):
    """The worker-local static cache level.

    With ``path=None`` (default) blocks live in RAM but are charged at
    ``IOCost.disk_ms`` — the historic ``TwoLevelCache`` static dict, which
    models a local SSD without paying real file I/O in tests.  With a
    ``path`` every chunk is spilled to ``<path>/tier_<c>.npy`` and reads
    load from disk, for genuinely out-of-core feature/embedding serving."""

    kind = "disk"

    def __init__(self, chunk_rows: int, dim: int, *, path: str | None = None, **kw):
        super().__init__(chunk_rows, dim, **kw)
        self.path = path
        self._blocks: dict[int, np.ndarray] = {}  # path=None backing
        self._held: set[int] = set()  # path!=None backing
        # checksums guard the real-file backing only: RAM-backed blocks
        # are shared by reference across tiers (and legitimately mutated
        # through write_rows), so hashing them would false-positive
        self._sums: dict[int, int] = {}
        if path is not None:
            os.makedirs(path, exist_ok=True)

    def _chunk_file(self, c: int) -> str:
        return os.path.join(self.path, f"tier_{c:06d}.npy")

    def read_chunk(self, c: int) -> np.ndarray:
        self._fire_read()
        if self.path is None:
            return self._blocks[c]
        fn = self._chunk_file(c)
        if not os.path.exists(fn):
            raise ChunkReadError(
                f"chunk {c} of DiskTier missing: no file at {fn}"
            )
        try:
            block = np.load(fn)
        except (ValueError, EOFError, OSError) as exc:
            raise ChunkReadError(
                f"chunk {c} of DiskTier unreadable "
                f"(truncated or corrupt file): {fn}: {exc}"
            ) from exc
        block = self._maybe_corrupt(block)
        want = self._sums.get(c)
        if want is not None and block_checksum(block) != want:
            raise ChunkReadError(
                f"chunk {c} of DiskTier failed checksum verification: {fn}"
            )
        return block

    def write_chunk(self, c: int, block: np.ndarray) -> None:
        if self.path is None:
            self._blocks[c] = block
            return
        # tmp + replace: a failed write never leaves a partial .npy behind
        # (and never clobbers a previously good chunk file)
        fn = self._chunk_file(c)
        tmp = fn + ".tmp"
        try:
            with open(tmp, "wb") as fh:
                np.save(fh, block)
            os.replace(tmp, fn)
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        self._sums[c] = block_checksum(block)
        self._held.add(c)

    def delete_chunk(self, c: int) -> None:
        if self.path is None:
            self._blocks.pop(c, None)
            return
        if c in self._held:
            self._held.discard(c)
            self._sums.pop(c, None)
            try:
                os.remove(self._chunk_file(c))
            except OSError:
                pass

    def chunk_ids(self) -> list[int]:
        return list(self._blocks) if self.path is None else list(self._held)

    def __len__(self) -> int:
        return len(self._blocks) if self.path is None else len(self._held)

    def __contains__(self, c: int) -> bool:
        return c in (self._blocks if self.path is None else self._held)
