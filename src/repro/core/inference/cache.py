"""Two-level embedding caching system (paper §III-D).

Level 1 — **static disk cache**: before each layer's inference, worker i
pre-fills a local copy of every chunk row it will need: the embeddings of all
vertices in partition i plus the (precomputed) out-of-partition sampled
neighbors of its boundary vertices.  After the fill, every read is a local
hit by construction (the paper's 100% hit-ratio guarantee).

Level 2 — **dynamic memory cache**: chunk-granular FIFO (or LRU) over the
static cache, capacity a fraction of the worker's chunk count; repeated
accesses of nearby vertices (boosted by the PDS reorder) hit memory instead
of disk.

Accounting matches Fig. 14b / 15b: ``chunk_reads`` = reads that missed the
dynamic cache (served by static disk), ``dynamic_hits`` = memory hits,
``fill_chunks`` = chunks fetched from DFS during the fill phase.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from enum import Enum

import numpy as np

from repro.core.inference.store import ChunkedEmbeddingStore, IOCost, chunk_runs

__all__ = ["CachePolicy", "TwoLevelCache"]


class CachePolicy(str, Enum):
    FIFO = "fifo"
    LRU = "lru"


@dataclass
class CacheStats:
    fill_chunks: int = 0  # DFS fetches during static fill
    static_reads: int = 0  # dynamic misses served by static disk
    dynamic_hits: int = 0
    rows_served: int = 0

    @property
    def total_chunk_reads(self) -> int:
        return self.static_reads

    @property
    def dynamic_hit_ratio(self) -> float:
        tot = self.static_reads + self.dynamic_hits
        return self.dynamic_hits / tot if tot else 0.0

    def modeled_time_ms(self, cost: IOCost) -> float:
        return (
            self.fill_chunks * cost.dfs_ms
            + self.static_reads * cost.disk_ms
            + self.dynamic_hits * cost.mem_ms
        )


class TwoLevelCache:
    def __init__(
        self,
        store: ChunkedEmbeddingStore,
        policy: CachePolicy = CachePolicy.FIFO,
        dynamic_frac: float = 0.10,
    ):
        self.store = store
        self.policy = CachePolicy(policy)
        self.dynamic_frac = dynamic_frac
        self.static: dict[int, np.ndarray] = {}  # chunk id -> block ("disk")
        self.dynamic: OrderedDict[int, np.ndarray] = OrderedDict()
        self.dynamic_capacity = 0
        self.stats = CacheStats()

    # -- static fill -----------------------------------------------------------
    def fill_static(self, rows_needed: np.ndarray) -> None:
        """Fetch from DFS every chunk containing a needed row (fill phase)."""
        self.static.clear()
        self.dynamic.clear()
        chunks = np.unique(np.asarray(rows_needed, np.int64) // self.store.chunk_rows)
        for c in chunks:
            self.static[int(c)] = self.store.read_chunk(int(c))
            self.stats.fill_chunks += 1
        self.dynamic_capacity = max(1, int(self.dynamic_frac * len(self.static)))

    # -- read path ---------------------------------------------------------------
    def _get_chunk(self, c: int) -> np.ndarray:
        if c in self.dynamic:
            self.stats.dynamic_hits += 1
            if self.policy is CachePolicy.LRU:
                self.dynamic.move_to_end(c)
            return self.dynamic[c]
        # dynamic miss -> static disk read (guaranteed present after fill)
        block = self.static.get(c)
        if block is None:  # fill-free use (tests): fall back to DFS
            block = self.store.read_chunk(c)
            self.stats.fill_chunks += 1
            self.static[c] = block
        self.stats.static_reads += 1
        self.dynamic[c] = block
        if len(self.dynamic) > self.dynamic_capacity:
            self.dynamic.popitem(last=False)  # FIFO and LRU both evict head
        return block

    def read_rows(self, rows: np.ndarray) -> np.ndarray:
        """Gather rows, grouped by chunk via one argsort (no O(rows) boolean
        mask scan per chunk); one ``_get_chunk`` per distinct chunk, so the
        cache accounting is identical to the scalar path."""
        rows = np.asarray(rows, dtype=np.int64)
        out = np.empty((rows.shape[0], self.store.dim), dtype=self.store.dtype)
        for c, pos, crows in chunk_runs(rows, self.store.chunk_rows):
            block = self._get_chunk(c)
            out[pos] = block[crows - c * self.store.chunk_rows]
        self.stats.rows_served += rows.shape[0]
        return out
