"""DEPRECATED module — the caching system moved to ``repro.core.storage``.

``TwoLevelCache`` survives as a thin shim over a two-tier
:class:`repro.core.storage.HybridCache` (``memory`` → ``disk`` over the
store), kept for one release of deprecation, mirroring the
``backend.sample()`` playbook.  The accounting contract is unchanged:

    fill_chunks   chunks fetched from DFS (static fill + demand misses)
    static_reads  dynamic misses served by the static disk level
    dynamic_hits  in-memory hits

The historic fill-free bug — ``dynamic_capacity`` stuck at 0 so the dynamic
tier evicted on every insert — is fixed by the hybrid cache's auto-sizing:
capacity grows with the chunks admitted below, so LRU vs FIFO behave
differently even without a ``fill_static`` call.

New code should build a ``HybridCache`` directly (pluggable tiers and
policies, including the PDS-locality-aware one).
"""
from __future__ import annotations

from dataclasses import dataclass
from enum import Enum

import numpy as np

from repro.core.storage import HybridCache, IOCost, build_tiers
from repro.core.storage.store import DFSTier

__all__ = ["CachePolicy", "CacheStats", "TwoLevelCache"]


class CachePolicy(str, Enum):
    """Legacy two-policy enum; the full set lives in
    ``repro.core.storage.CACHE_POLICIES`` (fifo, lru, locality, ...)."""

    FIFO = "fifo"
    LRU = "lru"


@dataclass
class CacheStats:
    fill_chunks: int = 0  # DFS fetches during static fill
    static_reads: int = 0  # dynamic misses served by static disk
    dynamic_hits: int = 0
    rows_served: int = 0

    @property
    def total_chunk_reads(self) -> int:
        return self.static_reads

    @property
    def dynamic_hit_ratio(self) -> float:
        tot = self.static_reads + self.dynamic_hits
        return self.dynamic_hits / tot if tot else 0.0

    def modeled_time_ms(self, cost: IOCost) -> float:
        return (
            self.fill_chunks * cost.dfs_ms
            + self.static_reads * cost.disk_ms
            + self.dynamic_hits * cost.mem_ms
        )


class _LiveCacheStats(CacheStats):
    """A ``CacheStats`` whose counters read through to a ``HybridCache``
    live, so legacy code that keeps a reference to ``cache.stats`` and
    reads it later keeps seeing current values."""

    def __init__(self, hybrid: HybridCache):
        self._hybrid = hybrid

    fill_chunks = property(lambda self: self._hybrid.stats.fill_chunks)
    static_reads = property(lambda self: self._hybrid.stats.static_reads)
    dynamic_hits = property(lambda self: self._hybrid.stats.dynamic_hits)
    rows_served = property(lambda self: self._hybrid.stats.rows_served)


class TwoLevelCache:
    """DEPRECATED shim: a ``memory -> disk`` ``HybridCache`` behind the
    historic two-level surface (``fill_static`` + ``read_rows``)."""

    def __init__(
        self,
        store: DFSTier,
        policy: CachePolicy = CachePolicy.FIFO,
        dynamic_frac: float = 0.10,
    ):
        self.store = store
        self.policy = CachePolicy(policy)
        self.dynamic_frac = dynamic_frac
        self.hybrid = HybridCache(
            store,
            build_tiers(
                ("memory", "disk"), store.chunk_rows, store.dim, dtype=store.dtype
            ),
            policy=self.policy.value,
            dynamic_frac=dynamic_frac,
        )
        self.stats = _LiveCacheStats(self.hybrid)

    # -- legacy surface -----------------------------------------------------
    def fill_static(self, rows_needed: np.ndarray) -> None:
        """Fetch from DFS every chunk containing a needed row (now an
        explicit ``plan_fill`` + ``fill`` on the hybrid cache)."""
        self.hybrid.fill(self.hybrid.plan_fill(rows_needed))

    def read_rows(self, rows: np.ndarray) -> np.ndarray:
        return self.hybrid.read_rows(rows)

    @property
    def dynamic_capacity(self) -> int:
        return self.hybrid._effective_capacity(0)
