from repro.core.inference.store import ChunkedEmbeddingStore, IOCost
from repro.core.inference.cache import TwoLevelCache, CachePolicy, CacheStats
from repro.core.inference.engine import (
    LayerwiseInferenceEngine,
    samplewise_inference,
    assign_inference_owners,
    csr_gather,
)
# the tiered storage subsystem these shims now delegate to
from repro.core.storage import (
    DFSTier,
    FeatureSource,
    HybridCache,
    StorageTier,
    TierStats,
)

__all__ = [
    "ChunkedEmbeddingStore",
    "IOCost",
    "TwoLevelCache",
    "CachePolicy",
    "CacheStats",
    "DFSTier",
    "FeatureSource",
    "HybridCache",
    "StorageTier",
    "TierStats",
    "LayerwiseInferenceEngine",
    "samplewise_inference",
    "assign_inference_owners",
    "csr_gather",
]
