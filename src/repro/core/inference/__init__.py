from repro.core.inference.store import ChunkedEmbeddingStore, IOCost
from repro.core.inference.cache import TwoLevelCache, CachePolicy
from repro.core.inference.engine import (
    LayerwiseInferenceEngine,
    samplewise_inference,
    assign_inference_owners,
    csr_gather,
)

__all__ = [
    "ChunkedEmbeddingStore",
    "IOCost",
    "TwoLevelCache",
    "CachePolicy",
    "LayerwiseInferenceEngine",
    "samplewise_inference",
    "assign_inference_owners",
    "csr_gather",
]
