"""Layerwise (redundancy-free) graph inference engine (paper §III-D, Fig. 7).

A K-layer GNN is split into K one-layer slices.  Slice k reads layer-(k-1)
embeddings of every vertex and its one-hop sampled neighbors from the
two-level cache, computes layer-k embeddings for ALL vertices, and writes
them to the chunked store — so no vertex-layer embedding is ever computed
twice.  Work is allocated one-partition-per-worker; vertex IDs for embedding
I/O come from the graph reorder algorithm (PDS by default).

``samplewise_inference`` is the paper's baseline: each target's K-hop subgraph
is fed through the whole model independently, recomputing shared neighbors.
Both paths share ``layer_fns`` so speedups are apples-to-apples.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.inference.cache import CachePolicy, CacheStats, TwoLevelCache
from repro.core.inference.store import ChunkedEmbeddingStore, IOCost
from repro.core.sampling.service import (
    DEFAULT_DIRECTION,
    MAX_PARTS,
    GatherApplyClient,
)
from repro.graph.graph import GraphPartition, HeteroGraph
from repro.graph.reorder import reorder_permutation

__all__ = [
    "assign_inference_owners",
    "LayerwiseInferenceEngine",
    "samplewise_inference",
]


def assign_inference_owners(
    router_mask: np.ndarray, num_parts: int, seed: int = 0
) -> np.ndarray:
    """One inference owner per vertex: interior vertices go to their partition;
    boundary vertices go greedily to their least-loaded hosting partition."""
    if num_parts > MAX_PARTS:
        raise ValueError(
            f"assign_inference_owners supports at most {MAX_PARTS} partitions "
            f"(uint64 hosting bitmask), got num_parts={num_parts}"
        )
    n = router_mask.shape[0]
    owner = np.full(n, -1, dtype=np.int16)
    loads = np.zeros(num_parts, dtype=np.int64)
    bits = np.unpackbits(
        router_mask.view(np.uint8).reshape(n, 8), axis=1, bitorder="little"
    )[:, :num_parts]
    npart = bits.sum(axis=1)
    interior = npart == 1
    owner[interior] = np.argmax(bits[interior], axis=1)
    loads += np.bincount(owner[interior][owner[interior] >= 0], minlength=num_parts)
    boundary = np.flatnonzero(~interior)
    rng = np.random.default_rng(seed)
    boundary = rng.permutation(boundary)
    for batch in np.array_split(boundary, max(1, boundary.shape[0] // 8192)):
        if batch.shape[0] == 0:
            continue
        # choose min-load hosting partition (loads frozen within the batch)
        cand = bits[batch].astype(np.float64)
        cand[cand == 0] = np.inf
        scored = cand * (loads + 1)
        pick = np.argmin(scored, axis=1).astype(np.int16)
        owner[batch] = pick
        loads += np.bincount(pick, minlength=num_parts)
    assert (owner >= 0).all()
    return owner


@dataclass
class LayerStats:
    cache: CacheStats = field(default_factory=CacheStats)
    vertices_computed: int = 0
    edges_aggregated: int = 0


@dataclass
class InferenceResult:
    final_store: ChunkedEmbeddingStore
    newid: np.ndarray  # vertex gid -> row id in stores
    owner: np.ndarray
    layer_stats: list[LayerStats] = field(default_factory=list)

    def total_chunk_reads(self) -> int:
        return sum(s.cache.static_reads for s in self.layer_stats)

    def total_dynamic_hits(self) -> int:
        return sum(s.cache.dynamic_hits for s in self.layer_stats)

    def dynamic_hit_ratio(self) -> float:
        r = self.total_chunk_reads()
        h = self.total_dynamic_hits()
        return h / (h + r) if (h + r) else 0.0

    def modeled_io_ms(self, cost: IOCost) -> float:
        return sum(s.cache.modeled_time_ms(cost) for s in self.layer_stats)

    def vertices_computed(self) -> int:
        return sum(s.vertices_computed for s in self.layer_stats)


class LayerwiseInferenceEngine:
    def __init__(
        self,
        g: HeteroGraph,
        client: GatherApplyClient,
        layer_fns: list,
        feats: np.ndarray,
        workdir: str,
        *,
        fanouts: list[int] | None = None,
        reorder_alg: str = "PDS",
        chunk_rows: int = 4096,
        policy: CachePolicy | str = CachePolicy.FIFO,
        dynamic_frac: float = 0.10,
        batch_size: int = 4096,
        direction: str = DEFAULT_DIRECTION,
        out_dims: list[int] | None = None,
        seed: int = 0,
    ):
        self.g = g
        self.client = client
        self.layer_fns = layer_fns
        self.feats = feats
        self.workdir = workdir
        self.fanouts = fanouts or [10] * len(layer_fns)
        self.reorder_alg = reorder_alg
        self.chunk_rows = chunk_rows
        self.policy = CachePolicy(policy)
        self.dynamic_frac = dynamic_frac
        self.batch_size = batch_size
        self.direction = direction
        self.out_dims = out_dims or [feats.shape[1]] * len(layer_fns)
        self.seed = seed

    # ------------------------------------------------------------------
    def run(self) -> InferenceResult:
        g = self.g
        num_parts = self.client.router.num_parts
        owner = assign_inference_owners(self.client.router.mask, num_parts, self.seed)
        deg = g.out_degrees() + g.in_degrees()
        perm = reorder_permutation(
            self.reorder_alg,
            global_ids=np.arange(g.num_vertices, dtype=np.int64),
            degrees=deg,
            partition_ids=owner,
        )
        newid = np.empty(g.num_vertices, dtype=np.int64)
        newid[perm] = np.arange(g.num_vertices)

        # layer-0 store: input features in newid order
        store_prev = ChunkedEmbeddingStore(
            f"{self.workdir}/layer0",
            g.num_vertices,
            self.feats.shape[1],
            self.chunk_rows,
        )
        store_prev.write_rows(newid, self.feats)

        result = InferenceResult(
            final_store=store_prev, newid=newid, owner=owner
        )

        for k, layer_fn in enumerate(self.layer_fns):
            stats = LayerStats()
            store_next = ChunkedEmbeddingStore(
                f"{self.workdir}/layer{k + 1}",
                g.num_vertices,
                self.out_dims[k],
                self.chunk_rows,
            )
            for p in range(num_parts):
                verts = np.flatnonzero(owner == p)
                # inference order within the worker follows the reorder ids
                verts = verts[np.argsort(newid[verts], kind="stable")]
                # one-hop sampled neighbors for the whole worker (precomputed,
                # also defines the boundary prefetch set for the static fill)
                sub = self.client.sample_khop(
                    verts, [self.fanouts[k]], direction=self.direction
                )
                hop = sub.hops[0]
                # static cache fill: all local rows + sampled neighbor rows
                cache = TwoLevelCache(store_prev, self.policy, self.dynamic_frac)
                rows_needed = newid[
                    np.unique(np.concatenate([verts, hop.dst]))
                ]
                cache.fill_static(rows_needed)
                # process in inference order batches
                order = np.argsort(hop.src, kind="stable")
                h_src_sorted = hop.src[order]
                h_dst_sorted = hop.dst[order]
                starts = np.searchsorted(h_src_sorted, verts)
                ends = np.searchsorted(h_src_sorted, verts, side="right")
                for lo in range(0, verts.shape[0], self.batch_size):
                    vb = verts[lo : lo + self.batch_size]
                    s_, e_ = starts[lo : lo + self.batch_size], ends[lo : lo + self.batch_size]
                    counts = e_ - s_
                    nbr_rows = np.concatenate(
                        [h_dst_sorted[a:b] for a, b in zip(s_, e_)]
                    ) if vb.shape[0] else np.zeros(0, np.int64)
                    seg = np.repeat(np.arange(vb.shape[0]), counts)
                    h_self = cache.read_rows(newid[vb])
                    h_nbr = (
                        cache.read_rows(newid[nbr_rows])
                        if nbr_rows.shape[0]
                        else np.zeros((0, store_prev.dim), store_prev.dtype)
                    )
                    h_new = layer_fn(k, h_self, h_nbr, seg)
                    store_next.write_rows(newid[vb], np.asarray(h_new))
                    stats.vertices_computed += vb.shape[0]
                    stats.edges_aggregated += int(nbr_rows.shape[0])
                stats.cache.fill_chunks += cache.stats.fill_chunks
                stats.cache.static_reads += cache.stats.static_reads
                stats.cache.dynamic_hits += cache.stats.dynamic_hits
                stats.cache.rows_served += cache.stats.rows_served
            result.layer_stats.append(stats)
            store_prev = store_next
        result.final_store = store_prev
        return result


def samplewise_inference(
    g: HeteroGraph,
    client: GatherApplyClient,
    layer_fns: list,
    feats: np.ndarray,
    targets: np.ndarray,
    *,
    fanouts: list[int] | None = None,
    batch_size: int = 256,
    direction: str = "out",
) -> tuple[np.ndarray, dict]:
    """Naive baseline: per-target K-hop subgraph through the full model.

    Returns (embeddings[targets], stats) where stats counts the redundant
    vertex-layer computations the layerwise engine avoids."""
    K = len(layer_fns)
    fanouts = fanouts or [10] * K
    stats = {"vertices_computed": 0, "edges_aggregated": 0, "feature_rows_read": 0}
    out = None

    for lo in range(0, targets.shape[0], batch_size):
        tb = np.unique(targets[lo : lo + batch_size])
        sub = client.sample_khop(tb, fanouts, direction=direction)
        # A vertex first reached at depth d has its sampled one-hop edges in
        # hop d; layer k therefore aggregates the union of hops 0..K-1-k and
        # needs h^{k-1} for every vertex at depth <= K-k.
        frontiers = [tb]
        for hop in sub.hops:
            frontiers.append(np.unique(hop.dst))
        all_verts = np.unique(np.concatenate(frontiers))
        hcur = {int(v): feats[v] for v in all_verts}
        stats["feature_rows_read"] += all_verts.shape[0]
        for k in range(K):
            layer = layer_fns[k]
            es = np.concatenate([h.src for h in sub.hops[: K - k]])
            ed = np.concatenate([h.dst for h in sub.hops[: K - k]])
            need_verts = np.unique(np.concatenate(frontiers[: K - k]))
            order = np.argsort(es, kind="stable")
            es, ed = es[order], ed[order]
            s_ = np.searchsorted(es, need_verts)
            e_ = np.searchsorted(es, need_verts, side="right")
            counts = e_ - s_
            nbrs = (
                np.concatenate([ed[a:b] for a, b in zip(s_, e_)])
                if need_verts.shape[0]
                else np.zeros(0, np.int64)
            )
            seg = np.repeat(np.arange(need_verts.shape[0]), counts)
            h_self = np.stack([hcur[int(v)] for v in need_verts])
            h_nbr = (
                np.stack([hcur[int(v)] for v in nbrs])
                if nbrs.shape[0]
                else np.zeros((0, h_self.shape[1]), h_self.dtype)
            )
            h_new = np.asarray(layer(k, h_self, h_nbr, seg))
            hcur = {int(v): h_new[i] for i, v in enumerate(need_verts)}
            stats["vertices_computed"] += need_verts.shape[0]
            stats["edges_aggregated"] += int(nbrs.shape[0])
        hb = np.stack([hcur[int(v)] for v in tb])  # tb is unique-sorted
        # map back to the original (possibly unsorted) batch order
        hb = hb[np.searchsorted(tb, targets[lo : lo + batch_size])]
        out = hb if out is None else np.concatenate([out, hb])
    return out, stats
