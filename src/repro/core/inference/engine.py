"""Layerwise (redundancy-free) graph inference engine (paper §III-D, Fig. 7).

A K-layer GNN is split into K one-layer slices.  Slice k reads layer-(k-1)
embeddings of every vertex and its one-hop sampled neighbors through a
tiered ``HybridCache`` (``repro.core.storage``; tier stack and eviction
policy come from the storage config), computes layer-k embeddings for ALL
vertices, and writes them to the chunked store — so no vertex-layer
embedding is ever computed twice.  Work is allocated one-partition-per-worker; vertex IDs for embedding
I/O come from the graph reorder algorithm (PDS by default).

Execution modes
---------------
``mode="bucketed"`` (default) is the device-resident fast path: the
per-batch (self, nbr, seg, etype) triple is padded to a small set of
power-of-two shape buckets and fed to a jit-compiled layer slice, so every
``(layer, bucket)`` pair compiles exactly once and each batch costs one
host→device transfer and one device→host readback.  Neighbor gathers are a
vectorized CSR-offset gather (:func:`csr_gather`) — no per-vertex Python.
Layer fns that expose a traceable ``.jax`` slice (see
``GNNModel.embed_layer_fn``) run under jit; plain numpy callables still work
and get the vectorized gather without jit.

``mode="reference"`` preserves the pre-optimization inner loop (per-vertex
slice-and-concatenate gathers, eager per-batch layer calls) so benchmarks
can report before/after engine wall-clock on identical inputs.

``samplewise_inference`` is the paper's baseline: each target's K-hop
subgraph is fed through the whole model independently, recomputing shared
neighbors.  Both paths share ``layer_fns`` so speedups are apples-to-apples.
"""
from __future__ import annotations

import functools
import inspect
from dataclasses import dataclass, field

import numpy as np

from repro.core.inference.cache import CacheStats
from repro.core.storage import (
    DFSTier,
    HybridCache,
    HybridStats,
    IOCost,
    TierStats,
    build_tiers,
)
from repro.core.sampling.service import (
    DEFAULT_DIRECTION,
    MAX_PARTS,
    GatherApplyClient,
    SamplingSpec,
)

# domain-separation tag for the engine's sample-request RNG keys, so they
# never alias a loader/trainer request stream on a shared service
_ENGINE_KEY_TAG = 0x1F7E
from repro.graph.graph import GraphPartition, HeteroGraph
from repro.graph.reorder import reorder_permutation

__all__ = [
    "assign_inference_owners",
    "csr_gather",
    "LayerwiseInferenceEngine",
    "samplewise_inference",
]


def csr_gather(values: np.ndarray, starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenate ``values[starts[i] : starts[i] + counts[i]]`` for all i,
    without a per-segment Python loop.

    Equivalent to ``np.concatenate([values[s:s+c] for s, c in zip(starts,
    counts)])`` but built from one ``np.repeat`` over the CSR offsets plus a
    single fancy-index — the engine's neighbor gather hotspot."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if total == 0:
        return values[:0]
    starts = np.asarray(starts, dtype=np.int64)
    shift = starts - np.concatenate(([0], np.cumsum(counts)[:-1]))
    idx = np.repeat(shift, counts) + np.arange(total, dtype=np.int64)
    return values[idx]


def _pow2_ceil(n: int, floor: int) -> int:
    return max(floor, 1 << max(0, int(n) - 1).bit_length())


def assign_inference_owners(
    router_mask: np.ndarray, num_parts: int, seed: int = 0
) -> np.ndarray:
    """One inference owner per vertex: interior vertices go to their partition;
    boundary vertices go greedily to their least-loaded hosting partition."""
    if num_parts > MAX_PARTS:
        raise ValueError(
            f"assign_inference_owners supports at most {MAX_PARTS} partitions "
            f"(uint64 hosting bitmask), got num_parts={num_parts}"
        )
    n = router_mask.shape[0]
    owner = np.full(n, -1, dtype=np.int16)
    loads = np.zeros(num_parts, dtype=np.int64)
    bits = np.unpackbits(
        router_mask.view(np.uint8).reshape(n, 8), axis=1, bitorder="little"
    )[:, :num_parts]
    npart = bits.sum(axis=1)
    interior = npart == 1
    owner[interior] = np.argmax(bits[interior], axis=1)
    loads += np.bincount(owner[interior][owner[interior] >= 0], minlength=num_parts)
    boundary = np.flatnonzero(~interior)
    rng = np.random.default_rng(seed)
    boundary = rng.permutation(boundary)
    for batch in np.array_split(boundary, max(1, boundary.shape[0] // 8192)):
        if batch.shape[0] == 0:
            continue
        # choose min-load hosting partition (loads frozen within the batch)
        cand = bits[batch].astype(np.float64)
        cand[cand == 0] = np.inf
        scored = cand * (loads + 1)
        pick = np.argmin(scored, axis=1).astype(np.int16)
        owner[batch] = pick
        loads += np.bincount(pick, minlength=num_parts)
    assert (owner >= 0).all()
    return owner


@dataclass
class LayerStats:
    cache: CacheStats = field(default_factory=CacheStats)
    # aggregated per-tier accounting (fast→slow) across this layer's
    # partition caches; empty until the first partition finishes
    tiers: list = field(default_factory=list)
    vertices_computed: int = 0
    edges_aggregated: int = 0
    # padding-waste accounting, mirroring ServeStats occupancy: real vs
    # power-of-two-padded rows the bucketed slices actually dispatched,
    # and per (vertex-bucket, edge-bucket) batch counts.  This is what the
    # ragged kernels' tile skip saves — visible per layer in reports.
    batch_rows: int = 0
    padded_rows: int = 0
    batch_edges: int = 0
    padded_edges: int = 0
    bucket_batches: dict = field(default_factory=dict)

    def note_batch(
        self, rows: int, padded_rows: int, edges: int, padded_edges: int
    ) -> None:
        self.batch_rows += rows
        self.padded_rows += padded_rows
        self.batch_edges += edges
        self.padded_edges += padded_edges
        self.bucket_batches[(padded_rows, padded_edges)] = (
            self.bucket_batches.get((padded_rows, padded_edges), 0) + 1
        )

    def occupancy(self) -> float:
        """Fraction of padded vertex rows that carried real vertices."""
        return self.batch_rows / self.padded_rows if self.padded_rows else 0.0

    def edge_occupancy(self) -> float:
        return self.batch_edges / self.padded_edges if self.padded_edges else 0.0

    def absorb(self, hs: HybridStats) -> None:
        """Fold one partition cache's counters into this layer's totals."""
        self.cache.fill_chunks += hs.fill_chunks
        self.cache.static_reads += hs.static_reads
        self.cache.dynamic_hits += hs.dynamic_hits
        self.cache.rows_served += hs.rows_served
        if not self.tiers:
            self.tiers = [TierStats(kind=t.kind) for t in hs.tiers]
        for agg, t in zip(self.tiers, hs.tiers):
            agg.hits += t.hits
            agg.admits += t.admits
            agg.evictions += t.evictions

    def modeled_io_ms(self, cost: IOCost) -> float:
        """Tier-aware rollup (the legacy two-level formula misattributes
        hits for stacks that are not exactly memory+disk)."""
        if not self.tiers:
            return self.cache.modeled_time_ms(cost)
        ms = self.cache.fill_chunks * cost.dfs_ms
        for t in self.tiers:
            ms += t.hits * cost.per_chunk_ms(t.kind)
        return ms


@dataclass
class InferenceResult:
    final_store: DFSTier
    newid: np.ndarray  # vertex gid -> row id in stores
    owner: np.ndarray
    layer_stats: list[LayerStats] = field(default_factory=list)
    # distinct (layer, bucket) shapes this run sent through the jit path;
    # each compiles at most once over the engine's lifetime
    slice_compiles: int = 0

    def total_chunk_reads(self) -> int:
        return sum(s.cache.static_reads for s in self.layer_stats)

    def total_dynamic_hits(self) -> int:
        return sum(s.cache.dynamic_hits for s in self.layer_stats)

    def dynamic_hit_ratio(self) -> float:
        r = self.total_chunk_reads()
        h = self.total_dynamic_hits()
        return h / (h + r) if (h + r) else 0.0

    def modeled_io_ms(self, cost: IOCost) -> float:
        return sum(s.modeled_io_ms(cost) for s in self.layer_stats)

    def vertices_computed(self) -> int:
        return sum(s.vertices_computed for s in self.layer_stats)


@dataclass
class _ServeSliceStats:
    """Throwaway ``slice_compiles`` sink for online ``run_layer_batch``
    calls (the lifetime counters on the engine still record the shape)."""

    slice_compiles: int = 0


class LayerwiseInferenceEngine:
    def __init__(
        self,
        g: HeteroGraph,
        client,  # SamplingService (preferred) or a raw GatherApplyClient
        layer_fns: list,
        feats: np.ndarray,
        workdir: str,
        *,
        fanouts: list[int] | None = None,
        reorder_alg: str = "PDS",
        chunk_rows: int = 4096,
        policy="fifo",  # CACHE_POLICIES name, class, or legacy CachePolicy
        dynamic_frac: float = 0.10,
        storage_tiers: tuple = ("memory", "disk"),
        tier_capacities: tuple = (),
        batch_size: int = 4096,
        direction: str = DEFAULT_DIRECTION,
        out_dims: list[int] | None = None,
        seed: int = 0,
        mode: str = "bucketed",
        use_jit: bool = True,
        use_kernel: bool | None = None,
        kernel_autotune: bool = False,
        kernel_cache_dir: str | None = None,
        edge_buckets: tuple | None = None,
        ticket_timeout: float | None = None,
        retry_policy=None,  # RetryPolicy for tiered-storage reads
        faults=None,  # FaultPlan/FaultInjector armed on the cache tiers
    ):
        if mode not in ("bucketed", "reference"):
            raise ValueError(f"mode must be 'bucketed' or 'reference', got {mode!r}")
        self.g = g
        self.client = client
        self.layer_fns = layer_fns
        self.feats = feats
        self.workdir = workdir
        self.fanouts = fanouts or [10] * len(layer_fns)
        self.reorder_alg = reorder_alg
        self.chunk_rows = chunk_rows
        self.policy = policy
        self.dynamic_frac = dynamic_frac
        self.storage_tiers = tuple(storage_tiers)
        self.tier_capacities = tuple(tier_capacities)
        self.batch_size = batch_size
        self.direction = direction
        self.out_dims = out_dims or [feats.shape[1]] * len(layer_fns)
        self.seed = seed
        self.mode = mode
        self.use_jit = use_jit
        self.use_kernel = use_kernel
        self.kernel_autotune = kernel_autotune
        self.kernel_cache_dir = kernel_cache_dir
        self.edge_buckets = tuple(edge_buckets) if edge_buckets else ()
        self.ticket_timeout = ticket_timeout
        self.retry_policy = retry_policy
        self.faults = faults
        self._jitted: dict = {}  # layer k -> jit'd slice (shape-keyed inside)
        # filled by run(): the per-layer DFS stores (index k = layer-k
        # embeddings, 0 = input features) and the last InferenceResult —
        # the online serving tier reads layer K-1 through these instead of
        # re-opening the store paths (keeps live checksums)
        self.layer_stores: list = []
        self.last_result: InferenceResult | None = None
        self._shapes_seen: set = set()  # (layer, Bp, Ep) -> compile counter
        # lifetime views for repro.analysis.recompile_guard: actual traces
        # of each jit'd slice, and every (layer, Bp, Ep) ever executed
        # (never cleared, unlike _shapes_seen which resets per run)
        self._trace_counts: dict = {}
        self._shapes_lifetime: set = set()

    # -- shape bucketing ------------------------------------------------
    def _vertex_bucket(self, b: int) -> int:
        return min(self.batch_size, _pow2_ceil(b, 64))

    def _edge_bucket(self, e: int) -> int:
        if self.edge_buckets:
            for cap in self.edge_buckets:
                if e <= cap:
                    return int(cap)
        return _pow2_ceil(e, 256)

    def _slice_fn(self, k: int, layer_fn):
        """The jit'd traceable slice for layer k, or None (numpy fallback)."""
        if self.mode != "bucketed" or not self.use_jit:
            return None
        jf = getattr(layer_fn, "jax", None)
        if jf is None:
            return None
        if k not in self._jitted:
            import jax

            if (
                self.use_kernel is not None
                and "use_kernel" in inspect.signature(jf).parameters
            ):
                jf = functools.partial(jf, use_kernel=self.use_kernel)

            # every jit cache miss re-traces the Python callable, so a
            # counting wrapper *under* jax.jit observes exactly the
            # compiles (recompile_guard asserts this against the
            # (layer, bucket) bound)
            def traced(*args, _jf=jf, _k=k):
                self._trace_counts[_k] = self._trace_counts.get(_k, 0) + 1
                return _jf(*args)

            self._jitted[k] = jax.jit(traced)
        return self._jitted[k]

    def jit_trace_count(self) -> int:
        """Total times any layer slice was traced (== jit compiles) over
        the engine's lifetime.  ``repro.analysis.recompile_guard`` diffs
        this against ``shape_count()`` to catch unbounded recompilation."""
        return sum(self._trace_counts.values())

    def shape_count(self) -> int:
        """Distinct (layer, vertex-bucket, edge-bucket) triples ever run."""
        return len(self._shapes_lifetime)

    # -- tiered storage -------------------------------------------------
    def _build_cache(self, store: DFSTier) -> HybridCache:
        """One per-(layer, partition) tier stack from the storage config."""
        tiers = build_tiers(
            self.storage_tiers,
            store.chunk_rows,
            store.dim,
            capacities=self.tier_capacities,
            dtype=store.dtype,
            faults=self.faults,
        )
        return HybridCache(
            store,
            tiers,
            policy=self.policy,
            dynamic_frac=self.dynamic_frac,
            retry_policy=self.retry_policy,
        )

    # ------------------------------------------------------------------
    def run(self) -> InferenceResult:
        g = self.g
        num_parts = self.client.router.num_parts
        owner = assign_inference_owners(self.client.router.mask, num_parts, self.seed)
        deg = g.out_degrees() + g.in_degrees()
        perm = reorder_permutation(
            self.reorder_alg,
            global_ids=np.arange(g.num_vertices, dtype=np.int64),
            degrees=deg,
            partition_ids=owner,
        )
        newid = np.empty(g.num_vertices, dtype=np.int64)
        newid[perm] = np.arange(g.num_vertices)

        # layer-0 store: input features in newid order
        store_prev = DFSTier(
            f"{self.workdir}/layer0",
            g.num_vertices,
            self.feats.shape[1],
            self.chunk_rows,
        )
        store_prev.write_rows(newid, self.feats)

        result = InferenceResult(
            final_store=store_prev, newid=newid, owner=owner
        )
        stores = [store_prev]

        # inference order within each worker follows the reorder ids
        part_verts = []
        for p in range(num_parts):
            verts = np.flatnonzero(owner == p)
            part_verts.append(verts[np.argsort(newid[verts], kind="stable")])

        submit = getattr(self.client, "submit", None)
        self._shapes_seen.clear()  # slice_compiles counts per-run shapes
        for k, layer_fn in enumerate(self.layer_fns):
            stats = LayerStats()
            slice_fn = self._slice_fn(k, layer_fn)
            needs_etype = getattr(layer_fn, "needs_etype", False)
            store_next = DFSTier(
                f"{self.workdir}/layer{k + 1}",
                g.num_vertices,
                self.out_dims[k],
                self.chunk_rows,
            )
            # one-hop sampled neighbors for every worker: submit ALL workers'
            # requests up front so the service schedules them in one round
            # (balanced dispatch across servers); explicit keys make the
            # sample independent of any other traffic on a shared service
            tickets = None
            if submit is not None:
                spec = SamplingSpec(
                    fanouts=(self.fanouts[k],), direction=self.direction
                )
                tickets = [
                    submit(
                        part_verts[p],
                        spec,
                        key=(self.seed, k, p, _ENGINE_KEY_TAG),
                    )
                    for p in range(num_parts)
                ]
            for p in range(num_parts):
                verts = part_verts[p]
                # (the precomputed one-hop also defines the boundary
                # prefetch set for the static fill)
                if tickets is not None:
                    sub = tickets[p].result(timeout=self.ticket_timeout)
                    tickets[p] = None  # release the hop data once consumed
                else:
                    sub = self.client.sample_khop(
                        verts, [self.fanouts[k]], direction=self.direction
                    )
                hop = sub.hops[0]
                # static cache fill: all local rows + sampled neighbor rows.
                # The partition's own rows are the fill-plan focus window —
                # the PDS reorder packs them contiguously, so the locality
                # policy evicts far boundary chunks first.
                cache = self._build_cache(store_prev)
                rows_needed = newid[
                    np.unique(np.concatenate([verts, hop.dst]))
                ]
                cache.fill(
                    cache.plan_fill(rows_needed, focus_rows=newid[verts])
                )
                # process in inference order batches
                order = np.argsort(hop.src, kind="stable")
                h_src_sorted = hop.src[order]
                h_dst_sorted = hop.dst[order]
                # edge types are gathered only for layers that consume them
                # (hgt); other models must not pay for the extra gather
                if needs_etype and hop.eid is not None:
                    h_et_sorted = g.edge_types[hop.eid[order]].astype(np.int32)
                elif needs_etype:
                    h_et_sorted = np.zeros(h_src_sorted.shape[0], np.int32)
                else:
                    h_et_sorted = None
                starts = np.searchsorted(h_src_sorted, verts)
                ends = np.searchsorted(h_src_sorted, verts, side="right")
                for lo in range(0, verts.shape[0], self.batch_size):
                    vb = verts[lo : lo + self.batch_size]
                    s_ = starts[lo : lo + self.batch_size]
                    e_ = ends[lo : lo + self.batch_size]
                    counts = e_ - s_
                    if self.mode == "reference":
                        nbr_rows = np.concatenate(
                            [h_dst_sorted[a:b] for a, b in zip(s_, e_)]
                        ) if vb.shape[0] else np.zeros(0, np.int64)
                    else:
                        nbr_rows = csr_gather(h_dst_sorted, s_, counts)
                    et = (
                        csr_gather(h_et_sorted, s_, counts)
                        if h_et_sorted is not None
                        else None
                    )
                    seg = np.repeat(np.arange(vb.shape[0]), counts)
                    h_self = cache.read_rows(newid[vb])
                    h_nbr = (
                        cache.read_rows(newid[nbr_rows])
                        if nbr_rows.shape[0]
                        else np.zeros((0, store_prev.dim), store_prev.dtype)
                    )
                    if slice_fn is not None:
                        h_new = self._run_slice(
                            k, slice_fn, h_self, h_nbr, seg, et, result, stats
                        )
                    elif needs_etype:
                        h_new = np.asarray(
                            layer_fn(k, h_self, h_nbr, seg, et)
                        )
                    else:
                        h_new = np.asarray(layer_fn(k, h_self, h_nbr, seg))
                    store_next.write_rows(newid[vb], h_new)
                    stats.vertices_computed += vb.shape[0]
                    stats.edges_aggregated += int(nbr_rows.shape[0])
                stats.absorb(cache.stats)
                cache.evict()  # release this partition's cache residency
            result.layer_stats.append(stats)
            stores.append(store_next)
            store_prev = store_next
        result.final_store = store_prev
        self.layer_stores = stores
        self.last_result = result
        return result

    # -- online serving entry point --------------------------------------
    def run_layer_batch(self, k, h_self, h_nbr, seg, et=None) -> np.ndarray:
        """One layer-``k`` slice over an online batch, outside ``run()``.

        Shares the offline path's jit cache, bucket ladder, and
        ``_trace_counts``/``_shapes_lifetime`` bookkeeping, so
        ``recompile_guard`` covers serving with the same
        one-compile-per-(layer, bucket) bound.  Falls back to the plain
        numpy layer callable when the slice is not jit-eligible."""
        layer_fn = self.layer_fns[k]
        slice_fn = self._slice_fn(k, layer_fn)
        if slice_fn is not None:
            shim = _ServeSliceStats()
            return self._run_slice(k, slice_fn, h_self, h_nbr, seg, et, shim)
        if getattr(layer_fn, "needs_etype", False):
            return np.asarray(layer_fn(k, h_self, h_nbr, seg, et))
        return np.asarray(layer_fn(k, h_self, h_nbr, seg))

    # -- bucketed device execution --------------------------------------
    def _run_slice(self, k, slice_fn, h_self, h_nbr, seg, et, result, stats=None):
        """Pad one batch to its (vertex, edge) shape bucket and run the
        jit-compiled slice: one host→device transfer in, one device→host
        readback out.  Shapes repeat across batches, so each (layer, bucket)
        pair traces and compiles exactly once for the whole run."""
        b, e = h_self.shape[0], seg.shape[0]
        bp, ep = self._vertex_bucket(b), self._edge_bucket(e)
        key = (k, bp, ep)
        if (
            self.kernel_autotune
            and self.use_kernel
            and key not in self._shapes_lifetime
        ):
            # tune this bucket's kernel shapes BEFORE the first jit trace,
            # so the trace-time block-size lookup sees the tuned winners
            # (the jit cache then pins them — still one compile per bucket)
            shapes_of = getattr(self.layer_fns[k], "kernel_shapes", None)
            if shapes_of is not None:
                from repro.kernels.autotune import autotune_for_slice

                autotune_for_slice(
                    shapes_of(ep, bp, h_nbr.shape[1]),
                    h_nbr.dtype,
                    cache_dir=self.kernel_cache_dir,
                )
        if key not in self._shapes_seen:
            self._shapes_seen.add(key)
            result.slice_compiles += 1
        self._shapes_lifetime.add(key)
        if stats is not None:
            stats.note_batch(b, bp, e, ep)
        hs = np.zeros((bp, h_self.shape[1]), h_self.dtype)
        hs[:b] = h_self
        hn = np.zeros((ep, h_nbr.shape[1]), h_nbr.dtype)
        hn[:e] = h_nbr
        sg = np.full(ep, -1, np.int32)
        sg[:e] = seg
        etp = np.zeros(ep, np.int32)
        if et is not None:
            etp[:e] = et
        out = slice_fn(hs, hn, sg, etp)
        return np.asarray(out[:b])


def samplewise_inference(
    g: HeteroGraph,
    client: GatherApplyClient,
    layer_fns: list,
    feats: np.ndarray,
    targets: np.ndarray,
    *,
    fanouts: list[int] | None = None,
    batch_size: int = 256,
    direction: str = "out",
) -> tuple[np.ndarray, dict]:
    """Naive baseline: per-target K-hop subgraph through the full model.

    Vectorized over a compacted id space (``searchsorted`` into the sorted
    vertex universe instead of a per-vertex Python dict), so the baseline is
    honestly fast and speedup claims measure algorithmic redundancy, not
    interpreter overhead.  Returns (embeddings[targets], stats) where stats
    counts the redundant vertex-layer computations the layerwise engine
    avoids."""
    K = len(layer_fns)
    fanouts = fanouts or [10] * K
    stats = {"vertices_computed": 0, "edges_aggregated": 0, "feature_rows_read": 0}
    out = None

    for lo in range(0, targets.shape[0], batch_size):
        tb = np.unique(targets[lo : lo + batch_size])
        sub = client.sample_khop(tb, fanouts, direction=direction)
        # A vertex first reached at depth d has its sampled one-hop edges in
        # hop d; layer k therefore aggregates the union of hops 0..K-1-k and
        # needs h^{k-1} for every vertex at depth <= K-k.
        frontiers = [tb]
        hop_et = []
        for hop in sub.hops:
            frontiers.append(np.unique(hop.dst))
            hop_et.append(
                g.edge_types[hop.eid].astype(np.int32)
                if hop.eid is not None
                else np.zeros(hop.src.shape[0], np.int32)
            )
        all_verts = np.unique(np.concatenate(frontiers))
        hcur = np.ascontiguousarray(feats[all_verts])
        stats["feature_rows_read"] += all_verts.shape[0]
        for k in range(K):
            layer = layer_fns[k]
            es = np.concatenate([h.src for h in sub.hops[: K - k]])
            ed = np.concatenate([h.dst for h in sub.hops[: K - k]])
            et = np.concatenate(hop_et[: K - k])
            need_verts = np.unique(np.concatenate(frontiers[: K - k]))
            order = np.argsort(es, kind="stable")
            es, ed, et = es[order], ed[order], et[order]
            s_ = np.searchsorted(es, need_verts)
            e_ = np.searchsorted(es, need_verts, side="right")
            counts = e_ - s_
            nbrs = csr_gather(ed, s_, counts)
            et_g = csr_gather(et, s_, counts)
            seg = np.repeat(np.arange(need_verts.shape[0]), counts)
            need_pos = np.searchsorted(all_verts, need_verts)
            h_self = hcur[need_pos]
            h_nbr = (
                hcur[np.searchsorted(all_verts, nbrs)]
                if nbrs.shape[0]
                else np.zeros((0, h_self.shape[1]), h_self.dtype)
            )
            if getattr(layer, "needs_etype", False):
                h_new = np.asarray(layer(k, h_self, h_nbr, seg, et_g))
            else:
                h_new = np.asarray(layer(k, h_self, h_nbr, seg))
            nxt = np.zeros((all_verts.shape[0], h_new.shape[1]), h_new.dtype)
            nxt[need_pos] = h_new
            hcur = nxt
            stats["vertices_computed"] += need_verts.shape[0]
            stats["edges_aggregated"] += int(nbrs.shape[0])
        hb = hcur[np.searchsorted(all_verts, tb)]  # tb is unique-sorted
        # map back to the original (possibly unsorted) batch order
        hb = hb[np.searchsorted(tb, targets[lo : lo + batch_size])]
        out = hb if out is None else np.concatenate([out, hb])
    return out, stats
