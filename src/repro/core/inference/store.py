"""DEPRECATED module — the chunked store moved to ``repro.core.storage``.

``ChunkedEmbeddingStore`` is now a thin alias of
:class:`repro.core.storage.DFSTier` (same constructor, same files on disk,
same counters) kept for one release of deprecation, mirroring the
``backend.sample()`` playbook; ``IOCost`` and ``chunk_runs`` re-export from
their new home.  New call sites should import from ``repro.core.storage``.
"""
from __future__ import annotations

from repro.core.storage.store import DFSTier, IOCost, StoreStats, chunk_runs

__all__ = ["ChunkedEmbeddingStore", "IOCost", "StoreStats", "chunk_runs"]


class ChunkedEmbeddingStore(DFSTier):
    """DEPRECATED alias of :class:`repro.core.storage.DFSTier`."""
