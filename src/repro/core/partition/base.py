"""The partitioning subsystem's shared surface: ``Partitioner`` protocol,
the rich ``PartitionPlan`` artifact, and the ``PARTITIONERS`` registry.

Every registered partitioner is an *instance* implementing

    plan = partitioner.partition(g, num_parts, seed=..., direction=...)

and returns a ``PartitionPlan`` — the one artifact the rest of the stack
(builders, sampler backends, the pipeline cache) consumes.  Besides the raw
vertex-cut edge assignment the plan carries per-partition vertex/edge
counts, the paper's Eq. (2)-(4) quality scores (RF / VB / EB) and, for
iterative partitioners, a per-iteration convergence trace.

The registry lives here (not in ``repro.api.backends``) for the same reason
``CACHE_POLICIES`` lives in ``repro.core.storage``: the subsystem owns its
own extension point and the API package re-exports it.  ``Registry`` itself
is dependency-free (``repro.utils``), so nothing below ``repro.api`` is
imported from here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.sampling.service import DEFAULT_DIRECTION
from repro.graph.graph import HeteroGraph
from repro.utils import Registry

__all__ = [
    "PartitionPlan",
    "Partitioner",
    "PartitionerBase",
    "PARTITIONERS",
    "hosted_vertex_counts",
]


def hosted_vertex_counts(
    g: HeteroGraph, edge_parts: np.ndarray, num_parts: int
) -> np.ndarray:
    """Vertices hosted per partition (endpoints of its edges), vectorized:
    one unique over the (partition, vertex) incidence pairs, no per-partition
    edge scan."""
    ep = edge_parts.astype(np.int64)
    n = np.int64(max(1, g.num_vertices))
    pairs = np.concatenate([ep * n + g.src, ep * n + g.dst])
    uniq = np.unique(pairs)
    return np.bincount((uniq // n).astype(np.int64), minlength=num_parts)


@dataclass(frozen=True)
class PartitionPlan:
    """Output of any registered partitioner.

    ``edge_parts[e]`` is the partition id of edge e (the vertex-cut edge
    assignment every backend builds from).  ``vertex_owner`` is set only by
    edge-cut (vertex) partitioners and is required by the ``edge_cut``
    sampler backend for owner routing.

    The remaining fields are the plan's quality scorecard, populated by
    :meth:`from_assignment` (all registry entries go through it):
    ``edge_counts``/``vertex_counts`` are |E_p| and hosted-|V_p| per
    partition, ``replication_factor``/``vertex_balance``/``edge_balance``
    the paper's Eq. (2)-(4), and ``iteration_trace`` a dict of stacked
    per-iteration arrays for iterative partitioners (AdaDNE/DNE record
    ``remaining``, ``edge_counts``, ``vertex_counts`` and ``lam``)."""

    edge_parts: np.ndarray
    vertex_owner: np.ndarray | None = None
    num_parts: int = 0
    partitioner: str = ""
    seed: int = 0
    edge_counts: np.ndarray | None = None
    vertex_counts: np.ndarray | None = None
    replication_factor: float | None = None
    vertex_balance: float | None = None
    edge_balance: float | None = None
    iteration_trace: dict | None = None

    # ------------------------------------------------------------------
    @classmethod
    def from_assignment(
        cls,
        g: HeteroGraph,
        edge_parts: np.ndarray,
        num_parts: int,
        *,
        vertex_owner: np.ndarray | None = None,
        partitioner: str = "",
        seed: int = 0,
        iteration_trace: dict | None = None,
    ) -> "PartitionPlan":
        """Build a plan with the quality scorecard computed from the raw
        vertex-cut edge assignment."""
        edge_parts = np.asarray(edge_parts)
        ec = np.bincount(edge_parts.astype(np.int64), minlength=num_parts)
        vc = hosted_vertex_counts(g, edge_parts, num_parts)
        return cls(
            edge_parts=edge_parts,
            vertex_owner=vertex_owner,
            num_parts=num_parts,
            partitioner=partitioner,
            seed=seed,
            edge_counts=ec,
            vertex_counts=vc,
            replication_factor=float(vc.sum()) / max(1, g.num_vertices),
            vertex_balance=float(vc.max()) / max(1, int(vc.min())),
            edge_balance=float(ec.max()) / max(1, int(ec.min())),
            iteration_trace=iteration_trace,
        )

    def metrics(self) -> dict:
        """The scorecard in the shape of ``partition_metrics`` (RF/VB/EB)."""
        return {
            "RF": self.replication_factor,
            "VB": self.vertex_balance,
            "EB": self.edge_balance,
            "vertices": (
                self.vertex_counts.tolist()
                if self.vertex_counts is not None
                else None
            ),
            "edges": (
                self.edge_counts.tolist()
                if self.edge_counts is not None
                else None
            ),
        }


@runtime_checkable
class Partitioner(Protocol):
    """The one partitioning surface: a named component producing a plan."""

    name: str

    def partition(
        self,
        g: HeteroGraph,
        num_parts: int,
        *,
        seed: int = 0,
        direction: str = DEFAULT_DIRECTION,
    ) -> PartitionPlan: ...


class PartitionerBase:
    """Convenience base: makes a partitioner callable like the old free
    functions (``PARTITIONERS.get(name)(g, parts, seed=0)``) so registry
    call sites keep one calling convention."""

    name = "base"

    @property
    def cache_token(self) -> str:
        """String folded into the pipeline's content-addressed cache key.
        Must change whenever the instance is configured to produce a
        different plan for the same (graph, num_parts, seed, direction) —
        the default covers stateless partitioners; configurable ones
        append their hyperparameters."""
        return self.name

    def __call__(
        self,
        g: HeteroGraph,
        num_parts: int,
        *,
        seed: int = 0,
        direction: str = DEFAULT_DIRECTION,
    ) -> PartitionPlan:
        return self.partition(g, num_parts, seed=seed, direction=direction)

    def __repr__(self) -> str:
        return f"{type(self).__name__}(name={self.name!r})"


# Populated by ``repro.core.partition.__init__`` (one instance per entry:
# adadne, adadne_loop, dne, dne_loop, ldg, hash2d, random); re-exported as
# ``repro.api.PARTITIONERS``.
PARTITIONERS: Registry = Registry("partitioner")
