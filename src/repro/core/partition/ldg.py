"""Streaming Linear Deterministic Greedy (LDG) edge-cut partitioner.

Stand-in for the paper's ParMETIS baseline (METIS multilevel coarsening is out
of scope; LDG is the standard streaming edge-cut baseline and shows the same
failure mode on power-law graphs: cut-edge/halo redundancy and edge imbalance,
cf. DESIGN.md §6).  Assigns VERTICES to partitions:

    score(v, p) = |N(v) ∩ V_p| * (1 - |V_p| / C)      C = capacity = N/P * slack

The stream is processed in *chunks* of vertices: one vectorized pass scores a
whole chunk against the current assignment snapshot (neighbor-partition
counts via one bincount over (row, partition) keys), then partition sizes
are refreshed between chunks — replacing the old per-vertex Python scoring
loop.  Within a chunk vertices don't see each other's placements (classic
batched-streaming approximation); the capacity penalty between chunks keeps
the balance property, and results stay deterministic at fixed seed.
"""
from __future__ import annotations

import numpy as np

from repro.core.partition.base import (
    DEFAULT_DIRECTION,
    PartitionerBase,
    PartitionPlan,
)
from repro.graph.graph import HeteroGraph
from repro.utils import csr_slots, incidence_csr

__all__ = ["LDGPartitioner", "ldg_edge_cut", "edge_cut_to_edge_assignment"]


def _neighbor_csr(g: HeteroGraph) -> tuple[np.ndarray, np.ndarray]:
    """Undirected neighbor CSR: vertex -> concatenated out+in neighbors."""
    return incidence_csr(g.num_vertices, [(g.src, g.dst), (g.dst, g.src)])


def ldg_edge_cut(
    g: HeteroGraph,
    num_parts: int,
    seed: int = 0,
    slack: float = 1.05,
    passes: int = 1,
    chunk: int = 256,
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = g.num_vertices
    P = num_parts
    cap = slack * n / P
    assign = np.full(n, -1, dtype=np.int16)
    sizes = np.zeros(P, dtype=np.int64)
    indptr, nbr = _neighbor_csr(g)
    deg = indptr[1:] - indptr[:-1]

    for _ in range(passes):
        perm = rng.permutation(n)
        for lo in range(0, n, chunk):
            vs = perm[lo : lo + chunk]
            olds = assign[vs]
            placed_old = olds[olds >= 0]
            if placed_old.shape[0]:
                sizes -= np.bincount(placed_old, minlength=P)
            lens = deg[vs]
            rows = np.repeat(np.arange(vs.shape[0], dtype=np.int64), lens)
            nbrs = nbr[csr_slots(indptr, vs)]
            placed = assign[nbrs]
            ok = placed >= 0
            counts = np.bincount(
                rows[ok] * P + placed[ok], minlength=vs.shape[0] * P
            ).reshape(vs.shape[0], P)
            fill = 1.0 - sizes / cap
            score = counts * np.maximum(0.0, fill) + 1e-9 * fill
            p = np.argmax(score, axis=1).astype(np.int16)
            assign[vs] = p
            sizes += np.bincount(p, minlength=P)
    return assign


def edge_cut_to_edge_assignment(
    g: HeteroGraph,
    vertex_parts: np.ndarray,
    local_direction: str = DEFAULT_DIRECTION,
) -> np.ndarray:
    """An edge lives on the partition of the vertex whose ``local_direction``
    one-hop must be answered locally.  The default follows the stack-wide
    ``DEFAULT_DIRECTION`` so hand-wired baselines sample coherently with the
    clients' default; pass ``"in"`` for the strict DistDGL convention
    (edges assigned by DESTINATION owner, in-sampling never leaves the
    server) together with ``direction="in"`` sampling."""
    if local_direction not in ("in", "out"):
        raise ValueError(f"local_direction must be 'in' or 'out', got {local_direction!r}")
    anchor = g.dst if local_direction == "in" else g.src
    return vertex_parts[anchor].astype(np.int16)


class LDGPartitioner(PartitionerBase):
    """LDG streaming edge-cut behind the ``Partitioner`` protocol: vertices
    get owners; edges follow the vertex whose ``direction`` one-hop must stay
    local (so GLISP-vs-baseline comparisons sample the same direction on both
    systems)."""

    name = "ldg"

    def __init__(self, slack: float = 1.05, passes: int = 1, chunk: int = 256):
        self.slack = slack
        self.passes = passes
        self.chunk = chunk

    @property
    def cache_token(self) -> str:
        return f"{self.name}:slack={self.slack}:passes={self.passes}:chunk={self.chunk}"

    def partition(
        self,
        g: HeteroGraph,
        num_parts: int,
        *,
        seed: int = 0,
        direction: str = DEFAULT_DIRECTION,
    ) -> PartitionPlan:
        vp = ldg_edge_cut(
            g,
            num_parts,
            seed=seed,
            slack=self.slack,
            passes=self.passes,
            chunk=self.chunk,
        )
        ep = edge_cut_to_edge_assignment(g, vp, local_direction=direction)
        return PartitionPlan.from_assignment(
            g,
            ep,
            num_parts,
            vertex_owner=vp.astype(np.int64),
            partitioner=self.name,
            seed=seed,
        )
