"""Streaming Linear Deterministic Greedy (LDG) edge-cut partitioner.

Stand-in for the paper's ParMETIS baseline (METIS multilevel coarsening is out
of scope; LDG is the standard streaming edge-cut baseline and shows the same
failure mode on power-law graphs: cut-edge/halo redundancy and edge imbalance,
cf. DESIGN.md §6).  Assigns VERTICES to partitions:

    score(v, p) = |N(v) ∩ V_p| * (1 - |V_p| / C)      C = capacity = N/P * slack
"""
from __future__ import annotations

import numpy as np

from repro.core.sampling.service import DEFAULT_DIRECTION
from repro.graph.graph import HeteroGraph

__all__ = ["ldg_edge_cut", "edge_cut_to_edge_assignment"]


def ldg_edge_cut(
    g: HeteroGraph, num_parts: int, seed: int = 0, slack: float = 1.05, passes: int = 1
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = g.num_vertices
    cap = slack * n / num_parts
    assign = np.full(n, -1, dtype=np.int16)
    sizes = np.zeros(num_parts, dtype=np.int64)

    # undirected incidence
    indptr, order = g.out_csr()
    in_indptr, in_order = g.in_csr()

    for _ in range(passes):
        for v in rng.permutation(n):
            nbrs = np.concatenate(
                [
                    g.dst[order[indptr[v] : indptr[v + 1]]],
                    g.src[in_order[in_indptr[v] : in_indptr[v + 1]]],
                ]
            )
            old = assign[v]
            if old >= 0:
                sizes[old] -= 1
            counts = np.zeros(num_parts, dtype=np.int64)
            if nbrs.shape[0]:
                placed = assign[nbrs]
                placed = placed[placed >= 0]
                if placed.shape[0]:
                    counts = np.bincount(placed, minlength=num_parts)
            score = counts * np.maximum(0.0, 1.0 - sizes / cap) + 1e-9 * (
                1.0 - sizes / cap
            )
            p = int(np.argmax(score))
            assign[v] = p
            sizes[p] += 1
    return assign


def edge_cut_to_edge_assignment(
    g: HeteroGraph,
    vertex_parts: np.ndarray,
    local_direction: str = DEFAULT_DIRECTION,
) -> np.ndarray:
    """An edge lives on the partition of the vertex whose ``local_direction``
    one-hop must be answered locally.  The default follows the stack-wide
    ``DEFAULT_DIRECTION`` so hand-wired baselines sample coherently with the
    clients' default; pass ``"in"`` for the strict DistDGL convention
    (edges assigned by DESTINATION owner, in-sampling never leaves the
    server) together with ``direction="in"`` sampling."""
    if local_direction not in ("in", "out"):
        raise ValueError(f"local_direction must be 'in' or 'out', got {local_direction!r}")
    anchor = g.dst if local_direction == "in" else g.src
    return vertex_parts[anchor].astype(np.int16)
