"""Streaming Linear Deterministic Greedy (LDG) edge-cut partitioner.

Stand-in for the paper's ParMETIS baseline (METIS multilevel coarsening is out
of scope; LDG is the standard streaming edge-cut baseline and shows the same
failure mode on power-law graphs: cut-edge/halo redundancy and edge imbalance,
cf. DESIGN.md §6).  Assigns VERTICES to partitions:

    score(v, p) = |N(v) ∩ V_p| * (1 - |V_p| / C)      C = capacity = N/P * slack
"""
from __future__ import annotations

import numpy as np

from repro.graph.graph import HeteroGraph

__all__ = ["ldg_edge_cut", "edge_cut_to_edge_assignment"]


def ldg_edge_cut(
    g: HeteroGraph, num_parts: int, seed: int = 0, slack: float = 1.05, passes: int = 1
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = g.num_vertices
    cap = slack * n / num_parts
    assign = np.full(n, -1, dtype=np.int16)
    sizes = np.zeros(num_parts, dtype=np.int64)

    # undirected incidence
    indptr, order = g.out_csr()
    in_indptr, in_order = g.in_csr()

    for _ in range(passes):
        for v in rng.permutation(n):
            nbrs = np.concatenate(
                [
                    g.dst[order[indptr[v] : indptr[v + 1]]],
                    g.src[in_order[in_indptr[v] : in_indptr[v + 1]]],
                ]
            )
            old = assign[v]
            if old >= 0:
                sizes[old] -= 1
            counts = np.zeros(num_parts, dtype=np.int64)
            if nbrs.shape[0]:
                placed = assign[nbrs]
                placed = placed[placed >= 0]
                if placed.shape[0]:
                    counts = np.bincount(placed, minlength=num_parts)
            score = counts * np.maximum(0.0, 1.0 - sizes / cap) + 1e-9 * (
                1.0 - sizes / cap
            )
            p = int(np.argmax(score))
            assign[v] = p
            sizes[p] += 1
    return assign


def edge_cut_to_edge_assignment(g: HeteroGraph, vertex_parts: np.ndarray) -> np.ndarray:
    """DistDGL convention: an edge lives on the partition of its DESTINATION
    vertex (in-edges of owned vertices are local so one-hop in-sampling never
    leaves the server)."""
    return vertex_parts[g.dst].astype(np.int16)
