"""The partition→reorder→materialize pipeline with a content-addressed cache.

``GLISPSystem.build`` used to run the partitioner inline on every call — the
only build stage with no artifact reuse, and by far the most expensive one at
scale.  ``PartitionPipeline`` makes the three preprocessing stages explicit:

    1. **partition**   — any ``Partitioner`` registry entry -> ``PartitionPlan``
    2. **reorder**     — the per-vertex locality permutation (PDS/BFS/...)
       grouped by the plan's per-vertex partition
    3. **materialize** — ``build_partitions`` -> ``GraphPartition`` list

Stages 1-2 are pure functions of (graph content, pipeline config), so their
artifacts are cached on disk under a content-addressed key::

    sha256(graph arrays) + {partitioner, num_parts, seed, direction,
                            reorder, cache version}  ->  <key>.npz

A second ``run`` over the same graph+config loads the plan and permutation
in milliseconds and reports ``cache_hit=True``; repeated training/inference
runs skip repartitioning entirely.  Materialization is recomputed (it is
deterministic given the plan and an order of magnitude cheaper than
partitioning).  Bump ``CACHE_VERSION`` when a partitioner's algorithm
changes so stale artifacts can never resurrect.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
import zipfile
from dataclasses import dataclass, field

import numpy as np

from repro.core.partition.base import (
    DEFAULT_DIRECTION,
    PARTITIONERS,
    Partitioner,
    PartitionPlan,
)
from repro.graph.graph import GraphPartition, HeteroGraph, build_partitions
from repro.graph.reorder import REORDER_ALGS, reorder_permutation

__all__ = ["PartitionPipeline", "PipelineResult", "graph_fingerprint"]

CACHE_VERSION = 1


def graph_fingerprint(g: HeteroGraph) -> str:
    """Content hash of the graph structure (the partition/reorder inputs)."""
    h = hashlib.sha256()
    h.update(np.int64(g.num_vertices).tobytes())
    for arr in (g.src, g.dst, g.edge_types, g.vertex_types):
        h.update(np.ascontiguousarray(arr).tobytes())
    if g.edge_weights is not None:
        h.update(np.ascontiguousarray(g.edge_weights).tobytes())
    return h.hexdigest()


def derive_vertex_partition(g: HeteroGraph, plan: PartitionPlan) -> np.ndarray:
    """Per-vertex partition id used as the reorder grouping key: the plan's
    ``vertex_owner`` when the partitioner produced one, else the lowest-id
    hosting partition of the vertex-cut assignment (deterministic, one
    vectorized scatter-min over the edge endpoints)."""
    if plan.vertex_owner is not None:
        return plan.vertex_owner.astype(np.int64)
    sentinel = np.iinfo(np.int64).max
    owner = np.full(g.num_vertices, sentinel, dtype=np.int64)
    ep = plan.edge_parts.astype(np.int64)
    np.minimum.at(owner, g.src, ep)
    np.minimum.at(owner, g.dst, ep)
    owner[owner == sentinel] = 0  # isolated vertices
    return owner


@dataclass
class PipelineResult:
    plan: PartitionPlan
    perm: np.ndarray  # reorder permutation: perm[new_id] = old vertex id
    partitions: list[GraphPartition]
    seconds: dict = field(default_factory=dict)  # stage -> wall seconds
    cache_hit: bool = False
    cache_key: str | None = None

    @property
    def partition_seconds(self) -> float:
        return self.seconds.get("partition", 0.0)


class PartitionPipeline:
    """Explicit three-stage preprocessing pipeline (see module docstring).

    ``partitioner`` is a registry name or any ``Partitioner`` instance;
    ``cache_dir=None`` disables the artifact cache (every run computes)."""

    def __init__(
        self,
        partitioner: str | Partitioner,
        num_parts: int,
        *,
        reorder: str = "pds",
        seed: int = 0,
        direction: str = DEFAULT_DIRECTION,
        cache_dir: str | None = None,
    ):
        if isinstance(partitioner, str):
            partitioner = PARTITIONERS.get(partitioner)
        self.partitioner = partitioner
        if num_parts <= 0:
            raise ValueError(f"num_parts must be positive, got {num_parts}")
        self.num_parts = int(num_parts)
        alg = reorder.upper()
        if alg not in REORDER_ALGS:
            raise ValueError(
                f"reorder must be one of {REORDER_ALGS}, got {reorder!r}"
            )
        self.reorder = alg
        self.seed = int(seed)
        self.direction = direction
        self.cache_dir = cache_dir

    # ------------------------------------------------------------------
    def cache_key(self, g: HeteroGraph) -> str:
        # the partitioner contributes its cache_token (name + every
        # hyperparameter that changes the plan), so differently-configured
        # instances of the same algorithm never share an artifact
        part = self.partitioner
        token = getattr(
            part, "cache_token", getattr(part, "name", type(part).__name__)
        )
        cfg = {
            "v": CACHE_VERSION,
            "partitioner": str(token),
            "num_parts": self.num_parts,
            "seed": self.seed,
            "direction": self.direction,
            "reorder": self.reorder,
        }
        h = hashlib.sha256(graph_fingerprint(g).encode())
        h.update(json.dumps(cfg, sort_keys=True).encode())
        return h.hexdigest()[:32]

    def _cache_path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"glisp-partition-{key}.npz")

    # ------------------------------------------------------------------
    def _load(self, path: str) -> tuple[PartitionPlan, np.ndarray] | None:
        if not os.path.exists(path):
            return None
        try:
            with np.load(path, allow_pickle=False) as z:
                meta = json.loads(str(z["meta"]))
                plan = PartitionPlan(
                    edge_parts=z["edge_parts"],
                    vertex_owner=(
                        z["vertex_owner"] if "vertex_owner" in z.files else None
                    ),
                    num_parts=meta["num_parts"],
                    partitioner=meta["partitioner"],
                    seed=meta["seed"],
                    edge_counts=z["edge_counts"],
                    vertex_counts=z["vertex_counts"],
                    replication_factor=meta["rf"],
                    vertex_balance=meta["vb"],
                    edge_balance=meta["eb"],
                )
                return plan, z["perm"]
        except (
            OSError,
            EOFError,
            KeyError,
            ValueError,
            zipfile.BadZipFile,
            json.JSONDecodeError,
        ):
            return None  # unreadable/corrupt artifact: recompute

    def _save(self, path: str, plan: PartitionPlan, perm: np.ndarray) -> None:
        os.makedirs(self.cache_dir, exist_ok=True)
        meta = {
            "num_parts": plan.num_parts,
            "partitioner": plan.partitioner,
            "seed": plan.seed,
            "rf": plan.replication_factor,
            "vb": plan.vertex_balance,
            "eb": plan.edge_balance,
        }
        arrays = {
            "edge_parts": plan.edge_parts,
            "perm": perm,
            "edge_counts": plan.edge_counts,
            "vertex_counts": plan.vertex_counts,
            "meta": np.array(json.dumps(meta)),
        }
        if plan.vertex_owner is not None:
            arrays["vertex_owner"] = plan.vertex_owner
        tmp = f"{path}.tmp-{os.getpid()}"
        with open(tmp, "wb") as fh:
            np.savez(fh, **arrays)
        os.replace(tmp, path)  # atomic publish: concurrent runs never torn

    # ------------------------------------------------------------------
    def _reorder_perm(self, g: HeteroGraph, plan: PartitionPlan) -> np.ndarray:
        owner = derive_vertex_partition(g, plan)
        deg = g.out_degrees() + g.in_degrees()
        indptr = indices = None
        if self.reorder == "BFS":
            indptr, order = g.out_csr()
            indices = g.dst[order]
        return reorder_permutation(
            self.reorder,
            global_ids=np.arange(g.num_vertices, dtype=np.int64),
            degrees=deg,
            partition_ids=owner,
            indptr=indptr,
            indices=indices,
            seed=self.seed,
        )

    def run(self, g: HeteroGraph) -> PipelineResult:
        seconds: dict = {}
        key = path = None
        plan = perm = None
        cache_hit = False
        if self.cache_dir is not None:
            key = self.cache_key(g)
            path = self._cache_path(key)
            t0 = time.perf_counter()
            loaded = self._load(path)
            if loaded is not None:
                plan, perm = loaded
                cache_hit = True
                seconds["partition"] = time.perf_counter() - t0
                seconds["reorder"] = 0.0
        if plan is None:
            t0 = time.perf_counter()
            plan = self.partitioner.partition(
                g, self.num_parts, seed=self.seed, direction=self.direction
            )
            seconds["partition"] = time.perf_counter() - t0
            t0 = time.perf_counter()
            perm = self._reorder_perm(g, plan)
            seconds["reorder"] = time.perf_counter() - t0
            if path is not None:
                self._save(path, plan, perm)
        t0 = time.perf_counter()
        parts = build_partitions(g, plan.edge_parts, self.num_parts)
        seconds["materialize"] = time.perf_counter() - t0
        return PipelineResult(
            plan=plan,
            perm=perm,
            partitions=parts,
            seconds=seconds,
            cache_hit=cache_hit,
            cache_key=key,
        )
