from repro.core.partition.hash_part import (
    random_edge_partition,
    hash2d_partition,
    vertex_hash_partition,
)
from repro.core.partition.ldg import ldg_edge_cut, edge_cut_to_edge_assignment
from repro.core.partition.dne import NeighborExpansionPartitioner, distributed_ne, adadne

__all__ = [
    "random_edge_partition",
    "hash2d_partition",
    "vertex_hash_partition",
    "ldg_edge_cut",
    "edge_cut_to_edge_assignment",
    "NeighborExpansionPartitioner",
    "distributed_ne",
    "adadne",
]
