"""``repro.core.partition`` — the balanced graph-partitioning subsystem.

One protocol (``Partitioner``), one artifact (``PartitionPlan``), one
registry (``PARTITIONERS``), and an explicit cached pipeline
(``PartitionPipeline``: partition -> reorder -> materialize).  Registered
entries::

    adadne / dne            lockstep-vectorized neighbor expansion (paper §III-B)
    adadne_loop / dne_loop  sequential reference implementations (benchmarks,
                            statistical-equivalence gate for the vectorized path)
    ldg                     chunked streaming edge-cut baseline (vertex owners)
    hash2d / random         hash baselines

The legacy free functions (``adadne``, ``distributed_ne``, ``ldg_edge_cut``,
...) remain as shims returning raw assignments; see docs/api.md for the
migration table.
"""
from repro.core.partition.base import (
    PARTITIONERS,
    Partitioner,
    PartitionerBase,
    PartitionPlan,
    hosted_vertex_counts,
)
from repro.core.partition.hash_part import (
    Hash2DPartitioner,
    RandomEdgePartitioner,
    hash2d_partition,
    random_edge_partition,
    vertex_hash_partition,
)
from repro.core.partition.ldg import (
    LDGPartitioner,
    edge_cut_to_edge_assignment,
    ldg_edge_cut,
)
from repro.core.partition.dne import (
    NEConfig,
    NeighborExpansionPartitioner,
    adadne,
    distributed_ne,
)
from repro.core.partition.pipeline import (
    PartitionPipeline,
    PipelineResult,
    graph_fingerprint,
)

# -- registry population (one configured instance per entry) ----------------
for _p in (
    NeighborExpansionPartitioner(adaptive=True),
    NeighborExpansionPartitioner(adaptive=True, mode="loop"),
    NeighborExpansionPartitioner(adaptive=False),
    NeighborExpansionPartitioner(adaptive=False, mode="loop"),
    LDGPartitioner(),
    Hash2DPartitioner(),
    RandomEdgePartitioner(),
):
    if _p.name not in PARTITIONERS:  # idempotent under module reload
        PARTITIONERS.register(_p.name, _p)
del _p

__all__ = [
    "PARTITIONERS",
    "Partitioner",
    "PartitionerBase",
    "PartitionPlan",
    "PartitionPipeline",
    "PipelineResult",
    "NEConfig",
    "NeighborExpansionPartitioner",
    "LDGPartitioner",
    "Hash2DPartitioner",
    "RandomEdgePartitioner",
    "graph_fingerprint",
    "hosted_vertex_counts",
    "random_edge_partition",
    "hash2d_partition",
    "vertex_hash_partition",
    "ldg_edge_cut",
    "edge_cut_to_edge_assignment",
    "adadne",
    "distributed_ne",
]
