"""Neighbor-expansion vertex-cut partitioners: DistributedNE and AdaDNE.

DistributedNE (Hanai et al., VLDB'19): every partition greedily expands an
edge set from seed vertices; per iteration it (1) selects the λ·|B_p|
smallest-degree boundary vertices, (2) allocates their unallocated incident
edges (one-hop allocation), (3) allocates unallocated edges whose two
endpoints already share a partition to the common partition with the fewest
edges (two-hop allocation), and (4) stops expanding a partition when
|E_p| > τ·|E|/|P|.

AdaDNE (the paper's contribution): replaces the hard edge threshold with an
*adaptive expansion factor* — per iteration and partition

    VS_p = |P|·|V_p| / Σ_q |V_q|          (5)
    ES_p = |P|·|E_p| / Σ_q |E_q|          (6)
    λ_p  <- λ_p · exp(α(1−VS_p) + β(1−ES_p))   (7)

so over-full partitions expand slower and under-full ones faster, giving soft
constraints on BOTH vertex and edge balance (the hard threshold is removed,
equivalent to τ = |P|).

Two execution modes share the config and the greedy policy:

``mode="lockstep"`` (default) simulates the P logical workers the way the
paper's cluster actually runs them — one *batched* expansion step per
iteration.  All partitions select their smallest-degree boundary candidates
against the same snapshot, their one-hop edge claims are resolved in one
vectorized pass (per contested edge the lowest-|E_p| partition wins, ties
broken by lower partition id via lexsort — the same greedy preference the
sequential code expresses), and membership/boundary bookkeeping is one
grouped update over (partition, vertex) pairs.  Candidate pools are kept
sorted by a static (degree, id) rank, so smallest-degree-first selection is
a prefix cut and appending new boundary vertices is a vectorized sorted
merge; no per-partition Python inner loop ever touches edges or vertices,
and nothing re-sorts or re-scans a full candidate set per iteration.

``mode="loop"`` preserves the original sequential reference implementation
(partition p sees partition p-1's allocations within the same iteration)
for before/after benchmarking and as the statistical-equivalence gate for
the lockstep rewrite.

Partition membership is a uint64 bitmask per vertex (P ≤ 64), making the
two-hop common-partition test a vectorized AND in both modes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.core.partition.base import (
    DEFAULT_DIRECTION,
    PartitionerBase,
    PartitionPlan,
)
from repro.graph.graph import HeteroGraph
from repro.utils import concat_ranges, csr_slots, incidence_csr

__all__ = [
    "NEConfig",
    "NeighborExpansionPartitioner",
    "distributed_ne",
    "adadne",
]

NE_MODES = ("lockstep", "loop")


@dataclass(frozen=True)
class NEConfig:
    # ``num_parts``/``seed`` are legacy defaults for the class-level call
    # style; the protocol call ``partition(g, num_parts, seed=...)``
    # overrides both.
    num_parts: int = 0
    adaptive: bool = False  # False -> DistributedNE, True -> AdaDNE
    lam0: float = 0.1  # initial expansion factor (DNE default)
    tau: float = 1.1  # DNE imbalance factor (ignored when adaptive)
    alpha: float = 1.0  # AdaDNE vertex-score weight
    beta: float = 1.0  # AdaDNE edge-score weight
    seed: int = 0
    max_iters: int = 100_000
    verbose: bool = False
    # Per-iteration per-partition edge-allocation budget as a fraction of
    # |E|/|P|.  The paper's clusters take thousands of fine-grained iterations
    # on billion-edge graphs; at laptop scale one unbudgeted iteration can
    # swallow 35% of the graph before the adaptive feedback (7) reacts.  The
    # budget restores the iteration granularity the algorithm assumes; it does
    # not change the expansion policy.
    budget_frac: float = 0.01
    mode: str = "lockstep"  # lockstep (vectorized) | loop (sequential legacy)
    trace: bool = True  # record the per-iteration convergence trace


# ---------------------------------------------------------------------------
# shared vectorized helpers (CSR machinery lives in ``repro.utils``)
# ---------------------------------------------------------------------------

_ranges = concat_ranges
_gather_slots = csr_slots


def _incidence(g: HeteroGraph) -> tuple[np.ndarray, np.ndarray]:
    """Undirected incidence CSR: vertex -> incident edge ids (out then in)."""
    eids = np.arange(g.num_edges, dtype=np.int64)
    return incidence_csr(g.num_vertices, [(g.src, eids), (g.dst, eids)])


def _iteration_budgets(
    lam: np.ndarray,
    bsize: np.ndarray,
    terminated: np.ndarray,
    E: int,
    budget_frac: float,
) -> np.ndarray:
    """Per-iteration edge-allocation budgets for ACTIVE partitions only.

    The continuum expansion speed of partition p is ∝ λ_p·|B_p|; one system
    iteration allocates ~budget_frac·|E| edges split proportionally, with a
    16-edge floor so tiny partitions still make progress.  Terminated
    partitions get exactly 0 — the old ``np.maximum(16, ...)`` over the full
    vector handed every partition DNE's hard threshold had already stopped a
    nonzero budget floor."""
    budgets = np.zeros(lam.shape[0], dtype=np.int64)
    active = ~terminated
    if not active.any():
        return budgets
    w = lam * np.maximum(bsize.astype(np.float64), 1.0)
    w = np.where(active, w, 0.0)
    w_norm = w / max(1e-12, float(w.sum()))
    budgets[active] = np.maximum(
        16, (budget_frac * E * w_norm[active])
    ).astype(np.int64)
    return budgets


def _flush_sequence(nE: np.ndarray, K: int) -> np.ndarray:
    """The partition sequence of ``for each of K edges: p = argmin(nE);
    nE[p] += 1`` — computed in closed form instead of an O(K·P) Python loop.

    The argmin-with-lowest-index-tiebreak greedy consumes "slots" in
    lexicographic (level, partition) order, where partition p offers slots at
    fill levels nE[p], nE[p]+1, ...; the answer is the first K slots of that
    stream.  Bit-identical to the sequential loop by construction."""
    P = int(nE.shape[0])
    if K <= 0:
        return np.zeros(0, dtype=np.int16)
    nE = nE.astype(np.int64)
    s_idx = np.argsort(nE, kind="stable")
    s = nE[s_idx]
    prefix = np.concatenate(([0], np.cumsum(s)))
    # cap_at[i] = number of slots strictly below level s[i]
    cap_at = np.arange(P, dtype=np.int64) * s - prefix[:P]
    i = int(np.searchsorted(cap_at, K, side="right")) - 1
    m = int(np.searchsorted(s, s[i], side="right"))  # parts with nE <= s[i]
    extra = K - int(cap_at[i])
    full_levels, rem = divmod(extra, m)
    level = int(s[i]) + full_levels
    fin = np.maximum(nE, level)
    active_parts = np.sort(s_idx[:m])
    fin[active_parts[:rem]] += 1
    addc = fin - nE
    part_rep = np.repeat(np.arange(P, dtype=np.int64), addc)
    levels = np.repeat(nE, addc) + _ranges(addc)
    order = np.lexsort((part_rep, levels))
    return part_rep[order].astype(np.int16)


class _TraceRecorder:
    """Per-iteration convergence trace -> dict of stacked arrays."""

    def __init__(self, enabled: bool):
        self.enabled = enabled
        self.remaining: list[int] = []
        self.edge_counts: list[np.ndarray] = []
        self.vertex_counts: list[np.ndarray] = []
        self.lam: list[np.ndarray] = []

    def record(self, remaining, nE, nV, lam) -> None:
        if not self.enabled:
            return
        self.remaining.append(int(remaining))
        self.edge_counts.append(nE.copy())
        self.vertex_counts.append(nV.copy())
        self.lam.append(lam.copy())

    def build(self, P: int) -> dict | None:
        if not self.enabled:
            return None
        if not self.remaining:
            z = np.zeros((0, P), dtype=np.int64)
            return {
                "remaining": np.zeros(0, dtype=np.int64),
                "edge_counts": z,
                "vertex_counts": z,
                "lam": np.zeros((0, P), dtype=np.float64),
            }
        return {
            "remaining": np.asarray(self.remaining, dtype=np.int64),
            "edge_counts": np.stack(self.edge_counts),
            "vertex_counts": np.stack(self.vertex_counts),
            "lam": np.stack(self.lam),
        }


# ---------------------------------------------------------------------------
# the partitioner
# ---------------------------------------------------------------------------


class NeighborExpansionPartitioner(PartitionerBase):
    """DistributedNE / AdaDNE behind the ``Partitioner`` protocol.

    ``cfg`` supplies the algorithm knobs; ``partition(g, num_parts,
    seed=...)`` overrides the legacy ``cfg.num_parts``/``cfg.seed`` defaults
    per call and returns a scored :class:`PartitionPlan` (the raw edge
    assignment lives in ``plan.edge_parts``)."""

    def __init__(self, cfg: NEConfig | None = None, **overrides):
        if cfg is None:
            cfg = NEConfig(**overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        if cfg.mode not in NE_MODES:
            raise ValueError(f"mode must be one of {NE_MODES}, got {cfg.mode!r}")
        if cfg.num_parts > 64:
            raise ValueError("bitmask implementation supports up to 64 partitions")
        self.cfg = cfg

    @property
    def name(self) -> str:
        base = "adadne" if self.cfg.adaptive else "dne"
        return base + ("_loop" if self.cfg.mode == "loop" else "")

    @property
    def cache_token(self) -> str:
        c = self.cfg
        return (
            f"{self.name}:lam0={c.lam0}:tau={c.tau}:alpha={c.alpha}"
            f":beta={c.beta}:budget={c.budget_frac}:iters={c.max_iters}"
        )

    # ------------------------------------------------------------------
    def partition(
        self,
        g: HeteroGraph,
        num_parts: int | None = None,
        *,
        seed: int | None = None,
        direction: str = DEFAULT_DIRECTION,
    ) -> PartitionPlan:
        cfg = self.cfg
        P = int(num_parts) if num_parts is not None else int(cfg.num_parts)
        if P <= 0:
            raise ValueError(f"num_parts must be positive, got {P}")
        if P > 64:
            raise ValueError("bitmask implementation supports up to 64 partitions")
        sd = int(cfg.seed if seed is None else seed)
        run = self._run_loop if cfg.mode == "loop" else self._run_lockstep
        edge_part, trace = run(g, P, sd)
        assert (edge_part >= 0).all()
        return PartitionPlan.from_assignment(
            g,
            edge_part,
            P,
            partitioner=self.name,
            seed=sd,
            iteration_trace=trace,
        )

    # ------------------------------------------------------------------
    # lockstep (vectorized) mode
    # ------------------------------------------------------------------
    def _run_lockstep(
        self, g: HeteroGraph, P: int, seed: int
    ) -> tuple[np.ndarray, dict | None]:
        cfg = self.cfg
        rng = np.random.default_rng(seed)
        E, N = g.num_edges, g.num_vertices
        deg = g.out_degrees() + g.in_degrees()
        inc_indptr, inc_eid = _incidence(g)
        # static selection key: rank of (degree, vertex id) — pools kept
        # sorted by it, so "smallest-degree-first" selection is a prefix cut
        vertex_of_rank = np.lexsort((np.arange(N), deg))
        rank = np.empty(N, dtype=np.int64)
        rank[vertex_of_rank] = np.arange(N)
        deg_by_rank = deg[vertex_of_rank]

        edge_part = np.full(E, -1, dtype=np.int16)
        mask = np.zeros(N, dtype=np.uint64)  # partition membership bitmask
        in_boundary = np.zeros((P, N), dtype=bool)
        # Per-partition candidate pools: sorted arrays of vertex RANKS (the
        # rank is unique, so it IS the vertex via ``vertex_of_rank``).  A
        # vertex enters a pool at most once (``in_boundary`` guard) and
        # selection always consumes a prefix, so pools never hold already-
        # expanded entries — no dense candidate matrices, no compaction.
        pools: list[np.ndarray] = [np.zeros(0, dtype=np.int64) for _ in range(P)]
        nE = np.zeros(P, dtype=np.int64)
        nV = np.zeros(P, dtype=np.int64)
        lam = np.full(P, cfg.lam0, dtype=np.float64)
        terminated = np.zeros(P, dtype=bool)
        Et = cfg.tau * E / P  # DNE hard threshold
        trace = _TraceRecorder(cfg.trace)

        seeds = rng.choice(N, size=P, replace=False)
        for p, s in enumerate(seeds):
            in_boundary[p, s] = True
            pools[p] = rank[np.array([s], dtype=np.int64)]

        remaining = E
        it = 0
        while remaining > 0 and it < cfg.max_iters:
            it += 1
            if cfg.adaptive:
                tot_v, tot_e = max(1, nV.sum()), max(1, nE.sum())
                vs = P * nV / tot_v
                es = P * nE / tot_e
                lam = lam * np.exp(cfg.alpha * (1.0 - vs) + cfg.beta * (1.0 - es))
                np.clip(lam, 1e-4, 1.0, out=lam)
            else:
                terminated = nE > Et
            active = ~terminated

            bsize = np.fromiter(
                (v.size for v in pools), dtype=np.int64, count=P
            )
            # reseed stalled active partitions from unallocated edges
            need = np.flatnonzero(active & (bsize == 0))
            if need.size:
                un = np.flatnonzero(edge_part == -1)
                if un.size:
                    picks = g.src[un[rng.integers(0, un.size, size=need.size)]]
                    for p, s in zip(need, picks):
                        if not in_boundary[p, s]:
                            in_boundary[p, s] = True
                        pools[p] = rank[np.array([s], dtype=np.int64)]
                        bsize[p] = 1
            budgets = _iteration_budgets(lam, bsize, terminated, E, cfg.budget_frac)

            # --- batched candidate selection -------------------------------
            # All partitions select against the same snapshot: partition p
            # takes the prefix of its rank-sorted pool limited by both
            # k = max(1, λ_p·|B_p|) and the budget's cumulative-degree cut
            # (identical ordering to the loop mode's stable degree argsort).
            sel_chunks: list[np.ndarray] = []
            sel_sizes: list[int] = []
            act = np.flatnonzero(active & (bsize > 0))
            for p in act:
                c = pools[p]
                k = min(c.size, max(1, int(lam[p] * c.size)))
                cap = min(k, int(budgets[p]) + 1)
                pre = c[:cap]
                cut = int(
                    np.searchsorted(
                        np.cumsum(deg_by_rank[pre]), budgets[p], side="left"
                    )
                ) + 1
                q = min(cap, cut)
                sel_chunks.append(vertex_of_rank[pre[:q]])
                sel_sizes.append(q)
                pools[p] = c[q:]
            progressed = False
            if sel_chunks:
                sv = np.concatenate(sel_chunks)
                sp = np.repeat(act, sel_sizes)

                # --- one-hop allocation with conflict resolution ----------
                lens = inc_indptr[sv + 1] - inc_indptr[sv]
                slots = np.repeat(inc_indptr[sv], lens) + _ranges(lens)
                eids = inc_eid[slots]
                owner = np.repeat(sp, lens)
                free = edge_part[eids] == -1
                eids, owner = eids[free], owner[free]
                if eids.size:
                    # per contested edge the lowest-|E_p| claimant wins,
                    # ties to the lower partition id (lexsort key order)
                    o = np.lexsort((owner, nE[owner], eids))
                    es_, os_ = eids[o], owner[o]
                    first = np.empty(es_.size, dtype=bool)
                    first[0] = True
                    first[1:] = es_[1:] != es_[:-1]
                    win_e, win_p = es_[first], os_[first]
                    edge_part[win_e] = win_p.astype(np.int16)
                    nE += np.bincount(win_p, minlength=P)
                    remaining -= win_e.size
                    progressed = True

                    # grouped membership + boundary update over unique
                    # (partition, endpoint) pairs
                    pv = np.concatenate([win_p, win_p])
                    vv = np.concatenate([g.src[win_e], g.dst[win_e]])
                    pk = np.unique(pv * np.int64(N) + vv)
                    up = pk // N
                    uv = pk % N
                    bitv = np.left_shift(np.uint64(1), up.astype(np.uint64))
                    fresh = (mask[uv] & bitv) == 0
                    nV += np.bincount(up[fresh], minlength=P)
                    # grouped OR into the membership bitmask (reduceat over
                    # vertex-sorted runs — ufunc.at is an order slower)
                    o2 = np.argsort(uv, kind="stable")
                    vs2, bs2 = uv[o2], bitv[o2]
                    heads = np.empty(vs2.size, dtype=bool)
                    heads[0] = True
                    heads[1:] = vs2[1:] != vs2[:-1]
                    starts2 = np.flatnonzero(heads)
                    mask[vs2[starts2]] |= np.bitwise_or.reduceat(bs2, starts2)
                    # vertices never seen by p before join its boundary pool
                    # (selected vertices are already in_boundary, so pools
                    # stay free of expanded entries)
                    newb = np.flatnonzero(~in_boundary[up, uv])
                    in_boundary[up[newb], uv[newb]] = True
                    # pairs are sorted by partition: one sorted-merge per pool
                    ub, starts = np.unique(up[newb], return_index=True)
                    stops = np.append(starts[1:], newb.size)
                    for j, p in enumerate(ub):
                        add_r = rank[uv[newb[starts[j] : stops[j]]]]
                        add_r.sort()
                        old = pools[p]
                        out = np.empty(old.size + add_r.size, dtype=np.int64)
                        idx = np.searchsorted(old, add_r) + np.arange(
                            add_r.size
                        )
                        out[idx] = add_r
                        keep_old = np.ones(out.size, dtype=bool)
                        keep_old[idx] = False
                        out[keep_old] = old
                        pools[p] = out

                    # --- two-hop allocation -------------------------------
                    # a free edge can only gain a common partition when an
                    # endpoint's membership CHANGED this round, and that
                    # endpoint is then in uv[fresh] — scanning only those is
                    # exhaustive and skips the re-gather of hub neighbor
                    # lists every round
                    touched = np.unique(uv[fresh])
                    te = inc_eid[_gather_slots(inc_indptr, touched)]
                    te = te[edge_part[te] == -1]
                    if te.size:
                        te = np.unique(te)
                    if te.size:
                        common = mask[g.src[te]] & mask[g.dst[te]]
                        has = common != 0
                        te, common = te[has], common[has]
                        if te.size:
                            bits = (
                                (common[:, None] >> np.arange(P, dtype=np.uint64))
                                & np.uint64(1)
                            ).astype(bool)
                            score = np.where(
                                bits, nE[None, :], np.iinfo(np.int64).max
                            )
                            pick = np.argmin(score, axis=1)
                            edge_part[te] = pick.astype(np.int16)
                            nE += np.bincount(pick, minlength=P)
                            remaining -= te.size

            if cfg.verbose:
                print(
                    f"it={it} rem={remaining} nE={nE.tolist()} nV={nV.tolist()} "
                    f"lam={np.round(lam, 4).tolist()}"
                )
            trace.record(remaining, nE, nV, lam)
            if not progressed:
                remaining = self._flush(edge_part, nE)
        if remaining > 0:  # max_iters exhausted
            self._flush(edge_part, nE)
        return edge_part, trace.build(P)

    # ------------------------------------------------------------------
    # sequential (legacy reference) mode
    # ------------------------------------------------------------------
    def _run_loop(
        self, g: HeteroGraph, P: int, seed: int
    ) -> tuple[np.ndarray, dict | None]:
        cfg = self.cfg
        rng = np.random.default_rng(seed)
        E, N = g.num_edges, g.num_vertices
        deg = g.out_degrees() + g.in_degrees()
        inc_indptr, inc_eid = _incidence(g)
        edge_part = np.full(E, -1, dtype=np.int16)
        mask = np.zeros(N, dtype=np.uint64)
        boundary = np.zeros((P, N), dtype=bool)
        expanded = np.zeros((P, N), dtype=bool)
        nE = np.zeros(P, dtype=np.int64)
        nV = np.zeros(P, dtype=np.int64)
        lam = np.full(P, cfg.lam0, dtype=np.float64)
        terminated = np.zeros(P, dtype=bool)
        Et = cfg.tau * E / P
        trace = _TraceRecorder(cfg.trace)

        seeds = rng.choice(N, size=P, replace=False)
        for p, s in enumerate(seeds):
            boundary[p, s] = True

        remaining = E
        it = 0
        while remaining > 0 and it < cfg.max_iters:
            it += 1
            if cfg.adaptive:
                tot_v, tot_e = max(1, nV.sum()), max(1, nE.sum())
                vs = P * nV / tot_v
                es = P * nE / tot_e
                lam = lam * np.exp(cfg.alpha * (1.0 - vs) + cfg.beta * (1.0 - es))
                np.clip(lam, 1e-4, 1.0, out=lam)
            else:
                terminated = nE > Et

            progressed = False
            newly_touched: list[np.ndarray] = []
            bsize = np.array(
                [
                    np.count_nonzero(boundary[p] & ~expanded[p])
                    for p in range(P)
                ],
                dtype=np.int64,
            )
            budgets = _iteration_budgets(lam, bsize, terminated, E, cfg.budget_frac)
            for p in range(P):
                if terminated[p]:
                    continue
                cand = np.flatnonzero(boundary[p] & ~expanded[p])
                if cand.shape[0] == 0:
                    # reseed from an unallocated edge
                    un = np.flatnonzero(edge_part == -1)
                    if un.shape[0] == 0:
                        continue
                    s = g.src[un[rng.integers(0, un.shape[0])]]
                    boundary[p, s] = True
                    cand = np.array([s])
                k = max(1, int(lam[p] * cand.shape[0]))
                k = min(k, cand.shape[0])
                # smallest-degree-first selection (DNE heuristic)
                sel = cand[np.argsort(deg[cand], kind="stable")[:k]]
                # iteration-granularity edge budget: cut the selection prefix
                # whose incident-degree sum fits the budget
                budget = int(budgets[p])
                cum = np.cumsum(deg[sel])
                cut = int(np.searchsorted(cum, budget, side="left")) + 1
                sel = sel[:cut]
                expanded[p, sel] = True

                # one-hop allocation: unallocated incident edges of sel -> p
                slots = _gather_slots(inc_indptr, sel)
                eids = inc_eid[slots]
                un = eids[edge_part[eids] == -1]
                if un.shape[0]:
                    un = np.unique(un)
                    edge_part[un] = p
                    nE[p] += un.shape[0]
                    remaining -= un.shape[0]
                    progressed = True
                    ends = np.concatenate([g.src[un], g.dst[un]])
                    ends = np.unique(ends)
                    bit = np.uint64(1 << p)
                    fresh = (mask[ends] & bit) == 0
                    nV[p] += int(fresh.sum())
                    mask[ends] |= bit
                    newb = ends[~expanded[p, ends]]
                    boundary[p, newb] = True
                    newly_touched.append(ends)

            # two-hop allocation: unallocated edges whose endpoints share a
            # partition go to the common partition with fewest edges
            if newly_touched:
                touched = np.unique(np.concatenate(newly_touched))
                slots = _gather_slots(inc_indptr, touched)
                eids = np.unique(inc_eid[slots])
                eids = eids[edge_part[eids] == -1]
                if eids.shape[0]:
                    common = mask[g.src[eids]] & mask[g.dst[eids]]
                    has = common != 0
                    eids, common = eids[has], common[has]
                    if eids.shape[0]:
                        # greedy by ascending |E_p| ≈ argmin over common set
                        done = np.zeros(eids.shape[0], dtype=bool)
                        for p in np.argsort(nE):
                            bit = np.uint64(1 << int(p))
                            hit = (~done) & ((common & bit) != 0)
                            cnt = int(hit.sum())
                            if cnt == 0:
                                continue
                            sel_e = eids[hit]
                            edge_part[sel_e] = p
                            nE[p] += cnt
                            remaining -= cnt
                            done |= hit
                            progressed = True
                        # endpoints already members; no new vertices

            if cfg.verbose:
                print(
                    f"it={it} rem={remaining} nE={nE.tolist()} nV={nV.tolist()} "
                    f"lam={np.round(lam, 4).tolist()}"
                )
            trace.record(remaining, nE, nV, lam)
            if not progressed:
                remaining = self._flush(edge_part, nE)
        if remaining > 0:
            self._flush(edge_part, nE)
        return edge_part, trace.build(P)

    # ------------------------------------------------------------------
    @staticmethod
    def _flush(edge_part: np.ndarray, nE: np.ndarray) -> int:
        """Stall flush: spread every unallocated edge greedily onto the
        least-loaded partition — the closed-form :func:`_flush_sequence`
        replaces the old O(E·P) per-edge argmin loop bit-identically.
        Returns the new ``remaining`` count (always 0)."""
        un = np.flatnonzero(edge_part == -1)
        if un.shape[0]:
            seq = _flush_sequence(nE, un.shape[0])
            edge_part[un] = seq
            nE += np.bincount(seq, minlength=nE.shape[0])
        return 0


# ---------------------------------------------------------------------------
# legacy free-function shims (kept one release of deprecation; they return
# the RAW edge assignment — new call sites should use the registry entries,
# which return a scored ``PartitionPlan``)
# ---------------------------------------------------------------------------


def distributed_ne(
    g: HeteroGraph,
    num_parts: int,
    tau: float = 1.1,
    lam: float = 0.1,
    seed: int = 0,
    mode: str = "lockstep",
) -> np.ndarray:
    """DEPRECATED: ``PARTITIONERS.get("dne").partition(...).edge_parts``."""
    return NeighborExpansionPartitioner(
        NEConfig(adaptive=False, tau=tau, lam0=lam, mode=mode)
    ).partition(g, num_parts, seed=seed).edge_parts


def adadne(
    g: HeteroGraph,
    num_parts: int,
    lam: float = 0.1,
    alpha: float = 1.0,
    beta: float = 1.0,
    seed: int = 0,
    mode: str = "lockstep",
) -> np.ndarray:
    """DEPRECATED: ``PARTITIONERS.get("adadne").partition(...).edge_parts``."""
    return NeighborExpansionPartitioner(
        NEConfig(adaptive=True, lam0=lam, alpha=alpha, beta=beta, mode=mode)
    ).partition(g, num_parts, seed=seed).edge_parts
