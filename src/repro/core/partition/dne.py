"""Neighbor-expansion vertex-cut partitioners: DistributedNE and AdaDNE.

DistributedNE (Hanai et al., VLDB'19): every partition greedily expands an
edge set from seed vertices; per iteration it (1) selects the λ·|B_p|
smallest-degree boundary vertices, (2) allocates their unallocated incident
edges (one-hop allocation), (3) allocates unallocated edges whose two
endpoints already share a partition to the common partition with the fewest
edges (two-hop allocation), and (4) stops expanding a partition when
|E_p| > τ·|E|/|P|.

AdaDNE (the paper's contribution): replaces the hard edge threshold with an
*adaptive expansion factor* — per iteration and partition

    VS_p = |P|·|V_p| / Σ_q |V_q|          (5)
    ES_p = |P|·|E_p| / Σ_q |E_q|          (6)
    λ_p  <- λ_p · exp(α(1−VS_p) + β(1−ES_p))   (7)

so over-full partitions expand slower and under-full ones faster, giving soft
constraints on BOTH vertex and edge balance (the hard threshold is removed,
equivalent to τ = |P|).

The P logical workers are simulated in lockstep; partition membership is a
uint64 bitmask per vertex (P ≤ 64), making the two-hop common-partition test
a vectorized AND.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.graph import HeteroGraph

__all__ = ["NeighborExpansionPartitioner", "distributed_ne", "adadne"]


@dataclass
class NEConfig:
    num_parts: int
    adaptive: bool = False  # False -> DistributedNE, True -> AdaDNE
    lam0: float = 0.1  # initial expansion factor (DNE default)
    tau: float = 1.1  # DNE imbalance factor (ignored when adaptive)
    alpha: float = 1.0  # AdaDNE vertex-score weight
    beta: float = 1.0  # AdaDNE edge-score weight
    seed: int = 0
    max_iters: int = 100_000
    verbose: bool = False
    # Per-iteration per-partition edge-allocation budget as a fraction of
    # |E|/|P|.  The paper's clusters take thousands of fine-grained iterations
    # on billion-edge graphs; at laptop scale one unbudgeted iteration can
    # swallow 35% of the graph before the adaptive feedback (7) reacts.  The
    # budget restores the iteration granularity the algorithm assumes; it does
    # not change the expansion policy.
    budget_frac: float = 0.01


class NeighborExpansionPartitioner:
    def __init__(self, cfg: NEConfig):
        if cfg.num_parts > 64:
            raise ValueError("bitmask implementation supports up to 64 partitions")
        self.cfg = cfg

    # ------------------------------------------------------------------
    def partition(self, g: HeteroGraph) -> np.ndarray:
        cfg = self.cfg
        P = cfg.num_parts
        rng = np.random.default_rng(cfg.seed)
        E, N = g.num_edges, g.num_vertices

        # undirected incidence CSR: vertex -> (edge ids)
        deg_out = g.out_degrees()
        deg_in = g.in_degrees()
        deg = deg_out + deg_in
        inc_indptr = np.zeros(N + 1, dtype=np.int64)
        np.cumsum(deg, out=inc_indptr[1:])
        inc_eid = np.empty(2 * E, dtype=np.int64)
        # fill out-edge slots then in-edge slots, vectorized per pass
        inc_eid_list_ptr = inc_indptr[:-1].copy()
        for arr_v, arr_e in ((g.src, np.arange(E)), (g.dst, np.arange(E))):
            srt = np.argsort(arr_v, kind="stable")
            vs = arr_v[srt]
            es = arr_e[srt]
            # contiguous runs per vertex
            starts = np.searchsorted(vs, np.arange(N))
            ends = np.searchsorted(vs, np.arange(N) + 1)
            lens = ends - starts
            dest = np.repeat(inc_eid_list_ptr, lens) + _ranges(lens)
            inc_eid[dest] = es
            inc_eid_list_ptr = inc_eid_list_ptr + lens
        edge_part = np.full(E, -1, dtype=np.int16)
        mask = np.zeros(N, dtype=np.uint64)  # partition membership bitmask
        boundary = np.zeros((P, N), dtype=bool)
        expanded = np.zeros((P, N), dtype=bool)
        nE = np.zeros(P, dtype=np.int64)
        nV = np.zeros(P, dtype=np.int64)
        lam = np.full(P, cfg.lam0, dtype=np.float64)
        terminated = np.zeros(P, dtype=bool)
        Et = cfg.tau * E / P  # DNE hard threshold

        # initial seeds: distinct random vertices
        seeds = rng.choice(N, size=P, replace=False)
        for p, s in enumerate(seeds):
            boundary[p, s] = True

        remaining = E
        it = 0
        while remaining > 0 and it < cfg.max_iters:
            it += 1
            if cfg.adaptive:
                tot_v, tot_e = max(1, nV.sum()), max(1, nE.sum())
                vs = P * nV / tot_v
                es = P * nE / tot_e
                lam = lam * np.exp(cfg.alpha * (1.0 - vs) + cfg.beta * (1.0 - es))
                np.clip(lam, 1e-4, 1.0, out=lam)
            else:
                terminated = nE > Et

            progressed = False
            newly_touched: list[np.ndarray] = []
            # Budget per partition this iteration.  The continuum expansion
            # speed of partition p is proportional to λ_p·|B_p| (the number of
            # vertices it expands); we discretize so one system iteration
            # allocates ~budget_frac·|E| edges total, split across partitions
            # proportionally to λ_p·|B_p|.  For DNE (λ constant) speed is then
            # ∝ |B_p| with the hard threshold as the only balance control; for
            # AdaDNE the adaptive λ_p modulates the speed (the soft constraint).
            bsize = np.array(
                [
                    np.count_nonzero(boundary[p] & ~expanded[p])
                    for p in range(P)
                ],
                dtype=np.float64,
            )
            w = lam * np.maximum(bsize, 1.0)
            w[terminated] = 0.0
            w_norm = w / max(1e-12, w.sum())
            budgets = np.maximum(16, (cfg.budget_frac * E * w_norm)).astype(np.int64)
            for p in range(P):
                if terminated[p]:
                    continue
                cand = np.flatnonzero(boundary[p] & ~expanded[p])
                if cand.shape[0] == 0:
                    # reseed from an unallocated edge
                    un = np.flatnonzero(edge_part == -1)
                    if un.shape[0] == 0:
                        continue
                    s = g.src[un[rng.integers(0, un.shape[0])]]
                    boundary[p, s] = True
                    cand = np.array([s])
                k = max(1, int(lam[p] * cand.shape[0]))
                k = min(k, cand.shape[0])
                # smallest-degree-first selection (DNE heuristic)
                sel = cand[np.argsort(deg[cand], kind="stable")[:k]]
                # iteration-granularity edge budget: cut the selection prefix
                # whose incident-degree sum fits the budget
                budget = int(budgets[p])
                cum = np.cumsum(deg[sel])
                cut = int(np.searchsorted(cum, budget, side="left")) + 1
                sel = sel[:cut]
                expanded[p, sel] = True

                # one-hop allocation: unallocated incident edges of sel -> p
                slots = _gather_slots(inc_indptr, sel)
                eids = inc_eid[slots]
                un = eids[edge_part[eids] == -1]
                if un.shape[0]:
                    un = np.unique(un)
                    edge_part[un] = p
                    nE[p] += un.shape[0]
                    remaining -= un.shape[0]
                    progressed = True
                    ends = np.concatenate([g.src[un], g.dst[un]])
                    ends = np.unique(ends)
                    bit = np.uint64(1 << p)
                    fresh = (mask[ends] & bit) == 0
                    nV[p] += int(fresh.sum())
                    mask[ends] |= bit
                    newb = ends[~expanded[p, ends]]
                    boundary[p, newb] = True
                    newly_touched.append(ends)

            # two-hop allocation: unallocated edges whose endpoints share a
            # partition go to the common partition with fewest edges
            if newly_touched:
                touched = np.unique(np.concatenate(newly_touched))
                slots = _gather_slots(inc_indptr, touched)
                eids = np.unique(inc_eid[slots])
                eids = eids[edge_part[eids] == -1]
                if eids.shape[0]:
                    common = mask[g.src[eids]] & mask[g.dst[eids]]
                    has = common != 0
                    eids, common = eids[has], common[has]
                    if eids.shape[0]:
                        # greedy by ascending |E_p| ≈ argmin over common set
                        done = np.zeros(eids.shape[0], dtype=bool)
                        for p in np.argsort(nE):
                            bit = np.uint64(1 << int(p))
                            hit = (~done) & ((common & bit) != 0)
                            cnt = int(hit.sum())
                            if cnt == 0:
                                continue
                            sel_e = eids[hit]
                            edge_part[sel_e] = p
                            nE[p] += cnt
                            remaining -= cnt
                            done |= hit
                            progressed = True
                        # endpoints already members; no new vertices

            if cfg.verbose:
                print(
                    f"it={it} rem={remaining} nE={nE.tolist()} nV={nV.tolist()} "
                    f"lam={np.round(lam, 4).tolist()}"
                )
            if not progressed:
                # stalled (e.g. all DNE partitions terminated): flush the rest
                un = np.flatnonzero(edge_part == -1)
                if un.shape[0] == 0:
                    break
                for e in un:
                    p = int(np.argmin(nE))
                    edge_part[e] = p
                    nE[p] += 1
                remaining = 0
        assert (edge_part >= 0).all()
        return edge_part


def _ranges(lens: np.ndarray) -> np.ndarray:
    """[0..lens[0]) ++ [0..lens[1]) ++ ... as one array."""
    if lens.shape[0] == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(lens)
    out = np.arange(ends[-1], dtype=np.int64)
    out -= np.repeat(ends - lens, lens)
    return out


def _gather_slots(indptr: np.ndarray, verts: np.ndarray) -> np.ndarray:
    """Concatenated CSR slot ranges of ``verts``."""
    lens = indptr[verts + 1] - indptr[verts]
    return np.repeat(indptr[verts], lens) + _ranges(lens)


def distributed_ne(
    g: HeteroGraph, num_parts: int, tau: float = 1.1, lam: float = 0.1, seed: int = 0
) -> np.ndarray:
    return NeighborExpansionPartitioner(
        NEConfig(num_parts=num_parts, adaptive=False, tau=tau, lam0=lam, seed=seed)
    ).partition(g)


def adadne(
    g: HeteroGraph,
    num_parts: int,
    lam: float = 0.1,
    alpha: float = 1.0,
    beta: float = 1.0,
    seed: int = 0,
) -> np.ndarray:
    return NeighborExpansionPartitioner(
        NEConfig(
            num_parts=num_parts,
            adaptive=True,
            lam0=lam,
            alpha=alpha,
            beta=beta,
            seed=seed,
        )
    ).partition(g)
