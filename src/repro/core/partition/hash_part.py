"""Hash-based partitioners: random edge hash, 2D grid hash (vertex-cut) and
vertex hash (edge-cut).  These are the cheap baselines (GraphLearn uses hash
partitioning; DistributedNE uses 2D hash for its initial placement).

``RandomEdgePartitioner`` / ``Hash2DPartitioner`` wrap the free functions
behind the ``Partitioner`` protocol for the registry; the functions stay the
supported functional surface (they were always one-liners)."""
from __future__ import annotations

import numpy as np

from repro.core.partition.base import (
    DEFAULT_DIRECTION,
    PartitionerBase,
    PartitionPlan,
)
from repro.graph.graph import HeteroGraph
from repro.utils import stable_hash64

__all__ = [
    "random_edge_partition",
    "hash2d_partition",
    "vertex_hash_partition",
    "RandomEdgePartitioner",
    "Hash2DPartitioner",
]


def random_edge_partition(g: HeteroGraph, num_parts: int, seed: int = 0) -> np.ndarray:
    eid = np.arange(g.num_edges, dtype=np.int64)
    return (stable_hash64(eid, salt=seed) % np.uint64(num_parts)).astype(np.int16)


def _factor_grid(p: int) -> tuple[int, int]:
    r = int(np.sqrt(p))
    while p % r:
        r -= 1
    return r, p // r


def hash2d_partition(g: HeteroGraph, num_parts: int, seed: int = 0) -> np.ndarray:
    """Classic 2D grid: partition = (hash(src) mod R, hash(dst) mod C).

    Bounds the replication factor at R + C - 1 per vertex."""
    rows, cols = _factor_grid(num_parts)
    hs = stable_hash64(g.src, salt=seed) % np.uint64(rows)
    hd = stable_hash64(g.dst, salt=seed + 1) % np.uint64(cols)
    return (hs.astype(np.int64) * cols + hd.astype(np.int64)).astype(np.int16)


def vertex_hash_partition(g: HeteroGraph, num_parts: int, seed: int = 0) -> np.ndarray:
    """Edge-cut by vertex hash: returns a VERTEX assignment [N]."""
    vid = np.arange(g.num_vertices, dtype=np.int64)
    return (stable_hash64(vid, salt=seed) % np.uint64(num_parts)).astype(np.int16)


class _HashPartitioner(PartitionerBase):
    """Shared protocol adapter over a (g, num_parts, seed) -> edge_parts fn."""

    _fn = staticmethod(random_edge_partition)

    def partition(
        self,
        g: HeteroGraph,
        num_parts: int,
        *,
        seed: int = 0,
        direction: str = DEFAULT_DIRECTION,
    ) -> PartitionPlan:
        ep = self._fn(g, num_parts, seed=seed)
        return PartitionPlan.from_assignment(
            g, ep, num_parts, partitioner=self.name, seed=seed
        )


class RandomEdgePartitioner(_HashPartitioner):
    name = "random"
    _fn = staticmethod(random_edge_partition)


class Hash2DPartitioner(_HashPartitioner):
    name = "hash2d"
    _fn = staticmethod(hash2d_partition)
