"""Deterministic fault injection for chaos testing and failover machinery.

GLISP's deployment story assumes sampling servers, storage tiers, and
prefetch workers that can fail and recover.  This module provides the
shared vocabulary for *exercising* those failure paths reproducibly:

``FaultPlan``
    A frozen schedule of per-site failure specs.  Whether invocation
    ``n`` of site ``s`` fails is a pure function of ``(plan.seed, s, n)``
    — a hash-derived Bernoulli draw — so a chaos run is exactly
    reproducible: rerunning the same plan against the same workload
    injects the same faults at the same points.

``FaultInjector``
    The runtime counterpart: carries per-site invocation counters and
    burst state.  Subsystems call ``fire(site)`` at their injection
    point; it raises :class:`InjectedFault` when the schedule says so.

``RetryPolicy``
    Capped exponential backoff shared by the sampling dispatch path and
    the tiered-storage read path.

``CircuitBreaker``
    Quarantines a repeatedly failing target (e.g. one sampling-server
    replica) so dispatches stop burning retry budget on it, with a
    half-open probe after a cooldown.

Sites are dotted names spaced per subsystem (``server.<part>.<replica>``,
``disk.read``, ``dfs.read``, ``worker``, ``train.step``); plans match
them with ``fnmatch`` patterns (first match wins), so one plan can
target a single replica (``server.0.1``) or a whole subsystem
(``server.*``).
"""

from __future__ import annotations

import fnmatch
import hashlib
import struct
import time
from dataclasses import dataclass, field

__all__ = [
    "CircuitBreaker",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "RetryPolicy",
]


class InjectedFault(RuntimeError):
    """A failure raised by a :class:`FaultInjector` per its plan."""

    def __init__(self, site: str, invocation: int):
        super().__init__(f"injected fault at site {site!r} (invocation {invocation})")
        self.site = site
        self.invocation = invocation


@dataclass(frozen=True)
class FaultSpec:
    """Failure behaviour for one site pattern.

    ``p`` is the per-invocation Bernoulli probability of *triggering* a
    failure; a trigger fails ``burst`` consecutive invocations (the
    trigger itself plus ``burst - 1`` followers), modelling a server
    that stays down briefly rather than flapping per call.  ``limit``
    caps the total failures the site may inject (``None`` = unlimited);
    a finite limit lets property tests guarantee that retries
    eventually succeed (any dispatch recovers once
    ``attempts * replicas > limit``).
    """

    p: float = 0.0
    burst: int = 1
    limit: int | None = None

    def validate(self) -> None:
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault probability must be in [0, 1], got {self.p}")
        if self.burst < 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        if self.limit is not None and self.limit < 0:
            raise ValueError(f"limit must be >= 0, got {self.limit}")

    def to_dict(self) -> dict:
        return {"p": self.p, "burst": self.burst, "limit": self.limit}


def _unit_draw(seed: int, site: str, invocation: int) -> float:
    """Uniform [0, 1) draw keyed by ``(seed, site, invocation)``.

    Hash-derived (blake2b) rather than a stateful generator so the
    decision for any invocation is independent of evaluation order —
    two subsystems interleaving their sites cannot perturb each other.
    """
    payload = site.encode() + struct.pack("<qq", seed, invocation)
    digest = hashlib.blake2b(payload, digest_size=8).digest()
    return struct.unpack("<Q", digest)[0] / 2.0**64


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic, frozen chaos schedule.

    ``sites`` maps ``fnmatch`` patterns to :class:`FaultSpec`; the first
    matching pattern wins, so specific overrides (``("server.0.0",
    FaultSpec(p=1.0))``) should precede catch-alls (``("server.*",
    FaultSpec(p=0.05))``).  The plan itself is immutable; runtime
    counters live in the :class:`FaultInjector` it spawns.
    """

    seed: int = 0
    sites: tuple = ()

    def __post_init__(self):
        for entry in self.sites:
            pattern, spec = entry
            if not isinstance(pattern, str) or not isinstance(spec, FaultSpec):
                raise TypeError(
                    "FaultPlan.sites entries must be (pattern, FaultSpec), "
                    f"got {entry!r}"
                )
            spec.validate()

    @classmethod
    def bernoulli(
        cls,
        p: float,
        *,
        site: str = "*",
        seed: int = 0,
        burst: int = 1,
        limit: int | None = None,
    ) -> "FaultPlan":
        """Single-pattern convenience constructor."""
        return cls(seed=seed, sites=((site, FaultSpec(p=p, burst=burst, limit=limit)),))

    def spec_for(self, site: str) -> FaultSpec | None:
        for pattern, spec in self.sites:
            if fnmatch.fnmatchcase(site, pattern):
                return spec
        return None

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)

    def to_dict(self) -> dict:
        return {
            "seed": self.seed,
            "sites": [[pattern, spec.to_dict()] for pattern, spec in self.sites],
        }


class FaultInjector:
    """Runtime state for a :class:`FaultPlan`: per-site counters + bursts.

    Not thread-safe by itself; callers that share one injector across
    threads (e.g. ``SamplingService`` under its round lock) must already
    serialise the calls.  Each site's decision stream depends only on
    its own invocation count, so distinct sites never perturb each
    other.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.invocations: dict[str, int] = {}
        self.failures: dict[str, int] = {}
        self._burst_left: dict[str, int] = {}

    def should_fail(self, site: str) -> bool:
        """Advance site ``site`` by one invocation; True if it must fail."""
        spec = self.plan.spec_for(site)
        if spec is None or (spec.p <= 0.0 and self._burst_left.get(site, 0) <= 0):
            return False
        n = self.invocations.get(site, 0)
        self.invocations[site] = n + 1
        fails = self.failures.get(site, 0)
        if spec.limit is not None and fails >= spec.limit:
            return False
        if self._burst_left.get(site, 0) > 0:
            self._burst_left[site] -= 1
            self.failures[site] = fails + 1
            return True
        if _unit_draw(self.plan.seed, site, n) < spec.p:
            self._burst_left[site] = spec.burst - 1
            self.failures[site] = fails + 1
            return True
        return False

    def fire(self, site: str) -> None:
        """Raise :class:`InjectedFault` if this invocation should fail."""
        if self.should_fail(site):
            raise InjectedFault(site, self.invocations.get(site, 1) - 1)

    def total_failures(self) -> int:
        return sum(self.failures.values())

    def counters(self) -> dict:
        """Per-site ``{"invocations": n, "failures": f}`` snapshot."""
        return {
            site: {
                "invocations": self.invocations.get(site, 0),
                "failures": self.failures.get(site, 0),
            }
            for site in sorted(self.invocations)
        }


def as_injector(faults) -> FaultInjector | None:
    """Normalise a ``FaultPlan | FaultInjector | None`` into an injector.

    Config carries the frozen plan; runtime objects want the stateful
    injector.  Passing an injector through lets several subsystems share
    one set of counters when a test wires them together by hand.
    """
    if faults is None:
        return None
    if isinstance(faults, FaultInjector):
        return faults
    if isinstance(faults, FaultPlan):
        return faults.injector()
    raise TypeError(f"expected FaultPlan, FaultInjector, or None, got {type(faults)!r}")


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff for transient-failure retries.

    ``max_attempts`` counts total tries per target (1 = no retry).  The
    default ``base_delay_s=0`` keeps in-process chaos tests instant;
    real transports set a small base so retries do not hammer a server
    that is restarting.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.0
    max_delay_s: float = 0.1
    multiplier: float = 2.0

    def validate(self) -> None:
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError("retry delays must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError(f"multiplier must be >= 1, got {self.multiplier}")

    def backoff(self, attempt: int) -> float:
        """Delay before retrying after the ``attempt``-th failure (1-based)."""
        if self.base_delay_s <= 0.0:
            return 0.0
        return min(self.max_delay_s, self.base_delay_s * self.multiplier ** (attempt - 1))

    def sleep(self, attempt: int, *, deadline: float | None = None) -> None:
        """Sleep the backoff for ``attempt``, clipped to ``deadline``.

        ``deadline`` is an absolute ``time.monotonic()`` value; when the
        budget is already spent the sleep is skipped so deadline-aware
        callers can fail fast instead of overshooting.
        """
        delay = self.backoff(attempt)
        if deadline is not None:
            delay = min(delay, max(0.0, deadline - time.monotonic()))
        if delay > 0.0:
            time.sleep(delay)

    def to_dict(self) -> dict:
        return {
            "max_attempts": self.max_attempts,
            "base_delay_s": self.base_delay_s,
            "max_delay_s": self.max_delay_s,
            "multiplier": self.multiplier,
        }


@dataclass
class CircuitBreaker:
    """Quarantines a target after repeated consecutive failures.

    After ``threshold`` consecutive failures the breaker opens:
    ``allow()`` returns False for the next ``cooldown`` checks, then a
    single half-open probe is admitted.  A probe success closes the
    breaker; a probe failure re-opens it immediately.  The cooldown is
    counted in ``allow()`` calls, not wall time, so breaker behaviour is
    as deterministic as the dispatch schedule driving it.
    """

    threshold: int = 3
    cooldown: int = 8
    consecutive_failures: int = 0
    opens: int = 0
    _cooldown_left: int = field(default=0, repr=False)
    _half_open: bool = field(default=False, repr=False)

    def allow(self) -> bool:
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            if self._cooldown_left == 0:
                self._half_open = True
                return True
            return False
        return True

    def record_success(self) -> None:
        self.consecutive_failures = 0
        self._half_open = False

    def record_failure(self) -> None:
        self.consecutive_failures += 1
        if self._half_open or self.consecutive_failures >= self.threshold:
            self._cooldown_left = self.cooldown
            self._half_open = False
            self.consecutive_failures = 0
            self.opens += 1

    @property
    def state(self) -> str:
        if self._cooldown_left > 0:
            return "open"
        if self._half_open:
            return "half_open"
        return "closed"
