"""Data-parallel GNN training over a ``jax.sharding`` mesh.

``DataParallelGNNTrainer`` finally wires the so-far-unused
``launch/mesh.py`` + ``launch/shardings.py`` machinery to training: the
train step runs with the batch sharded over the mesh's data axis and the
params/optimizer replicated, which on one host's ``make_local_mesh`` CPU
devices is the exact program a multi-host deployment runs per pod.

Layout per step, for a mesh with ``S``-way data parallelism:

- ``train_ids`` are dealt round-robin into ``S`` shard streams, each with
  its own sampling client (``BatchPipeline``) over the SAME shared
  backend — per-host sampling clients, one submission window each, with
  pipeline-owned request keys so every shard's batch stream is
  deterministic no matter how the service interleaves them;
- each step takes one padded batch per shard, pads them to a common
  bucket shape (:func:`stack_batches`) and stacks a leading shard axis;
- the stacked batch is ``device_put`` with ``PartitionSpec(data_axes)``
  on dim 0 — shard ``i``'s rows land on data-slice ``i`` — while params
  and optimizer state are replicated (``PartitionSpec()``);
- the jit'd step ``vmap``s the per-shard loss over the shard axis and
  takes the mean, so the gradient is the average of per-shard gradients
  and XLA inserts the cross-shard reduction itself.

``reference=True`` runs a second, unsharded single-device step (its own
params/optimizer replica, same init) on the very same stacked batches and
records its losses — benchmarks assert the sharded step matches it, which
is the acceptance check that data parallelism changed the placement and
nothing else.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api.pipeline import BatchPipeline
from repro.launch.mesh import make_local_mesh
from repro.launch.shardings import data_axes
from repro.models.gnn.batching import GNNBatch
from repro.train.optim import AdamWConfig, adamw_init, adamw_update

__all__ = ["DataParallelGNNTrainer", "DPTrainLog", "stack_batches"]

# per-shard pipeline seeds must differ (distinct seed permutations and
# request-key bases) but be derived from one trainer seed; a prime stride
# keeps them disjoint from the service's own replica seeding
_SHARD_SEED_STRIDE = 7919


@dataclass
class DPTrainLog:
    steps: list = field(default_factory=list)
    losses: list = field(default_factory=list)
    # single-device reference losses (reference=True), same positions
    ref_losses: list = field(default_factory=list)
    wall: list = field(default_factory=list)
    sample_time: float = 0.0
    compute_time: float = 0.0


def stack_batches(batches: list[GNNBatch]) -> GNNBatch:
    """Stack per-shard ``GNNBatch``es along a new leading shard axis.

    Shards sample independently, so their padded bucket shapes may
    differ; every array is first padded to the max bucket across shards
    using the batching pads (zero feature rows, ``valid=False``, edge
    positions ``-1``, edge type ``0``) — semantically inert by the same
    argument as the original padding.  Seed counts must match (the
    caller drops ragged tails); stacking never changes any shard's rows.
    """
    bs = {b.seed_pos.shape[0] for b in batches}
    if len(bs) != 1:
        raise ValueError(f"shards disagree on seeds per batch: {sorted(bs)}")
    vmax = max(b.feats.shape[0] for b in batches)
    num_layers = len(batches[0].layer_dst)
    emax = [
        max(b.layer_dst[k].shape[0] for b in batches)
        for k in range(num_layers)
    ]

    def pad0(arr, n, fill):
        if arr.shape[0] == n:
            return arr
        out = np.full((n,) + arr.shape[1:], fill, dtype=arr.dtype)
        out[: arr.shape[0]] = arr
        return out

    return GNNBatch(
        feats=np.stack([pad0(b.feats, vmax, 0.0) for b in batches]),
        valid=np.stack([pad0(b.valid, vmax, False) for b in batches]),
        seed_pos=np.stack([b.seed_pos for b in batches]),
        labels=np.stack([b.labels for b in batches]),
        layer_dst=[
            np.stack([pad0(b.layer_dst[k], emax[k], -1) for b in batches])
            for k in range(num_layers)
        ],
        layer_src=[
            np.stack([pad0(b.layer_src[k], emax[k], -1) for b in batches])
            for k in range(num_layers)
        ],
        layer_etype=[
            np.stack([pad0(b.layer_etype[k], emax[k], 0) for b in batches])
            for k in range(num_layers)
        ],
        # degree columns are per-vertex-row, so the vertex pad (zero
        # count) keeps them consistent with the -1-padded edge lists
        layer_cnt=(
            [
                np.stack([pad0(b.layer_cnt[k], vmax, 0.0) for b in batches])
                for k in range(num_layers)
            ]
            if all(b.layer_cnt is not None for b in batches)
            else None
        ),
    )


class DataParallelGNNTrainer:
    def __init__(
        self,
        model,
        backend,
        graph,
        train_ids: np.ndarray,
        *,
        mesh=None,
        spec=None,
        fanouts=None,
        batch_size: int = 256,  # GLOBAL batch: split evenly across shards
        opt: AdamWConfig | None = None,
        seed: int = 0,
        prefetch: int = 0,
        inflight: int = 1,
        vertex_quantum: int = 256,
        edge_quantum: int = 1024,
        ticket_timeout: float | None = None,
        reference: bool = False,
    ):
        if spec is None and fanouts is None:
            raise ValueError("pass a SamplingSpec or fanouts")
        self.model = model
        self.mesh = mesh if mesh is not None else make_local_mesh()
        da = data_axes(self.mesh)
        names = da if isinstance(da, tuple) else (da,)
        self.num_shards = int(np.prod([self.mesh.shape[a] for a in names]))
        if batch_size % self.num_shards != 0:
            raise ValueError(
                f"global batch_size {batch_size} must divide evenly over "
                f"{self.num_shards} data shard(s)"
            )
        self._batch_sharding = NamedSharding(self.mesh, P(da))
        self._replicated = NamedSharding(self.mesh, P())
        # one sampling client per shard over the SHARED backend; thread-mode
        # prefetch (the pool's channel fds must stay in this process, and
        # the shards' real parallelism is the remote workers / XLA anyway)
        self.pipelines = [
            BatchPipeline(
                backend,
                graph,
                np.asarray(train_ids)[i :: self.num_shards],
                list(spec.fanouts) if spec is not None else list(fanouts),
                model.num_layers,
                batch_size=batch_size // self.num_shards,
                spec=spec,
                prefetch=prefetch,
                inflight=inflight,
                workers="thread",
                seed=seed + _SHARD_SEED_STRIDE * i,
                vertex_quantum=vertex_quantum,
                edge_quantum=edge_quantum,
                ticket_timeout=ticket_timeout,
            )
            for i in range(self.num_shards)
        ]
        self.opt_cfg = opt or AdamWConfig(lr=1e-3, weight_decay=1e-4)
        self.params = model.init(jax.random.PRNGKey(seed))
        self.opt_state = adamw_init(self.params)
        self.log = DPTrainLog()
        self.reference = reference

        def loss_fn(params, batch):
            # per-shard loss over the leading shard axis; the mean makes
            # the gradient the shard-average — textbook data parallelism
            return jax.vmap(lambda b: model.loss(params, b))(batch).mean()

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state, _ = adamw_update(
                params, grads, opt_state, self.opt_cfg
            )
            return params, opt_state, loss

        self._step = jax.jit(step)
        if reference:
            # an independent jit instance: compiled for the unsharded
            # (single-device) input layout, with its own replica of the
            # same initial params/optimizer
            self._ref_step = jax.jit(step)
            self.ref_params = model.init(jax.random.PRNGKey(seed))
            self.ref_opt_state = adamw_init(self.ref_params)

    def _place(self) -> None:
        self.params = jax.device_put(self.params, self._replicated)
        self.opt_state = jax.device_put(self.opt_state, self._replicated)

    def train(
        self,
        epochs: int = 1,
        log_every: int = 10,
        max_steps: int | None = None,
    ) -> DPTrainLog:
        self._place()
        streams = [pl.batches(epochs) for pl in self.pipelines]
        step = 0
        try:
            while max_steps is None or step < max_steps:
                t0 = time.perf_counter()
                items = [next(s, None) for s in streams]
                if any(it is None for it in items):
                    break  # a shard ran dry: drop the ragged tail
                shard_batches = [
                    jax.tree.map(np.asarray, b) for _, b in items
                ]
                if len({b.seed_pos.shape[0] for b in shard_batches}) != 1:
                    break  # unequal final partial batches: ragged tail
                stacked = stack_batches(shard_batches)
                t1 = time.perf_counter()
                self.log.sample_time += t1 - t0
                sharded = jax.device_put(stacked, self._batch_sharding)
                self.params, self.opt_state, loss = self._step(
                    self.params, self.opt_state, sharded
                )
                loss = float(loss)
                self.log.compute_time += time.perf_counter() - t1
                if step % log_every == 0:
                    self.log.steps.append(step)
                    self.log.losses.append(loss)
                    if self.reference:
                        dev_batch = jax.tree.map(jnp.asarray, stacked)
                        self.ref_params, self.ref_opt_state, ref_loss = (
                            self._ref_step(
                                self.ref_params, self.ref_opt_state, dev_batch
                            )
                        )
                        self.log.ref_losses.append(float(ref_loss))
                step += 1
        finally:
            for s in streams:
                close = getattr(s, "close", None)
                if close is not None:
                    close()
        return self.log
