from repro.train.optim import adamw_init, adamw_update, sgd_update, clip_by_global_norm
from repro.train.checkpoint import save_checkpoint, load_checkpoint
from repro.train.loop import GNNTrainer, LMTrainer

__all__ = [
    "adamw_init",
    "adamw_update",
    "sgd_update",
    "clip_by_global_norm",
    "save_checkpoint",
    "load_checkpoint",
    "GNNTrainer",
    "LMTrainer",
]
