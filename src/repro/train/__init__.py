from repro.train.optim import adamw_init, adamw_update, sgd_update, clip_by_global_norm
from repro.train.checkpoint import save_checkpoint, load_checkpoint
from repro.train.loop import GNNTrainer, LMTrainer
from repro.train.data_parallel import (
    DataParallelGNNTrainer,
    DPTrainLog,
    stack_batches,
)

__all__ = [
    "adamw_init",
    "adamw_update",
    "sgd_update",
    "clip_by_global_norm",
    "save_checkpoint",
    "load_checkpoint",
    "GNNTrainer",
    "LMTrainer",
    "DataParallelGNNTrainer",
    "DPTrainLog",
    "stack_batches",
]
