"""Hand-written optimizers on pytrees: AdamW (decoupled weight decay) + SGD
with momentum, plus global-norm clipping.  No optax dependency."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "sgd_update",
    "clip_by_global_norm",
]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_frac·lr."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["nu"],
        grads,
    )
    bc1 = 1 - b1**step.astype(jnp.float32)
    bc2 = 1 - b2**step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return (
            p.astype(jnp.float32)
            - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, {"lr": lr, "grad_norm": gnorm}


def sgd_update(params, grads, state, lr: float = 0.1, momentum: float = 0.9):
    if state is None:
        state = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    vel = jax.tree.map(
        lambda v, g: momentum * v + g.astype(jnp.float32), state, grads
    )
    new_params = jax.tree.map(lambda p, v: (p - lr * v).astype(p.dtype), params, vel)
    return new_params, vel
