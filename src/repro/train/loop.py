"""Training loops.

``GNNTrainer`` — the paper's end-to-end pipeline: the GLISP batch pipeline
(``repro.api.pipeline.BatchPipeline``) feeds padded minibatches into a jit'd
AdamW step (the Fig. 11 workload).  With ``prefetch >= 1`` host-side
sampling runs on a background thread and overlaps the device step.
``checkpoint_every > 0`` auto-saves an atomic checkpoint every N steps;
``resume()`` restores it and ``train()`` fast-forwards the (deterministic,
keyed) batch stream to the saved step, so a crashed-and-resumed run ends
with bit-identical weights to an uninterrupted one.
``LMTrainer`` — causal-LM training for the assigned architecture pool
(synthetic token stream), used by smoke tests and the quickstart.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.pipeline import BatchPipeline
from repro.core.sampling.service import DEFAULT_DIRECTION
from repro.data.graph_loader import SeedBatchLoader
from repro.data.tokens import SyntheticTokenStream
from repro.models.gnn.models import GNNModel
from repro.models.transformer.config import ArchConfig
from repro.models.transformer.model import forward, init_params, lm_loss
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optim import AdamWConfig, adamw_init, adamw_update

__all__ = ["GNNTrainer", "LMTrainer"]


@dataclass
class TrainLog:
    steps: list = field(default_factory=list)
    losses: list = field(default_factory=list)
    accs: list = field(default_factory=list)
    wall: list = field(default_factory=list)
    sample_time: float = 0.0
    compute_time: float = 0.0


class GNNTrainer:
    def __init__(
        self,
        model: GNNModel,
        client,  # SamplerBackend, SamplingService, or a raw blocking client
        g,
        fanouts,
        train_ids: np.ndarray,
        batch_size: int = 256,
        opt: AdamWConfig | None = None,
        direction: str = DEFAULT_DIRECTION,
        seed: int = 0,
        weighted: bool = False,
        prefetch: int = 0,
        inflight: int = 1,  # in-flight sample requests on the service
        spec=None,  # SamplingSpec; overrides fanouts/weighted/direction
        worker_cores: tuple | None = None,
        partition_of: np.ndarray | None = None,
        balance_partitions: bool = False,
        feature_source=None,  # FeatureSource; None = g.vertex_feats
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 0,  # steps between auto-checkpoints; 0 = off
        ticket_timeout: float | None = None,
        worker_respawns: int = 1,
    ):
        self.model = model
        self.client = client
        self.g = g
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = int(checkpoint_every)
        if self.checkpoint_every > 0 and checkpoint_dir is None:
            raise ValueError("checkpoint_every > 0 requires a checkpoint_dir")
        self._resume_step = 0
        self.pipeline = BatchPipeline(
            client,
            g,
            train_ids,
            fanouts,
            model.num_layers,
            batch_size=batch_size,
            spec=spec,
            weighted=weighted,
            direction=direction,
            prefetch=prefetch,
            inflight=inflight,
            worker_cores=worker_cores,
            seed=seed,
            partition_of=partition_of,
            balance_partitions=balance_partitions,
            feature_source=feature_source,
            ticket_timeout=ticket_timeout,
            worker_respawns=worker_respawns,
        )
        self.fanouts = self.pipeline.fanouts
        self.direction = self.pipeline.direction
        self.loader = self.pipeline.loader
        self.opt_cfg = opt or AdamWConfig(lr=1e-3, weight_decay=1e-4)
        self.params = model.init(jax.random.PRNGKey(seed))
        self.opt_state = adamw_init(self.params)
        self.log = TrainLog()

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt_state, info = adamw_update(
                params, grads, opt_state, self.opt_cfg
            )
            return params, opt_state, loss

        self._step = jax.jit(step)

        def acc_fn(params, batch):
            logits = model.apply(params, batch)
            return (jnp.argmax(logits, -1) == batch.labels).mean()

        self._acc = jax.jit(acc_fn)

    def make_batch(self, seeds):
        return self.pipeline.make_batch(seeds)

    # -- checkpoint / resume -------------------------------------------------
    @property
    def checkpoint_path(self) -> str:
        if self.checkpoint_dir is None:
            raise ValueError("trainer has no checkpoint_dir")
        return os.path.join(self.checkpoint_dir, "gnn_checkpoint.npz")

    def save(self, path: str | None = None, step: int = 0) -> str:
        """Atomic checkpoint of params + optimizer state (+ step)."""
        return save_checkpoint(
            path or self.checkpoint_path,
            {"params": self.params, "opt": self.opt_state},
            step,
        )

    def resume(self, path: str | None = None) -> int:
        """Restore the latest checkpoint; returns the restored step count.

        The next ``train()`` call fast-forwards its (deterministic, keyed)
        batch stream past the restored steps, so resuming reproduces the
        uninterrupted run bit-for-bit: the skipped batches are never
        recomputed, only their stream positions are consumed."""
        tree, step = load_checkpoint(
            path or self.checkpoint_path,
            {"params": self.params, "opt": self.opt_state},
        )
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self._resume_step = int(step or 0)
        return self._resume_step

    def train(
        self,
        epochs: int = 1,
        log_every: int = 10,
        max_steps: int | None = None,
    ):
        step = 0
        skip = self._resume_step  # batches already trained before resume()
        for seeds, batch in self.pipeline.batches(epochs):
            if max_steps is not None and step >= max_steps:
                break
            if step < skip:
                # replay: consume the stream position without recomputing
                # (the batch itself is identical by keyed construction)
                step += 1
                continue
            t1 = time.perf_counter()
            self.params, self.opt_state, loss = self._step(
                self.params, self.opt_state, batch
            )
            loss = float(loss)
            t2 = time.perf_counter()
            self.log.compute_time += t2 - t1
            if step % log_every == 0:
                self.log.steps.append(step)
                self.log.losses.append(loss)
            step += 1
            if self.checkpoint_every and step % self.checkpoint_every == 0:
                self.save(step=step)
        self._resume_step = 0
        # producer-side host clock: equals the old serial sample_time when
        # prefetch=0; with prefetch it is the OVERLAPPED sampling time
        self.log.sample_time = self.pipeline.sample_time
        return self.log

    def evaluate(self, test_ids: np.ndarray, batches: int = 8) -> float:
        loader = SeedBatchLoader(test_ids, self.loader.batch, seed=123)
        accs = []
        for i, seeds in enumerate(loader.epoch()):
            if i >= batches:
                break
            batch = jax.tree.map(jnp.asarray, self.make_batch(seeds))
            accs.append(float(self._acc(self.params, batch)))
        return float(np.mean(accs)) if accs else 0.0


class LMTrainer:
    def __init__(
        self,
        cfg: ArchConfig,
        batch: int,
        seq_len: int,
        opt: AdamWConfig | None = None,
        seed: int = 0,
        remat: bool = True,
    ):
        self.cfg = cfg
        self.stream = SyntheticTokenStream(cfg.vocab_size, batch, seq_len, seed)
        self.opt_cfg = opt or AdamWConfig(lr=3e-4)
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self.opt_state = adamw_init(self.params)
        self.log = TrainLog()

        def step(params, opt_state, inputs, targets):
            (loss, (nll, aux)), grads = jax.value_and_grad(
                lambda p: lm_loss(p, cfg, inputs, targets, remat=remat),
                has_aux=True,
            )(params)
            params, opt_state, info = adamw_update(params, grads, opt_state, self.opt_cfg)
            return params, opt_state, loss, nll

        self._step = jax.jit(step)

    def train(self, steps: int, log_every: int = 10):
        for s in range(steps):
            inp, tgt = self.stream.next_batch()
            t0 = time.perf_counter()
            self.params, self.opt_state, loss, nll = self._step(
                self.params, self.opt_state, jnp.asarray(inp), jnp.asarray(tgt)
            )
            nll = float(nll)
            self.log.compute_time += time.perf_counter() - t0
            if s % log_every == 0 or s == steps - 1:
                self.log.steps.append(s)
                self.log.losses.append(nll)
        return self.log

    def save(self, path: str, step: int = 0):
        save_checkpoint(path, {"params": self.params, "opt": self.opt_state}, step)
