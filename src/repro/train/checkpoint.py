"""Checkpointing: pytree <-> single .npz with slash-joined path keys.

Works for params, optimizer state, and nested lists/dicts (stage lists in the
transformer params).  Lists are encoded as dict keys "<i>" and restored by
the reference-tree structure on load.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint"]


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten(v, f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}{i}/")
    elif tree is None:
        return
    else:
        yield prefix[:-1], np.asarray(tree)


def save_checkpoint(path: str, tree, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = dict(_flatten(tree))
    if step is not None:
        flat["__step__"] = np.asarray(step)
    np.savez(path, **flat)


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (a template pytree)."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    step = int(flat.pop("__step__")) if "__step__" in flat else None

    def rebuild(template, prefix=""):
        if isinstance(template, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in template.items()}
        if isinstance(template, (list, tuple)):
            t = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(template)]
            return type(template)(t) if isinstance(template, tuple) else t
        if template is None:
            return None
        arr = flat[prefix[:-1]]
        return jnp.asarray(arr, dtype=template.dtype if hasattr(template, "dtype") else None)

    return rebuild(like), step
