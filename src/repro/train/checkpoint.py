"""Checkpointing: pytree <-> single .npz with slash-joined path keys.

Works for params, optimizer state, and nested lists/dicts (stage lists in the
transformer params).  Lists are encoded as dict keys "<i>" and restored by
the reference-tree structure on load.

Saves are atomic (tmp file in the same directory + fsync + ``os.replace``):
a crash mid-save leaves either the previous checkpoint or the new one,
never a truncated file — the invariant ``GNNTrainer.resume()`` relies on.
Structure problems on load (missing/extra keys, shape mismatches against
the template tree) raise :class:`CheckpointError` with the offending key
paths, instead of a bare ``KeyError`` or numpy broadcast error.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointError", "save_checkpoint", "load_checkpoint"]


class CheckpointError(RuntimeError):
    """A checkpoint file is missing or does not match the template tree."""


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten(v, f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}{i}/")
    elif tree is None:
        return
    else:
        yield prefix[:-1], np.asarray(tree)


def _npz_path(path: str) -> str:
    # np.savez appends ".npz" to a bare path; mirror that so save and load
    # agree on the on-disk name regardless of how the caller spelled it
    return path if path.endswith(".npz") else path + ".npz"


def save_checkpoint(path: str, tree, step: int | None = None) -> str:
    """Atomically write ``tree`` (+ optional ``step``) to ``path``.

    Returns the final on-disk path (``path`` with ``.npz`` appended when
    missing, matching ``np.savez``)."""
    final = _npz_path(path)
    directory = os.path.dirname(final) or "."
    os.makedirs(directory, exist_ok=True)
    flat = dict(_flatten(tree))
    if step is not None:
        flat["__step__"] = np.asarray(step)
    tmp = final + ".tmp"
    try:
        with open(tmp, "wb") as fh:
            np.savez(fh, **flat)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, final)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    # best-effort directory fsync so the rename itself is durable
    try:
        dfd = os.open(directory, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:
        pass
    return final


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (a template pytree).

    Raises :class:`CheckpointError` when the file is absent or its keys /
    array shapes do not match the template."""
    final = _npz_path(path)
    if not os.path.exists(final):
        raise CheckpointError(f"no checkpoint file at {final}")
    try:
        with np.load(final) as z:
            flat = {k: z[k] for k in z.files}
    except (ValueError, EOFError, OSError) as exc:
        raise CheckpointError(
            f"checkpoint {final} is unreadable (truncated or corrupt): {exc}"
        ) from exc
    step = int(flat.pop("__step__")) if "__step__" in flat else None
    consumed = set()

    def rebuild(template, prefix=""):
        if isinstance(template, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in template.items()}
        if isinstance(template, (list, tuple)):
            t = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(template)]
            return type(template)(t) if isinstance(template, tuple) else t
        if template is None:
            return None
        key = prefix[:-1]
        if key not in flat:
            raise CheckpointError(
                f"checkpoint {final} missing key {key!r} — the saved tree "
                "does not match the template structure"
            )
        consumed.add(key)
        arr = flat[key]
        want = getattr(template, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise CheckpointError(
                f"checkpoint {final} shape mismatch at {key!r}: "
                f"saved {tuple(arr.shape)}, template expects {tuple(want)}"
            )
        return jnp.asarray(arr, dtype=template.dtype if hasattr(template, "dtype") else None)

    tree = rebuild(like)
    extra = sorted(set(flat) - consumed)
    if extra:
        raise CheckpointError(
            f"checkpoint {final} holds keys absent from the template "
            f"(structure mismatch): {extra[:5]}"
            + ("..." if len(extra) > 5 else "")
        )
    return tree, step
