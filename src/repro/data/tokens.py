"""Deterministic synthetic LM data: a Zipfian token stream with local n-gram
structure (so the loss actually decreases), and ShapeDtypeStruct input specs
for the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticTokenStream", "lm_input_specs"]


class SyntheticTokenStream:
    """Zipf-distributed tokens with a first-order Markov skeleton: token t+1
    is (a·t + b) mod V with prob q, else a fresh Zipf draw — learnable
    structure for convergence tests."""

    def __init__(
        self,
        vocab_size: int,
        batch: int,
        seq_len: int,
        seed: int = 0,
        zipf_a: float = 1.2,
        markov_q: float = 0.7,
    ):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.rng = np.random.default_rng(seed)
        self.q = markov_q
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self.p = p / p.sum()
        self.a = 31
        self.b = 17

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        """(inputs [B, S], targets [B, S]) with targets = inputs shifted."""
        b, s, v = self.batch, self.seq, self.vocab
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = self.rng.choice(v, size=b, p=self.p)
        fresh = self.rng.choice(v, size=(b, s), p=self.p)
        follow = self.rng.random((b, s)) < self.q
        for t in range(s):
            nxt = (self.a * toks[:, t] + self.b) % v
            toks[:, t + 1] = np.where(follow[:, t], nxt, fresh[:, t])
        return toks[:, :-1], toks[:, 1:]


def lm_input_specs(batch: int, seq_len: int, *, d_model: int = 0, embeddings: bool = False):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    if embeddings:
        return {
            "inputs": jax.ShapeDtypeStruct((batch, seq_len, d_model), jnp.bfloat16),
            "targets": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        }
    return {
        "inputs": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }
