from repro.data.tokens import SyntheticTokenStream, lm_input_specs
from repro.data.graph_loader import SeedBatchLoader

__all__ = ["SyntheticTokenStream", "lm_input_specs", "SeedBatchLoader"]
