"""Seed-batch loader for GNN training: shuffled epochs over the training set,
optionally emulating DistDGL's balanced-seed setup (equal seeds per
partition, paper §IV-C)."""
from __future__ import annotations

import numpy as np

__all__ = ["SeedBatchLoader"]


class SeedBatchLoader:
    def __init__(
        self,
        train_ids: np.ndarray,
        batch_size: int,
        seed: int = 0,
        partition_of: np.ndarray | None = None,
        balance_partitions: bool = False,
    ):
        self.ids = np.asarray(train_ids)
        self.batch = batch_size
        self.rng = np.random.default_rng(seed)
        self.partition_of = partition_of
        self.balance = balance_partitions and partition_of is not None

    def epoch(self):
        if not self.balance:
            order = self.rng.permutation(self.ids)
            for lo in range(0, order.shape[0] - self.batch + 1, self.batch):
                yield order[lo : lo + self.batch]
            return
        # balanced: round-robin across partitions (DistDGL's balanced seeds)
        parts = self.partition_of[self.ids]
        groups = [
            self.rng.permutation(self.ids[parts == p]) for p in np.unique(parts)
        ]
        per = self.batch // len(groups)
        n_batches = min(g.shape[0] // max(1, per) for g in groups)
        for i in range(n_batches):
            chunks = [g[i * per : (i + 1) * per] for g in groups]
            yield np.concatenate(chunks)[: self.batch]
