"""Continuous batcher: pack admitted requests into the engine's buckets.

The inference engine compiles one jit slice per (layer, vertex-bucket,
edge-bucket) shape.  The batcher's job is to ride those existing buckets:
it accumulates queued requests until the pending vertex rows would spill
past the compute budget (``max_rows``, the engine's inference batch size —
the largest vertex bucket), or until the oldest pending request has waited
``max_delay_ms`` (a partial bucket flushes on the timer rather than
starving at low load).  Because padded shapes snap to the same power-of-two
ladder the offline engine already traced, a warmed server triggers zero new
compiles — ``repro.analysis.recompile_guard`` asserts exactly that over the
serving loop.
"""
from __future__ import annotations

__all__ = ["ContinuousBatcher"]


class ContinuousBatcher:
    """Time- and size-bounded packer over (entry, rows) pairs.

    Pure scheduling — no compute, no clocks of its own: callers pass
    ``now`` (monotonic seconds) into every method, which keeps the policy
    deterministic and unit-testable."""

    def __init__(self, max_rows: int, max_delay_ms: float):
        if max_rows <= 0:
            raise ValueError(f"max_rows must be positive, got {max_rows}")
        if max_delay_ms < 0:
            raise ValueError(
                f"max_delay_ms must be >= 0, got {max_delay_ms}"
            )
        self.max_rows = int(max_rows)
        self.max_delay_s = float(max_delay_ms) / 1e3
        self._pending: list = []  # (entry, rows, added_at) in arrival order
        self._rows = 0

    def add(self, entry, rows: int, now: float) -> None:
        self._pending.append((entry, int(rows), now))
        self._rows += int(rows)

    @property
    def pending_rows(self) -> int:
        return self._rows

    def __len__(self) -> int:
        return len(self._pending)

    def has_room(self) -> bool:
        """Whether another request fits before the size trigger fires."""
        return self._rows < self.max_rows

    def ready(self, now: float) -> bool:
        """Flush trigger: bucket budget reached, or the oldest pending
        request has waited out the delay timer."""
        if not self._pending:
            return False
        if self._rows >= self.max_rows:
            return True
        return (now - self._pending[0][2]) >= self.max_delay_s

    def take(self, now: float, force: bool = False) -> list | None:
        """Pop one batch (arrival order) if a trigger fired, else ``None``.

        ``force=True`` flushes a partial batch immediately — the server
        uses it when the engine would otherwise sit idle (nothing left to
        wait for).  At most ``max_rows`` rows are taken; the first entry
        is always included even if it alone exceeds the budget, so an
        oversized request cannot deadlock the batcher."""
        if not self._pending or not (force or self.ready(now)):
            return None
        batch, total = [], 0
        while self._pending:
            entry, rows, _ = self._pending[0]
            if batch and total + rows > self.max_rows:
                break
            self._pending.pop(0)
            batch.append(entry)
            total += rows
            self._rows -= rows
        return batch
