"""``repro.serve`` — online GNN inference serving.

The serving tier answers "embed these vertices now" requests over a
completed layerwise inference run: bounded admission, continuous batching
into the engine's compiled shape buckets, per-request deadlines, and
SLO-grade metrics.  Construct via ``GLISPSystem.server()``.
"""
from repro.serve.batcher import ContinuousBatcher
from repro.serve.queue import RequestQueue
from repro.serve.request import ServeRequest, ServeResponse
from repro.serve.server import GNNServer
from repro.serve.stats import LatencyEstimator, P2Quantile, ServeStats

__all__ = [
    "ContinuousBatcher",
    "GNNServer",
    "LatencyEstimator",
    "P2Quantile",
    "RequestQueue",
    "ServeRequest",
    "ServeResponse",
    "ServeStats",
]
