"""Bounded admission queue for the serving tier.

Admission control is the first SLO mechanism: a server drowning in
requests must shed load *at the door* with an explicit rejection the
client sees, not buffer unboundedly until every queued request misses its
deadline.  ``push`` therefore returns ``False`` when the queue is full —
callers turn that into a ``status="rejected"`` response and count it.
"""
from __future__ import annotations

import collections

__all__ = ["RequestQueue"]


class RequestQueue:
    """FIFO queue with a hard depth bound and explicit rejection."""

    def __init__(self, depth: int):
        if depth <= 0:
            raise ValueError(f"queue depth must be positive, got {depth}")
        self.depth = int(depth)
        # bound enforced by push() below: a full queue must REJECT (the
        # caller sees False and answers status="rejected"), which
        # deque(maxlen=) cannot express — it silently drops the oldest
        # entry instead
        self._q = collections.deque()  # glint: disable=PRJ005 -- see above

    def push(self, item) -> bool:
        """Admit ``item``; ``False`` (and no side effect) when full."""
        if len(self._q) >= self.depth:
            return False
        self._q.append(item)
        return True

    def pop(self):
        """Oldest admitted item, or ``None`` when empty."""
        return self._q.popleft() if self._q else None

    def peek(self):
        return self._q[0] if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
