"""Serve-tier metrics: counters, batch occupancy, and online percentiles.

The latency estimator is the P² (piecewise-parabolic) streaming quantile
algorithm (Jain & Chlamtac, 1985): five markers per tracked quantile,
O(1) memory and update cost, no sample buffer — exact until five
observations arrive, then a parabolic approximation.  Good enough for SLO
dashboards; the benchmark cross-checks it against exact percentiles on the
recorded latency list.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["P2Quantile", "LatencyEstimator", "ServeStats"]


class P2Quantile:
    """One streaming quantile via the P² algorithm (no sample retention)."""

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = q
        self._heights: list[float] = []  # marker heights (5 once warm)
        self._pos: list[float] = []  # actual marker positions (1-based)
        self._want: list[float] = []  # desired marker positions
        self.count = 0

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        h = self._heights
        if self.count <= 5:
            h.append(x)
            h.sort()
            if self.count == 5:
                q = self.q
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
                self._want = [1.0, 1 + 2 * q, 1 + 4 * q, 3 + 2 * q, 5.0]
            return
        # locate the cell containing x, clamping the extremes
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            self._pos[i] += 1.0
        q = self.q
        incr = [0.0, q / 2, q, (1 + q) / 2, 1.0]
        for i in range(5):
            self._want[i] += incr[i]
        # adjust interior markers toward their desired positions
        for i in (1, 2, 3):
            d = self._want[i] - self._pos[i]
            left = self._pos[i] - self._pos[i - 1]
            right = self._pos[i + 1] - self._pos[i]
            if (d >= 1.0 and right > 1.0) or (d <= -1.0 and left > 1.0):
                s = 1.0 if d >= 0 else -1.0
                cand = self._parabolic(i, s)
                if not h[i - 1] < cand < h[i + 1]:
                    cand = self._linear(i, s)
                h[i] = cand
                self._pos[i] += s

    def _parabolic(self, i: int, s: float) -> float:
        h, n = self._heights, self._pos
        return h[i] + s / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + s)
            * (h[i + 1] - h[i])
            / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - s)
            * (h[i] - h[i - 1])
            / (n[i] - n[i - 1])
        )

    def _linear(self, i: int, s: float) -> float:
        h, n = self._heights, self._pos
        j = i + int(s)
        return h[i] + s * (h[j] - h[i]) / (n[j] - n[i])

    def value(self) -> float:
        if not self._heights:
            return 0.0
        if self.count < 5:
            # exact small-sample quantile (nearest-rank on the sorted buffer)
            idx = min(
                len(self._heights) - 1,
                max(0, round(self.q * (len(self._heights) - 1))),
            )
            return self._heights[idx]
        return self._heights[2]


class LatencyEstimator:
    """Online P50/P95/P99 over completion latencies (milliseconds)."""

    QUANTILES = (0.50, 0.95, 0.99)

    def __init__(self):
        self._est = {q: P2Quantile(q) for q in self.QUANTILES}
        self.count = 0
        self.total_ms = 0.0
        self.max_ms = 0.0

    def add(self, ms: float) -> None:
        self.count += 1
        self.total_ms += ms
        self.max_ms = max(self.max_ms, ms)
        for est in self._est.values():
            est.add(ms)

    def quantile(self, q: float) -> float:
        return self._est[q].value()

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p95(self) -> float:
        return self.quantile(0.95)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def mean(self) -> float:
        return self.total_ms / self.count if self.count else 0.0

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean_ms": self.mean,
            "p50_ms": self.p50,
            "p95_ms": self.p95,
            "p99_ms": self.p99,
            "max_ms": self.max_ms,
        }


@dataclass
class ServeStats:
    """First-class serving metrics: every path a request can take shows up
    in exactly one counter, and capacity effects (queue depth, padding
    waste, cache tiering) are observable without instrumenting callers."""

    # request lifecycle counters
    submitted: int = 0
    completed: int = 0
    rejected: int = 0  # admission-queue full: explicit, never silent
    timed_out: int = 0  # deadline passed before the response was computed
    degraded: int = 0  # ok responses built from partial-fanout samples

    # queue observability
    queue_depth: int = 0  # current
    queue_peak: int = 0

    # batch occupancy: real rows/edges vs the padded bucket shapes that
    # actually went through the jit slice (padding waste = 1 - occupancy)
    batches: int = 0
    batch_rows: int = 0
    padded_rows: int = 0
    batch_edges: int = 0
    padded_edges: int = 0

    # per-tier serving-cache hit fractions, refreshed after every batch
    # (keys as HybridStats.hit_ratios(): "0:memory", "1:disk", ..., "dfs")
    cache_hit_ratios: dict = field(default_factory=dict)

    # sampling-backend health by site (keys as system.server_health():
    # "server.<part>.<replica>", plus "worker.<part>" rows under a remote
    # dispatcher), refreshed after every batch — surfaces breaker/worker
    # state on the same dashboard as the serving counters
    server_health: dict = field(default_factory=dict)

    latency: LatencyEstimator = field(default_factory=LatencyEstimator)

    def note_queue_depth(self, depth: int) -> None:
        self.queue_depth = depth
        self.queue_peak = max(self.queue_peak, depth)

    def note_batch(self, rows: int, padded_rows: int, edges: int, padded_edges: int) -> None:
        self.batches += 1
        self.batch_rows += rows
        self.padded_rows += padded_rows
        self.batch_edges += edges
        self.padded_edges += padded_edges

    def occupancy(self) -> float:
        """Fraction of padded vertex rows that carried real requests."""
        return self.batch_rows / self.padded_rows if self.padded_rows else 0.0

    def edge_occupancy(self) -> float:
        return self.batch_edges / self.padded_edges if self.padded_edges else 0.0

    def mean_batch_requests(self) -> float:
        done = self.completed - self.timed_out
        return done / self.batches if self.batches else 0.0

    def snapshot(self) -> dict:
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "timed_out": self.timed_out,
            "degraded": self.degraded,
            "queue_depth": self.queue_depth,
            "queue_peak": self.queue_peak,
            "batches": self.batches,
            "occupancy": self.occupancy(),
            "edge_occupancy": self.edge_occupancy(),
            "cache_hit_ratios": dict(self.cache_hit_ratios),
            "server_health": dict(self.server_health),
            "latency": self.latency.summary(),
        }
