"""``GNNServer`` — the online inference serving driver.

Serving turns the offline layerwise artifact into a request surface: an
``infer_layerwise`` run leaves per-layer embedding stores on disk, and a
live "embed these vertices now" request only needs the FINAL layer
recomputed — one sampled hop plus one layer slice over the layer-(K-1)
store.  That store is read through a serving ``HybridCache``, so the Zipf
head (hot users) migrates into the memory tier and the paper's power-law
popularity assumption becomes a serving win, not just a partitioning one.

Request lifecycle (cooperative, single-threaded like ``SamplingService``):

1. ``submit`` — admission against the bounded :class:`RequestQueue`
   (queue-full is an explicit ``rejected`` response, counted, never
   silent), then the request's one-hop sample is submitted to the
   ``SamplingService`` immediately, keyed ``(_SERVE_TAG, request_id)``:
   sampling for everything queued rides in flight together, hiding hop
   latency behind the compute of earlier batches.
2. ``step`` — the :class:`ContinuousBatcher` packs queue-order requests
   into the engine's power-of-two shape buckets; partial buckets flush on
   the ``max_batch_delay_ms`` timer.  Each flushed batch waits on its
   tickets under the per-request deadline (``SampleTicket.result(timeout=)``),
   completes deadline-missed requests with explicit ``timeout`` responses,
   and runs one padded slice through the engine's cached jit — the same
   (layer, bucket) compile the offline pass already traced.
3. ``response`` / ``drain`` — collect :class:`ServeResponse` objects.

Determinism: each request's sample stream is keyed by its request id and
its compute rows are padded row-independently, so the returned embeddings
are bit-identical whether the request was served solo or packed into any
batch mix (property-tested in tests/test_serve.py).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.inference.engine import csr_gather
from repro.core.sampling.service import SampleTimeout, SamplingSpec
from repro.serve.batcher import ContinuousBatcher
from repro.serve.queue import RequestQueue
from repro.serve.request import ServeRequest, ServeResponse
from repro.serve.stats import ServeStats

__all__ = ["GNNServer"]

# domain-separation tag for serving sample-request keys: never aliases the
# trainer/loader (pipeline counter) or engine (_ENGINE_KEY_TAG) streams
_SERVE_TAG = 0x5E12


class GNNServer:
    """Online serving over a built ``GLISPSystem`` with a completed
    ``infer_layerwise`` run (construct via ``system.server()``)."""

    def __init__(
        self,
        system,
        *,
        queue_depth: int = 64,
        max_batch_delay_ms: float = 2.0,
        deadline_ms: float | None = 100.0,
    ):
        engine = system.infer_engine
        if engine is None or engine.last_result is None or not engine.layer_stores:
            raise ValueError(
                "GNNServer needs a completed infer_layerwise() run on this "
                "system (the per-layer embedding stores and the cached "
                "engine drive serving); call system.infer_layerwise(...) "
                "first"
            )
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be positive or None, got {deadline_ms}"
            )
        self.system = system
        self.engine = engine
        self.deadline_ms = deadline_ms
        k = len(engine.layer_fns) - 1
        self.layer = k
        self.newid = engine.last_result.newid
        self.store = engine.layer_stores[k]  # layer-(K-1) embeddings
        # the serving cache: same tier stack/policy as the offline engine,
        # demand-filled by request traffic so hot rows settle in the fast
        # tiers (per-tier ratios surface in ServeStats.cache_hit_ratios)
        self.cache = engine._build_cache(self.store)
        self.spec = SamplingSpec(
            fanouts=(engine.fanouts[k],), direction=engine.direction
        )
        self._needs_etype = getattr(engine.layer_fns[k], "needs_etype", False)
        self.queue = RequestQueue(queue_depth)
        self.batcher = ContinuousBatcher(engine.batch_size, max_batch_delay_ms)
        self.stats = ServeStats()
        self._next_id = 0
        self._responses: dict[int, ServeResponse] = {}
        self._tickets: dict[int, object] = {}  # request_id -> SampleTicket

    # -- submission ----------------------------------------------------
    def submit(
        self,
        vertices: np.ndarray,
        *,
        deadline_ms: float | None = None,
        now: float | None = None,
    ) -> int:
        """Admit one request; returns its request id.

        Rejected requests (queue full) complete immediately with
        ``status="rejected"`` — poll :meth:`response` either way."""
        now = time.monotonic() if now is None else now
        rid = self._next_id
        self._next_id += 1
        req = ServeRequest.make(rid, vertices, deadline_ms, now)
        self.stats.submitted += 1
        if not self.queue.push(req):
            self.stats.rejected += 1
            self._responses[rid] = ServeResponse(request_id=rid, status="rejected")
            return rid
        self.stats.note_queue_depth(len(self.queue))
        # sample NOW, not at batch-flush time: every queued request's hop
        # rides the SamplingService in-flight window while earlier batches
        # compute — request keying keeps the draw independent of traffic
        self._tickets[rid] = self.system.submit(
            req.unique, self.spec, key=(_SERVE_TAG, rid)
        )
        return rid

    def response(self, request_id: int, *, pop: bool = True) -> ServeResponse | None:
        """The finished response for ``request_id``, or ``None`` if still
        pending.  ``pop=True`` releases it from the server's buffer."""
        if pop:
            return self._responses.pop(request_id, None)
        return self._responses.get(request_id)

    def pending(self) -> int:
        """Requests admitted but not yet answered."""
        return len(self.queue) + len(self.batcher)

    # -- the serving loop ----------------------------------------------
    def step(self, *, now: float | None = None, force: bool = False) -> int:
        """One scheduler step: move admitted requests into the batcher,
        flush if a trigger fired (``force=True`` flushes a partial batch —
        use when no further arrivals are expected), compute, complete.
        Returns the number of requests answered this step."""
        now = time.monotonic() if now is None else now
        while self.queue and self.batcher.has_room():
            req = self.queue.pop()
            self.batcher.add(req, req.unique.shape[0], now)
        self.stats.note_queue_depth(len(self.queue))
        batch = self.batcher.take(now, force=force)
        if batch is None:
            return 0
        return self._serve_batch(batch)

    def drain(self) -> None:
        """Serve until nothing is pending (forces partial flushes)."""
        while self.pending():
            self.step(force=True)

    def call(self, vertices: np.ndarray, *, deadline_ms: float | None = None) -> ServeResponse:
        """Blocking convenience: submit one request and serve it through."""
        # GNNServer.submit keys its sampling itself: (_SERVE_TAG, request_id)
        rid = self.submit(vertices, deadline_ms=deadline_ms)  # glint: disable=DET004 -- see above
        resp = self.response(rid)
        while resp is None:
            self.step(force=True)
            resp = self.response(rid)
        return resp

    # -- batch execution -----------------------------------------------
    def _finish(self, req: ServeRequest, resp: ServeResponse, now: float) -> None:
        resp.latency_ms = (now - req.submitted_at) * 1e3
        self._responses[req.request_id] = resp
        self.stats.completed += 1
        if resp.status == "timeout":
            self.stats.timed_out += 1
        if resp.degraded:
            self.stats.degraded += 1
        self.stats.latency.add(resp.latency_ms)

    def _serve_batch(self, batch: list) -> int:
        """Wait out the batch's samples, drop deadline-missed requests with
        explicit timeout responses, run ONE padded slice for the rest."""
        live: list = []  # (req, sub)
        for req in batch:
            ticket = self._tickets.pop(req.request_id)
            deadline = req.deadline_at(self.deadline_ms)
            now = time.monotonic()
            if deadline is not None and now >= deadline:
                ticket.cancel()
                self._finish(
                    req, ServeResponse(request_id=req.request_id, status="timeout"), now
                )
                continue
            try:
                timeout = None if deadline is None else deadline - now
                sub = ticket.result(timeout=timeout)
            except SampleTimeout:
                self._finish(
                    req,
                    ServeResponse(request_id=req.request_id, status="timeout"),
                    time.monotonic(),
                )
                continue
            live.append((req, sub))
        if not live:
            return len(batch)
        outs = self._compute(live)
        done = time.monotonic()
        for (req, sub), emb in zip(live, outs):
            self._finish(
                req,
                ServeResponse(
                    request_id=req.request_id,
                    status="ok",
                    embeddings=emb,
                    degraded=sub.degraded,
                    batch_requests=len(live),
                ),
                done,
            )
        self.stats.cache_hit_ratios = self.cache.stats.hit_ratios()
        self.stats.server_health = dict(self.system.server_health())
        return len(batch)

    def _compute(self, live: list) -> list[np.ndarray]:
        """One bucketed slice over the batch.  Every request's arrays are
        built independently and concatenated — segment ids only shift by a
        base offset and the padded slice is row-independent, so each
        request's output rows are bit-identical to a solo run."""
        engine, g = self.engine, self.system.graph
        selfs, nbrs, segs, ets, metas = [], [], [], [], []
        base = 0
        for req, sub in live:
            verts = req.unique
            hop = sub.hops[0]
            order = np.argsort(hop.src, kind="stable")
            src, dst = hop.src[order], hop.dst[order]
            starts = np.searchsorted(src, verts)
            counts = np.searchsorted(src, verts, side="right") - starts
            nbr_ids = csr_gather(dst, starts, counts)
            if self._needs_etype:
                if hop.eid is not None:
                    et_sorted = g.edge_types[hop.eid[order]].astype(np.int32)
                else:
                    et_sorted = np.zeros(src.shape[0], np.int32)
                ets.append(csr_gather(et_sorted, starts, counts))
            selfs.append(self.cache.read_rows(self.newid[verts]))
            nbrs.append(
                self.cache.read_rows(self.newid[nbr_ids])
                if nbr_ids.shape[0]
                else np.zeros((0, self.store.dim), self.store.dtype)
            )
            segs.append(np.repeat(np.arange(verts.shape[0]), counts) + base)
            metas.append((verts.shape[0], int(nbr_ids.shape[0])))
            base += verts.shape[0]
        h_self = np.concatenate(selfs)
        h_nbr = np.concatenate(nbrs)
        seg = np.concatenate(segs).astype(np.int64)
        et = np.concatenate(ets).astype(np.int32) if ets else None
        h_new = engine.run_layer_batch(self.layer, h_self, h_nbr, seg, et)
        self.stats.note_batch(
            h_self.shape[0],
            engine._vertex_bucket(h_self.shape[0]),
            seg.shape[0],
            engine._edge_bucket(seg.shape[0]),
        )
        outs, lo = [], 0
        for (req, _), (nv, _ne) in zip(live, metas):
            block = h_new[lo : lo + nv]
            lo += nv
            # unique-sorted rows back to the requested vertex order
            outs.append(block[np.searchsorted(req.unique, req.vertices)])
        return outs
