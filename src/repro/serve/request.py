"""Serve-tier request/response pair.

A :class:`ServeRequest` is "embed these vertices now": a set of vertex ids
plus a per-request deadline.  The server answers with a
:class:`ServeResponse` carrying the final-layer embeddings in the order the
vertices were requested — or an explicit non-``ok`` status.  Nothing is ever
dropped silently: admission failure is ``status="rejected"``, a missed
deadline is ``status="timeout"``, and a partial-fanout sample (faulted
replicas exhausted) completes with ``degraded=True``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["ServeRequest", "ServeResponse"]


@dataclass(frozen=True)
class ServeRequest:
    """One inference request: vertex ids + deadline.

    ``request_id`` names the request's sampling RNG stream (the server keys
    ``SamplingService.submit`` with it), so the response is a pure function
    of ``(system, request_id, vertices)`` — bit-identical no matter how the
    request is batched with other traffic.  ``deadline_ms`` is the latency
    budget from admission; ``None`` defers to the server's configured
    default."""

    request_id: int
    vertices: np.ndarray
    deadline_ms: float | None = None
    submitted_at: float = 0.0  # monotonic admission timestamp

    # unique-sorted view the compute path runs on (submit() normalizes seeds
    # the same way, so sampling and compute agree on the row universe)
    unique: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))

    @staticmethod
    def make(
        request_id: int,
        vertices: np.ndarray,
        deadline_ms: float | None,
        now: float,
    ) -> "ServeRequest":
        verts = np.asarray(vertices, dtype=np.int64)
        if verts.ndim != 1 or verts.shape[0] == 0:
            raise ValueError(
                f"ServeRequest needs a non-empty 1-D vertex array, got "
                f"shape {verts.shape}"
            )
        return ServeRequest(
            request_id=request_id,
            vertices=verts,
            deadline_ms=deadline_ms,
            submitted_at=now,
            unique=np.unique(verts),
        )

    def deadline_at(self, default_ms: float | None) -> float | None:
        """Absolute monotonic deadline, or None for no bound."""
        ms = self.deadline_ms if self.deadline_ms is not None else default_ms
        return None if ms is None else self.submitted_at + ms / 1e3


@dataclass
class ServeResponse:
    """The answer to one :class:`ServeRequest`.

    ``status`` is one of ``"ok"`` / ``"rejected"`` (admission queue full) /
    ``"timeout"`` (deadline passed before completion).  ``embeddings`` is
    ``(len(vertices), out_dim)`` in the requested order for ``ok``
    responses, ``None`` otherwise.  ``degraded=True`` stamps an ``ok``
    response whose sample lost dispatches to faults (partial fanout — the
    flagged-never-silent contract of ``SampledSubgraph.degraded``)."""

    request_id: int
    status: str
    embeddings: np.ndarray | None = None
    degraded: bool = False
    latency_ms: float = 0.0
    # how many requests shared the compute batch (1 = served solo)
    batch_requests: int = 0

    @property
    def ok(self) -> bool:
        return self.status == "ok"
