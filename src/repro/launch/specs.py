"""Assigned input shapes, ShapeDtypeStruct input specs, and the jit-able
step functions (train / prefill / decode) shared by dryrun, train.py and
serve.py."""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.transformer.config import ArchConfig
from repro.models.transformer.model import forward, init_cache, init_params, lm_loss
from repro.train.optim import AdamWConfig, adamw_init, adamw_update

__all__ = [
    "SHAPES",
    "resolve_config",
    "input_specs",
    "params_shapes",
    "opt_shapes",
    "cache_shapes",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
]

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def resolve_config(
    cfg: ArchConfig, shape_name: str, model_axis: int = 0
) -> ArchConfig | None:
    """Apply the long-context strategy and (when a mesh model-axis size is
    given) head padding for clean tensor-parallel tiling; None means the
    combination is skipped (no arch skips here — every assigned arch has
    native or windowed long decode; see DESIGN.md §4)."""
    if shape_name == "long_500k":
        if cfg.long_context == "window":
            cfg = dataclasses.replace(cfg, window=cfg.long_context_window)
        elif cfg.long_context != "native":
            return None  # "skip"
    if model_axis > 1:
        # head padding pays off where full-sequence attention runs (the
        # score-AR pathology); decode's grouped path has tiny scores, and
        # padded kv would inflate the cache instead
        pad_ok = SHAPES[shape_name]["kind"] in ("train", "prefill")
        cfg = pad_heads_for_mesh(cfg, model_axis, enable_padding=pad_ok)
    return cfg


def pad_heads_for_mesh(
    cfg: ArchConfig, msize: int, enable_padding: bool = True
) -> ArchConfig:
    """Resolve head padding + GQA mode for an msize-way tensor-parallel axis.

    GSPMD only tiles whole tensor dims, so the attention einsums stay
    collective-free iff either (group mode) the kv-head dim itself shards
    msize ways, or (repeat mode) kv is replicated and padded q heads shard
    as whole heads.  A flat split landing inside head_dim instead makes
    every score einsum contract a sharded dim → per-block f32 all-reduces
    (EXPERIMENTS.md §Perf).  Candidates, cheapest padded-head count wins:
      (a) pad kv heads to msize           (group mode, kv sharded)
      (b) pad GQA groups to msize         (group mode, kv replicated)
      (c) pad q heads to lcm(msize, hkv)  (repeat mode, kv replicated)
    Dead heads are sliced away before wo (like vocab padding)."""
    if cfg.kv_lora_rank or not cfg.num_heads:
        return dataclasses.replace(cfg, tp_size=msize)
    h, hkv = cfg.num_heads, cfg.num_kv_heads
    g = h // hkv
    ru = lambda a, b: -(-a // b) * b
    cands = []
    # (a) kv heads shard fully
    hkv_a = ru(hkv, msize)
    cands.append((hkv_a * g, hkv_a))
    # (b) groups shard fully, kv heads replicated
    cands.append((hkv * ru(g, msize), hkv))
    # (c) repeat mode: whole padded q heads shard; must stay multiple of hkv
    l = math.lcm(msize, hkv)
    cands.append((ru(h, l), hkv))
    h_pad, hkv_pad = min(cands)
    if h_pad == h and hkv_pad == hkv:
        return dataclasses.replace(cfg, tp_size=msize)
    if not enable_padding or h_pad > 1.5 * h:
        # dead-head overhead exceeds the measured collective win (gemma
        # train: pad 2.0x regressed the bound 2.77 -> 3.27s) — skip
        return dataclasses.replace(cfg, tp_size=msize)
    return dataclasses.replace(
        cfg, q_head_pad=h_pad, kv_head_pad=hkv_pad, tp_size=msize
    )


def input_specs(cfg: ArchConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for the model inputs of this shape (the
    vlm/audio modality frontend stub: embeddings of the right shape)."""
    sh = SHAPES[shape_name]
    b, s, kind = sh["batch"], sh["seq"], sh["kind"]
    tok = lambda bb, ss: jax.ShapeDtypeStruct((bb, ss), jnp.int32)
    emb = lambda bb, ss: jax.ShapeDtypeStruct((bb, ss, cfg.d_model), jnp.bfloat16)
    is_emb = cfg.input_mode == "embeddings"
    if kind == "train":
        return {
            "inputs": emb(b, s) if is_emb else tok(b, s),
            "targets": tok(b, s),
        }
    if kind == "prefill":
        return {"inputs": emb(b, s) if is_emb else tok(b, s)}
    # decode: one new token against a seq_len-deep cache
    return {"inputs": emb(b, 1) if is_emb else tok(b, 1)}


def params_shapes(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def opt_shapes(cfg: ArchConfig):
    return jax.eval_shape(adamw_init, params_shapes(cfg))


def cache_shapes(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig | None = None,
    remat: bool = True,
    unroll: bool = False,
):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        (loss, (nll, aux)), grads = jax.value_and_grad(
            lambda p: lm_loss(
                p, cfg, batch["inputs"], batch["targets"], remat=remat, unroll=unroll
            ),
            has_aux=True,
        )(params)
        params, opt_state, info = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, "nll": nll, "aux": aux, **info}

    return train_step


def make_prefill_step(cfg: ArchConfig, unroll: bool = False):
    def prefill(params, cache, batch):
        logits, _, cache = forward(
            params, cfg, batch["inputs"], cache, 0, last_only=True, unroll=unroll
        )
        return logits[:, -1], cache

    return prefill


def make_decode_step(cfg: ArchConfig, unroll: bool = False):
    def decode(params, cache, batch, pos):
        logits, _, cache = forward(
            params, cfg, batch["inputs"], cache, pos, unroll=unroll
        )
        return logits[:, -1], cache

    return decode
