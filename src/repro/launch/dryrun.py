import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh WITHOUT real hardware, then extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k --mesh single --out experiments/dryrun

The two XLA_FLAGS lines above MUST run before any other jax import — jax
locks the device count at first init (hence 512 host placeholder devices
exist only inside this process; tests and benches see 1).
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.shardings import (
    batch_specs,
    cache_specs,
    data_axes,
    named,
    opt_state_specs,
    param_specs,
)
from repro.launch.specs import (
    SHAPES,
    cache_shapes,
    input_specs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    opt_shapes,
    params_shapes,
    resolve_config,
)
from jax.sharding import PartitionSpec as P

from repro.launch.roofline import parse_collectives, roofline

def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str, verbose=True, unroll=False):
    cfg0 = get_config(arch)
    cfg = resolve_config(cfg0, shape_name, model_axis=16)
    if cfg is not None and cfg.moe is not None and cfg.moe.num_experts % 16:
        # grouped per-data-shard dispatch ONLY when experts don't divide the
        # model axis (mixtral 8/16): expert-divisible archs (deepseek 64/16)
        # get natural expert-parallel propagation from the sharded weights,
        # and the group constraints fight it (measured: 23s -> 155s coll).
        import dataclasses as _dc

        dsize = 32 if multi_pod else 16
        if SHAPES[shape_name]["batch"] * SHAPES[shape_name]["seq"] % dsize == 0:
            axes = ("pod", "data") if multi_pod else ("data",)
            cfg = _dc.replace(
                cfg, moe_dispatch_groups=dsize, data_axis_names=axes
            )
    if cfg is None:
        return {"arch": arch, "shape": shape_name, "skipped": True}
    mesh = make_production_mesh(multi_pod=multi_pod)
    num_chips = mesh.devices.size
    sh = SHAPES[shape_name]
    kind = sh["kind"]

    pshapes = params_shapes(cfg)
    pspecs = param_specs(cfg, pshapes, mesh)
    ins = input_specs(cfg, shape_name)
    bspecs = batch_specs(cfg, sh["batch"], mesh)

    t0 = time.perf_counter()
    with mesh:
        if kind == "train":
            step = make_train_step(cfg, unroll=unroll)
            oshapes = opt_shapes(cfg)
            ospecs = opt_state_specs(pspecs)
            metr_specs = {k: P() for k in ("loss", "nll", "aux", "lr", "grad_norm")}
            jitted = jax.jit(
                step,
                in_shardings=named(mesh, (pspecs, ospecs, {k: bspecs[k] for k in ins})),
                out_shardings=named(mesh, (pspecs, ospecs, metr_specs)),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(pshapes, oshapes, ins)
        else:
            cache_len = sh["seq"]
            cshapes = cache_shapes(cfg, sh["batch"], cache_len)
            cspecs = cache_specs(cfg, cshapes, mesh)
            logits_spec = P(None, "model") if cfg.vocab_size % mesh.shape["model"] == 0 else P(None, None)
            if kind == "prefill":
                step = make_prefill_step(cfg, unroll=unroll)
                jitted = jax.jit(
                    step,
                    in_shardings=named(
                        mesh, (pspecs, cspecs, {"inputs": bspecs["inputs"]})
                    ),
                    out_shardings=named(mesh, (logits_spec, cspecs)),
                    donate_argnums=(1,),
                )
                lowered = jitted.lower(pshapes, cshapes, {"inputs": ins["inputs"]})
            else:
                step = make_decode_step(cfg, unroll=unroll)
                jitted = jax.jit(
                    step,
                    in_shardings=named(
                        mesh,
                        (pspecs, cspecs, {"inputs": bspecs["inputs"]}, None),
                    ),
                    out_shardings=named(mesh, (logits_spec, cspecs)),
                    donate_argnums=(1,),
                )
                pos = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = jitted.lower(pshapes, cshapes, {"inputs": ins["inputs"]}, pos)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    rf = roofline(cfg, shape_name, dict(mesh.shape), num_chips, cost, coll)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_chips": int(num_chips),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes_per_device": int(
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
            ),
        },
        "collectives": coll,
        "roofline": rf,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=2)
    if verbose:
        print(
            f"[dryrun] {arch} × {shape_name} × {result['mesh']}: "
            f"compile {t_compile:.1f}s | "
            f"mem/dev {result['memory']['peak_bytes_per_device']/2**30:.2f} GiB | "
            f"compute {rf['compute_s']*1e3:.2f} ms, memory {rf['memory_s']*1e3:.2f} ms, "
            f"collective {rf['collective_s']*1e3:.2f} ms -> {rf['dominant']}",
            flush=True,
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=list(SHAPES) + ["all"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll layer scans (accurate cost_analysis)")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for a in archs:
        for s in shapes:
            for mp in meshes:
                try:
                    run_one(a, s, mp, args.out, unroll=args.unroll)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((a, s, mp, repr(e)))
                    print(f"[dryrun] FAIL {a} × {s} × {'multi' if mp else 'single'}: {e}",
                          flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nAll dry-runs compiled successfully.")


if __name__ == "__main__":
    main()
