"""Roofline extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), all per-chip seconds:

    compute_s    = analytic_model_flops / chips / peak_bf16
    memory_s     = max(cost_analysis bytes, analytic traffic) / HBM_bw
    collective_s = collective bytes parsed from the post-SPMD HLO / ICI_bw

Why analytic FLOPs: XLA's HloCostAnalysis counts a `while` body ONCE — a
24-layer lax.scan (or a 32-block flash loop) is undercounted by its trip
count.  We therefore count model FLOPs analytically (the standard MFU
accounting, including the attention S² terms, MoE capacity and SSD chunk
terms) and report the raw cost_analysis number alongside for transparency.

Collective bytes ARE taken from the compiled HLO (that's the real compiled
schedule), with while-loop trip counts recovered from the loop-condition
constants and multiplied through nested bodies.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

from repro.launch.mesh import HW
from repro.launch.specs import SHAPES
from repro.models.transformer.config import ArchConfig

__all__ = [
    "analytic_flops",
    "analytic_hbm_bytes",
    "parse_collectives",
    "roofline",
    "kernel_flops",
    "kernel_hbm_bytes",
    "kernel_roofline",
]


# ---------------------------------------------------------------------------
# analytic FLOPs
# ---------------------------------------------------------------------------


def _avg_context(S: int, window: int) -> float:
    """Mean causal context length over positions 0..S-1 (window-capped)."""
    if window <= 0 or window >= S:
        return S / 2
    # mean of min(t, w) over t in [0, S)
    return (window * (window - 1) / 2 + (S - window) * window) / S


def _mixer_flops_seq(cfg: ArchConfig, kind: str, S: int, decode_ctx: int | None):
    """FLOPs for one mixer layer over a sequence of S tokens (decode: S=1 and
    attention context = decode_ctx)."""
    d, dh = cfg.d_model, cfg.resolved_head_dim
    h, hkv = cfg.num_heads, cfg.num_kv_heads
    if kind in ("attn", "local_attn"):
        window = cfg.local_window if kind == "local_attn" else cfg.window
        if cfg.kv_lora_rank:
            r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
            proj = S * 2 * d * (h * (dh + rd) + r + rd) + S * 2 * h * dh * d
            if decode_ctx is None:
                up = S * 2 * r * 2 * h * dh
                ctx = _avg_context(S, window)
            else:
                ctx = min(decode_ctx, window) if window else decode_ctx
                up = 2 * ctx * r * 2 * h * dh  # non-absorbed MLA decode
            attn = 2 * S * ctx * h * (dh + rd) + 2 * S * ctx * h * dh
            return proj + up + attn
        proj = S * (2 * d * h * dh + 4 * d * hkv * dh + 2 * h * dh * d)
        ctx = (
            _avg_context(S, window)
            if decode_ctx is None
            else (min(decode_ctx, window) if window else decode_ctx)
        )
        attn = 4 * S * ctx * h * dh
        return proj + attn
    if kind == "ssm":
        s = cfg.ssm
        d_in = s.expand * d
        nh = s.num_heads or d_in // s.head_dim
        g, n, p, L = s.num_groups, s.state_dim, s.head_dim, s.chunk
        proj = S * 2 * d * (2 * d_in + 2 * g * n + nh)
        conv = S * 2 * s.conv_width * (d_in + 2 * g * n)
        if decode_ctx is None:
            ssd = S * nh * (2 * L * n + 2 * L * p + 4 * n * p)
        else:
            ssd = S * nh * 6 * n * p  # single recurrence step
        out = S * 2 * d_in * d
        return proj + conv + ssd + out
    if kind == "rglru":
        return S * (2 * d * 2 * d + 4 * d * d + 2 * d * d + 12 * d)
    raise ValueError(kind)


def _mlp_flops_seq(cfg: ArchConfig, kind: str, S: int):
    d = cfg.d_model
    if kind == "ssm":
        return 0
    if cfg.moe is not None:
        e = cfg.moe
        dff = e.expert_d_ff or cfg.d_ff
        return S * (
            2 * d * e.num_experts
            + e.top_k * e.capacity_factor * 6 * d * dff
            + e.num_shared * 6 * d * dff
        )
    mats = 2 if cfg.activation == "gelu" else 3
    return S * mats * 2 * d * cfg.d_ff


def analytic_flops(cfg: ArchConfig, shape_name: str) -> dict:
    """Global (all-chips) FLOPs for one step of this shape."""
    sh = SHAPES[shape_name]
    B, S, kind = sh["batch"], sh["seq"], sh["kind"]
    decode = kind == "decode"
    s_tok = 1 if decode else S
    ctx = S if decode else None

    fwd = 0.0
    for lk in cfg.layer_kinds():
        fwd += _mixer_flops_seq(cfg, lk, s_tok, ctx)
        fwd += _mlp_flops_seq(cfg, lk, s_tok)
    head_tokens = s_tok if kind == "train" else 1
    fwd += head_tokens * 2 * cfg.d_model * cfg.vocab_size
    fwd *= B
    total = 3 * fwd if kind == "train" else fwd
    # 6·N·D convention for cross-checking (active params for MoE)
    n_active = cfg.num_params()
    if cfg.moe is not None:
        e = cfg.moe
        dff = e.expert_d_ff or cfg.d_ff
        n_active -= cfg.num_layers * (e.num_experts - e.top_k) * 3 * cfg.d_model * dff
    model_flops_6nd = (6 if kind == "train" else 2) * n_active * B * s_tok
    return {"total": total, "fwd": fwd, "6nd": model_flops_6nd}


# ---------------------------------------------------------------------------
# analytic HBM traffic (documented lower-bound model, per device)
# ---------------------------------------------------------------------------


def analytic_hbm_bytes(cfg: ArchConfig, shape_name: str, mesh_shape: dict) -> float:
    sh = SHAPES[shape_name]
    B, S, kind = sh["batch"], sh["seq"], sh["kind"]
    msize = mesh_shape.get("model", 1)
    dsize = 1
    for a in ("data", "pod"):
        dsize *= mesh_shape.get(a, 1)
    n_params = cfg.num_params()
    p_dev = 4 * n_params / msize  # fp32 master weights, model-sharded only
    b_dev = max(1, B // dsize)

    if kind == "train":
        # params: fwd read + remat read + bwd read; grads w+r; adam m,v r+w;
        # saved layer inputs (bf16) w+r; logits fp32 few passes
        act = cfg.num_layers * b_dev * S * cfg.d_model * 2 * 2
        logits = 3 * b_dev * S * (cfg.vocab_size / msize) * 4
        return 3 * p_dev + 2 * p_dev + 4 * p_dev + act + logits
    if kind == "prefill":
        act = cfg.num_layers * b_dev * S * cfg.d_model * 2 * 2
        cache = _cache_bytes_dev(cfg, S, b_dev, msize)
        return p_dev + act + cache
    # decode: weights once (fp32 read), cache read+write
    cache = _cache_bytes_dev(cfg, S, b_dev, msize)
    return p_dev + 2 * cache


def _cache_bytes_dev(cfg: ArchConfig, S: int, b_dev: int, msize: int) -> float:
    total = 0.0
    for lk in cfg.layer_kinds():
        if lk in ("attn", "local_attn"):
            L = S
            if lk == "local_attn":
                L = min(S, cfg.local_window)
            elif cfg.window:
                L = min(S, cfg.window)
            if cfg.kv_lora_rank:
                per_tok = (cfg.kv_lora_rank + cfg.rope_head_dim) * 2
            else:
                per_tok = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2
            # kv-head (or sequence) dim is model-sharded when divisible
            total += b_dev * L * per_tok / msize
        elif lk == "ssm":
            s = cfg.ssm
            nh = s.num_heads or s.expand * cfg.d_model // s.head_dim
            total += b_dev * nh * s.head_dim * s.state_dim * 4
        elif lk == "rglru":
            total += b_dev * cfg.d_model * 4
    return total


# ---------------------------------------------------------------------------
# collective parsing with while-loop trip counts
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"\bwhile\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_RE = re.compile(
    r"=\s+(\(?[a-z0-9\[\],{}\s:]*?\)?)\s*(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\("
)


def _shape_bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for x in dims.split(","):
                n *= int(x)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Comp:
    colls: dict
    counts: dict
    whiles: list  # (cond_name, body_name)


def parse_collectives(hlo_text: str) -> dict:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    cur_name = None
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_START.match(line.strip()) if line.rstrip().endswith("{") else None
        if m and not line.startswith(" "):
            cur_name = m.group(1)
            cur = _Comp({k: 0 for k in _COLL_OPS}, {k: 0 for k in _COLL_OPS}, [])
            comps[cur_name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur_name
            continue
        if cur is None:
            continue
        s = line.strip()
        if s == "}":
            cur = None
            continue
        wm = _WHILE_RE.search(s)
        if wm:
            cur.whiles.append((wm.group(1), wm.group(2)))
            continue
        cm = _COLL_RE.search(s)
        if cm and not s.startswith("ROOT %get"):
            if "-done(" in s:
                continue
            op = cm.group(2)
            out_bytes = _shape_bytes_of(cm.group(1))
            cur.colls[op] += out_bytes
            cur.counts[op] += 1

    def trip(cond_name: str) -> int:
        # crude but effective: the loop bound is the largest integer constant
        # in the condition computation (induction comparisons vs trip count)
        comp_text = _comp_texts.get(cond_name, "")
        consts = [int(x) for x in _CONST_RE.findall(comp_text)]
        return max(consts) if consts else 1

    # second pass to capture raw text per computation (for trip counts)
    _comp_texts: dict[str, str] = {}
    name = None
    buf: list[str] = []
    for line in hlo_text.splitlines():
        m = _COMP_START.match(line.strip()) if line.rstrip().endswith("{") else None
        if m and not line.startswith(" "):
            if name:
                _comp_texts[name] = "\n".join(buf)
            name = m.group(1)
            buf = []
        elif name:
            buf.append(line)
    if name:
        _comp_texts[name] = "\n".join(buf)

    memo: dict[str, tuple[dict, dict]] = {}

    def total(comp_name: str) -> tuple[dict, dict]:
        if comp_name in memo:
            return memo[comp_name]
        c = comps.get(comp_name)
        if c is None:
            return ({k: 0 for k in _COLL_OPS}, {k: 0 for k in _COLL_OPS})
        memo[comp_name] = (dict(c.colls), dict(c.counts))  # break cycles
        bytes_, counts_ = dict(c.colls), dict(c.counts)
        for cond, body in c.whiles:
            t = trip(cond)
            bb, bc = total(body)
            for k in _COLL_OPS:
                bytes_[k] += t * bb[k]
                counts_[k] += t * bc[k]
        memo[comp_name] = (bytes_, counts_)
        return memo[comp_name]

    if entry is None:
        entry = next(iter(comps), None)
    b, c = total(entry) if entry else ({k: 0 for k in _COLL_OPS},) * 2
    return {"bytes": b, "counts": c, "total_bytes": sum(b.values())}


# ---------------------------------------------------------------------------
# per-kernel analytic models (GNN Pallas suite; see repro.kernels.fused_gnn)
# ---------------------------------------------------------------------------
#
# Shape dict keys: edges E (padded), segments N, dim D, and optionally
# feat_rows F (gather ops, default N), valid_edges Ev (ragged ops, default
# E; padding assumed to be a suffix as the engine lays it out), block_rows
# BN / block_edges BM (default 128), dtype_bytes b (default 4).
#
# FLOPs count the one-hot contraction as a dense (BN×BM)·(BM×D) matmul per
# tile — that IS what the MXU executes, so achieved-vs-peak is an honest
# hardware fraction even though most one-hot entries are zero.  Bytes model
# HBM traffic under the kernels' actual block residency:
#   * segment_spmm (2-D grid) re-reads each edge tile once per ROW block;
#   * the fused/ragged 1-D-grid kernels keep the output resident and read
#     each edge tile once — and gather_spmm never materializes the [E, D]
#     message array at all (that round trip is the fusion win);
#   * ragged variants only touch the ~ceil(Ev/BM) non-empty tiles.


def _kshape(shape: dict) -> tuple:
    e = float(shape["edges"])
    n = float(shape["segments"])
    d = float(shape["dim"])
    f = float(shape.get("feat_rows", n))
    ev = float(shape.get("valid_edges", e))
    bn = float(shape.get("block_rows", 128))
    bm = float(shape.get("block_edges", 128))
    b = float(shape.get("dtype_bytes", 4))
    tiles = -(-e // bm)  # total edge tiles
    active = min(tiles, -(-ev // bm)) if ev > 0 else 0.0  # non-empty tiles
    return e, n, d, f, ev, bn, bm, b, tiles, active


KERNEL_OPS = (
    "segment_spmm",
    "segment_spmm_ragged",
    "gather_spmm",
    "gather_spmm_ragged",
    "gat_softmax_aggregate",
    "segment_max",
    "unfused_gather_spmm",  # gather -> segment_spmm sequence, for comparison
)


def kernel_flops(op: str, shape: dict) -> float:
    e, n, d, f, ev, bn, bm, b, tiles, active = _kshape(shape)
    matmul = 2.0 * n * d  # per edge row fed to the MXU
    if op in ("segment_spmm", "gather_spmm", "unfused_gather_spmm"):
        return e * matmul
    if op in ("segment_spmm_ragged", "gather_spmm_ragged"):
        return active * bm * matmul
    if op == "gat_softmax_aggregate":
        # matmul + membership/max/exp/rescale vector work per (edge, row)
        return e * (matmul + 8.0 * n)
    if op == "segment_max":
        return 2.0 * e * n  # compare + select
    raise ValueError(f"unknown kernel op {op!r}")


def kernel_hbm_bytes(op: str, shape: dict) -> float:
    e, n, d, f, ev, bn, bm, b, tiles, active = _kshape(shape)
    row_blocks = -(-n // bn)
    out = n * d * b
    if op == "segment_spmm":
        # each edge tile (msg + seg) re-read once per row block
        return row_blocks * e * (d * b + 4) + out
    if op == "segment_spmm_ragged":
        return active * bm * (d * b + 4) + 4 * tiles + out
    if op == "gather_spmm":
        return f * d * b + e * 8 + out
    if op == "gather_spmm_ragged":
        return f * d * b + e * 8 + 4 * tiles + out
    if op == "gat_softmax_aggregate":
        return e * (d * b + b + 4) + n * (d + 2) * 4
    if op == "segment_max":
        return e * (b + 4) + n * 4
    if op == "unfused_gather_spmm":
        # gather: feats read + [E, D] msg write; spmm: msg+seg re-read per
        # row block; out write.  The msg round trip is what fusion deletes.
        return f * d * b + e * d * b + row_blocks * e * (d * b + 4) + out
    raise ValueError(f"unknown kernel op {op!r}")


def kernel_roofline(op: str, shape: dict, wall_s: float, dtype: str = "f32") -> dict:
    """Achieved-vs-peak for one measured kernel wall-clock.

    Peak FLOP/s follows the dtype (the MXU's f32 rate is half its bf16
    rate); ``bound`` names the limiting resource at these shapes and
    ``frac_of_*`` are the honest hardware fractions ``benchmarks/kernels.py``
    reports.  In interpret mode wall-clock is Python-loop dominated, so the
    fractions are only meaningful on a real TPU — the analytic terms and the
    ``bound_s`` floor are hardware truths either way."""
    fl = kernel_flops(op, shape)
    by = kernel_hbm_bytes(op, shape)
    peak = HW["peak_flops_bf16"] * (0.5 if dtype in ("f32", "float32") else 1.0)
    compute_s = fl / peak
    memory_s = by / HW["hbm_bw"]
    bound_s = max(compute_s, memory_s)
    achieved_flops = fl / wall_s if wall_s > 0 else 0.0
    achieved_bw = by / wall_s if wall_s > 0 else 0.0
    return {
        "op": op,
        "flops": fl,
        "hbm_bytes": by,
        "arithmetic_intensity": fl / by if by else 0.0,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "bound_s": bound_s,
        "bound": "compute" if compute_s >= memory_s else "memory",
        "wall_s": wall_s,
        "achieved_flops_per_s": achieved_flops,
        "frac_of_peak_flops": achieved_flops / peak if peak else 0.0,
        "achieved_bytes_per_s": achieved_bw,
        "frac_of_hbm_bw": achieved_bw / HW["hbm_bw"],
        "frac_of_bound": bound_s / wall_s if wall_s > 0 else 0.0,
    }


# ---------------------------------------------------------------------------
# combined roofline
# ---------------------------------------------------------------------------


def roofline(
    cfg: ArchConfig,
    shape_name: str,
    mesh_shape: dict,
    num_chips: int,
    cost: dict,
    coll: dict,
) -> dict:
    fl = analytic_flops(cfg, shape_name)
    flops_dev = fl["total"] / num_chips
    hlo_flops_dev = float(cost.get("flops", 0.0))
    hlo_bytes_dev = float(cost.get("bytes accessed", 0.0))
    analytic_bytes = analytic_hbm_bytes(cfg, shape_name, mesh_shape)
    bytes_dev = max(hlo_bytes_dev, analytic_bytes)
    compute_s = flops_dev / HW["peak_flops_bf16"]
    memory_s = bytes_dev / HW["hbm_bw"]
    collective_s = coll["total_bytes"] / HW["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dominant,
        "step_time_bound_s": max(terms.values()),
        "analytic_flops_global": fl["total"],
        "model_flops_6nd_global": fl["6nd"],
        "useful_flops_ratio": fl["6nd"] / fl["total"] if fl["total"] else 0.0,
        "hlo_flops_per_device_raw": hlo_flops_dev,
        "hlo_bytes_per_device_raw": hlo_bytes_dev,
        "analytic_bytes_per_device": analytic_bytes,
        "collective_bytes_per_device": coll["total_bytes"],
    }
