"""Training launcher.

Two modes:
  GNN (the paper's workload):
    PYTHONPATH=src python -m repro.launch.train gnn --config sage-products \
        --epochs 2
  LM (assigned architecture pool, reduced configs on CPU):
    PYTHONPATH=src python -m repro.launch.train lm --arch gemma-2b --reduced \
        --steps 50 --batch 8 --seq 128

On real TPU hardware the LM path shards over make_production_mesh(); on this
CPU box it runs the reduced configs on the local degenerate mesh.
"""
from __future__ import annotations

import argparse

import numpy as np


def run_gnn(args):
    import jax

    from repro.configs.gnn import get_gnn_config
    from repro.core.partition import (
        adadne,
        distributed_ne,
        hash2d_partition,
        random_edge_partition,
    )
    from repro.core.sampling import GatherApplyClient, SamplingServer, VertexRouter
    from repro.graph import build_partitions, named_dataset
    from repro.models.gnn import GNNModel
    from repro.train import GNNTrainer

    cfg = get_gnn_config(args.config)
    g = named_dataset(
        cfg.dataset, feat_dim=cfg.feat_dim, num_classes=cfg.num_classes,
        seed=args.seed, scale=args.scale,
    )
    print(f"dataset {cfg.dataset}: {g.num_vertices} vertices, {g.num_edges} edges")
    part_fn = {
        "adadne": adadne,
        "dne": distributed_ne,
        "hash2d": hash2d_partition,
        "random": random_edge_partition,
    }[cfg.partitioner]
    ep = part_fn(g, cfg.num_parts, seed=args.seed)
    parts = build_partitions(g, ep, cfg.num_parts)
    client = GatherApplyClient(
        [SamplingServer(p, seed=args.seed) for p in parts],
        VertexRouter(g, ep, cfg.num_parts),
        seed=args.seed,
    )
    model = GNNModel(
        cfg.model,
        cfg.feat_dim,
        hidden=cfg.hidden,
        num_layers=cfg.num_layers,
        num_classes=cfg.num_classes,
        num_heads=cfg.num_heads,
    )
    ids = np.arange(g.num_vertices)
    rng = np.random.default_rng(args.seed)
    rng.shuffle(ids)
    n_train = int(0.8 * len(ids))
    trainer = GNNTrainer(
        model, client, g, list(cfg.fanouts), ids[:n_train],
        batch_size=cfg.batch_size, direction=cfg.direction, seed=args.seed,
    )
    log = trainer.train(epochs=args.epochs, log_every=args.log_every)
    acc = trainer.evaluate(ids[n_train:])
    print(
        f"final loss {log.losses[-1]:.4f} | test acc {acc:.4f} | "
        f"sample {log.sample_time:.1f}s compute {log.compute_time:.1f}s"
    )


def run_lm(args):
    from repro.configs import get_config
    from repro.train import LMTrainer

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.input_mode == "embeddings":
        raise SystemExit(
            f"{cfg.name} consumes precomputed embeddings; use "
            "python -m repro.launch.serve (transformer decode) or "
            "examples/serve_gnn.py (online GNN serving)"
        )
    tr = LMTrainer(cfg, batch=args.batch, seq_len=args.seq, seed=args.seed)
    log = tr.train(args.steps, log_every=args.log_every)
    print(f"nll: {log.losses[0]:.4f} -> {log.losses[-1]:.4f}")
    if args.ckpt:
        tr.save(args.ckpt, step=args.steps)
        print("checkpoint saved to", args.ckpt)


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)
    g = sub.add_parser("gnn")
    g.add_argument("--config", default="sage-products")
    g.add_argument("--epochs", type=int, default=1)
    g.add_argument("--scale", type=float, default=0.25)
    g.add_argument("--seed", type=int, default=0)
    g.add_argument("--log-every", type=int, default=10)
    lm = sub.add_parser("lm")
    lm.add_argument("--arch", default="gemma-2b")
    lm.add_argument("--reduced", action="store_true", default=True)
    lm.add_argument("--steps", type=int, default=50)
    lm.add_argument("--batch", type=int, default=8)
    lm.add_argument("--seq", type=int, default=128)
    lm.add_argument("--seed", type=int, default=0)
    lm.add_argument("--log-every", type=int, default=10)
    lm.add_argument("--ckpt", default="")
    args = ap.parse_args()
    if args.mode == "gnn":
        run_gnn(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
