"""Production meshes.  Target hardware: TPU v5e pods — 256 chips/pod as a
(16, 16) ("data", "model") mesh; two pods add a leading "pod" axis that the
shardings fold into data parallelism.

Functions (never module-level constants) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before first jax use.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


# TPU v5e hardware constants used by the roofline analysis
HW = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(num_devices: int | None = None):
    """Degenerate mesh over whatever devices exist (CPU smoke tests)."""
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
