"""Sharding rules: map every param/optimizer/cache/batch leaf to a
PartitionSpec on the ("data", "model") production mesh (multi-pod meshes
fold the "pod" axis into data parallelism).

Baseline policy (tensor parallel on "model"):
  embed [V, d]               -> (model, None)          vocab-sharded table
  attn wq / wk / wv [.., d, H*Dh] -> (.., None, model) head-sharded
  attn wo [.., H*Dh, d]      -> (.., model, None)
  MLA w_uk/w_uv [.., r, H*Dh]-> (.., None, model)
  mlp w_gate/w_up [.., d, F] -> (.., None, model);  w_down -> (.., model, None)
  moe experts [.., E, d, F]  -> expert-parallel (E over model) when E % model
                                == 0 (DeepSeek 64/16), else tensor-parallel on
                                F (Mixtral 8 experts, F=14336)
  rglru channel params       -> channel dim over model (channels independent)
  mamba2 (130M)              -> replicated (model too small to matter)
  anything non-divisible     -> replicated (rule falls through)

KV caches: batch over data; kv-head dim over model when divisible, else the
*sequence* dim over model (MQA kv=1 — GSPMD turns decode attention into a
partial-softmax + collective; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "data_axes",
    "model_axis_size",
    "batch_specs",
    "param_specs",
    "opt_state_specs",
    "cache_specs",
    "named",
]


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _div(n: int, m: int) -> bool:
    return n % m == 0


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return "/".join(out)


def _param_rule(path: str, shape: tuple, cfg, msize: int) -> P:
    """shape includes the stacked leading layer axis inside stages."""
    parts = path.split("/")
    leaf = parts[-1]
    stacked = parts[0] == "stages"
    lead = (None,) if stacked else ()
    nd = len(shape) - len(lead)

    def spec(*dims):
        return P(*(lead + dims))

    if path == "embed":
        return P("model", None) if _div(shape[0], msize) else P(None, None)
    if path == "head":
        return P(None, "model") if _div(shape[1], msize) else P(None, None)
    if leaf in ("norm1", "norm2", "final_norm", "A_log", "D", "dt_bias", "norm_w", "lam"):
        return P(*([None] * len(shape)))
    # attention: kv projections shard only over WHOLE kv heads — a flat
    # split that lands inside head_dim makes every attention einsum contract
    # a sharded dim (per-block f32 score all-reduces; EXPERIMENTS.md §Perf)
    if leaf in ("wk", "wv"):
        hkv = getattr(cfg, "padded_kv_heads", 0)
        return (
            spec(None, "model")
            if hkv and _div(hkv, msize)
            else spec(None, None)
        )
    if leaf in ("wq", "w_uk", "w_uv"):
        return spec(None, "model") if _div(shape[-1], msize) else spec(None, None)
    if leaf == "wo":
        return spec("model", None) if _div(shape[-2], msize) else spec(None, None)
    if leaf in ("w_dkv", "w_krope"):
        return spec(None, None)
    # MoE experts [E, d, F] / [E, F, d]
    if "mlp" in parts and leaf in ("w_gate", "w_up", "w_down") and nd == 3:
        E = shape[-3]
        if _div(E, msize):  # expert parallel
            return spec("model", None, None)
        # tensor parallel within experts
        if leaf == "w_down":
            return spec(None, "model", None) if _div(shape[-2], msize) else spec(None, None, None)
        return spec(None, None, "model") if _div(shape[-1], msize) else spec(None, None, None)
    if leaf == "router":
        return spec(None, None)
    # dense / shared-expert MLPs [d, F] / [F, d]
    if leaf in ("w_gate", "w_up"):
        return spec(None, "model") if _div(shape[-1], msize) else spec(None, None)
    if leaf == "w_down":
        return spec("model", None) if _div(shape[-2], msize) else spec(None, None)
    # mamba2 / rglru projections
    if leaf in ("in_proj", "w_ig", "w_rg"):
        if cfg.family == "ssm":
            return spec(*([None] * nd))  # 130M: replicate
        return spec(None, "model") if _div(shape[-1], msize) else spec(None, None)
    if leaf == "out_proj":
        if cfg.family == "ssm":
            return spec(*([None] * nd))
        return spec("model", None) if _div(shape[-2], msize) else spec(None, None)
    if leaf == "conv":
        if cfg.family != "ssm" and _div(shape[-1], msize):
            return spec(None, "model")
        return spec(*([None] * nd))
    return P(*([None] * len(shape)))


def param_specs(cfg, params_shapes, mesh: Mesh) -> Any:
    """params_shapes: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    msize = model_axis_size(mesh)

    def rule(path, leaf):
        return _param_rule(_path_str(path), leaf.shape, cfg, msize)

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def opt_state_specs(pspecs):
    """AdamW state mirrors params; step is replicated."""
    return {
        "mu": pspecs,
        "nu": pspecs,
        "step": P(),
    }


def batch_specs(cfg, batch: int, mesh: Mesh):
    da = data_axes(mesh)
    dsize = np.prod([mesh.shape[a] for a in (da if isinstance(da, tuple) else (da,))])
    bspec = da if batch % dsize == 0 and batch >= dsize else None
    if cfg.input_mode == "embeddings":
        return {"inputs": P(bspec, None, None), "targets": P(bspec, None)}
    return {"inputs": P(bspec, None), "targets": P(bspec, None)}


def _cache_rule(path: str, shape: tuple, cfg, mesh: Mesh) -> P:
    """Cache leaves carry a stacked layer axis at dim 0."""
    da = data_axes(mesh)
    msize = model_axis_size(mesh)
    dsize = np.prod([mesh.shape[a] for a in (da if isinstance(da, tuple) else (da,))])
    leaf = path.split("/")[-1]
    if leaf == "pos":
        return P(None)  # stacked scalar per layer
    if leaf == "kpos":
        return P(None, None)
    batch = shape[1] if len(shape) > 1 else 1
    b = da if batch % dsize == 0 and batch >= dsize else None
    if leaf in ("k", "v"):  # [L_stage, B, S, Hkv, Dh]
        if _div(shape[3], msize):
            return P(None, b, None, "model", None)
        if _div(shape[2], msize):
            return P(None, b, "model", None, None)  # shard sequence (MQA)
        return P(None, b, None, None, None)
    if leaf in ("ckv", "krope"):  # [L_stage, B, S, r]
        if _div(shape[2], msize):
            return P(None, b, "model", None)
        return P(None, b, None, None)
    if leaf == "state":  # ssm [L,B,H,P,N] or rglru [L,B,d]
        if len(shape) == 5:
            return (
                P(None, b, "model", None, None)
                if _div(shape[2], msize)
                else P(None, b, None, None, None)
            )
        return (
            P(None, b, "model") if _div(shape[2], msize) else P(None, b, None)
        )
    if leaf == "conv":  # [L, B, W-1, C]
        return (
            P(None, b, None, "model")
            if _div(shape[3], msize)
            else P(None, b, None, None)
        )
    return P(*([None] * len(shape)))


def cache_specs(cfg, cache_shapes, mesh: Mesh):
    def rule(path, leaf):
        return _cache_rule(_path_str(path), leaf.shape, cfg, mesh)

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)
