"""Serving launcher: batched prefill + decode with the KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Runs the reduced config on CPU (full configs are exercised via dryrun.py on
the production mesh).  Reports prefill and per-token decode latency.

This launcher serves the *transformer* stack only.  For online GNN
embedding serving — continuous batching over a layerwise-inference
artifact — use ``GLISPSystem.server()`` (``repro.serve``); the end-to-end
demo is ``examples/serve_gnn.py``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.specs import make_decode_step, make_prefill_step
from repro.models.transformer.model import init_cache, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    max_len = args.prompt_len + args.gen
    cache = init_cache(cfg, args.batch, max_len)

    if cfg.input_mode == "embeddings":
        prompt = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), dtype=jnp.float32
        )
        embed = lambda tok: jax.random.normal(
            jax.random.fold_in(key, 1), (args.batch, 1, cfg.d_model)
        )
    else:
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
        embed = None

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, cache, {"inputs": prompt})
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = [jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)]
    t0 = time.perf_counter()
    for i in range(args.gen):
        pos = args.prompt_len + i
        if cfg.input_mode == "embeddings":
            inp = embed(toks[-1])
        else:
            inp = toks[-1][:, None]
        logits, cache = decode(params, cache, {"inputs": inp}, jnp.int32(pos))
        toks.append(jnp.argmax(logits[:, : cfg.vocab_size], axis=-1))
    jax.block_until_ready(toks[-1])
    t_decode = (time.perf_counter() - t0) / args.gen

    print(f"arch {cfg.name}: prefill({args.prompt_len} tok) {t_prefill*1e3:.1f} ms, "
          f"decode {t_decode*1e3:.1f} ms/tok")
    print("sampled tokens (greedy):", [int(t[0]) for t in toks][:10], "...")


if __name__ == "__main__":
    main()
