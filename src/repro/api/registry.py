"""String-keyed component registries: partitioners, sampler backends, reorder
algorithms, cache policies, storage tiers.

Every pluggable piece of the GLISP system is resolved by name through a
``Registry`` so configs stay plain data (``GLISPConfig`` fields are strings)
and downstream code extends the system without touching the facade.  Each
registry documents its own entry contract — e.g. ``PARTITIONERS`` holds
``Partitioner`` INSTANCES (objects with a ``name`` and a
``partition(g, num_parts, *, seed, direction) -> PartitionPlan`` method):

    from repro.api import PARTITIONERS, PartitionPlan

    class MyPartitioner:
        name = "my-partitioner"

        def partition(self, g, num_parts, *, seed=0, direction="out"):
            ...
            return PartitionPlan.from_assignment(
                g, ep, num_parts, partitioner=self.name, seed=seed
            )

    PARTITIONERS.register("my-partitioner", MyPartitioner())

Unknown names raise ``ValueError`` listing what IS registered — the
config-typo failure mode is a one-line fix instead of a silent KeyError deep
in a build stack.

The class itself lives in ``repro.utils`` (dependency-free) so core
subsystems — e.g. the ``repro.core.storage`` cache-policy registry — can
define registries without importing the API package; this module stays the
canonical public import path.
"""
from __future__ import annotations

from repro.utils import Registry

__all__ = ["Registry"]
