"""String-keyed component registries: partitioners, sampler backends, reorder
algorithms, cache policies.

Every pluggable piece of the GLISP system is resolved by name through a
``Registry`` so configs stay plain data (``GLISPConfig`` fields are strings)
and downstream code extends the system without touching the facade:

    from repro.api import PARTITIONERS

    @PARTITIONERS.register("my-partitioner")
    def my_partitioner(g, num_parts, *, seed=0, direction="out"):
        ...
        return PartitionPlan(edge_parts=ep)

Unknown names raise ``ValueError`` listing what IS registered — the
config-typo failure mode is a one-line fix instead of a silent KeyError deep
in a build stack.
"""
from __future__ import annotations

from typing import Callable, Generic, Iterator, TypeVar

T = TypeVar("T")

__all__ = ["Registry"]


class Registry(Generic[T]):
    """Case-insensitive name -> component map with decorator registration."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, T] = {}

    @staticmethod
    def _key(name: str) -> str:
        return name.strip().lower()

    def register(self, name: str, obj: T | None = None):
        """``REG.register("name", obj)`` or ``@REG.register("name")``."""
        key = self._key(name)

        def _add(o: T) -> T:
            if key in self._entries:
                raise ValueError(f"{self.kind} {name!r} already registered")
            self._entries[key] = o
            return o

        return _add if obj is None else _add(obj)

    def get(self, name: str) -> T:
        key = self._key(name)
        if key not in self._entries:
            known = ", ".join(sorted(self._entries)) or "<none>"
            raise ValueError(
                f"unknown {self.kind} {name!r}; registered: {known}"
            )
        return self._entries[key]

    def names(self) -> list[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return self._key(name) in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._entries))

    def __len__(self) -> int:
        return len(self._entries)
