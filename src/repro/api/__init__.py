"""repro.api — the unified GLISP system facade.

One config, four registries, one build call:

    from repro.api import GLISPConfig, GLISPSystem

    system = GLISPSystem.build(graph, GLISPConfig(num_parts=4))
    trainer = system.train(model, train_ids, epochs=2)

See docs/api.md for the full surface and extension points.
"""
from repro.api.backends import (
    CACHE_POLICIES,
    PARTITIONERS,
    REORDERS,
    SAMPLERS,
    STORAGE_TIERS,
    EdgeCutBackend,
    GatherApplyBackend,
    Partitioner,
    PartitionPipeline,
    PartitionPlan,
    SamplerBackend,
)
from repro.api.config import GLISPConfig
from repro.api.pipeline import BatchPipeline
from repro.api.registry import Registry
from repro.api.system import GLISPSystem
from repro.core.faults import (
    CircuitBreaker,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    RetryPolicy,
)
from repro.core.sampling.service import (
    DEFAULT_DIRECTION,
    SampleRequest,
    SampleTicket,
    SampleTimeout,
    SamplingService,
    SamplingSpec,
)
from repro.serve import (
    GNNServer,
    ServeRequest,
    ServeResponse,
    ServeStats,
)
from repro.core.storage import (
    ArrayFeatureSource,
    DFSTier,
    FeatureSource,
    HybridCache,
    IOCost,
    StorageTier,
    StoreFeatureSource,
    as_feature_source,
)

__all__ = [
    "GLISPConfig",
    "GLISPSystem",
    "BatchPipeline",
    "Registry",
    "PartitionPlan",
    "Partitioner",
    "PartitionPipeline",
    "SamplerBackend",
    "GatherApplyBackend",
    "EdgeCutBackend",
    "SamplingSpec",
    "SampleRequest",
    "SampleTicket",
    "SampleTimeout",
    "SamplingService",
    "CircuitBreaker",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "RetryPolicy",
    "GNNServer",
    "ServeRequest",
    "ServeResponse",
    "ServeStats",
    "ArrayFeatureSource",
    "DFSTier",
    "FeatureSource",
    "HybridCache",
    "IOCost",
    "StorageTier",
    "StoreFeatureSource",
    "as_feature_source",
    "PARTITIONERS",
    "SAMPLERS",
    "REORDERS",
    "CACHE_POLICIES",
    "STORAGE_TIERS",
    "DEFAULT_DIRECTION",
]
