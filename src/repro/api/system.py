"""``GLISPSystem`` — the single front door to the GLISP stack.

    from repro.api import GLISPConfig, GLISPSystem

    system = GLISPSystem.build(g, GLISPConfig(num_parts=4, fanouts=(15, 10, 5)))
    ticket = system.submit(seeds)                   # async request plan
    sub = ticket.result()                           # Gather-Apply K-hop
    sub = system.sample(seeds)                      # blocking convenience
    for seeds, batch in system.loader(train_ids):   # prefetching pipeline
        ...
    trainer = system.train(model, train_ids, epochs=2)
    result = system.infer_layerwise(layer_fns, workdir)

``build`` runs partitioner -> partition materialization -> sampling service,
each resolved by name from the registries in ``repro.api.backends``; no
caller ever wires ``SamplingServer`` / ``VertexRouter`` by hand again.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.api.backends import (
    CACHE_POLICIES,
    REORDERS,
    SAMPLERS,
    GatherApplyBackend,
    PartitionPipeline,
    PartitionPlan,
    SamplerBackend,
)
from repro.api.config import GLISPConfig
from repro.api.pipeline import BatchPipeline
from repro.graph.graph import GraphPartition, HeteroGraph
from repro.graph.metrics import partition_metrics

__all__ = ["GLISPSystem"]


@dataclass
class GLISPSystem:
    graph: HeteroGraph
    config: GLISPConfig
    plan: PartitionPlan
    partitions: list[GraphPartition]
    backend: SamplerBackend
    partition_seconds: float = 0.0
    # True when the partition/reorder artifacts were loaded from the
    # content-addressed pipeline cache instead of computed
    partition_cache_hit: bool = False
    # reorder permutation from the pipeline (perm[new_id] = old vertex id),
    # grouped by the plan's per-vertex partition per config.reorder
    reorder_perm: np.ndarray | None = field(default=None, repr=False)
    pipeline_seconds: dict = field(default_factory=dict, repr=False)
    _metrics: dict | None = field(default=None, repr=False)
    # (signature, engine, pinned refs) for infer_layerwise reuse: repeat
    # calls with the same resolved parameters hit the same engine, so its
    # jitted (layer, bucket) slices never recompile across calls
    _infer_cache: tuple | None = field(default=None, repr=False)

    # ------------------------------------------------------------------
    @classmethod
    def build(
        cls,
        graph: HeteroGraph,
        config: GLISPConfig | None = None,
        *,
        cache_dir: str | None = None,
        **overrides,
    ):
        """Compose the full system from a config (plus keyword overrides).

        Partitioning runs through the cached ``PartitionPipeline``:
        ``cache_dir`` (or ``config.partition_cache_dir``) names an on-disk
        artifact cache keyed by graph content + partition config, so a
        second build over the same inputs skips repartitioning entirely
        (``partition_cache_hit`` reports which path was taken)."""
        config = (config or GLISPConfig()).replace(**overrides).validate()
        pipeline = PartitionPipeline(
            config.partitioner,
            config.num_parts,
            reorder=config.reorder,
            seed=config.seed,
            direction=config.direction,
            cache_dir=(
                cache_dir if cache_dir is not None else config.partition_cache_dir
            ),
        )
        res = pipeline.run(graph)
        plan = res.plan
        if config.balance_partitions and plan.vertex_owner is None:
            raise ValueError(
                "balance_partitions needs per-vertex owners, which only "
                "vertex partitioners produce (e.g. partitioner='ldg'); "
                f"{config.partitioner!r} yields a vertex-cut edge assignment"
            )
        backend = SAMPLERS.get(config.sampler)(graph, plan, res.partitions, config)
        return cls(
            graph=graph,
            config=config,
            plan=plan,
            partitions=res.partitions,
            backend=backend,
            partition_seconds=res.partition_seconds,
            partition_cache_hit=res.cache_hit,
            reorder_perm=res.perm,
            pipeline_seconds=res.seconds,
        )

    # -- sampling ------------------------------------------------------
    @property
    def service(self):
        """The shared ``SamplingService`` (servers, scheduler, counters)."""
        return self.backend.service

    @property
    def client(self):
        """Legacy alias for :attr:`service` (workload counters live here)."""
        return self.backend.service

    def submit(
        self,
        seeds: np.ndarray,
        spec=None,
        *,
        key=None,
        fanouts=None,
        weighted: bool | None = None,
        direction: str | None = None,
        replace: bool | None = None,
    ):
        """Submit an asynchronous sample request; returns a ``SampleTicket``.

        The plan is ``spec`` (a ``SamplingSpec``) or the config's spec with
        per-call overrides.  Multiple tickets may ride in flight at once —
        the service overlaps their hops and coalesces shared frontier
        seeds; ``ticket.result()`` is bit-identical either way."""
        if spec is None:
            spec = self.config.sampling_spec(
                fanouts=fanouts,
                weighted=weighted,
                direction=direction,
                replace=replace,
            )
        elif any(
            x is not None for x in (fanouts, weighted, direction, replace)
        ):
            raise ValueError(
                "pass either a SamplingSpec or individual "
                "fanouts/weighted/direction/replace overrides, not both"
            )
        return self.backend.submit(seeds, spec, key=key)

    def sample(
        self,
        seeds: np.ndarray,
        fanouts=None,
        *,
        spec=None,
        weighted: bool | None = None,
        direction: str | None = None,
        replace: bool | None = None,
        key=None,
    ):
        """Blocking convenience: ``submit(...).result()``.

        Pass ``key=`` to pin the request's RNG key; without it the service
        assigns a sequence key (fine for a lone blocking caller, not for
        code sharing the service with other submitters)."""
        # timeout=None defers to the service's configured ticket_timeout
        return self.submit(
            seeds,
            spec,
            fanouts=fanouts,
            weighted=weighted,
            direction=direction,
            replace=replace,
            key=key,
        ).result(timeout=None)

    def partition_metrics(self) -> dict:
        if self._metrics is None:
            self._metrics = partition_metrics(
                self.partitions, self.graph.num_vertices
            )
        return self._metrics

    def server_workloads(self) -> np.ndarray:
        return self.backend.server_workloads()

    def server_health(self) -> dict:
        """Health of every sampling server replica (circuit-breaker view):
        ``{"server.<part>.<replica>": "up" | "quarantined"}``."""
        return self.service.server_health()

    def reset_stats(self) -> None:
        self.backend.reset_stats()

    # -- lifecycle -----------------------------------------------------
    def close(self, timeout: float = 2.0) -> None:
        """Release owned OS resources — today that is the remote sampling
        worker pool when ``dist_transport != "inproc"``.  Idempotent; the
        in-process system is a no-op, so unconditional cleanup is cheap."""
        close = getattr(self.backend, "close", None)
        if close is not None:
            close(timeout=timeout)

    def __enter__(self) -> "GLISPSystem":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- batch pipeline ------------------------------------------------
    def loader(
        self,
        seeds: np.ndarray,
        num_layers: int | None = None,
        *,
        batch_size: int | None = None,
        prefetch: int | None = None,
        seed: int | None = None,
        fanouts=None,
        spec=None,
        inflight: int | None = None,
        feature_source=None,
    ) -> BatchPipeline:
        """A prefetching seed->batch pipeline over this system's service.

        ``feature_source`` (a ``repro.core.storage.FeatureSource``) swaps
        the in-memory feature matrix for e.g. a disk-backed tiered store —
        batches are bit-identical either way."""
        cfg = self.config
        partition_of = (
            self.plan.vertex_owner if cfg.balance_partitions else None
        )
        if spec is None:
            spec = cfg.sampling_spec(fanouts=fanouts)
        elif fanouts is not None:
            raise ValueError("pass either a SamplingSpec or fanouts, not both")
        return BatchPipeline(
            self.backend,
            self.graph,
            seeds,
            list(spec.fanouts),
            num_layers if num_layers is not None else len(spec.fanouts),
            batch_size=batch_size if batch_size is not None else cfg.batch_size,
            spec=spec,
            prefetch=prefetch if prefetch is not None else cfg.prefetch,
            inflight=inflight if inflight is not None else cfg.inflight,
            seed=cfg.seed if seed is None else seed,
            partition_of=partition_of,
            balance_partitions=cfg.balance_partitions,
            vertex_quantum=cfg.vertex_quantum,
            edge_quantum=cfg.edge_quantum,
            feature_source=feature_source,
            ticket_timeout=cfg.ticket_timeout,
            worker_respawns=cfg.worker_respawns,
        )

    # -- training ------------------------------------------------------
    def trainer(
        self,
        model,
        train_ids: np.ndarray,
        *,
        opt=None,
        batch_size: int | None = None,
        prefetch: int | None = None,
        worker_cores: tuple | None = None,
        spec=None,
        inflight: int | None = None,
        feature_source=None,
    ):
        """A ``GNNTrainer`` wired to this system's backend and config."""
        from repro.train.loop import GNNTrainer  # lazy: avoids import cycle

        cfg = self.config
        spec = spec if spec is not None else cfg.sampling_spec()
        return GNNTrainer(
            model,
            self.backend,
            self.graph,
            list(spec.fanouts),
            train_ids,
            batch_size=batch_size if batch_size is not None else cfg.batch_size,
            opt=opt,
            spec=spec,
            seed=cfg.seed,
            prefetch=prefetch if prefetch is not None else cfg.prefetch,
            inflight=inflight if inflight is not None else cfg.inflight,
            worker_cores=worker_cores,
            partition_of=(
                self.plan.vertex_owner if cfg.balance_partitions else None
            ),
            balance_partitions=cfg.balance_partitions,
            feature_source=feature_source,
            checkpoint_dir=cfg.checkpoint_dir,
            checkpoint_every=cfg.checkpoint_every,
            ticket_timeout=cfg.ticket_timeout,
            worker_respawns=cfg.worker_respawns,
        )

    def train(
        self,
        model,
        train_ids: np.ndarray,
        *,
        epochs: int = 1,
        opt=None,
        log_every: int = 10,
        batch_size: int | None = None,
        prefetch: int | None = None,
        worker_cores: tuple | None = None,
    ):
        """Build a trainer, run ``epochs``, return the (trained) trainer."""
        tr = self.trainer(
            model,
            train_ids,
            opt=opt,
            batch_size=batch_size,
            prefetch=prefetch,
            worker_cores=worker_cores,
        )
        tr.train(epochs=epochs, log_every=log_every)
        return tr

    def dp_trainer(
        self,
        model,
        train_ids: np.ndarray,
        *,
        mesh=None,
        opt=None,
        batch_size: int | None = None,
        prefetch: int | None = None,
        reference: bool = False,
    ):
        """A ``DataParallelGNNTrainer``: the train step sharded over the
        mesh's data axis (``launch.make_local_mesh`` by default), params
        replicated, one sampling client per shard.  ``reference=True``
        additionally runs an unsharded single-device step on the same
        batches and logs its losses for equivalence checks."""
        from repro.train.data_parallel import (  # lazy: avoids import cycle
            DataParallelGNNTrainer,
        )

        cfg = self.config
        return DataParallelGNNTrainer(
            model,
            self.backend,
            self.graph,
            train_ids,
            mesh=mesh,
            spec=cfg.sampling_spec(),
            batch_size=batch_size if batch_size is not None else cfg.batch_size,
            opt=opt,
            seed=cfg.seed,
            prefetch=prefetch if prefetch is not None else cfg.prefetch,
            inflight=cfg.inflight,
            vertex_quantum=cfg.vertex_quantum,
            edge_quantum=cfg.edge_quantum,
            ticket_timeout=cfg.ticket_timeout,
            reference=reference,
        )

    # -- layerwise inference -------------------------------------------
    def infer_layerwise(
        self,
        layer_fns: list,
        workdir: str,
        *,
        feats: np.ndarray | None = None,
        fanouts=None,
        out_dims: list[int] | None = None,
        reorder: str | None = None,
        cache_policy: str | None = None,
        storage_tiers: tuple | None = None,
        tier_capacities: tuple | None = None,
        chunk_rows: int | None = None,
        dynamic_frac: float | None = None,
        batch_size: int | None = None,
        mode: str | None = None,
        jit: bool | None = None,
        use_kernel: bool | None = None,
        kernel_autotune: bool | None = None,
        kernel_cache_dir: str | None = None,
        edge_buckets: tuple | None = None,
    ):
        """Run the redundancy-free layerwise engine over the whole graph.

        ``mode``/``jit``/``use_kernel``/``edge_buckets`` control the
        device-resident bucketed execution path (see ``GLISPConfig``'s
        ``infer_*`` fields for the defaults); ``kernel_autotune``/
        ``kernel_cache_dir`` sweep Pallas block sizes per shape bucket
        before its first compile (``repro.kernels.autotune``).

        Repeat calls with the same resolved parameters (and the *same*
        ``layer_fns``/``feats`` objects) reuse one engine, so jitted
        (layer, bucket) slices carry over and nothing recompiles — the
        property ``repro.analysis.recompile_guard`` asserts."""
        from repro.core.inference.engine import LayerwiseInferenceEngine

        if not isinstance(self.backend, GatherApplyBackend):
            raise ValueError(
                "layerwise inference needs the 'gather_apply' sampler backend "
                f"(vertex-cut hosting sets drive owner assignment); this "
                f"system uses {self.config.sampler!r}"
            )
        cfg = self.config
        if fanouts is None and len(cfg.fanouts) >= len(layer_fns):
            # follow the config like every other facade method; a config
            # with fewer fanouts than layers falls back to the engine default
            fanouts = cfg.fanouts[: len(layer_fns)]
        feats_arr = self.graph.vertex_feats if feats is None else feats
        resolved = dict(
            workdir=workdir,
            fanouts=tuple(fanouts) if fanouts is not None else None,
            reorder=reorder or cfg.reorder,
            chunk_rows=chunk_rows if chunk_rows is not None else cfg.chunk_rows,
            cache_policy=cache_policy or cfg.cache_policy,
            storage_tiers=(
                tuple(storage_tiers)
                if storage_tiers is not None
                else cfg.storage_tiers
            ),
            tier_capacities=(
                tuple(tier_capacities)
                if tier_capacities is not None
                else cfg.tier_capacities
            ),
            dynamic_frac=(
                dynamic_frac if dynamic_frac is not None else cfg.dynamic_frac
            ),
            batch_size=(
                batch_size if batch_size is not None else cfg.infer_batch_size
            ),
            direction=cfg.direction,
            out_dims=tuple(out_dims) if out_dims is not None else None,
            seed=cfg.seed,
            mode=mode if mode is not None else cfg.infer_mode,
            jit=jit if jit is not None else cfg.infer_jit,
            use_kernel=(
                use_kernel if use_kernel is not None else cfg.infer_use_kernel
            ),
            kernel_autotune=(
                kernel_autotune
                if kernel_autotune is not None
                else cfg.kernel_autotune
            ),
            kernel_cache_dir=(
                kernel_cache_dir
                if kernel_cache_dir is not None
                else cfg.kernel_cache_dir
            ),
            edge_buckets=(
                tuple(edge_buckets)
                if edge_buckets is not None
                else cfg.infer_edge_buckets
            ),
        )
        # identity (not value) for the unhashables: reusing the compiled
        # slices is only sound for the very same layer callables/features
        sig = (
            tuple(resolved.items()),
            tuple(id(fn) for fn in layer_fns),
            id(feats_arr),
        )
        if self._infer_cache is not None and self._infer_cache[0] == sig:
            return self._infer_cache[1].run()
        engine = LayerwiseInferenceEngine(
            self.graph,
            self.client,
            layer_fns,
            feats_arr,
            workdir,
            fanouts=list(fanouts) if fanouts is not None else None,
            reorder_alg=REORDERS.get(resolved["reorder"]),
            chunk_rows=resolved["chunk_rows"],
            policy=CACHE_POLICIES.get(resolved["cache_policy"]),
            storage_tiers=resolved["storage_tiers"],
            tier_capacities=resolved["tier_capacities"],
            dynamic_frac=resolved["dynamic_frac"],
            batch_size=resolved["batch_size"],
            direction=resolved["direction"],
            out_dims=out_dims,
            seed=resolved["seed"],
            mode=resolved["mode"],
            use_jit=resolved["jit"],
            use_kernel=resolved["use_kernel"],
            kernel_autotune=resolved["kernel_autotune"],
            kernel_cache_dir=resolved["kernel_cache_dir"],
            edge_buckets=resolved["edge_buckets"],
            ticket_timeout=cfg.ticket_timeout,
            retry_policy=cfg.retry_policy,
            faults=cfg.fault_plan,
        )
        # pin layer_fns/feats so the id()s in the signature stay valid
        self._infer_cache = (sig, engine, (list(layer_fns), feats_arr))
        return engine.run()

    @property
    def infer_engine(self):
        """The engine behind the last ``infer_layerwise`` call (None before
        the first); exposes ``jit_trace_count()``/``shape_count()`` for
        ``repro.analysis.recompile_guard``."""
        return self._infer_cache[1] if self._infer_cache is not None else None

    # -- online serving ------------------------------------------------
    def server(
        self,
        *,
        queue_depth: int | None = None,
        max_batch_delay_ms: float | None = None,
        deadline_ms: float | None | str = "config",
    ):
        """An online :class:`repro.serve.GNNServer` over the last
        ``infer_layerwise`` run (call that first — serving recomputes only
        the final layer, reading the layer-(K-1) store through a demand
        cache).  Knobs default to the config's ``serve_*`` fields;
        ``deadline_ms=None`` explicitly disables the request deadline."""
        from repro.serve.server import GNNServer  # lazy: avoids import cycle

        cfg = self.config
        return GNNServer(
            self,
            queue_depth=(
                queue_depth if queue_depth is not None else cfg.serve_queue_depth
            ),
            max_batch_delay_ms=(
                max_batch_delay_ms
                if max_batch_delay_ms is not None
                else cfg.serve_max_batch_delay_ms
            ),
            deadline_ms=(
                cfg.serve_deadline_ms if deadline_ms == "config" else deadline_ms
            ),
        )
