"""Pluggable components behind the GLISP facade.

Defines the registries named by ``GLISPConfig`` string fields (partitioners,
samplers, reorders, cache policies, storage tiers) and the
``SamplerBackend`` protocol.  Since the request-plan redesign, BOTH sampler
backends are one ``SamplingService`` behind different routing strategies
(``GatherApplyRouting`` for GLISP, ``OwnerRouting`` for the DistDGL-style
baseline) — no parallel client class hierarchies.  The preferred surface is
asynchronous:

    ticket = backend.submit(seeds, spec)        # SampleTicket (future)
    sub = ticket.result()

``backend.sample(seeds, fanouts, ...)`` remains as a submit-and-wait shim
for one release of deprecation; new call sites should build a
``SamplingSpec`` and go through ``submit``.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Protocol, runtime_checkable

import numpy as np

from repro.api.registry import Registry
from repro.core.storage import CACHE_POLICIES, STORAGE_TIERS
from repro.core.partition import (
    PARTITIONERS,
    Partitioner,
    PartitionPipeline,
    PartitionPlan,
)
from repro.core.sampling.service import (
    DEFAULT_DIRECTION,
    GatherApplyRouting,
    OwnerRouting,
    SampledSubgraph,
    SampleTicket,
    SamplingService,
    SamplingSpec,
    SamplingServer,
    ServerStats,
    VertexRouter,
)
from repro.graph.graph import GraphPartition, HeteroGraph
from repro.graph.reorder import REORDER_ALGS

if TYPE_CHECKING:
    from repro.api.config import GLISPConfig

__all__ = [
    "PartitionPlan",
    "Partitioner",
    "PartitionPipeline",
    "SamplerBackend",
    "GatherApplyBackend",
    "EdgeCutBackend",
    "PARTITIONERS",
    "SAMPLERS",
    "REORDERS",
    "CACHE_POLICIES",
    "STORAGE_TIERS",
]


# ---------------------------------------------------------------------------
# Partitioners: ``PARTITIONERS``, ``PartitionPlan`` and the ``Partitioner``
# protocol are owned by the partitioning subsystem (``repro.core.partition``,
# mirroring the storage-owned ``CACHE_POLICIES``) and re-exported here as the
# canonical public import path.  Every entry is a ``Partitioner`` instance:
# ``PARTITIONERS.get(name).partition(g, num_parts, seed=..., direction=...)``
# (instances are also callable with the same signature).
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# Sampler backends
# ---------------------------------------------------------------------------


@runtime_checkable
class SamplerBackend(Protocol):
    """The one sampling surface the facade, trainer and engine consume."""

    name: str

    def submit(
        self,
        seeds: np.ndarray,
        spec: SamplingSpec | None = None,
        *,
        key=None,
    ) -> SampleTicket: ...

    # DEPRECATED submit-and-wait shim (kept one release)
    def sample(
        self,
        seeds: np.ndarray,
        fanouts: list[int],
        *,
        weighted: bool = False,
        direction: str = DEFAULT_DIRECTION,
    ) -> SampledSubgraph: ...

    def server_workloads(self) -> np.ndarray: ...

    def reset_stats(self) -> None: ...


class _ServiceBackend:
    """Shared adapter: one ``SamplingService`` behind the backend protocol."""

    name = "base"

    def __init__(self, service: SamplingService):
        self.service = service

    # -- async request-plan surface ------------------------------------
    def submit(
        self,
        seeds: np.ndarray,
        spec: SamplingSpec | None = None,
        *,
        key=None,
    ) -> SampleTicket:
        return self.service.submit(seeds, spec, key=key)

    # -- blocking shim (one release of deprecation) --------------------
    def sample(
        self,
        seeds: np.ndarray,
        fanouts: list[int],
        *,
        weighted: bool = False,
        direction: str = DEFAULT_DIRECTION,
    ) -> SampledSubgraph:
        """DEPRECATED: submit-and-wait over :meth:`submit`."""
        return self.service.sample_khop(
            seeds, list(fanouts), weighted=weighted, direction=direction
        )

    # -- stats ---------------------------------------------------------
    def stats(self) -> ServerStats:
        return self.service.stats()

    def server_workloads(self) -> np.ndarray:
        return self.service.server_workloads()

    def reset_stats(self) -> None:
        # the service's reset clears per-server counters AND the
        # parallel/total work accumulators — no adapter workaround needed
        self.service.reset_stats()

    def close(self, timeout: float = 2.0) -> None:
        """Release the remote worker pool, if this backend has one."""
        self.service.close(timeout=timeout)

    @property
    def client(self):
        """Legacy alias: the service plays the old client role."""
        return self.service

    @property
    def parallel_work(self) -> float:
        return self.service.parallel_work

    @property
    def total_work(self) -> float:
        return self.service.total_work

    def __repr__(self) -> str:
        return f"{type(self).__name__}(servers={len(self.service.servers)})"


def _build_dispatcher(parts: list[GraphPartition], config: "GLISPConfig", cost: str):
    """The remote worker pool for ``dist_transport != "inproc"`` — one
    forked process per partition, mirroring the service's replica layout
    and fault machinery so results stay bit-identical."""
    if config.dist_transport == "inproc":
        return None
    from repro.dist.client import WorkerPool  # lazy: inproc stays fork-free

    return WorkerPool(
        parts,
        transport=config.dist_transport,
        seed=config.seed,
        cost_model=cost,
        replicas=config.server_replicas,
        fault_plan=config.fault_plan,
        retry_policy=config.retry_policy,
        respawns=config.worker_respawns,
        dispatch_timeout=config.dist_dispatch_timeout,
    )


class GatherApplyBackend(_ServiceBackend):
    """GLISP: vertex-cut servers, Gather from every host, Apply merge."""

    name = "gather_apply"

    @property
    def router(self) -> VertexRouter:
        return self.service.router


class EdgeCutBackend(_ServiceBackend):
    """DistDGL-style baseline: one-hop answered only by the seed's owner."""

    name = "edge_cut"

    @property
    def vertex_owner(self) -> np.ndarray:
        return self.service.routing.owner


SAMPLERS: Registry = Registry("sampler backend")


@SAMPLERS.register("gather_apply")
def _build_gather_apply(
    g: HeteroGraph,
    plan: PartitionPlan,
    parts: list[GraphPartition],
    config: "GLISPConfig",
) -> GatherApplyBackend:
    cost = config.cost_model or "algd"
    servers = [SamplingServer(p, seed=config.seed, cost_model=cost) for p in parts]
    router = VertexRouter(g, plan.edge_parts, config.num_parts)
    service = SamplingService(
        servers,
        GatherApplyRouting(router),
        seed=config.seed,
        coalesce=config.coalesce,
        max_server_batch=config.max_server_batch,
        replicas=config.server_replicas,
        fault_plan=config.fault_plan,
        retry_policy=config.retry_policy,
        ticket_timeout=config.ticket_timeout,
        dispatcher=_build_dispatcher(parts, config, cost),
    )
    return GatherApplyBackend(service)


@SAMPLERS.register("edge_cut")
def _build_edge_cut(
    g: HeteroGraph,
    plan: PartitionPlan,
    parts: list[GraphPartition],
    config: "GLISPConfig",
) -> EdgeCutBackend:
    if plan.vertex_owner is None:
        raise ValueError(
            "the 'edge_cut' sampler backend needs a vertex partitioner that "
            "produces owners (e.g. partitioner='ldg'); "
            f"{config.partitioner!r} yields only a vertex-cut edge assignment"
        )
    cost = config.cost_model or "scan"
    servers = [SamplingServer(p, seed=config.seed, cost_model=cost) for p in parts]
    service = SamplingService(
        servers,
        OwnerRouting(plan.vertex_owner, config.num_parts),
        seed=config.seed,
        coalesce=config.coalesce,
        max_server_batch=config.max_server_batch,
        replicas=config.server_replicas,
        fault_plan=config.fault_plan,
        retry_policy=config.retry_policy,
        ticket_timeout=config.ticket_timeout,
        dispatcher=_build_dispatcher(parts, config, cost),
    )
    return EdgeCutBackend(service)


# ---------------------------------------------------------------------------
# Reorder algorithms (thin: validate + canonicalize).  Cache policies and
# storage tiers re-export from the tiered storage subsystem
# (``repro.core.storage``), which owns their registries.
# ---------------------------------------------------------------------------

REORDERS: Registry = Registry("reorder algorithm")
for _alg in REORDER_ALGS:
    REORDERS.register(_alg, _alg)
