"""Pluggable components behind the GLISP facade.

Defines the four registries named by ``GLISPConfig`` string fields and the
``SamplerBackend`` protocol that puts ``GatherApplyClient`` (GLISP) and
``EdgeCutClient`` (DistDGL-style baseline) behind ONE sampling surface:

    backend.sample(seeds, fanouts, weighted=..., direction=...) -> SampledSubgraph

Both backends share the same default direction (``DEFAULT_DIRECTION``) and
the same stats discipline — ``reset_stats()`` clears per-server counters AND
the client's parallel/total work accumulators, which the raw clients handled
inconsistently (callers had to poke ``client.parallel_work = 0.0`` by hand).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Protocol, runtime_checkable

import numpy as np

from repro.api.registry import Registry
from repro.core.inference.cache import CachePolicy
from repro.core.partition import (
    adadne,
    distributed_ne,
    edge_cut_to_edge_assignment,
    hash2d_partition,
    ldg_edge_cut,
    random_edge_partition,
)
from repro.core.sampling.service import (
    DEFAULT_DIRECTION,
    EdgeCutClient,
    GatherApplyClient,
    SampledSubgraph,
    SamplingServer,
    VertexRouter,
)
from repro.graph.graph import GraphPartition, HeteroGraph
from repro.graph.reorder import REORDER_ALGS

if TYPE_CHECKING:
    from repro.api.config import GLISPConfig

__all__ = [
    "PartitionPlan",
    "SamplerBackend",
    "GatherApplyBackend",
    "EdgeCutBackend",
    "PARTITIONERS",
    "SAMPLERS",
    "REORDERS",
    "CACHE_POLICIES",
]


# ---------------------------------------------------------------------------
# Partitioners: name -> fn(g, num_parts, *, seed, direction) -> PartitionPlan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PartitionPlan:
    """Output of any registered partitioner.

    ``edge_parts[e]`` is the partition id of edge e (the vertex-cut edge
    assignment every backend builds from).  ``vertex_owner`` is set only by
    edge-cut (vertex) partitioners and is required by the ``edge_cut``
    sampler backend for owner routing."""

    edge_parts: np.ndarray
    vertex_owner: np.ndarray | None = None


PARTITIONERS: Registry = Registry("partitioner")


def _register_edge_partitioner(name: str, fn) -> None:
    def _wrapped(
        g: HeteroGraph,
        num_parts: int,
        *,
        seed: int = 0,
        direction: str = DEFAULT_DIRECTION,
    ) -> PartitionPlan:
        return PartitionPlan(edge_parts=fn(g, num_parts, seed=seed))

    _wrapped.__name__ = f"partitioner_{name}"
    PARTITIONERS.register(name, _wrapped)


_register_edge_partitioner("adadne", adadne)
_register_edge_partitioner("dne", distributed_ne)
_register_edge_partitioner("hash2d", hash2d_partition)
_register_edge_partitioner("random", random_edge_partition)


@PARTITIONERS.register("ldg")
def _ldg_plan(
    g: HeteroGraph,
    num_parts: int,
    *,
    seed: int = 0,
    direction: str = DEFAULT_DIRECTION,
) -> PartitionPlan:
    """LDG streaming edge-cut: vertices get owners; edges follow the vertex
    whose ``direction`` one-hop must stay local (so GLISP-vs-baseline
    comparisons sample the same direction on both systems)."""
    vp = ldg_edge_cut(g, num_parts, seed=seed)
    ep = edge_cut_to_edge_assignment(g, vp, local_direction=direction)
    return PartitionPlan(edge_parts=ep, vertex_owner=vp.astype(np.int64))


# ---------------------------------------------------------------------------
# Sampler backends
# ---------------------------------------------------------------------------


@runtime_checkable
class SamplerBackend(Protocol):
    """The one sampling surface the facade, trainer and engine consume."""

    name: str

    def sample(
        self,
        seeds: np.ndarray,
        fanouts: list[int],
        *,
        weighted: bool = False,
        direction: str = DEFAULT_DIRECTION,
    ) -> SampledSubgraph: ...

    def server_workloads(self) -> np.ndarray: ...

    def reset_stats(self) -> None: ...


class _ClientBackend:
    """Shared adapter over the in-process simulation clients."""

    name = "base"

    def __init__(self, client):
        self.client = client

    def sample(
        self,
        seeds: np.ndarray,
        fanouts: list[int],
        *,
        weighted: bool = False,
        direction: str = DEFAULT_DIRECTION,
    ) -> SampledSubgraph:
        return self.client.sample_khop(
            seeds, list(fanouts), weighted=weighted, direction=direction
        )

    def server_workloads(self) -> np.ndarray:
        return self.client.server_workloads()

    def reset_stats(self) -> None:
        self.client.reset_stats()
        self.client.parallel_work = 0.0
        self.client.total_work = 0.0

    @property
    def parallel_work(self) -> float:
        return self.client.parallel_work

    @property
    def total_work(self) -> float:
        return self.client.total_work

    def __repr__(self) -> str:
        return f"{type(self).__name__}(servers={len(self.client.servers)})"


class GatherApplyBackend(_ClientBackend):
    """GLISP: vertex-cut servers, Gather from every host, Apply merge."""

    name = "gather_apply"

    @property
    def router(self) -> VertexRouter:
        return self.client.router


class EdgeCutBackend(_ClientBackend):
    """DistDGL-style baseline: one-hop answered only by the seed's owner."""

    name = "edge_cut"

    @property
    def vertex_owner(self) -> np.ndarray:
        return self.client.owner


SAMPLERS: Registry = Registry("sampler backend")


@SAMPLERS.register("gather_apply")
def _build_gather_apply(
    g: HeteroGraph,
    plan: PartitionPlan,
    parts: list[GraphPartition],
    config: "GLISPConfig",
) -> GatherApplyBackend:
    cost = config.cost_model or "algd"
    servers = [SamplingServer(p, seed=config.seed, cost_model=cost) for p in parts]
    router = VertexRouter(g, plan.edge_parts, config.num_parts)
    return GatherApplyBackend(GatherApplyClient(servers, router, seed=config.seed))


@SAMPLERS.register("edge_cut")
def _build_edge_cut(
    g: HeteroGraph,
    plan: PartitionPlan,
    parts: list[GraphPartition],
    config: "GLISPConfig",
) -> EdgeCutBackend:
    if plan.vertex_owner is None:
        raise ValueError(
            "the 'edge_cut' sampler backend needs a vertex partitioner that "
            "produces owners (e.g. partitioner='ldg'); "
            f"{config.partitioner!r} yields only a vertex-cut edge assignment"
        )
    cost = config.cost_model or "scan"
    servers = [SamplingServer(p, seed=config.seed, cost_model=cost) for p in parts]
    return EdgeCutBackend(
        EdgeCutClient(servers, plan.vertex_owner, seed=config.seed)
    )


# ---------------------------------------------------------------------------
# Reorder algorithms and cache policies (thin: validate + canonicalize)
# ---------------------------------------------------------------------------

REORDERS: Registry = Registry("reorder algorithm")
for _alg in REORDER_ALGS:
    REORDERS.register(_alg, _alg)

CACHE_POLICIES: Registry = Registry("cache policy")
for _pol in CachePolicy:
    CACHE_POLICIES.register(_pol.value, _pol)
