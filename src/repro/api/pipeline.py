"""The fused batch pipeline: seed loading -> K-hop sampling -> padded batch.

``BatchPipeline`` composes ``SeedBatchLoader`` + the sampling service +
``subgraph_to_batch`` behind one iterator, with two *independent* overlap
axes:

``prefetch >= 1`` — the host-side producer (sampling + padding) runs ahead
    of the jit'd device step in a forked worker or thread, so the two
    overlap: ``sample_time + compute_time`` per step becomes roughly
    ``max(sample_time, compute_time)``.
``inflight >= 2`` — the producer keeps that many sample *requests* in
    flight on the ``SamplingService`` at once (a submission window), so the
    service's scheduler advances batch k's hop-2 beside batch k+1's hop-1,
    coalescing shared frontier seeds across the window.  Requests carry
    pipeline-owned keys ``(seed, batch_index)``, so the batch stream is
    bit-identical for ANY window depth and even when several pipelines
    share one service.

Two worker modes:

``process`` (default on POSIX) — a persistent forked worker owns the
    sampling state and streams batches through a bounded queue.  CPython's
    GIL makes a *thread* producer serialize against the consumer's Python
    sections (numpy only releases the GIL for a handful of ops), so a
    separate process is the only way host sampling truly runs beside XLA
    compute — the same reason DGL/PyTorch dataloaders use worker processes.
``thread`` — in-process double buffering via a daemon thread.  Zero-copy
    hand-off, but overlap is limited to the consumer's GIL-released windows.

Determinism: one persistent producer (process or thread) runs exactly the
serial code path on the same initial state, and sampling randomness is keyed
per request, so the batch stream is bit-identical to ``prefetch=0`` AND to
any ``inflight`` depth (tested in tests/test_api.py and tests/test_service.py).
Note that in process mode the sampling-server stats live in the worker, so
read workload counters with ``prefetch=0`` pipelines.
"""
from __future__ import annotations

import collections
import logging
import multiprocessing as mp
import os
import queue as queue_mod
import time
import traceback
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sampling.service import DEFAULT_DIRECTION, SamplingSpec
from repro.core.storage import as_feature_source
from repro.data.graph_loader import SeedBatchLoader
from repro.models.gnn.batching import GNNBatch, subgraph_to_batch
from repro.utils import prefetch_iterator

__all__ = ["BatchPipeline"]

_log = logging.getLogger(__name__)

_FORK_AVAILABLE = os.name == "posix" and "fork" in mp.get_all_start_methods()

_KEY_MASK = (1 << 64) - 1


class BatchPipeline:
    def __init__(
        self,
        backend,
        graph,
        seeds: np.ndarray,
        fanouts,
        num_layers: int,
        *,
        batch_size: int = 256,
        spec: SamplingSpec | None = None,
        weighted: bool = False,
        direction: str = DEFAULT_DIRECTION,
        prefetch: int = 2,
        inflight: int = 1,
        workers: str = "auto",  # auto | process | thread
        worker_cores: tuple | None = None,  # CPU affinity for process workers
        seed: int = 0,
        partition_of: np.ndarray | None = None,
        balance_partitions: bool = False,
        vertex_quantum: int = 256,
        edge_quantum: int = 1024,
        feature_source=None,  # FeatureSource; None = graph.vertex_feats
        ticket_timeout: float | None = None,
        worker_respawns: int = 1,
    ):
        """``ticket_timeout`` bounds every blocking ``ticket.result()``
        wait (None = wait forever, explicitly).  ``worker_respawns`` is the
        crash budget for the forked prefetch worker: a worker found dead
        mid-run is respawned up to this many times, replaying the keyed
        seed stream past the batches already delivered — the resumed
        stream is bit-identical by construction (see ``_respawn_worker``).
        ``worker_respawns=0`` restores the old fail-fast behavior."""
        if workers not in ("auto", "process", "thread"):
            raise ValueError(
                f"workers must be 'auto', 'process' or 'thread', got {workers!r}"
            )
        if inflight < 1:
            raise ValueError(f"inflight must be >= 1, got {inflight}")
        if worker_respawns < 0:
            raise ValueError(
                f"worker_respawns must be >= 0, got {worker_respawns}"
            )
        self.backend = backend
        # accept a SamplerBackend or a raw GatherApply/EdgeCut client; the
        # async submission window needs `submit` (the service surface)
        self._sample = getattr(backend, "sample", None) or backend.sample_khop
        self._submit = getattr(backend, "submit", None)
        self.graph = graph
        self.spec = (
            spec
            if spec is not None
            else SamplingSpec(
                fanouts=tuple(fanouts), weighted=weighted, direction=direction
            )
        ).validate()
        self.fanouts = list(self.spec.fanouts)
        self.num_layers = num_layers
        self.weighted = self.spec.weighted
        self.direction = self.spec.direction
        if self.spec.replace and self._submit is None:
            raise ValueError(
                "replace-policy sampling needs a SamplingService backend "
                "(raw clients only support without-replacement draws)"
            )
        self.prefetch = prefetch
        self.inflight = inflight
        # a remote-dispatching service (dist_transport != "inproc") cannot
        # sit behind a forked prefetch producer: the fork would duplicate
        # the worker-pool channel fds, and parent + child reading the same
        # pipes interleaves partial frames.  Thread-mode prefetch keeps the
        # pool's fds in one process (the remote workers provide the real
        # parallelism anyway).
        service = getattr(backend, "service", None)
        remote = service is not None and getattr(service, "dispatcher", None) is not None
        if remote and workers == "process":
            raise ValueError(
                "workers='process' cannot wrap a remote-dispatch sampling "
                "service (forked producer would share the worker-pool "
                "channels); use workers='thread' or dist_transport='inproc'"
            )
        self.workers = (
            (("thread" if remote else "process") if _FORK_AVAILABLE else "thread")
            if workers == "auto"
            else workers
        )
        self.worker_cores = worker_cores
        self.vertex_quantum = vertex_quantum
        self.edge_quantum = edge_quantum
        # the training-side feature path: any FeatureSource (e.g. a
        # disk-backed HybridCache) — batches are bit-identical to the
        # in-memory matrix because the cache only changes where rows live
        self.feature_source = as_feature_source(
            graph.vertex_feats if feature_source is None else feature_source
        )
        self.loader = SeedBatchLoader(
            seeds,
            batch_size,
            seed=seed,
            partition_of=partition_of,
            balance_partitions=balance_partitions,
        )
        self.sample_time = 0.0  # producer-side host time (sampling + padding)
        self.ticket_timeout = ticket_timeout
        self.worker_respawns = int(worker_respawns)
        self.respawn_count = 0  # workers respawned over this pipeline's life
        self._respawns_left = self.worker_respawns
        # request keys are pipeline-owned: (loader seed, running index), so
        # the stream is independent of the service's other consumers
        self._key_base = int(seed) & _KEY_MASK
        self._req_counter = 0
        self._pending = collections.deque()  # (seeds, SampleTicket) in order
        self._proc = None
        self._cmd_q = None
        self._data_q = None
        self._cancel = None  # mp.Event: stop the worker's current run early
        self._run_history: list[int] = []  # epochs of fully produced runs

    # ------------------------------------------------------------------
    def _next_key(self) -> tuple:
        key = (self._key_base, self._req_counter)
        self._req_counter += 1
        return key

    def _submit_ahead(self, seeds: np.ndarray) -> None:
        ticket = self._submit(seeds, self.spec, key=self._next_key())
        self._pending.append((seeds, ticket))

    def _take_sample(self, seeds: np.ndarray):
        """The subgraph for one seed batch: the pre-submitted in-flight
        ticket when the look-ahead window holds one, else a fresh request.
        Keys are assigned in batch order either way, so windowed and
        unwindowed streams are bit-identical."""
        if self._pending and np.array_equal(self._pending[0][0], seeds):
            _, ticket = self._pending.popleft()
            return ticket.result(timeout=self.ticket_timeout)
        if self._submit is not None:
            ticket = self._submit(seeds, self.spec, key=self._next_key())
            return ticket.result(timeout=self.ticket_timeout)
        return self._sample(
            seeds, self.fanouts, weighted=self.weighted, direction=self.direction
        )

    def make_batch(self, seeds: np.ndarray) -> GNNBatch:
        """One seed batch through sampling + padding (numpy, no prefetch)."""
        sub = self._take_sample(seeds)
        return subgraph_to_batch(
            sub,
            self.feature_source,
            self.graph.labels,
            self.num_layers,
            edge_types=self.graph.edge_types,
            vertex_quantum=self.vertex_quantum,
            edge_quantum=self.edge_quantum,
        )

    def _seed_stream(self, epochs: int):
        for _ in range(epochs):
            for seeds in self.loader.epoch():
                if self._cancel is not None and self._cancel.is_set():
                    return
                yield seeds

    def _drop_pending(self) -> None:
        """Cancel in-flight window tickets so abandoned requests stop
        consuming scheduler rounds and skewing workload counters."""
        while self._pending:
            _, ticket = self._pending.popleft()
            ticket.cancel()

    def _forward_run(self, epochs: int) -> None:
        """Replay one completed run WITHOUT sampling: consume the seed
        stream (advancing the loader's per-epoch permutation RNG) and burn
        one request key per batch, leaving the producer state exactly
        where a real run would have left it.  Used by a respawned worker
        to fast-forward to the crashed run."""
        for _ in self._seed_stream(epochs):
            if self._submit is not None:
                self._next_key()

    def _produce_np(self, epochs: int, skip: int = 0):
        """The serial producer: pure numpy, safe inside the forked worker.
        With ``inflight >= 2`` and a service backend it keeps a window of
        sample requests in flight ahead of the batch being padded.
        ``skip`` fast-forwards past the first ``skip`` batches (already
        delivered before a worker crash) without sampling them — stream
        positions and request keys are consumed so batch ``i`` keeps key
        ``(seed, i)`` and the remainder is bit-identical.

        The bit-identity contract (any prefetch/inflight depth, shared or
        private service) applies to runs driven to completion: abandoning a
        run mid-epoch leaves the seed loader — and, pre-dating this PR, any
        prefetch look-ahead — at an implementation-defined position, so a
        SUBSEQUENT run on the same pipeline resumes from wherever the
        producer stopped."""
        self._drop_pending()  # stale tickets from an abandoned run
        stream = self._seed_stream(epochs)
        for _ in range(skip):
            if next(stream, None) is None:
                break
            if self._submit is not None:
                self._next_key()
        windowed = self.inflight > 1 and self._submit is not None
        # bounded by construction: the refill loop below never grows it past
        # self.inflight (validated positive), so no maxlen is needed
        queue: collections.deque = collections.deque()  # glint: disable=PRJ005 -- see above
        try:
            while True:
                if windowed:
                    while len(queue) < self.inflight:
                        nxt = next(stream, None)
                        if nxt is None:
                            break
                        t0 = time.perf_counter()
                        self._submit_ahead(nxt)
                        self.sample_time += time.perf_counter() - t0
                        queue.append(nxt)
                    if not queue:
                        return
                    seeds = queue.popleft()
                else:
                    seeds = next(stream, None)
                    if seeds is None:
                        return
                t0 = time.perf_counter()
                batch = self.make_batch(seeds)
                self.sample_time += time.perf_counter() - t0
                yield seeds, batch
        finally:
            self._drop_pending()

    def _produce(self, epochs: int):
        for seeds, batch in self._produce_np(epochs):
            # host->device staging rides with the producer so the consumer's
            # step loop is nothing but dispatch + block
            yield seeds, jax.tree.map(jnp.asarray, batch)

    def batches(self, epochs: int = 1):
        """Yield ``(seeds, GNNBatch)`` with arrays staged as jax arrays;
        sampling runs ahead of the consumer when ``prefetch >= 1``."""
        if self.prefetch <= 0:
            return self._produce(epochs)
        if self.workers == "process" and _FORK_AVAILABLE:
            return self._process_batches(epochs)
        # thread mode: prefetch_iterator stops and joins its producer when
        # the generator is closed/abandoned, so the shared loader/backend
        # state is never mutated concurrently with a later epoch
        return prefetch_iterator(self._produce(epochs), self.prefetch)

    def __iter__(self):
        return self.batches(1)

    # -- process-mode plumbing -----------------------------------------
    def _worker_loop(self):  # runs in the forked child: numpy only, no XLA
        if self.worker_cores and hasattr(os, "sched_setaffinity"):
            try:
                # dedicate host cores to sampling (the consumer keeps the
                # device cores), like dataloader-worker pinning in DGL
                os.sched_setaffinity(0, set(self.worker_cores))
            except OSError:
                pass
        while True:
            # glint: disable=PRJ004 -- SimpleQueue has no timeout kwarg; an
            # idle worker is stopped via close(), which escalates to kill()
            cmd = self._cmd_q.get()
            if cmd[0] == "stop":
                return
            if cmd[0] == "forward":
                # replay a prior completed run without sampling (respawn
                # fast-forward); ack so the parent can sequence commands
                self._forward_run(cmd[1])
                self._data_q.put(("fwd",))
                continue
            try:
                for seeds, batch in self._produce_np(cmd[1], skip=cmd[2]):
                    self._data_q.put(("item", seeds, batch))
                self._data_q.put(("done", self.sample_time))
            except BaseException as exc:  # noqa: BLE001 - re-raised in parent
                self._data_q.put(
                    ("error", f"{type(exc).__name__}: {exc}\n{traceback.format_exc()}")
                )

    def _ensure_worker(self):
        if self._proc is not None and self._proc.is_alive():
            return
        ctx = mp.get_context("fork")
        self._cmd_q = ctx.SimpleQueue()
        self._data_q = ctx.Queue(maxsize=max(1, self.prefetch))
        self._cancel = ctx.Event()
        with warnings.catch_warnings():
            # jax warns that fork + threads can deadlock; the child touches
            # only numpy state, never XLA, which is the supported pattern
            warnings.simplefilter("ignore", RuntimeWarning)
            self._proc = ctx.Process(target=self._worker_loop, daemon=True)
            self._proc.start()

    def _next_msg(self):
        """Queue read that notices a dead worker instead of hanging."""
        while True:
            try:
                return self._data_q.get(timeout=1.0)
            except queue_mod.Empty:
                if self._proc is None or not self._proc.is_alive():
                    code = self._proc.exitcode if self._proc is not None else None
                    self.close()
                    raise RuntimeError(
                        f"prefetch worker died (exit code {code}) without "
                        "reporting an error — likely killed (OOM?) or crashed "
                        "in native code"
                    )

    def _respawn_worker(self, code, epochs: int, delivered: int) -> None:
        """Fork a fresh worker and fast-forward it to the crashed run.

        The fresh child forks from THIS process's pristine producer state
        (the parent never advances the loader/key state in process mode),
        so it replays every previously completed run via cheap ``forward``
        commands, then re-enters the crashed run skipping the ``delivered``
        batches already yielded.  Because sampling randomness is keyed
        ``(seed, batch_index)`` and the skip path consumes exactly the
        stream positions and keys a real run would, the resumed stream is
        bit-identical to an uncrashed one by construction."""
        self._respawns_left -= 1
        self.respawn_count += 1
        _log.warning(
            "prefetch worker died (exit code %s); respawning (%d left in "
            "crash budget) and replaying %d delivered batch(es)",
            code,
            self._respawns_left,
            delivered,
        )
        self._proc = None  # force a fresh fork (with fresh, empty queues)
        self._ensure_worker()
        self._cancel.clear()
        for past_epochs in self._run_history:
            self._cmd_q.put(("forward", past_epochs))
            try:
                msg = self._data_q.get(timeout=60.0)
            except queue_mod.Empty:
                msg = None
            if msg is None or msg[0] != "fwd":
                self.close()
                raise RuntimeError(
                    "respawned prefetch worker failed to replay run history"
                )
        self._cmd_q.put(("produce", epochs, delivered))

    def _read_or_respawn(self, epochs: int, delivered: int):
        """Queue read; a dead worker is respawned (crash budget permitting)
        and told to resume past the batches already delivered."""
        while True:
            try:
                return self._data_q.get(timeout=1.0)
            except queue_mod.Empty:
                if self._proc is not None and self._proc.is_alive():
                    continue
                code = self._proc.exitcode if self._proc is not None else None
                if self._respawns_left <= 0:
                    self.close()
                    raise RuntimeError(
                        f"prefetch worker died (exit code {code}) without "
                        "reporting an error — likely killed (OOM?) or crashed "
                        "in native code"
                        + (
                            f" — crash budget of {self.worker_respawns} "
                            "respawn(s) exhausted"
                            if self.worker_respawns
                            else ""
                        )
                    )
                self._respawn_worker(code, epochs, delivered)

    def _process_batches(self, epochs: int):
        self._ensure_worker()
        self._cancel.clear()
        self._cmd_q.put(("produce", epochs, 0))
        delivered = 0
        finished = False
        try:
            while True:
                msg = self._read_or_respawn(epochs, delivered)
                if msg[0] == "done":
                    finished = True
                    self.sample_time = msg[1]  # worker's cumulative clock
                    self._run_history.append(epochs)
                    return
                if msg[0] == "error":
                    finished = True
                    self.close()
                    raise RuntimeError(f"prefetch worker failed:\n{msg[1]}")
                _, seeds, batch = msg
                delivered += 1
                yield seeds, jax.tree.map(jnp.asarray, batch)
        finally:
            if not finished and self._proc is not None:
                # consumer stopped early (e.g. max_steps): cancel the run
                # and drain the few in-flight items so the worker is idle
                # (not sampling concurrently) before the next command
                self._cancel.set()
                while True:
                    try:
                        msg = self._next_msg()
                    except RuntimeError:
                        # worker died mid-drain: the run was already being
                        # abandoned, nothing left to recover
                        break
                    if msg[0] == "done":
                        self.sample_time = msg[1]
                        # an abandoned run still advanced the worker's
                        # loader/key state; record it so a later respawn
                        # replays it (bit-identity is only contracted for
                        # runs driven to completion — see _produce_np)
                        self._run_history.append(epochs)
                        break
                    if msg[0] == "error":
                        self.close()
                        raise RuntimeError(
                            f"prefetch worker failed:\n{msg[1]}"
                        )

    def close(self, timeout: float = 2.0) -> None:
        """Stop the worker process (no-op for thread/serial modes).

        Bounded: a graceful ``stop`` + join escalates to ``terminate()``
        (SIGTERM) and finally ``kill()`` (SIGKILL), so close() returns even
        when the worker is wedged in native code or ignoring SIGTERM."""
        proc, self._proc = self._proc, None
        if proc is not None and proc.is_alive():
            try:
                self._cmd_q.put(("stop",))
                proc.join(timeout=timeout)
            except (OSError, ValueError) as exc:
                # command queue already torn down (closed pipe / released
                # semaphore); fall through to terminate() below
                _log.debug("graceful worker stop failed: %s", exc)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=timeout)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=timeout)

    def __del__(self):  # best effort; daemon children die with the parent
        try:
            self.close()
        except Exception:
            pass
