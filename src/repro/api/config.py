"""``GLISPConfig`` — one plain-data description of a full GLISP deployment.

Every component is named by a registry string (see ``repro.api.backends``),
so a config serializes to JSON and a whole pipeline is reproducible from it:

    cfg = GLISPConfig(num_parts=4, partitioner="adadne", fanouts=(15, 10, 5))
    system = GLISPSystem.build(g, cfg)

The sampling-plan fields (``fanouts``/``weighted``/``direction``/``replace``)
are one ``SamplingSpec``: ``cfg.sampling_spec()`` materializes the typed,
validated object every sampling surface consumes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.core.faults import FaultPlan, RetryPolicy
from repro.core.sampling.service import (
    DEFAULT_DIRECTION,
    MAX_PARTS,
    SamplingSpec,
)

__all__ = ["GLISPConfig"]


@dataclass(frozen=True)
class GLISPConfig:
    # -- partitioning --------------------------------------------------------
    num_parts: int = 4
    # adadne | dne (lockstep-vectorized) | adadne_loop | dne_loop (sequential
    # reference) | ldg | hash2d | random
    partitioner: str = "adadne"
    # content-addressed on-disk cache for the partition->reorder pipeline
    # artifacts (plan + permutation); None disables.  A second build over the
    # same graph+config loads the plan instead of repartitioning.
    partition_cache_dir: str | None = None

    # -- sampling service ----------------------------------------------------
    sampler: str = "gather_apply"  # gather_apply | edge_cut
    fanouts: tuple = (10, 5)
    direction: str = DEFAULT_DIRECTION  # shared by trainer/engine/loader
    weighted: bool = False
    # with-replacement uniform draws (uniform-only); named sample_replace
    # because `replace()` is the config-evolution method
    sample_replace: bool = False
    # server cost model; None picks the backend's native one
    # (gather_apply -> "algd", edge_cut -> "scan")
    cost_model: str | None = None
    # request-level scheduling: dedupe duplicate frontier seeds across
    # in-flight requests (accounting only — results are bit-identical)
    coalesce: bool = True
    # split per-server dispatches larger than this many seeds; 0 = unsplit
    max_server_batch: int = 0
    # loader/trainer submission window: how many sample requests ride
    # in-flight on the service at once (1 = the old blocking behavior)
    inflight: int = 2
    # where the sampling servers live: "inproc" (the default in-process
    # simulation) or "mp"/"socket" — one forked worker process per
    # partition behind a repro.dist transport (pipes / socketpair).
    # Results are bit-identical across all three (keyed per-dispatch RNG)
    dist_transport: str = "inproc"
    # client-side deadline for one remote dispatch answer; also the
    # window in which a dead worker must be respawned
    dist_dispatch_timeout: float = 60.0

    # -- batch pipeline ------------------------------------------------------
    batch_size: int = 256
    prefetch: int = 2  # queue depth for background sampling; 0 = serial
    balance_partitions: bool = False  # DistDGL-style balanced seeds
    vertex_quantum: int = 256  # padding buckets for XLA static shapes
    edge_quantum: int = 1024

    # -- tiered storage ------------------------------------------------------
    reorder: str = "pds"  # ns | ds | ps | pds | bfs
    cache_policy: str = "fifo"  # fifo | lru | locality (CACHE_POLICIES)
    # cache tier stack fast→slow above the authoritative DFS store; names
    # resolve in STORAGE_TIERS (memory | disk)
    storage_tiers: tuple = ("memory", "disk")
    # per-tier chunk budgets aligned with storage_tiers; missing/0 = auto
    # (memory: dynamic_frac of the tier below; disk: unbounded)
    tier_capacities: tuple = ()
    dynamic_frac: float = 0.10
    chunk_rows: int = 4096
    infer_batch_size: int = 4096
    infer_mode: str = "bucketed"  # bucketed (device-resident jit) | reference
    infer_jit: bool = True  # jit layer slices exposing a traceable .jax
    # None = respect each layer fn's own default; True/False force the
    # Pallas segment-SpMM kernel path on/off inside the jit'd slices
    infer_use_kernel: bool | None = None
    # explicit edge-padding buckets (ascending); () = powers of two.  A
    # batch with more edges than the last bucket falls back to
    # power-of-two padding (extra compile) rather than failing
    infer_edge_buckets: tuple = ()
    # sweep kernel block sizes per (op, shape-bucket, dtype) before each
    # bucket's first jit trace (repro.kernels.autotune); only meaningful
    # with infer_use_kernel=True
    kernel_autotune: bool = False
    # directory for the tuner's content-addressed JSON artifact; None keeps
    # tuned configs in-process only (re-measured per process)
    kernel_cache_dir: str | None = None

    # -- fault tolerance -----------------------------------------------------
    # chaos schedule injected into the sampling servers + storage tiers;
    # None = no injection (and no injection overhead on the hot paths)
    fault_plan: FaultPlan | None = None
    # retry/backoff shared by the sampling dispatch and tier-read paths;
    # None = the RetryPolicy defaults (3 attempts, no delay)
    retry_policy: RetryPolicy | None = None
    # bound on every blocking ticket.result() wait; None = wait forever
    ticket_timeout: float | None = None
    # sampling-server replicas per partition (replica 0 is the primary);
    # >1 enables failover when a dispatch exhausts its retries
    server_replicas: int = 1
    # crash budget for the forked prefetch worker (see BatchPipeline)
    worker_respawns: int = 1
    # auto-checkpoint every N training steps into checkpoint_dir; 0 = off
    checkpoint_every: int = 0
    checkpoint_dir: str | None = None

    # -- online serving ------------------------------------------------------
    # admission-queue bound for GLISPSystem.server(); a full queue REJECTS
    # (explicit status="rejected" response) rather than buffering unboundedly
    serve_queue_depth: int = 64
    # a partial batch flushes once its oldest request has waited this long
    # (0 = flush every step); full batches flush immediately
    serve_max_batch_delay_ms: float = 2.0
    # default per-request deadline; a request whose sample has not landed by
    # then completes with status="timeout".  None = no deadline
    serve_deadline_ms: float | None = 100.0

    seed: int = 0

    # -----------------------------------------------------------------------
    def sampling_spec(
        self,
        *,
        fanouts=None,
        weighted: bool | None = None,
        direction: str | None = None,
        replace: bool | None = None,
    ) -> SamplingSpec:
        """The config's sampling plan as one typed object (with per-call
        overrides) — what ``system.sample/submit/loader/trainer`` consume."""
        return SamplingSpec(
            fanouts=tuple(fanouts if fanouts is not None else self.fanouts),
            weighted=self.weighted if weighted is None else weighted,
            direction=direction or self.direction,
            replace=self.sample_replace if replace is None else replace,
        )

    def validate(self) -> "GLISPConfig":
        """Check every registry name and numeric range; returns self."""
        from repro.api.backends import (
            CACHE_POLICIES,
            PARTITIONERS,
            REORDERS,
            SAMPLERS,
        )

        from repro.core.storage import STORAGE_TIERS

        if not 1 <= self.num_parts <= MAX_PARTS:
            raise ValueError(
                f"num_parts must be in [1, {MAX_PARTS}], got {self.num_parts}"
            )
        PARTITIONERS.get(self.partitioner)
        if self.partition_cache_dir is not None and (
            not isinstance(self.partition_cache_dir, str)
            or not self.partition_cache_dir
        ):
            raise ValueError(
                "partition_cache_dir must be None or a non-empty path, got "
                f"{self.partition_cache_dir!r}"
            )
        SAMPLERS.get(self.sampler)
        if self.reorder not in REORDERS:
            raise ValueError(
                f"reorder must be one of {REORDERS.names()}, "
                f"got {self.reorder!r}"
            )
        if self.cache_policy not in CACHE_POLICIES:
            raise ValueError(
                f"cache_policy must be one of {CACHE_POLICIES.names()}, "
                f"got {self.cache_policy!r}"
            )
        if not self.storage_tiers:
            raise ValueError("storage_tiers must name at least one cache tier")
        for name in self.storage_tiers:
            if name not in STORAGE_TIERS:
                raise ValueError(
                    f"storage_tiers entries must be one of "
                    f"{STORAGE_TIERS.names()}, got {name!r}"
                )
        if len(self.tier_capacities) > len(self.storage_tiers):
            raise ValueError(
                f"tier_capacities has {len(self.tier_capacities)} entries for "
                f"{len(self.storage_tiers)} storage_tiers"
            )
        for cap in self.tier_capacities:
            if cap < 0:
                raise ValueError(
                    f"tier_capacities entries must be >= 0 (0 = auto), got {cap}"
                )
        self.sampling_spec().validate()
        if self.cost_model not in (None, "algd", "scan"):
            raise ValueError(
                f"cost_model must be None, 'algd' or 'scan', got {self.cost_model!r}"
            )
        for name in (
            "batch_size",
            "vertex_quantum",
            "edge_quantum",
            "chunk_rows",
            "infer_batch_size",
            "inflight",
        ):
            v = getattr(self, name)
            if v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")
        for name in ("prefetch", "max_server_batch"):
            v = getattr(self, name)
            if v < 0:
                raise ValueError(f"{name} must be >= 0, got {v}")
        if not 0.0 < self.dynamic_frac <= 1.0:
            raise ValueError(
                f"dynamic_frac must be in (0, 1], got {self.dynamic_frac}"
            )
        if self.fault_plan is not None and not isinstance(
            self.fault_plan, FaultPlan
        ):
            raise TypeError(
                f"fault_plan must be a FaultPlan or None, got {self.fault_plan!r}"
            )
        if self.retry_policy is not None:
            if not isinstance(self.retry_policy, RetryPolicy):
                raise TypeError(
                    "retry_policy must be a RetryPolicy or None, got "
                    f"{self.retry_policy!r}"
                )
            self.retry_policy.validate()
        if self.ticket_timeout is not None and self.ticket_timeout <= 0:
            raise ValueError(
                f"ticket_timeout must be positive or None, got {self.ticket_timeout}"
            )
        if self.dist_transport not in ("inproc", "mp", "socket"):
            raise ValueError(
                "dist_transport must be 'inproc', 'mp' or 'socket', got "
                f"{self.dist_transport!r}"
            )
        if self.dist_dispatch_timeout <= 0:
            raise ValueError(
                "dist_dispatch_timeout must be positive, got "
                f"{self.dist_dispatch_timeout}"
            )
        if self.server_replicas < 1:
            raise ValueError(
                f"server_replicas must be >= 1, got {self.server_replicas}"
            )
        if self.worker_respawns < 0:
            raise ValueError(
                f"worker_respawns must be >= 0, got {self.worker_respawns}"
            )
        if self.checkpoint_every < 0:
            raise ValueError(
                f"checkpoint_every must be >= 0, got {self.checkpoint_every}"
            )
        if self.checkpoint_every > 0 and self.checkpoint_dir is None:
            raise ValueError("checkpoint_every > 0 requires a checkpoint_dir")
        if self.serve_queue_depth <= 0:
            raise ValueError(
                f"serve_queue_depth must be positive, got {self.serve_queue_depth}"
            )
        if self.serve_max_batch_delay_ms < 0:
            raise ValueError(
                "serve_max_batch_delay_ms must be >= 0, got "
                f"{self.serve_max_batch_delay_ms}"
            )
        if self.serve_deadline_ms is not None and self.serve_deadline_ms <= 0:
            raise ValueError(
                "serve_deadline_ms must be positive or None, got "
                f"{self.serve_deadline_ms}"
            )
        if self.kernel_cache_dir is not None and (
            not isinstance(self.kernel_cache_dir, str) or not self.kernel_cache_dir
        ):
            raise ValueError(
                "kernel_cache_dir must be None or a non-empty path, got "
                f"{self.kernel_cache_dir!r}"
            )
        if self.kernel_autotune and self.infer_use_kernel is not True:
            raise ValueError(
                "kernel_autotune=True requires infer_use_kernel=True (tuned "
                "block sizes only apply to the Pallas kernel path)"
            )
        if self.infer_mode not in ("bucketed", "reference"):
            raise ValueError(
                f"infer_mode must be 'bucketed' or 'reference', got {self.infer_mode!r}"
            )
        if any(b <= 0 for b in self.infer_edge_buckets) or list(
            self.infer_edge_buckets
        ) != sorted(self.infer_edge_buckets):
            raise ValueError(
                "infer_edge_buckets must be positive and ascending, got "
                f"{self.infer_edge_buckets!r}"
            )
        return self

    def replace(self, **kw) -> "GLISPConfig":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fanouts"] = list(self.fanouts)
        d["infer_edge_buckets"] = list(self.infer_edge_buckets)
        d["storage_tiers"] = list(self.storage_tiers)
        d["tier_capacities"] = list(self.tier_capacities)
        # typed fault-tolerance objects serialize via their own to_dict
        d["fault_plan"] = self.fault_plan.to_dict() if self.fault_plan else None
        d["retry_policy"] = (
            self.retry_policy.to_dict() if self.retry_policy else None
        )
        return d
