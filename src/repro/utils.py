"""Small shared utilities: timing, rng, byte accounting, padding helpers."""
from __future__ import annotations

import json
import queue
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Generic, Iterator, TypeVar

import numpy as np

__all__ = [
    "Registry",
    "Timer",
    "timed",
    "human_bytes",
    "nbytes_of",
    "pad_to",
    "ceil_div",
    "round_up",
    "stable_hash64",
    "json_dump",
    "prefetch_iterator",
    "concat_ranges",
    "csr_slots",
    "incidence_csr",
]

_T = TypeVar("_T")


class Registry(Generic[_T]):
    """Case-insensitive name -> component map with decorator registration.

    Lives here (dependency-free) so both ``repro.api`` and ``repro.core``
    subsystems can define registries without an import cycle; the canonical
    public re-export stays ``repro.api.registry.Registry``."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, _T] = {}

    @staticmethod
    def _key(name: str) -> str:
        return name.strip().lower()

    def register(self, name: str, obj: _T | None = None):
        """``REG.register("name", obj)`` or ``@REG.register("name")``."""
        key = self._key(name)

        def _add(o: _T) -> _T:
            if key in self._entries:
                raise ValueError(f"{self.kind} {name!r} already registered")
            self._entries[key] = o
            return o

        return _add if obj is None else _add(obj)

    def get(self, name: str) -> _T:
        key = self._key(name)
        if key not in self._entries:
            known = ", ".join(sorted(self._entries)) or "<none>"
            raise ValueError(
                f"unknown {self.kind} {name!r}; registered: {known}"
            )
        return self._entries[key]

    def names(self) -> list[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return self._key(name) in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._entries))

    def __len__(self) -> int:
        return len(self._entries)


def concat_ranges(lens: np.ndarray) -> np.ndarray:
    """``[0..lens[0]) ++ [0..lens[1]) ++ ...`` as one int64 array."""
    if lens.shape[0] == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(lens)
    out = np.arange(ends[-1], dtype=np.int64)
    out -= np.repeat(ends - lens, lens)
    return out


def csr_slots(indptr: np.ndarray, verts: np.ndarray) -> np.ndarray:
    """Concatenated CSR slot ranges of ``verts`` (one repeat + one arange,
    no per-vertex Python)."""
    lens = indptr[verts + 1] - indptr[verts]
    return np.repeat(indptr[verts], lens) + concat_ranges(lens)


def incidence_csr(
    num_vertices: int,
    passes: list[tuple[np.ndarray, np.ndarray]],
) -> tuple[np.ndarray, np.ndarray]:
    """Vertex -> payload CSR built from ``(vertex_array, payload_array)``
    passes, each filled vectorized in vertex-sorted runs.

    The partition subsystem's two uses: undirected edge incidence
    (``passes=[(src, eids), (dst, eids)]`` -> vertex's incident edge ids)
    and undirected neighbor lists (``passes=[(src, dst), (dst, src)]``)."""
    deg = np.zeros(num_vertices, dtype=np.int64)
    for verts, _ in passes:
        deg += np.bincount(verts, minlength=num_vertices)
    indptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    values = np.empty(indptr[-1], dtype=np.int64)
    fill_ptr = indptr[:-1].copy()
    for verts, payload in passes:
        srt = np.argsort(verts, kind="stable")
        vs = verts[srt]
        ps = payload[srt]
        starts = np.searchsorted(vs, np.arange(num_vertices))
        ends = np.searchsorted(vs, np.arange(num_vertices) + 1)
        lens = ends - starts
        values[np.repeat(fill_ptr, lens) + concat_ranges(lens)] = ps
        fill_ptr = fill_ptr + lens
    return indptr, values


def prefetch_iterator(it, depth: int):
    """Drain ``it`` on a background thread into a bounded queue of ``depth``
    items, yielding them in order (double-buffered host/device overlap when
    ``depth >= 2``).  The single producer preserves the source order, so the
    stream is bit-identical to iterating ``it`` directly.  ``depth <= 0``
    yields from ``it`` unchanged.  Producer exceptions re-raise at the
    consumer.  Closing/abandoning the generator early signals the producer
    to stop at its next item and unblocks it, so no thread or queued work is
    pinned for the process lifetime (note: items the source already produced
    ahead are discarded, and the source iterator is left mid-iteration)."""
    if depth <= 0:
        yield from it
        return
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()
    _END, _ERR = object(), object()

    def _safe_put(obj) -> bool:
        """Bounded-wait put that gives up once the consumer signals stop
        (a plain q.put could block forever against a full queue after the
        consumer is gone — e.g. the depth=1 end-sentinel)."""
        while not stop.is_set():
            try:
                q.put(obj, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _produce():
        try:
            for item in it:
                if not _safe_put(item):
                    return
        except BaseException as exc:  # noqa: BLE001 - re-raised at consumer
            _safe_put((_ERR, exc))
            return
        _safe_put(_END)

    t = threading.Thread(target=_produce, daemon=True, name="glisp-prefetch")
    t.start()
    try:
        while True:
            try:
                item = q.get(timeout=1.0)
            except queue.Empty:
                # a produced-then-died thread always enqueues _END/_ERR
                # first, so an empty queue + dead producer means it was
                # killed without reporting (the process-mode analogue
                # raises the same way in BatchPipeline._next_msg)
                if not t.is_alive():
                    raise RuntimeError(
                        "prefetch producer thread died without reporting"
                    )
                continue
            if item is _END:
                break
            if isinstance(item, tuple) and len(item) == 2 and item[0] is _ERR:
                raise item[1]
            yield item
        t.join()
    finally:
        stop.set()
        while True:  # unblock a producer waiting on the full queue
            try:
                q.get_nowait()
            except queue.Empty:
                break
        t.join(timeout=5)


@dataclass
class Timer:
    """Accumulating wall-clock timer keyed by section name."""

    totals: dict = field(default_factory=dict)
    counts: dict = field(default_factory=dict)

    @contextmanager
    def section(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.totals[name] = self.totals.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    def summary(self) -> dict:
        return {
            k: {"total_s": self.totals[k], "calls": self.counts[k]}
            for k in sorted(self.totals)
        }


@contextmanager
def timed(out: dict, key: str):
    t0 = time.perf_counter()
    try:
        yield
    finally:
        out[key] = out.get(key, 0.0) + time.perf_counter() - t0


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"


def nbytes_of(obj) -> int:
    """Total nbytes of a (nested) structure of numpy arrays."""
    if isinstance(obj, np.ndarray):
        return obj.nbytes
    if isinstance(obj, dict):
        return sum(nbytes_of(v) for v in obj.values())
    if isinstance(obj, (list, tuple)):
        return sum(nbytes_of(v) for v in obj)
    if hasattr(obj, "__dict__"):
        return sum(nbytes_of(v) for v in vars(obj).values())
    return 0


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def pad_to(arr: np.ndarray, n: int, fill=0) -> np.ndarray:
    """Pad axis-0 of ``arr`` to length ``n`` with ``fill`` (truncates if longer)."""
    if arr.shape[0] >= n:
        return arr[:n]
    pad_shape = (n - arr.shape[0],) + arr.shape[1:]
    return np.concatenate([arr, np.full(pad_shape, fill, dtype=arr.dtype)], axis=0)


def stable_hash64(x: np.ndarray, salt: int = 0) -> np.ndarray:
    """Deterministic 64-bit mix hash (splitmix64 finalizer), vectorized."""
    with np.errstate(over="ignore"):
        z = x.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15) * np.uint64(
            salt + 1
        )
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def json_dump(obj, path: str) -> None:
    class _Enc(json.JSONEncoder):
        def default(self, o):
            if isinstance(o, (np.integer,)):
                return int(o)
            if isinstance(o, (np.floating,)):
                return float(o)
            if isinstance(o, np.ndarray):
                return o.tolist()
            return super().default(o)

    with open(path, "w") as f:
        json.dump(obj, f, indent=2, cls=_Enc)
