"""The client side of ``repro.dist``: a pool of partition worker processes.

:class:`WorkerPool` forks one process per partition (each hosting that
partition's :class:`~repro.dist.worker.WorkerHost`), connected by a
:func:`~repro.dist.transport.channel_pair` — multiprocessing pipes
(``transport="mp"``) or a socketpair (``transport="socket"``).  It
implements the ``SamplingService`` remote-dispatch contract as two named
phases:

``dispatch(p, ci, chunk, key, hop, spec) -> handle``
    serialize one chunk's :class:`SampleDispatch` to partition ``p``'s
    worker and return immediately — all partitions' chunks go out before
    any answer is read, so workers genuinely overlap;

``collect(handle) -> (None, raw_gather) | None``
    block for that dispatch's :class:`DispatchResult` (FIFO per worker),
    returning exactly what an in-process ``_dispatch_gather`` would have:
    the raw gather tuple, or ``None`` for a lost (degraded) dispatch.

Failure semantics: a worker that dies mid-request is respawned (within
the ``respawns`` budget, mirroring ``BatchPipeline``), restored from its
last crash-consistency snapshot, and the in-flight dispatches are resent
in order — the keyed RNG and per-site fault counters make the replay
bit-identical, so a crash is invisible in the sample stream.  A worker
that exhausts the budget is marked permanently down and its dispatches
answer ``None`` (degraded), exactly like an exhausted replica group.

``close(timeout=)`` escalates shutdown-frame → join → terminate → kill,
the same ladder as ``BatchPipeline.close``.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import time
import warnings
from collections import deque

import numpy as np

from repro.core.faults import RetryPolicy
from repro.dist.transport import (
    ChannelClosed,
    DispatchResult,
    HealthRequest,
    HealthResponse,
    ProtocolError,
    ResetStatsAck,
    ResetStatsRequest,
    SampleDispatch,
    ShutdownRequest,
    StatsRequest,
    StatsResponse,
    channel_pair,
)
from repro.dist.worker import _worker_main

__all__ = ["WorkerPool"]

_FORK_AVAILABLE = os.name == "posix" and "fork" in mp.get_all_start_methods()

_CONTROL_TIMEOUT_S = 10.0


class _Worker:
    __slots__ = ("index", "proc", "channel", "inflight", "state", "up", "seq")

    def __init__(self, index: int):
        self.index = index
        self.proc = None
        self.channel = None
        # FIFO of (handle, SampleDispatch, t0) awaiting answers; kept until
        # collected so a respawned worker can replay them in order
        self.inflight: deque = deque()
        self.state: dict = {}  # latest crash-consistency snapshot
        self.up = False
        self.seq = 0


class WorkerPool:
    """One forked sampling-server process per partition."""

    def __init__(
        self,
        partitions,
        *,
        transport: str = "mp",
        seed: int = 0,
        cost_model: str = "algd",
        replicas: int = 1,
        fault_plan=None,
        retry_policy: RetryPolicy | None = None,
        respawns: int = 1,
        dispatch_timeout: float = 60.0,
    ):
        if transport not in ("mp", "socket"):
            raise ValueError(
                f"transport must be 'mp' or 'socket', got {transport!r}"
            )
        if not _FORK_AVAILABLE:
            raise RuntimeError(
                "WorkerPool needs POSIX fork (workers inherit the graph "
                "partitions by address); use dist_transport='inproc' here"
            )
        self.transport = transport
        self.partitions = list(partitions)
        self.dispatch_timeout = float(dispatch_timeout)
        self.respawns_left = int(respawns)
        self.respawn_count = 0
        self.latencies: list[float] = []  # client-observed dispatch ms
        self._options = dict(
            seed=int(seed),
            cost_model=cost_model,
            replicas=int(replicas),
            fault_plan=fault_plan,
            retry_policy=retry_policy,
        )
        self._closed = False
        self._workers = [_Worker(p) for p in range(len(self.partitions))]
        for w in self._workers:
            self._spawn(w)

    # -- process lifecycle ----------------------------------------------
    def _spawn(self, w: _Worker, restore: dict | None = None) -> None:
        parent_ch, child_ch = channel_pair(self.transport)
        ctx = mp.get_context("fork")
        opts = dict(self._options, restore=restore)
        with warnings.catch_warnings():
            # jax warns about fork after initialization; the workers never
            # touch jax (pure-numpy sampling), so the warning is noise here
            warnings.simplefilter("ignore", RuntimeWarning)
            proc = ctx.Process(
                target=_worker_main,
                args=(w.index, self.partitions[w.index], child_ch, opts),
                daemon=True,
            )
            proc.start()
        # the child's channel end must not stay open in the parent, or a
        # dead child never surfaces as EOF on our recv
        child_ch.close()
        w.proc, w.channel, w.up = proc, parent_ch, True

    def _mark_down(self, w: _Worker) -> None:
        w.up = False
        if w.channel is not None:
            w.channel.close()
        if w.proc is not None:
            w.proc.join(timeout=2.0)

    def _try_respawn(self, w: _Worker) -> bool:
        """Respawn a dead worker from its last snapshot and replay its
        in-flight dispatches in order; False once the budget is spent."""
        if self.respawns_left <= 0:
            return False
        self.respawns_left -= 1
        self.respawn_count += 1
        self._spawn(w, restore=w.state or None)
        try:
            for _, msg, _ in w.inflight:
                w.channel.send(msg)
        except ChannelClosed:
            self._mark_down(w)  # died during replay; loop may retry
        return True

    # -- the execute_hop dispatch contract ------------------------------
    def dispatch(self, p: int, ci: int, chunk, key, hop: int, spec):
        """Send one chunk's gather to partition ``p``; returns a handle
        for :meth:`collect`.  Never blocks on the answer."""
        if self._closed:
            raise RuntimeError("WorkerPool is closed")
        w = self._workers[p]
        msg = SampleDispatch(
            key=tuple(int(k) for k in key),
            hop=int(hop),
            part=int(p),
            chunk=int(ci),
            seeds=np.asarray(chunk, dtype=np.int64),
            fanout=int(spec.fanouts[hop]),
            direction=spec.direction,
            weighted=bool(spec.weighted),
            replace=bool(spec.replace),
        )
        handle = (p, w.seq)
        w.seq += 1
        w.inflight.append((handle, msg, time.perf_counter()))
        if w.up:
            try:
                w.channel.send(msg)
            except ChannelClosed:
                self._mark_down(w)  # collect() will respawn and replay
        return handle

    def collect(self, handle):
        """Block for ``handle``'s answer.  Returns ``(None, raw_gather)``
        — the in-process ``_dispatch_gather`` contract, with no serving
        server to name — or ``None`` for a lost/degraded dispatch."""
        p, _ = handle
        w = self._workers[p]
        if not w.inflight or w.inflight[0][0] != handle:
            raise ProtocolError(
                f"out-of-order collect: {handle} is not worker {p}'s "
                "oldest outstanding dispatch"
            )
        deadline = time.perf_counter() + self.dispatch_timeout
        while True:
            if not w.up:
                if not self._try_respawn(w):
                    # budget spent: permanently down, dispatch is lost
                    w.inflight.popleft()
                    return None
                continue
            try:
                if not w.channel.poll(0.05):
                    if not w.proc.is_alive():
                        self._mark_down(w)
                    elif time.perf_counter() > deadline:
                        raise TimeoutError(
                            f"worker {p} gave no answer within "
                            f"{self.dispatch_timeout}s"
                        )
                    continue
                res = w.channel.recv()
            except ChannelClosed:
                self._mark_down(w)
                continue
            _, msg, t0 = w.inflight.popleft()
            if (
                not isinstance(res, DispatchResult)
                or res.part != msg.part
                or res.chunk != msg.chunk
            ):
                raise ProtocolError(
                    f"worker {p} answered {res!r} to dispatch "
                    f"(part={msg.part}, chunk={msg.chunk})"
                )
            self.latencies.append((time.perf_counter() - t0) * 1e3)
            w.state = res.state
            if res.lost:
                return None
            if msg.weighted:
                return None, (res.src, res.dst, res.scores, res.eid)
            return None, (res.src, res.dst, res.eid)

    def drain_latencies(self) -> list[float]:
        out, self.latencies = self.latencies, []
        return out

    # -- control plane --------------------------------------------------
    def _control(self, request_msg, response_cls):
        """One control round-trip per live worker; ``None`` for dead ones.
        Only valid when no dispatches are outstanding (control frames
        share the channel with data)."""
        if any(w.inflight for w in self._workers):
            raise RuntimeError(
                "control requests require no outstanding dispatches"
            )
        replies: list = []
        for w in self._workers:
            if not w.up:
                replies.append(None)
                continue
            try:
                w.channel.send(request_msg)
                deadline = time.perf_counter() + _CONTROL_TIMEOUT_S
                while not w.channel.poll(0.05):
                    if (
                        not w.proc.is_alive()
                        or time.perf_counter() > deadline
                    ):
                        raise ChannelClosed(f"worker {w.index} unresponsive")
                res = w.channel.recv()
            except ChannelClosed:
                self._mark_down(w)
                replies.append(None)
                continue
            if not isinstance(res, response_cls):
                raise ProtocolError(
                    f"worker {w.index} answered {res!r} to "
                    f"{type(request_msg).__name__}"
                )
            replies.append(res)
        return replies

    def server_stats(self) -> dict:
        """``{site: ServerStats-field-dict}`` across every worker; dead
        workers contribute their last snapshot (their counters stopped
        when they died, which is exactly what the snapshot holds)."""
        merged: dict = {}
        for w, resp in zip(
            self._workers, self._control(StatsRequest(), StatsResponse)
        ):
            replicas = (
                resp.replicas if resp is not None
                else w.state.get("replicas", {})
            )
            merged.update(replicas)
        return merged

    def health(self) -> dict:
        """Per-site breaker health plus a ``worker.<p>`` liveness row per
        worker process."""
        out: dict = {}
        for w, resp in zip(
            self._workers, self._control(HealthRequest(), HealthResponse)
        ):
            out[f"worker.{w.index}"] = "up" if w.up else "down"
            if resp is not None:
                out.update(resp.health)
            else:
                for site in w.state.get("replicas", {}):
                    out[site] = "down"
        return out

    def workloads(self) -> np.ndarray:
        """Measured-at-the-worker modeled work per partition (summed over
        that partition's replicas) — same shape as the in-process
        ``server_workloads``."""
        sums = np.zeros(len(self.partitions))
        for site, stats in self.server_stats().items():
            part = int(site.split(".")[1])
            sums[part] += float(stats.get("work_units", 0.0))
        return sums

    def snapshot_workloads(self) -> list:
        """Per-partition work_units from the snapshots riding on already
        collected results — no control round-trip, so the service can
        difference it around a scheduling round (the per-round work
        accounting) without draining the dispatch window."""
        out = []
        for w in self._workers:
            out.append(
                sum(
                    float(s.get("work_units", 0.0))
                    for s in w.state.get("replicas", {}).values()
                )
            )
        return out

    def reset_stats(self) -> None:
        for w, resp in zip(
            self._workers, self._control(ResetStatsRequest(), ResetStatsAck)
        ):
            if resp is None and w.state.get("replicas"):
                # a dead worker cannot zero itself; zero its snapshot
                w.state = dict(w.state, replicas={})
        self.latencies = []

    # -- shutdown -------------------------------------------------------
    def close(self, timeout: float = 2.0) -> None:
        """Stop every worker: shutdown frame, then join/terminate/kill
        with bounded waits at each rung (BatchPipeline's ladder)."""
        if self._closed:
            return
        self._closed = True
        for w in self._workers:
            if w.up:
                try:
                    w.channel.send(ShutdownRequest())
                except ChannelClosed:
                    pass
        for w in self._workers:
            proc = w.proc
            if proc is None:
                continue
            proc.join(timeout=timeout)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=timeout)
            if proc.is_alive():
                proc.kill()
                # glint: disable=PRJ006 -- SIGKILL is uncatchable; this
                # join only reaps the already-dead child's zombie entry
                proc.join()
            if w.channel is not None:
                w.channel.close()
            w.up = False

    def __del__(self):
        try:
            self.close(timeout=0.5)
        except Exception:
            pass
