"""The worker-process side of ``repro.dist``: one partition's server host.

A :class:`WorkerHost` lives in its own OS process and owns one
partition's :class:`SamplingServer` replicas — the same primary+replica
group the in-process service builds, seeded identically (primary at
``seed``, replica ``r`` at ``seed + 104729*r``), with its own
``FaultInjector`` built from the same plan.  Fault decisions are a pure
function of ``(plan.seed, site, invocation)`` and every site's counter is
independent, so the worker's fault stream is bit-identical to the one the
in-process service would have produced for the same dispatch sequence.

``handle_dispatch`` mirrors ``SamplingService._dispatch_gather`` exactly:
walk non-quarantined replicas in order, up to ``RetryPolicy.max_attempts``
tries each, re-deriving the dispatch RNG from ``(key, hop, part, chunk)``
per attempt — never from the attempt number or the serving replica — so
retries and failovers redraw the bit-identical sample.  A dispatch that
exhausts every replica answers ``lost=True`` (degraded partial fanout)
instead of dying: worker death is reserved for real crashes.

Every :class:`DispatchResult` carries a crash-consistency ``state``
snapshot (per-replica stats, injector counters, breaker states).  The
pool keeps the latest snapshot per worker; a respawned worker restores it
and replays the in-flight dispatches, continuing the fault/breaker
streams exactly where its predecessor died.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.faults import InjectedFault, RetryPolicy, as_injector
from repro.core.sampling.service import (
    _GATHER_TAG,
    SamplingServer,
    ServerStats,
    _gather_once,
    request_rng,
)
from repro.dist.transport import (
    ChannelClosed,
    DispatchResult,
    HealthRequest,
    HealthResponse,
    ResetStatsAck,
    ResetStatsRequest,
    SampleDispatch,
    ShutdownAck,
    ShutdownRequest,
    StatsRequest,
    StatsResponse,
)

__all__ = ["WorkerHost", "REPLICA_SEED_STRIDE"]

# must match the replica seeding in SamplingService.__init__ — replica r of
# any partition draws from default_rng((seed + STRIDE*r) * 7919 + part_id)
# in both deployments, or cross-mode bit-identity breaks
REPLICA_SEED_STRIDE = 104729


class WorkerHost:
    """One partition's sampling servers, served over a transport channel."""

    def __init__(
        self,
        part_index: int,
        partition,
        channel,
        *,
        seed: int = 0,
        cost_model: str = "algd",
        replicas: int = 1,
        fault_plan=None,
        retry_policy: RetryPolicy | None = None,
        restore: dict | None = None,
    ):
        self.part_index = int(part_index)
        self.channel = channel
        self.seed = int(seed)
        self.faults = as_injector(fault_plan)
        self.retry_policy = (
            retry_policy if retry_policy is not None else RetryPolicy()
        )
        self.retry_policy.validate()
        self.group = [
            SamplingServer(
                partition,
                seed=self.seed,
                cost_model=cost_model,
                faults=self.faults,
            )
        ]
        for r in range(1, int(replicas)):
            self.group.append(
                SamplingServer(
                    partition,
                    seed=self.seed + REPLICA_SEED_STRIDE * r,
                    cost_model=cost_model,
                    replica_id=r,
                    faults=self.faults,
                )
            )
        if restore:
            self._restore(restore)

    # -- crash-consistency snapshots ------------------------------------
    def snapshot(self) -> dict:
        """Everything a respawned successor needs to continue this
        worker's deterministic streams: per-replica stats, fault-injector
        counters, and breaker states (order matches ``self.group``)."""
        snap: dict = {
            "replicas": {
                srv.site: dataclasses.asdict(srv.stats) for srv in self.group
            },
            "breakers": [
                {
                    "consecutive_failures": srv.breaker.consecutive_failures,
                    "opens": srv.breaker.opens,
                    "cooldown_left": srv.breaker._cooldown_left,
                    "half_open": srv.breaker._half_open,
                }
                for srv in self.group
            ],
        }
        if self.faults is not None:
            snap["injector"] = {
                "invocations": dict(self.faults.invocations),
                "failures": dict(self.faults.failures),
                "burst": dict(self.faults._burst_left),
            }
        return snap

    def _restore(self, snap: dict) -> None:
        for srv in self.group:
            d = snap.get("replicas", {}).get(srv.site)
            if d is not None:
                srv.stats = ServerStats(**d)
        for srv, b in zip(self.group, snap.get("breakers", [])):
            srv.breaker.consecutive_failures = int(b["consecutive_failures"])
            srv.breaker.opens = int(b["opens"])
            srv.breaker._cooldown_left = int(b["cooldown_left"])
            srv.breaker._half_open = bool(b["half_open"])
        inj = snap.get("injector")
        if inj is not None and self.faults is not None:
            self.faults.invocations = {
                str(k): int(v) for k, v in inj["invocations"].items()
            }
            self.faults.failures = {
                str(k): int(v) for k, v in inj["failures"].items()
            }
            self.faults._burst_left = {
                str(k): int(v) for k, v in inj["burst"].items()
            }

    # -- dispatch -------------------------------------------------------
    def handle_dispatch(self, msg: SampleDispatch) -> DispatchResult:
        """Mirror of ``SamplingService._dispatch_gather`` for one chunk."""
        t0 = time.perf_counter()
        policy = self.retry_policy
        retries0 = sum(srv.stats.retries for srv in self.group)
        chunk = np.asarray(msg.seeds, dtype=np.int64)
        for r, srv in enumerate(self.group):
            if not srv.breaker.allow():
                continue
            for attempt in range(1, policy.max_attempts + 1):
                # re-derived per attempt, keyed only by the dispatch
                # coordinates — retry/failover redraws bit-identically
                rng = request_rng(
                    self.seed,
                    tuple(msg.key),
                    msg.hop,
                    msg.part,
                    msg.chunk,
                    _GATHER_TAG,
                )
                try:
                    res = _gather_once(
                        srv,
                        chunk,
                        msg.fanout,
                        msg.direction,
                        weighted=msg.weighted,
                        replace=msg.replace,
                        rng=rng,
                    )
                except InjectedFault:
                    srv.breaker.record_failure()
                    if (
                        attempt < policy.max_attempts
                        and srv.breaker.state != "open"
                    ):
                        srv.stats.retries += 1
                        policy.sleep(attempt)
                        continue
                    break  # replica exhausted or quarantined: fail over
                srv.breaker.record_success()
                if r > 0:
                    srv.stats.failovers += 1
                if msg.weighted:
                    s, n, sc, e = res
                else:
                    (s, n, e), sc = res, None
                return DispatchResult(
                    part=msg.part,
                    chunk=msg.chunk,
                    src=s,
                    dst=n,
                    eid=e,
                    scores=sc,
                    retries=sum(v.stats.retries for v in self.group) - retries0,
                    failovers=r,
                    wall_ms=(time.perf_counter() - t0) * 1e3,
                    state=self.snapshot(),
                )
        # every replica exhausted: degraded partial fanout.  The CLIENT
        # counts this against degraded_dispatches — counting here too
        # would double-book it in merged stats.
        return DispatchResult(
            part=msg.part,
            chunk=msg.chunk,
            lost=True,
            retries=sum(v.stats.retries for v in self.group) - retries0,
            wall_ms=(time.perf_counter() - t0) * 1e3,
            state=self.snapshot(),
        )

    # -- control --------------------------------------------------------
    def server_stats(self) -> dict:
        return {srv.site: dataclasses.asdict(srv.stats) for srv in self.group}

    def server_health(self) -> dict:
        return {srv.site: srv.health for srv in self.group}

    def reset_stats(self) -> None:
        for srv in self.group:
            srv.stats = ServerStats()

    # -- serve loop -----------------------------------------------------
    def serve_forever(self) -> None:
        """Answer frames until shutdown or the peer disappears."""
        while True:
            try:
                msg = self.channel.recv()
            except ChannelClosed:
                return  # parent is gone; nothing left to answer
            if isinstance(msg, SampleDispatch):
                reply = self.handle_dispatch(msg)
            elif isinstance(msg, StatsRequest):
                reply = StatsResponse(
                    part=self.part_index, replicas=self.server_stats()
                )
            elif isinstance(msg, HealthRequest):
                reply = HealthResponse(
                    part=self.part_index, health=self.server_health()
                )
            elif isinstance(msg, ResetStatsRequest):
                self.reset_stats()
                reply = ResetStatsAck(part=self.part_index)
            elif isinstance(msg, ShutdownRequest):
                try:
                    self.channel.send(ShutdownAck(part=self.part_index))
                except ChannelClosed:
                    pass
                return
            else:
                # unknown control frame: a protocol drift we refuse to
                # paper over — die loudly, the pool will notice
                raise RuntimeError(f"worker got unexpected frame {msg!r}")
            try:
                self.channel.send(reply)
            except ChannelClosed:
                return


def _worker_main(part_index: int, partition, channel, options: dict) -> None:
    """Process entry point (fork target) for one partition worker."""
    try:
        WorkerHost(part_index, partition, channel, **options).serve_forever()
    finally:
        channel.close()
