"""The ``repro.dist`` wire layer: versioned, length-prefixed messages.

Every frame is ``header + payload``:

    header  = <4s magic "GLSP"> <u16 version> <u16 msg_type> <u64 payload_len>
    payload = one TLV-encoded dict of the message dataclass's fields

The TLV value codec covers exactly the types the sampling protocol needs
(None/bool/int/float/str/bytes/tuple/list/dict/ndarray); ints are
arbitrary-precision (request keys are 64-bit-masked and may not fit a
signed i64), ndarrays travel as ``dtype.str + shape + raw buffer`` and
decode to fresh writable copies, so a ``DispatchResult`` round-trips
bit-identically.

Decoding is strict: a bad magic is a :class:`ProtocolError`, a version
other than :data:`PROTOCOL_VERSION` is a :class:`VersionMismatch`, and a
frame shorter than its header promises is a :class:`TruncatedFrame` —
protocol drift between a client and a worker fails loudly at the first
frame instead of corrupting samples silently.

Two pluggable channels carry frames: :class:`PipeChannel` (a
``multiprocessing`` duplex pipe — the same-host fast path) and
:class:`SocketChannel` (any stream socket — the general case).  Both
expose ``send/recv/poll/close`` and raise :class:`ChannelClosed` when the
peer is gone, which is how the pool detects a dead worker mid-request.

The shape follows DGL's distributed ``graph_services`` RPC layer: typed
request/response pairs over one serialized transport, with control frames
(stats/health/reset/shutdown) riding the same channel as data.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import select
import socket
import struct
from dataclasses import dataclass

import numpy as np

__all__ = [
    "PROTOCOL_VERSION",
    "ProtocolError",
    "VersionMismatch",
    "TruncatedFrame",
    "ChannelClosed",
    "SampleDispatch",
    "DispatchResult",
    "StatsRequest",
    "StatsResponse",
    "HealthRequest",
    "HealthResponse",
    "ResetStatsRequest",
    "ResetStatsAck",
    "ShutdownRequest",
    "ShutdownAck",
    "MESSAGE_TYPES",
    "encode_frame",
    "decode_frame",
    "messages_equal",
    "PipeChannel",
    "SocketChannel",
    "channel_pair",
]

MAGIC = b"GLSP"
PROTOCOL_VERSION = 1

_HEADER = struct.Struct("<4sHHQ")  # magic, version, msg_type, payload_len


class ProtocolError(RuntimeError):
    """Malformed or unrecognized frame content (bad magic, unknown type)."""


class VersionMismatch(ProtocolError):
    """Peer speaks a different protocol version; refuse rather than guess."""


class TruncatedFrame(ProtocolError):
    """Frame shorter than its header (or a value) promised."""


class ChannelClosed(ConnectionError):
    """The transport peer is gone (EOF / broken pipe / reset)."""


# ---------------------------------------------------------------------------
# TLV value codec
# ---------------------------------------------------------------------------

_T_NONE = 0
_T_FALSE = 1
_T_TRUE = 2
_T_INT = 3
_T_FLOAT = 4
_T_STR = 5
_T_BYTES = 6
_T_TUPLE = 7
_T_LIST = 8
_T_DICT = 9
_T_NDARRAY = 10

_U32 = struct.Struct("<I")
_F64 = struct.Struct("<d")


def _pack_value(out: bytearray, v) -> None:
    # bool before int: bool is an int subclass
    if v is None:
        out.append(_T_NONE)
    elif isinstance(v, (bool, np.bool_)):
        out.append(_T_TRUE if v else _T_FALSE)
    elif isinstance(v, (int, np.integer)):
        v = int(v)
        out.append(_T_INT)
        sign = 1 if v < 0 else 0
        mag = (-v if sign else v).to_bytes((abs(v).bit_length() + 7) // 8 or 1, "little")
        out.append(sign)
        out += _U32.pack(len(mag))
        out += mag
    elif isinstance(v, (float, np.floating)):
        out.append(_T_FLOAT)
        out += _F64.pack(float(v))
    elif isinstance(v, str):
        raw = v.encode("utf-8")
        out.append(_T_STR)
        out += _U32.pack(len(raw))
        out += raw
    elif isinstance(v, (bytes, bytearray)):
        out.append(_T_BYTES)
        out += _U32.pack(len(v))
        out += bytes(v)
    elif isinstance(v, (tuple, list)):
        out.append(_T_TUPLE if isinstance(v, tuple) else _T_LIST)
        out += _U32.pack(len(v))
        for item in v:
            _pack_value(out, item)
    elif isinstance(v, dict):
        out.append(_T_DICT)
        out += _U32.pack(len(v))
        for k, item in v.items():
            _pack_value(out, k)
            _pack_value(out, item)
    elif isinstance(v, np.ndarray):
        arr = np.ascontiguousarray(v)
        out.append(_T_NDARRAY)
        _pack_value(out, arr.dtype.str)
        _pack_value(out, tuple(int(d) for d in arr.shape))
        raw = arr.tobytes()
        out += _U32.pack(len(raw))
        out += raw
    else:
        raise ProtocolError(f"unencodable value of type {type(v).__name__}: {v!r}")


class _Reader:
    __slots__ = ("buf", "pos")

    def __init__(self, buf: bytes, pos: int = 0):
        self.buf = buf
        self.pos = pos

    def take(self, n: int) -> bytes:
        end = self.pos + n
        if end > len(self.buf):
            raise TruncatedFrame(
                f"payload ends at byte {len(self.buf)} but a value needs "
                f"bytes up to {end}"
            )
        chunk = self.buf[self.pos : end]
        self.pos = end
        return chunk

    def u32(self) -> int:
        return _U32.unpack(self.take(4))[0]


def _unpack_value(r: _Reader):
    tag = r.take(1)[0]
    if tag == _T_NONE:
        return None
    if tag == _T_FALSE:
        return False
    if tag == _T_TRUE:
        return True
    if tag == _T_INT:
        sign = r.take(1)[0]
        mag = int.from_bytes(r.take(r.u32()), "little")
        return -mag if sign else mag
    if tag == _T_FLOAT:
        return _F64.unpack(r.take(8))[0]
    if tag == _T_STR:
        return r.take(r.u32()).decode("utf-8")
    if tag == _T_BYTES:
        return bytes(r.take(r.u32()))
    if tag in (_T_TUPLE, _T_LIST):
        n = r.u32()
        items = [_unpack_value(r) for _ in range(n)]
        return tuple(items) if tag == _T_TUPLE else items
    if tag == _T_DICT:
        n = r.u32()
        return {_unpack_value(r): _unpack_value(r) for _ in range(n)}
    if tag == _T_NDARRAY:
        dtype = np.dtype(_unpack_value(r))
        shape = _unpack_value(r)
        raw = r.take(r.u32())
        # copy: frombuffer views are read-only and pin the frame's memory
        return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
    raise ProtocolError(f"unknown TLV tag {tag}")


# ---------------------------------------------------------------------------
# Messages
# ---------------------------------------------------------------------------

MESSAGE_TYPES: dict[int, type] = {}


def _register_message(type_id: int):
    def deco(cls):
        cls.type_id = type_id
        if type_id in MESSAGE_TYPES:
            raise ValueError(f"duplicate message type id {type_id}")
        MESSAGE_TYPES[type_id] = cls
        return cls

    return deco


def _zeros() -> np.ndarray:
    return np.zeros(0, np.int64)


@_register_message(1)
@dataclass
class SampleDispatch:
    """One chunk of one request-hop, addressed to one partition's worker.

    ``(key, hop, part, chunk)`` is exactly the service's dispatch RNG key
    material — the worker re-derives the same keyed stream, so the answer
    is bit-identical to the in-process dispatch."""

    key: tuple
    hop: int
    part: int
    chunk: int
    seeds: np.ndarray
    fanout: int
    direction: str
    weighted: bool
    replace: bool


@_register_message(2)
@dataclass
class DispatchResult:
    """A worker's answer to one :class:`SampleDispatch`.

    ``lost=True`` is a degraded dispatch (every replica exhausted its
    retries or sat quarantined) — the arrays are empty and the client
    marks the request's hop partial, exactly like the in-process path.
    ``state`` is the worker's crash-consistency snapshot (fault-injector
    counters, breaker states, per-replica stats): the pool keeps the
    latest one per worker and hands it to a respawned process, so the
    replayed fault/breaker streams continue where the dead worker left
    off instead of restarting from zero."""

    part: int
    chunk: int
    lost: bool = False
    src: np.ndarray = dataclasses.field(default_factory=_zeros)
    dst: np.ndarray = dataclasses.field(default_factory=_zeros)
    eid: np.ndarray = dataclasses.field(default_factory=_zeros)
    scores: np.ndarray | None = None  # weighted gathers only
    retries: int = 0
    failovers: int = 0
    wall_ms: float = 0.0
    state: dict = dataclasses.field(default_factory=dict)


@_register_message(3)
@dataclass
class StatsRequest:
    pass


@_register_message(4)
@dataclass
class StatsResponse:
    part: int
    # site ("server.<part>.<replica>") -> ServerStats field dict
    replicas: dict = dataclasses.field(default_factory=dict)


@_register_message(5)
@dataclass
class HealthRequest:
    pass


@_register_message(6)
@dataclass
class HealthResponse:
    part: int
    health: dict = dataclasses.field(default_factory=dict)


@_register_message(7)
@dataclass
class ResetStatsRequest:
    pass


@_register_message(8)
@dataclass
class ResetStatsAck:
    part: int


@_register_message(9)
@dataclass
class ShutdownRequest:
    pass


@_register_message(10)
@dataclass
class ShutdownAck:
    part: int


def encode_frame(msg) -> bytes:
    """Serialize one message dataclass into a self-describing frame."""
    type_id = getattr(type(msg), "type_id", None)
    if type_id is None or MESSAGE_TYPES.get(type_id) is not type(msg):
        raise ProtocolError(f"not a registered message: {msg!r}")
    payload = bytearray()
    _pack_value(
        payload,
        {f.name: getattr(msg, f.name) for f in dataclasses.fields(msg)},
    )
    return _HEADER.pack(MAGIC, PROTOCOL_VERSION, type_id, len(payload)) + bytes(
        payload
    )


def decode_frame(buf: bytes):
    """Parse one frame back into its message dataclass (strictly)."""
    if len(buf) < _HEADER.size:
        raise TruncatedFrame(
            f"frame of {len(buf)} bytes is shorter than the "
            f"{_HEADER.size}-byte header"
        )
    magic, version, type_id, plen = _HEADER.unpack_from(buf)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r} (expected {MAGIC!r})")
    if version != PROTOCOL_VERSION:
        raise VersionMismatch(
            f"peer speaks protocol v{version}, this build speaks "
            f"v{PROTOCOL_VERSION}"
        )
    if len(buf) < _HEADER.size + plen:
        raise TruncatedFrame(
            f"header promises a {plen}-byte payload but only "
            f"{len(buf) - _HEADER.size} bytes follow"
        )
    cls = MESSAGE_TYPES.get(type_id)
    if cls is None:
        raise ProtocolError(f"unknown message type {type_id}")
    fields = _unpack_value(_Reader(buf, _HEADER.size))
    return cls(**fields)


def messages_equal(a, b) -> bool:
    """Field-wise equality that treats ndarrays bitwise (tests/debugging)."""
    if type(a) is not type(b):
        return False
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray) or isinstance(vb, np.ndarray):
            if (
                not isinstance(va, np.ndarray)
                or not isinstance(vb, np.ndarray)
                or va.dtype != vb.dtype
                or va.shape != vb.shape
                or not np.array_equal(va, vb)
            ):
                return False
        elif va != vb:
            return False
    return True


# ---------------------------------------------------------------------------
# Channels
# ---------------------------------------------------------------------------


class PipeChannel:
    """Frames over a ``multiprocessing`` duplex pipe (same-host fast path).

    ``Connection.send_bytes`` already length-prefixes at the OS level, so
    a frame arrives whole or not at all; the frame header still carries
    its own length so the two transports share one decoder."""

    kind = "mp"

    def __init__(self, conn):
        self.conn = conn

    def send(self, msg) -> None:
        try:
            self.conn.send_bytes(encode_frame(msg))
        except (BrokenPipeError, EOFError, OSError) as exc:
            raise ChannelClosed(f"pipe peer is gone: {exc}") from exc

    def poll(self, timeout: float = 0.0) -> bool:
        try:
            return self.conn.poll(timeout)
        except (BrokenPipeError, EOFError, OSError) as exc:
            raise ChannelClosed(f"pipe peer is gone: {exc}") from exc

    def recv(self):
        try:
            buf = self.conn.recv_bytes()
        except (BrokenPipeError, EOFError, OSError) as exc:
            raise ChannelClosed(f"pipe peer is gone: {exc}") from exc
        return decode_frame(buf)

    def close(self) -> None:
        try:
            self.conn.close()
        except OSError:
            pass


class SocketChannel:
    """Frames over any stream socket (the general, cross-host case)."""

    kind = "socket"

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.sock.setblocking(True)

    def send(self, msg) -> None:
        try:
            self.sock.sendall(encode_frame(msg))
        except OSError as exc:
            raise ChannelClosed(f"socket peer is gone: {exc}") from exc

    def poll(self, timeout: float = 0.0) -> bool:
        try:
            ready, _, _ = select.select([self.sock], [], [], timeout)
        except OSError as exc:
            raise ChannelClosed(f"socket peer is gone: {exc}") from exc
        return bool(ready)

    def _read_exact(self, n: int) -> bytes:
        chunks = []
        while n:
            try:
                chunk = self.sock.recv(min(n, 1 << 20))
            except OSError as exc:
                raise ChannelClosed(f"socket peer is gone: {exc}") from exc
            if not chunk:
                # mid-frame EOF is a dead peer, not a protocol bug
                raise ChannelClosed("socket closed by peer")
            chunks.append(chunk)
            n -= len(chunk)
        return b"".join(chunks)

    def recv(self):
        header = self._read_exact(_HEADER.size)
        _, _, _, plen = _HEADER.unpack(header)
        return decode_frame(header + self._read_exact(plen))

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def channel_pair(kind: str):
    """A connected ``(parent_end, child_end)`` channel pair, pre-fork."""
    if kind == "mp":
        a, b = mp.Pipe(duplex=True)
        return PipeChannel(a), PipeChannel(b)
    if kind == "socket":
        s1, s2 = socket.socketpair()
        return SocketChannel(s1), SocketChannel(s2)
    raise ValueError(f"channel kind must be 'mp' or 'socket', got {kind!r}")
