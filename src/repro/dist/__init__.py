"""``repro.dist`` — sampling servers behind a real transport.

The in-process :class:`~repro.core.sampling.service.SamplingService`
simulates GLISP's distributed sampling tier; this package makes it real:
:mod:`~repro.dist.transport` is the versioned wire format and channel
layer, :mod:`~repro.dist.worker` hosts one partition's server replicas in
its own OS process, and :mod:`~repro.dist.client` is the
:class:`WorkerPool` the service dispatches through when
``GLISPConfig(dist_transport="mp"|"socket")`` is set.

The PR 3 keyed-randomness design makes the split free of semantic drift:
every dispatch's RNG is derived from ``(seed, request key, hop, server,
chunk)``, so remote mode is bit-identical to in-process mode — the
determinism tests assert it.
"""
from repro.dist.client import WorkerPool
from repro.dist.transport import (
    PROTOCOL_VERSION,
    ChannelClosed,
    DispatchResult,
    ProtocolError,
    SampleDispatch,
    TruncatedFrame,
    VersionMismatch,
)
from repro.dist.worker import WorkerHost

__all__ = [
    "PROTOCOL_VERSION",
    "ChannelClosed",
    "DispatchResult",
    "ProtocolError",
    "SampleDispatch",
    "TruncatedFrame",
    "VersionMismatch",
    "WorkerHost",
    "WorkerPool",
]
