"""Partition quality metrics of paper Eq. (2)-(4): RF, EB, VB."""
from __future__ import annotations

import numpy as np

from repro.graph.graph import GraphPartition, HeteroGraph

__all__ = [
    "replication_factor",
    "edge_balance",
    "vertex_balance",
    "partition_metrics",
    "metrics_from_edge_assignment",
]


def replication_factor(parts: list[GraphPartition], num_global_vertices: int) -> float:
    return sum(p.num_vertices for p in parts) / max(1, num_global_vertices)


def edge_balance(parts: list[GraphPartition]) -> float:
    ne = [p.num_edges for p in parts]
    return max(ne) / max(1, min(ne))


def vertex_balance(parts: list[GraphPartition]) -> float:
    nv = [p.num_vertices for p in parts]
    return max(nv) / max(1, min(nv))


def partition_metrics(parts: list[GraphPartition], num_global_vertices: int) -> dict:
    return {
        "RF": replication_factor(parts, num_global_vertices),
        "EB": edge_balance(parts),
        "VB": vertex_balance(parts),
        "vertices": [p.num_vertices for p in parts],
        "edges": [p.num_edges for p in parts],
    }


def metrics_from_edge_assignment(
    g: HeteroGraph, edge_parts: np.ndarray, num_parts: int
) -> dict:
    """RF/EB/VB straight from a vertex-cut edge assignment (no materialize)."""
    nv, ne, total_v = [], [], 0
    for p in range(num_parts):
        mask = edge_parts == p
        ne.append(int(mask.sum()))
        vcount = np.union1d(g.src[mask], g.dst[mask]).shape[0]
        nv.append(int(vcount))
        total_v += vcount
    return {
        "RF": total_v / max(1, g.num_vertices),
        "EB": max(ne) / max(1, min(ne)),
        "VB": max(nv) / max(1, min(nv)),
        "vertices": nv,
        "edges": ne,
    }


def metrics_from_vertex_assignment(
    g: HeteroGraph, vertex_parts: np.ndarray, num_parts: int
) -> dict:
    """Metrics for an *edge-cut* (vertex assignment) partitioning with one-hop
    halo replication, as used by DistDGL-style systems: each partition stores
    its own vertices plus the endpoints of cut edges, and every edge incident
    to a partition's vertices (so one-hop sampling is local)."""
    nv, ne, total_v = [], [], 0
    sp = vertex_parts[g.src]
    dp = vertex_parts[g.dst]
    for p in range(num_parts):
        emask = (sp == p) | (dp == p)  # halo edges replicated
        ne.append(int(emask.sum()))
        verts = np.union1d(g.src[emask], g.dst[emask])
        own = np.flatnonzero(vertex_parts == p)
        vcount = np.union1d(verts, own).shape[0]
        nv.append(vcount)
        total_v += vcount
    return {
        "RF": total_v / max(1, g.num_vertices),
        "EB": max(ne) / max(1, min(ne)),
        "VB": max(nv) / max(1, min(nv)),
        "vertices": nv,
        "edges": ne,
    }
