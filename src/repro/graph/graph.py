"""Graph data structures.

``HeteroGraph`` is the *global* (unpartitioned) heterogeneous multigraph held
as COO + lazily-built CSR.  ``GraphPartition`` is the compact, read-only,
contiguous structure of paper Fig. 6 for one vertex-cut partition:

    global_id        int64 [Nv]   sorted ascending; local vertex id == index
    vertex_types     int16 [Nv]
    out_indptr       int64 [Nv+1] CSR offsets (edges sorted by (src,etype,dst))
    out_dst          int32 [Ne]   destination *local* ids; edge local id == idx
    in_indptr        int64 [Nv+1]
    in_src           int32 [Ne]   source local id of each incoming edge
    in_edge_id       int32 [Ne]   local edge id of each incoming edge
                                  (paper: in_edges stores (dst_id, edge_id))
    out_et_types     int16 [*]    edge-type ids per (vertex, type) group
    out_et_cum       int64 [*]    pre-accumulated per-vertex counts -> ranges
    out_et_indptr    int64 [Nv+1] offsets into out_et_types/out_et_cum
    (in_et_* mirror the above for incoming edges)
    out_degrees      int64 [Nv]   GLOBAL out-degree (original graph)
    in_degrees       int64 [Nv]   GLOBAL in-degree
    partition_bits   uint8 [Nv, ceil(P/8)]  bit p set => vertex also lives on p
    edge_weights     float32 [Ne] optional (weighted sampling)

No hash maps: global->local is a binary search over global_id, the per-edge
type id is a binary search over the aggregated (types, cum) representation.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

import numpy as np

from repro.utils import ceil_div, nbytes_of

# ---------------------------------------------------------------------------
# Global graph
# ---------------------------------------------------------------------------


@dataclass
class HeteroGraph:
    num_vertices: int
    src: np.ndarray  # int64 [E]
    dst: np.ndarray  # int64 [E]
    edge_types: np.ndarray  # int16 [E]
    vertex_types: np.ndarray  # int16 [N]
    edge_weights: np.ndarray | None = None  # float32 [E]
    vertex_feats: np.ndarray | None = None  # float32 [N, F] optional
    labels: np.ndarray | None = None  # int32 [N] optional
    _csr: dict = field(default_factory=dict, repr=False)

    # -- basic properties ---------------------------------------------------
    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_vertex_types(self) -> int:
        return int(self.vertex_types.max()) + 1 if self.num_vertices else 0

    @property
    def num_edge_types(self) -> int:
        return int(self.edge_types.max()) + 1 if self.num_edges else 0

    def out_degrees(self) -> np.ndarray:
        if "outdeg" not in self._csr:
            self._csr["outdeg"] = np.bincount(
                self.src, minlength=self.num_vertices
            ).astype(np.int64)
        return self._csr["outdeg"]

    def in_degrees(self) -> np.ndarray:
        if "indeg" not in self._csr:
            self._csr["indeg"] = np.bincount(
                self.dst, minlength=self.num_vertices
            ).astype(np.int64)
        return self._csr["indeg"]

    def out_csr(self):
        """(indptr, order) with edges ordered by (src, etype, dst)."""
        if "out" not in self._csr:
            order = np.lexsort((self.dst, self.edge_types, self.src))
            indptr = np.zeros(self.num_vertices + 1, dtype=np.int64)
            np.cumsum(self.out_degrees(), out=indptr[1:])
            self._csr["out"] = (indptr, order)
        return self._csr["out"]

    def in_csr(self):
        if "in" not in self._csr:
            order = np.lexsort((self.src, self.edge_types, self.dst))
            indptr = np.zeros(self.num_vertices + 1, dtype=np.int64)
            np.cumsum(self.in_degrees(), out=indptr[1:])
            self._csr["in"] = (indptr, order)
        return self._csr["in"]

    def neighbors(self, v: int, direction: str = "out") -> np.ndarray:
        if direction == "out":
            indptr, order = self.out_csr()
            return self.dst[order[indptr[v] : indptr[v + 1]]]
        indptr, order = self.in_csr()
        return self.src[order[indptr[v] : indptr[v + 1]]]


# ---------------------------------------------------------------------------
# Per-vertex edge-type aggregation (shared by out/in indexes)
# ---------------------------------------------------------------------------


def _build_etype_index(indptr: np.ndarray, etypes_sorted: np.ndarray):
    """Build the aggregated (indptr, types, cum) edge-type index of Fig. 6.

    ``etypes_sorted`` are the edge types laid out in CSR order where each
    vertex's edges are contiguous and sorted by type.  Returns per-vertex
    groups: ``et_indptr[v]:et_indptr[v+1]`` indexes into ``et_types`` /
    ``et_cum`` where ``et_cum`` holds the *pre-accumulated* count so the range
    of type ``t`` inside vertex v's neighbor list is
    ``[cum_{k-1}, cum_k)`` relative to ``indptr[v]``.
    """
    nv = indptr.shape[0] - 1
    ne = etypes_sorted.shape[0]
    if ne == 0:
        z = np.zeros(nv + 1, dtype=np.int64)
        return z, np.zeros(0, np.int16), np.zeros(0, np.int32)
    # boundaries where (vertex, type) changes
    vert_of_edge = np.repeat(np.arange(nv, dtype=np.int64), np.diff(indptr))
    change = np.empty(ne, dtype=bool)
    change[0] = True
    change[1:] = (vert_of_edge[1:] != vert_of_edge[:-1]) | (
        etypes_sorted[1:] != etypes_sorted[:-1]
    )
    group_starts = np.flatnonzero(change)
    group_vert = vert_of_edge[group_starts]
    group_type = etypes_sorted[group_starts].astype(np.int16)
    group_ends = np.append(group_starts[1:], ne)
    # cumulative count *within* each vertex: end offset relative to indptr[v]
    group_cum = (group_ends - indptr[group_vert]).astype(np.int32)
    et_indptr = np.zeros(nv + 1, dtype=np.int64)
    np.add.at(et_indptr, group_vert + 1, 1)
    np.cumsum(et_indptr, out=et_indptr)
    return et_indptr, group_type, group_cum


# ---------------------------------------------------------------------------
# Partition structure (paper Fig. 6)
# ---------------------------------------------------------------------------

_FIELDS = [
    "global_id",
    "vertex_types",
    "out_indptr",
    "out_dst",
    "in_indptr",
    "in_src",
    "in_edge_id",
    "out_et_indptr",
    "out_et_types",
    "out_et_cum",
    "in_et_indptr",
    "in_et_types",
    "in_et_cum",
    "out_degrees",
    "in_degrees",
    "partition_bits",
    "edge_weights",
    "edge_global_id",
]


@dataclass
class GraphPartition:
    part_id: int
    num_parts: int
    global_id: np.ndarray
    vertex_types: np.ndarray
    out_indptr: np.ndarray
    out_dst: np.ndarray
    in_indptr: np.ndarray
    in_src: np.ndarray
    in_edge_id: np.ndarray
    out_et_indptr: np.ndarray
    out_et_types: np.ndarray
    out_et_cum: np.ndarray
    in_et_indptr: np.ndarray
    in_et_types: np.ndarray
    in_et_cum: np.ndarray
    out_degrees: np.ndarray  # global degrees
    in_degrees: np.ndarray
    partition_bits: np.ndarray
    edge_weights: np.ndarray | None = None
    # global edge id per local edge (CSR out order); lets sampling return ids
    # that index the global graph's edge_types/edge_weights.  None for
    # partitions persisted before this field existed.
    edge_global_id: np.ndarray | None = None

    # -- sizes ----------------------------------------------------------------
    @property
    def num_vertices(self) -> int:
        return int(self.global_id.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.out_dst.shape[0])

    def memory_bytes(self) -> int:
        return sum(
            getattr(self, f).nbytes
            for f in _FIELDS
            if getattr(self, f) is not None
        )

    # -- O(log N) / O(1) queries replacing stored fields ----------------------
    def global_to_local(self, gids: np.ndarray) -> np.ndarray:
        """Binary search; -1 for ids not present."""
        gids = np.asarray(gids, dtype=np.int64)
        pos = np.searchsorted(self.global_id, gids)
        pos = np.minimum(pos, self.num_vertices - 1)
        ok = self.global_id[pos] == gids
        return np.where(ok, pos, -1).astype(np.int64)

    def local_to_global(self, lids: np.ndarray) -> np.ndarray:
        return self.global_id[np.asarray(lids)]

    def local_out_degree(self, lids: np.ndarray) -> np.ndarray:
        lids = np.asarray(lids)
        return self.out_indptr[lids + 1] - self.out_indptr[lids]

    def local_in_degree(self, lids: np.ndarray) -> np.ndarray:
        lids = np.asarray(lids)
        return self.in_indptr[lids + 1] - self.in_indptr[lids]

    def edge_type_of(self, edge_lids: np.ndarray) -> np.ndarray:
        """Edge type via binary search in the aggregated per-vertex index."""
        edge_lids = np.asarray(edge_lids, dtype=np.int64)
        # vertex owning each edge: binary search in out_indptr
        v = np.searchsorted(self.out_indptr, edge_lids, side="right") - 1
        rel = edge_lids - self.out_indptr[v]
        out = np.empty(edge_lids.shape[0], dtype=np.int16)
        for i in range(edge_lids.shape[0]):  # small query batches in practice
            s, e = self.out_et_indptr[v[i]], self.out_et_indptr[v[i] + 1]
            k = np.searchsorted(self.out_et_cum[s:e], rel[i], side="right")
            out[i] = self.out_et_types[s + k]
        return out

    def out_neighbors(self, lid: int, etype: int | None = None):
        """(dst_local_ids, edge_local_ids) of vertex ``lid``, optionally one type."""
        s, e = int(self.out_indptr[lid]), int(self.out_indptr[lid + 1])
        if etype is None:
            return self.out_dst[s:e], np.arange(s, e, dtype=np.int64)
        ts, te = self.out_et_indptr[lid], self.out_et_indptr[lid + 1]
        types = self.out_et_types[ts:te]
        k = np.searchsorted(types, etype)
        if k >= types.shape[0] or types[k] != etype:
            return (np.zeros(0, np.int32), np.zeros(0, np.int64))
        lo = 0 if k == 0 else int(self.out_et_cum[ts + k - 1])
        hi = int(self.out_et_cum[ts + k])
        return self.out_dst[s + lo : s + hi], np.arange(s + lo, s + hi, dtype=np.int64)

    def vertex_on_partitions(self, lids: np.ndarray) -> list[np.ndarray]:
        """Partition ids on which each vertex is replicated (from the bit array)."""
        lids = np.asarray(lids)
        bits = np.unpackbits(self.partition_bits[lids], axis=1, bitorder="little")
        return [np.flatnonzero(row[: self.num_parts]) for row in bits]

    def interior_mask(self) -> np.ndarray:
        """True for vertices that live on exactly one partition (interior)."""
        bits = np.unpackbits(self.partition_bits, axis=1, bitorder="little")
        return bits[:, : self.num_parts].sum(axis=1) == 1

    # -- persistence (contiguous binary layout + separate meta file) ----------
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        meta = {"part_id": self.part_id, "num_parts": self.num_parts, "fields": {}}
        with open(os.path.join(path, "data.bin"), "wb") as f:
            off = 0
            for name in _FIELDS:
                arr = getattr(self, name)
                if arr is None:
                    continue
                arr = np.ascontiguousarray(arr)
                f.write(arr.tobytes())
                meta["fields"][name] = {
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                    "offset": off,
                }
                off += arr.nbytes
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump(meta, f)

    @classmethod
    def load(cls, path: str) -> "GraphPartition":
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        buf = np.memmap(os.path.join(path, "data.bin"), dtype=np.uint8, mode="r")
        kwargs = {"part_id": meta["part_id"], "num_parts": meta["num_parts"]}
        for name in _FIELDS:
            info = meta["fields"].get(name)
            if info is None:
                kwargs[name] = None
                continue
            dt = np.dtype(info["dtype"])
            count = int(np.prod(info["shape"])) if info["shape"] else 1
            arr = np.frombuffer(
                buf, dtype=dt, count=count, offset=info["offset"]
            ).reshape(info["shape"])
            kwargs[name] = arr
        return cls(**kwargs)


# ---------------------------------------------------------------------------
# Partition builder: edge assignment -> GraphPartition list
# ---------------------------------------------------------------------------


def build_partitions(
    g: HeteroGraph, edge_parts: np.ndarray, num_parts: int
) -> list[GraphPartition]:
    """Materialize the Fig.-6 structure for a vertex-cut edge assignment.

    ``edge_parts[e]`` is the partition id of edge e.  Vertices incident to
    edges in several partitions become boundary vertices (replicated).
    """
    assert edge_parts.shape[0] == g.num_edges
    outdeg_g = g.out_degrees()
    indeg_g = g.in_degrees()

    # global vertex -> set-of-partitions bit array (computed once, shared)
    nbytes = ceil_div(num_parts, 8)
    vbits = np.zeros((g.num_vertices, nbytes), dtype=np.uint8)
    ep8 = edge_parts.astype(np.int64)
    for p in range(num_parts):
        mask = ep8 == p
        byte, bit = p // 8, p % 8
        touched = np.union1d(g.src[mask], g.dst[mask])
        vbits[touched, byte] |= np.uint8(1 << bit)

    parts = []
    for p in range(num_parts):
        eids = np.flatnonzero(ep8 == p)
        src, dst, et = g.src[eids], g.dst[eids], g.edge_types[eids]
        gids = np.union1d(src, dst)  # sorted ascending
        nv = gids.shape[0]
        s_loc = np.searchsorted(gids, src).astype(np.int32)
        d_loc = np.searchsorted(gids, dst).astype(np.int32)

        # out CSR, edges sorted by (src_local, etype, dst_local)
        order = np.lexsort((d_loc, et, s_loc))
        s_loc, d_loc, et = s_loc[order], d_loc[order], et[order]
        eids_sorted = eids[order]
        out_indptr = np.zeros(nv + 1, dtype=np.int64)
        np.add.at(out_indptr, s_loc + 1, 1)
        np.cumsum(out_indptr, out=out_indptr)
        out_et_indptr, out_et_types, out_et_cum = _build_etype_index(
            out_indptr, et
        )

        # in CSR: incoming edges sorted by (dst_local, etype, src_local);
        # stores (src_local, edge_local_id) per paper
        in_order = np.lexsort((s_loc, et, d_loc))
        in_indptr = np.zeros(nv + 1, dtype=np.int64)
        np.add.at(in_indptr, d_loc[in_order] + 1, 1)
        np.cumsum(in_indptr, out=in_indptr)
        in_et_indptr, in_et_types, in_et_cum = _build_etype_index(
            in_indptr, et[in_order]
        )

        parts.append(
            GraphPartition(
                part_id=p,
                num_parts=num_parts,
                global_id=gids.astype(np.int64),
                vertex_types=g.vertex_types[gids].astype(np.int16),
                out_indptr=out_indptr,
                out_dst=d_loc.astype(np.int32),
                in_indptr=in_indptr,
                in_src=s_loc[in_order].astype(np.int32),
                in_edge_id=in_order.astype(np.int32),
                out_et_indptr=out_et_indptr,
                out_et_types=out_et_types,
                out_et_cum=out_et_cum,
                in_et_indptr=in_et_indptr,
                in_et_types=in_et_types,
                in_et_cum=in_et_cum,
                out_degrees=outdeg_g[gids].astype(np.int32),
                in_degrees=indeg_g[gids].astype(np.int32),
                partition_bits=vbits[gids],
                edge_weights=(
                    g.edge_weights[eids_sorted].astype(np.float32)
                    if g.edge_weights is not None
                    else None
                ),
                edge_global_id=eids_sorted.astype(np.int64),
            )
        )
    return parts


def naive_partition_memory_bytes(g: HeteroGraph, edge_parts: np.ndarray, num_parts: int) -> int:
    """Memory model of the 'existing frameworks' layout (Table III bench).

    DistDGL/GraphLearn represent a heterogeneous graph as ONE HOMOGENEOUS
    SUBGRAPH PER EDGE TYPE (paper §I): each subgraph keeps its own in+out
    CSRs, its own vertex array, an explicit global<->local hash map (~2x a
    plain array) and explicit 64-bit edge ids, plus the COO endpoints that
    DGL retains alongside the CSRs.
    """
    total = 0
    for p in range(num_parts):
        eids = np.flatnonzero(edge_parts == p)
        src, dst, et = g.src[eids], g.dst[eids], g.edge_types[eids]
        gids = np.union1d(src, dst)
        nv = gids.shape[0]
        for t in np.unique(et):
            sel = et == t
            e_t = int(sel.sum())
            v_t = np.union1d(src[sel], dst[sel]).shape[0]
            total += 2 * (8 * (v_t + 1) + 8 * e_t)  # in + out CSR
            total += 16 * e_t  # COO (src, dst) retained
            total += 8 * e_t  # explicit edge local ids
            total += 8 * v_t  # per-type vertex global ids
            total += 32 * v_t  # global<->local hash map (~2x key+value)
        total += nv * 8 * 2  # degrees
    return total
