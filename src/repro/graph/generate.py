"""Synthetic graph generators.

Real datasets of the paper (OGBN-*, Twitter-2010, RelNet) are not available
offline; we generate power-law graphs whose degree distribution matches the
paper's Fig. 8 shape via preferential attachment (Barabási–Albert with the
repeated-edge-endpoint trick), plus heterogeneous vertex/edge types and
weights.  ``named_dataset`` provides scaled-down stand-ins keyed by the
paper's dataset names so benchmarks read like the paper's tables.
"""
from __future__ import annotations

import numpy as np

from repro.graph.graph import HeteroGraph

__all__ = ["power_law_graph", "erdos_renyi_graph", "named_dataset", "DATASETS"]


def power_law_graph(
    num_vertices: int,
    avg_degree: float = 8.0,
    num_vertex_types: int = 3,
    num_edge_types: int = 4,
    feat_dim: int = 0,
    num_classes: int = 0,
    seed: int = 0,
    num_communities: int | None = None,
    community_mix: float = 0.7,
) -> HeteroGraph:
    """Degree-corrected community power-law multigraph.

    Preferential attachment (endpoint drawn from the existing edge-endpoint
    list ⇒ degree-proportional) restricted to the new vertex's community with
    probability ``community_mix``, else global — real graphs have BOTH a
    power-law tail and community structure; the latter is the data locality
    GLISP's partitioner/reorder exploit (paper §I "inherent structural
    properties").  Vectorized in growth batches.
    """
    rng = np.random.default_rng(seed)
    m = max(1, int(round(avg_degree / 2)))
    if num_communities is None:
        num_communities = max(8, num_vertices // 512)  # chunk-scale communities
    C = max(1, min(num_communities, num_vertices // 64))
    comm = rng.integers(0, C, size=num_vertices).astype(np.int32)
    n0 = max(2 * m, 16 * C)
    core_src = rng.integers(0, n0, size=n0 * m)
    core_dst = rng.integers(0, n0, size=n0 * m)
    srcs = [core_src.astype(np.int64)]
    dsts = [core_dst.astype(np.int64)]
    endpoints = np.concatenate([core_src, core_dst]).astype(np.int64)
    comm_endpoints = [endpoints[comm[endpoints] == c] for c in range(C)]
    # celebrity pool: early vertices accumulate global hub degree (the
    # power-law hotspots that drive the paper's load-balance problem)
    n_celeb = max(4, num_vertices // 20000)
    celeb_endpoints = endpoints[endpoints < n_celeb]
    celeb_p = 0.05

    v = n0
    batch = max(1024, num_vertices // 64)
    while v < num_vertices:
        b = min(batch, num_vertices - v)
        new_ids = np.repeat(np.arange(v, v + b, dtype=np.int64), m)
        nedge = b * m
        # global preferential endpoint
        pref_g = endpoints[rng.integers(0, endpoints.shape[0], size=nedge)]
        # community preferential endpoint (grouped by community)
        pref_c = pref_g.copy()
        ecomm = comm[new_ids]
        for c in np.unique(ecomm):
            pool = comm_endpoints[c]
            sel = np.flatnonzero(ecomm == c)
            if pool.shape[0]:
                pref_c[sel] = pool[rng.integers(0, pool.shape[0], size=sel.shape[0])]
        unif = rng.integers(0, v, size=nedge)
        u = rng.random(nedge)
        take_celeb = u < celeb_p
        take_comm = (~take_celeb) & (u < celeb_p + community_mix)
        take_pref = rng.random(nedge) < 0.9
        pool = celeb_endpoints if celeb_endpoints.shape[0] else endpoints
        pref_celeb = pool[rng.integers(0, pool.shape[0], size=nedge)]
        targets = np.where(
            take_celeb,
            pref_celeb,
            np.where(take_comm, pref_c, np.where(take_pref, pref_g, unif)),
        )
        flip = rng.random(nedge) < 0.5
        s = np.where(flip, new_ids, targets)
        d = np.where(flip, targets, new_ids)
        srcs.append(s)
        dsts.append(d)
        fresh = np.concatenate([s, d])
        endpoints = np.concatenate([endpoints, fresh])
        fc = comm[fresh]
        for c in np.unique(fc):
            comm_endpoints[c] = np.concatenate(
                [comm_endpoints[c], fresh[fc == c]]
            )
        new_celebs = fresh[fresh < n_celeb]
        if new_celebs.shape[0]:
            celeb_endpoints = np.concatenate([celeb_endpoints, new_celebs])
        if endpoints.shape[0] > 8 * num_vertices * m:
            endpoints = endpoints[
                rng.integers(0, endpoints.shape[0], size=4 * num_vertices * m)
            ]
        v += b

    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    vt = rng.integers(0, num_vertex_types, size=num_vertices).astype(np.int16)
    # edge type correlated with endpoint types (realistic hetero structure)
    et = (
        (vt[src].astype(np.int64) * 7 + vt[dst].astype(np.int64) * 3 + rng.integers(0, 2, size=src.shape[0]))
        % num_edge_types
    ).astype(np.int16)
    ew = rng.gamma(2.0, 1.0, size=src.shape[0]).astype(np.float32)
    feats = (
        rng.standard_normal((num_vertices, feat_dim)).astype(np.float32)
        if feat_dim
        else None
    )
    labels = (
        rng.integers(0, num_classes, size=num_vertices).astype(np.int32)
        if num_classes
        else None
    )
    return HeteroGraph(
        num_vertices=num_vertices,
        src=src,
        dst=dst,
        edge_types=et,
        vertex_types=vt,
        edge_weights=ew,
        vertex_feats=feats,
        labels=labels,
    )


def erdos_renyi_graph(
    num_vertices: int, avg_degree: float = 8.0, seed: int = 0, **kw
) -> HeteroGraph:
    """Uniform-degree control graph (matches the paper's note that
    OGBN-Products is the one non-power-law dataset)."""
    rng = np.random.default_rng(seed)
    ne = int(num_vertices * avg_degree)
    src = rng.integers(0, num_vertices, size=ne).astype(np.int64)
    dst = rng.integers(0, num_vertices, size=ne).astype(np.int64)
    nvt = kw.get("num_vertex_types", 3)
    net = kw.get("num_edge_types", 4)
    vt = rng.integers(0, nvt, size=num_vertices).astype(np.int16)
    et = rng.integers(0, net, size=ne).astype(np.int16)
    ew = rng.gamma(2.0, 1.0, size=ne).astype(np.float32)
    feat_dim = kw.get("feat_dim", 0)
    num_classes = kw.get("num_classes", 0)
    return HeteroGraph(
        num_vertices=num_vertices,
        src=src,
        dst=dst,
        edge_types=et,
        vertex_types=vt,
        edge_weights=ew,
        vertex_feats=(
            rng.standard_normal((num_vertices, feat_dim)).astype(np.float32)
            if feat_dim
            else None
        ),
        labels=(
            rng.integers(0, num_classes, size=num_vertices).astype(np.int32)
            if num_classes
            else None
        ),
    )


# Scaled-down stand-ins for the paper's datasets (name -> generator kwargs).
# Average degrees mirror Table I; sizes are scaled to this box.
DATASETS = {
    "ogbn-products": dict(kind="er", num_vertices=40_000, avg_degree=25.2),
    "wikikg90m": dict(kind="pl", num_vertices=120_000, avg_degree=6.6),
    "twitter-2010": dict(kind="pl", num_vertices=60_000, avg_degree=35.3),
    "ogbn-paper": dict(kind="pl", num_vertices=150_000, avg_degree=14.5),
    "relnet": dict(kind="pl", num_vertices=400_000, avg_degree=4.7),
    # tiny variants for tests
    "tiny-pl": dict(kind="pl", num_vertices=2_000, avg_degree=8.0),
    "tiny-er": dict(kind="er", num_vertices=2_000, avg_degree=8.0),
}


def named_dataset(
    name: str, feat_dim: int = 0, num_classes: int = 0, seed: int = 0, scale: float = 1.0
) -> HeteroGraph:
    cfg = dict(DATASETS[name])
    kind = cfg.pop("kind")
    cfg["num_vertices"] = max(64, int(cfg["num_vertices"] * scale))
    gen = power_law_graph if kind == "pl" else erdos_renyi_graph
    return gen(feat_dim=feat_dim, num_classes=num_classes, seed=seed, **cfg)
