"""Graph reorder algorithms (paper §II-C, §III-D and Fig. 14).

All algorithms return a *permutation*: ``perm[new_local_id] = old_index``.
Equivalently vertices are sorted by a key:

    NS   (natural sort)          key = global_id
    DS   (degree sort)           key = -degree
    PS   (partition sort)        key = (partition_id, global_id)
    PDS  (partition degree sort) key = (partition_id, -degree)   <- paper's alg
    BFS                          BFS order (within partition when parts given)
"""
from __future__ import annotations

import numpy as np

__all__ = ["reorder_permutation", "REORDER_ALGS", "bfs_order"]

REORDER_ALGS = ("NS", "DS", "PS", "PDS", "BFS")


def bfs_order(
    indptr: np.ndarray, indices: np.ndarray, num_vertices: int, seed: int = 0
) -> np.ndarray:
    """Vectorized-frontier BFS order covering all components."""
    visited = np.zeros(num_vertices, dtype=bool)
    order = np.empty(num_vertices, dtype=np.int64)
    pos = 0
    rng = np.random.default_rng(seed)
    start_candidates = rng.permutation(num_vertices)
    ci = 0
    while pos < num_vertices:
        while ci < num_vertices and visited[start_candidates[ci]]:
            ci += 1
        if ci >= num_vertices:
            rest = np.flatnonzero(~visited)
            order[pos : pos + rest.shape[0]] = rest
            pos += rest.shape[0]
            break
        frontier = np.array([start_candidates[ci]], dtype=np.int64)
        visited[frontier] = True
        while frontier.shape[0]:
            order[pos : pos + frontier.shape[0]] = frontier
            pos += frontier.shape[0]
            # expand all frontier neighbors at once
            starts, ends = indptr[frontier], indptr[frontier + 1]
            total = int((ends - starts).sum())
            if total == 0:
                break
            nbrs = np.concatenate(
                [indices[s:e] for s, e in zip(starts, ends)]
            ) if frontier.shape[0] < 1024 else indices[
                np.concatenate([np.arange(s, e) for s, e in zip(starts, ends)])
            ]
            nbrs = np.unique(nbrs)
            nbrs = nbrs[~visited[nbrs]]
            visited[nbrs] = True
            frontier = nbrs
    return order


def reorder_permutation(
    alg: str,
    *,
    global_ids: np.ndarray,
    degrees: np.ndarray,
    partition_ids: np.ndarray | None = None,
    indptr: np.ndarray | None = None,
    indices: np.ndarray | None = None,
    seed: int = 0,
) -> np.ndarray:
    """Return perm of local indices (``perm[new_id] = old_idx``)."""
    n = global_ids.shape[0]
    alg = alg.upper()
    if alg == "NS":
        return np.argsort(global_ids, kind="stable")
    if alg == "DS":
        return np.argsort(-degrees, kind="stable")
    if alg == "PS":
        assert partition_ids is not None
        return np.lexsort((global_ids, partition_ids))
    if alg == "PDS":
        assert partition_ids is not None
        return np.lexsort((-degrees, partition_ids))
    if alg == "BFS":
        assert indptr is not None and indices is not None
        if partition_ids is None:
            return bfs_order(indptr, indices, n, seed)
        # real BFS over each partition's INDUCED subgraph (symmetrized so a
        # weakly-connected group is one BFS component), groups in partition
        # order — replaces the old hub-first degree-sort approximation
        out = []
        for p in np.unique(partition_ids):
            members = np.flatnonzero(partition_ids == p)
            sub_indptr, sub_indices = _induced_subgraph(
                indptr, indices, members
            )
            local = bfs_order(
                sub_indptr, sub_indices, members.shape[0], seed + int(p)
            )
            out.append(members[local])
        return np.concatenate(out)
    raise ValueError(f"unknown reorder algorithm {alg!r}")


def _induced_subgraph(
    indptr: np.ndarray, indices: np.ndarray, members: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Symmetrized CSR of the subgraph induced by sorted ``members``
    (local ids = positions in ``members``), fully vectorized."""
    from repro.utils import csr_slots

    m = members.shape[0]
    lens = indptr[members + 1] - indptr[members]
    if int(lens.sum()) == 0:
        return np.zeros(m + 1, dtype=np.int64), np.zeros(0, dtype=np.int64)
    nbrs = indices[csr_slots(indptr, members)]
    srcs = np.repeat(np.arange(m, dtype=np.int64), lens)
    # keep edges whose target is also a member; map to local ids
    pos = np.searchsorted(members, nbrs)
    pos = np.minimum(pos, m - 1)
    keep = members[pos] == nbrs
    u, v = srcs[keep], pos[keep]
    # symmetrize so BFS coverage matches weak connectivity
    uu = np.concatenate([u, v])
    vv = np.concatenate([v, u])
    order = np.argsort(uu, kind="stable")
    uu, vv = uu[order], vv[order]
    sub_indptr = np.zeros(m + 1, dtype=np.int64)
    np.add.at(sub_indptr, uu + 1, 1)
    np.cumsum(sub_indptr, out=sub_indptr)
    return sub_indptr, vv
