"""Graph reorder algorithms (paper §II-C, §III-D and Fig. 14).

All algorithms return a *permutation*: ``perm[new_local_id] = old_index``.
Equivalently vertices are sorted by a key:

    NS   (natural sort)          key = global_id
    DS   (degree sort)           key = -degree
    PS   (partition sort)        key = (partition_id, global_id)
    PDS  (partition degree sort) key = (partition_id, -degree)   <- paper's alg
    BFS                          BFS order (within partition when parts given)
"""
from __future__ import annotations

import numpy as np

__all__ = ["reorder_permutation", "REORDER_ALGS", "bfs_order"]

REORDER_ALGS = ("NS", "DS", "PS", "PDS", "BFS")


def bfs_order(
    indptr: np.ndarray, indices: np.ndarray, num_vertices: int, seed: int = 0
) -> np.ndarray:
    """Vectorized-frontier BFS order covering all components."""
    visited = np.zeros(num_vertices, dtype=bool)
    order = np.empty(num_vertices, dtype=np.int64)
    pos = 0
    rng = np.random.default_rng(seed)
    start_candidates = rng.permutation(num_vertices)
    ci = 0
    while pos < num_vertices:
        while ci < num_vertices and visited[start_candidates[ci]]:
            ci += 1
        if ci >= num_vertices:
            rest = np.flatnonzero(~visited)
            order[pos : pos + rest.shape[0]] = rest
            pos += rest.shape[0]
            break
        frontier = np.array([start_candidates[ci]], dtype=np.int64)
        visited[frontier] = True
        while frontier.shape[0]:
            order[pos : pos + frontier.shape[0]] = frontier
            pos += frontier.shape[0]
            # expand all frontier neighbors at once
            starts, ends = indptr[frontier], indptr[frontier + 1]
            total = int((ends - starts).sum())
            if total == 0:
                break
            nbrs = np.concatenate(
                [indices[s:e] for s, e in zip(starts, ends)]
            ) if frontier.shape[0] < 1024 else indices[
                np.concatenate([np.arange(s, e) for s, e in zip(starts, ends)])
            ]
            nbrs = np.unique(nbrs)
            nbrs = nbrs[~visited[nbrs]]
            visited[nbrs] = True
            frontier = nbrs
    return order


def reorder_permutation(
    alg: str,
    *,
    global_ids: np.ndarray,
    degrees: np.ndarray,
    partition_ids: np.ndarray | None = None,
    indptr: np.ndarray | None = None,
    indices: np.ndarray | None = None,
    seed: int = 0,
) -> np.ndarray:
    """Return perm of local indices (``perm[new_id] = old_idx``)."""
    n = global_ids.shape[0]
    alg = alg.upper()
    if alg == "NS":
        return np.argsort(global_ids, kind="stable")
    if alg == "DS":
        return np.argsort(-degrees, kind="stable")
    if alg == "PS":
        assert partition_ids is not None
        return np.lexsort((global_ids, partition_ids))
    if alg == "PDS":
        assert partition_ids is not None
        return np.lexsort((-degrees, partition_ids))
    if alg == "BFS":
        assert indptr is not None and indices is not None
        if partition_ids is None:
            return bfs_order(indptr, indices, n, seed)
        # BFS within each partition group, groups in partition order
        out = []
        for p in np.unique(partition_ids):
            members = np.flatnonzero(partition_ids == p)
            # induced subgraph BFS via degree-sorted start; cheap approximation:
            sub_order = members[
                np.argsort(-degrees[members], kind="stable")
            ]  # hub-first within part
            out.append(sub_order)
        return np.concatenate(out)
    raise ValueError(f"unknown reorder algorithm {alg!r}")
