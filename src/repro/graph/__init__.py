from repro.graph.graph import HeteroGraph, GraphPartition, build_partitions
from repro.graph.generate import power_law_graph, named_dataset
from repro.graph.metrics import partition_metrics, replication_factor, edge_balance, vertex_balance
from repro.graph.reorder import reorder_permutation, REORDER_ALGS

__all__ = [
    "HeteroGraph",
    "GraphPartition",
    "build_partitions",
    "power_law_graph",
    "named_dataset",
    "partition_metrics",
    "replication_factor",
    "edge_balance",
    "vertex_balance",
    "reorder_permutation",
    "REORDER_ALGS",
]
