"""Fused ragged Pallas kernels for the GNN hot path.

The bucketed inference engine and the training apply() path both reduce to
the same three dispatches per layer: gather neighbor rows, segment-sum them
into destination rows, normalize/activate.  ``segment_spmm.py`` already
turns the scatter into a block-tiled one-hot matmul; this module removes
the remaining HBM round trips and padding waste (ROADMAP item 1):

* :func:`gather_spmm_pallas` — fused gather+segment-SpMM.  Takes the
  feature matrix and per-edge row indices and gathers *inside* the edge
  tile, so the ``[E, D]`` message array is never materialized in HBM.
* :func:`gather_spmm_ragged_pallas` / :func:`segment_spmm_ragged_pallas` —
  masked/ragged variants driven by per-tile valid-edge counts.  Power-of-two
  bucket padding then costs one ``pl.when`` predicate per tile instead of
  MXU work (an all-padding tile is skipped entirely).
* :func:`gat_softmax_aggregate_pallas` — one-pass GAT edge-softmax +
  weighted aggregate (segment-max, exp, normalize, weighted segment-sum in
  a single kernel), replacing the 3-pass ``_seg_softmax`` + ``_seg_sum``
  sequence in ``models/gnn/models.py``.  Uses the flash-attention online
  rescaling trick (running max / denominator / accumulator as revisited
  output blocks) so segments can span edge tiles.
* :func:`segment_max_pallas` — standalone segment-max so ``_seg_softmax``'s
  max step can honor ``use_kernel`` too.

All kernels run on a 1-D grid over edge tiles with the full output array as
a revisited block: the gather happens once per edge tile (never once per
(row-tile, edge-tile) pair), which is also what makes the fused path beat
gather→``segment_spmm_pallas`` on wall-clock.  ``seg == -1`` / ``idx == -1``
mark padding.  Every kernel has a same-named ``*_ref`` oracle in ``ref.py``
(glint rule KRN001 enforces this) and plumbs ``interpret`` through.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "gather_spmm_pallas",
    "gather_spmm_ragged_pallas",
    "segment_spmm_ragged_pallas",
    "gat_softmax_aggregate_pallas",
    "segment_max_pallas",
]

# finite stand-in for -inf: exp(_NEG_INF - m) underflows to 0.0 and
# _NEG_INF - _NEG_INF == 0 (a true -inf would produce NaN there)
_NEG_INF = -1e30


def _pad_edges(arrs, m: int, block_edges: int, fills):
    """Pad every 1-D/2-D edge-indexed array up to a whole number of tiles
    (at least one, so the eb==0 init always runs even for m == 0)."""
    m_pad = -(-max(m, 1) // block_edges) * block_edges
    if m_pad == m:
        return arrs, m_pad
    out = []
    for a, fill in zip(arrs, fills):
        pad = ((0, m_pad - m),) + ((0, 0),) * (a.ndim - 1)
        out.append(jnp.pad(a, pad, constant_values=fill))
    return out, m_pad


def _onehot(seg, valid, n):
    """[BM, n] one-hot membership matrix (bool), padding rows all-zero."""
    rows = jax.lax.iota(jnp.int32, n)
    return (seg[:, None] == rows[None, :]) & valid[:, None]


# -- fused gather + segment-SpMM --------------------------------------------


def _gather_accumulate(idx_ref, seg_ref, feats_ref, out_ref):
    idx = idx_ref[...]  # [BM] int32 rows into feats (-1 = padding)
    seg = seg_ref[...]  # [BM] int32 destination segments (-1 = padding)
    feats = feats_ref[...]  # [F, D] resident feature block
    msg = jnp.take(feats, jnp.maximum(idx, 0), axis=0)  # [BM, D] in VMEM only
    ok = (idx >= 0) & (seg >= 0)
    onehot = _onehot(seg, ok, out_ref.shape[0]).astype(msg.dtype)
    out_ref[...] += jax.lax.dot_general(
        onehot,
        msg,
        dimension_numbers=(((0,), (0,)), ((), ())),  # onehot^T @ msg
        preferred_element_type=out_ref.dtype,
    )


def _gather_kernel(idx_ref, seg_ref, feats_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    _gather_accumulate(idx_ref, seg_ref, feats_ref, out_ref)


def _gather_ragged_kernel(cnt_ref, idx_ref, seg_ref, feats_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(cnt_ref[0] > 0)  # all-padding tiles cost one predicate
    def _compute():
        _gather_accumulate(idx_ref, seg_ref, feats_ref, out_ref)


def _gather_call(kernel, inputs, specs, grid, n, d, dtype, interpret):
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=specs,
        out_specs=pl.BlockSpec((n, d), lambda eb: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), dtype),
        interpret=interpret,
    )(*inputs)


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_edges", "interpret")
)
def gather_spmm_pallas(
    feats: jax.Array,
    idx: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    block_edges: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """out[s] = sum over edges e with seg[e] == s of feats[idx[e]].

    feats: [F, D]; idx, seg: [E] int32 with -1 padding.  The gather runs
    inside the edge tile — no [E, D] message array is ever materialized."""
    m = idx.shape[0]
    f, d = feats.shape
    (idx, seg), m_pad = _pad_edges(
        [idx.astype(jnp.int32), seg.astype(jnp.int32)], m, block_edges, [-1, -1]
    )
    return _gather_call(
        _gather_kernel,
        (idx, seg, feats),
        [
            pl.BlockSpec((block_edges,), lambda eb: (eb,)),
            pl.BlockSpec((block_edges,), lambda eb: (eb,)),
            pl.BlockSpec((f, d), lambda eb: (0, 0)),
        ],
        (m_pad // block_edges,),
        num_segments,
        d,
        feats.dtype,
        interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_edges", "interpret")
)
def gather_spmm_ragged_pallas(
    feats: jax.Array,
    idx: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    block_edges: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Ragged :func:`gather_spmm_pallas`: per-tile valid-edge counts are
    computed host-side-of-the-kernel and tiles with zero valid edges skip
    the gather+matmul entirely (bucket padding costs mask work, not MXU
    work).  Same semantics as the dense variant."""
    m = idx.shape[0]
    f, d = feats.shape
    (idx, seg), m_pad = _pad_edges(
        [idx.astype(jnp.int32), seg.astype(jnp.int32)], m, block_edges, [-1, -1]
    )
    valid = (idx >= 0) & (seg >= 0)
    counts = jnp.sum(valid.reshape(-1, block_edges), axis=1).astype(jnp.int32)
    return _gather_call(
        _gather_ragged_kernel,
        (counts, idx, seg, feats),
        [
            pl.BlockSpec((1,), lambda eb: (eb,)),
            pl.BlockSpec((block_edges,), lambda eb: (eb,)),
            pl.BlockSpec((block_edges,), lambda eb: (eb,)),
            pl.BlockSpec((f, d), lambda eb: (0, 0)),
        ],
        (m_pad // block_edges,),
        num_segments,
        d,
        feats.dtype,
        interpret,
    )


# -- ragged segment-SpMM (pre-gathered messages) -----------------------------


def _spmm_ragged_kernel(cnt_ref, seg_ref, msg_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(cnt_ref[0] > 0)
    def _compute():
        seg = seg_ref[...]
        msg = msg_ref[...]
        onehot = _onehot(seg, seg >= 0, out_ref.shape[0]).astype(msg.dtype)
        out_ref[...] += jax.lax.dot_general(
            onehot,
            msg,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=out_ref.dtype,
        )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_edges", "interpret")
)
def segment_spmm_ragged_pallas(
    msg: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    block_edges: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Ragged drop-in for :func:`segment_spmm_pallas` on a 1-D edge grid:
    the full output is a revisited block and all-padding edge tiles are
    skipped via per-tile valid counts — the engine's power-of-two bucket
    padding stops costing matmuls."""
    m, d = msg.shape
    (msg, seg), m_pad = _pad_edges(
        [msg, seg.astype(jnp.int32)], m, block_edges, [0, -1]
    )
    counts = jnp.sum((seg >= 0).reshape(-1, block_edges), axis=1).astype(jnp.int32)
    return pl.pallas_call(
        _spmm_ragged_kernel,
        grid=(m_pad // block_edges,),
        in_specs=[
            pl.BlockSpec((1,), lambda eb: (eb,)),
            pl.BlockSpec((block_edges,), lambda eb: (eb,)),
            pl.BlockSpec((block_edges, d), lambda eb: (eb, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, d), lambda eb: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), msg.dtype),
        interpret=interpret,
    )(counts, seg, msg)


# -- one-pass GAT edge-softmax + aggregate -----------------------------------


def _gat_kernel(seg_ref, logit_ref, msg_ref, acc_ref, m_ref, z_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        z_ref[...] = jnp.zeros_like(z_ref)

    seg = seg_ref[...]
    logit = logit_ref[...].astype(jnp.float32)  # [BM]
    msg = msg_ref[...].astype(jnp.float32)  # [BM, D]
    member = _onehot(seg, seg >= 0, acc_ref.shape[0])  # [BM, n] bool
    tile_max = jnp.max(jnp.where(member, logit[:, None], _NEG_INF), axis=0)
    m_prev = m_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, tile_max)
    # online-softmax rescale of the running sums (exp(0)=1 while a segment
    # is still empty; exp(-huge) underflows to 0 once a real max arrives)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(jnp.where(member, logit[:, None] - m_new[None, :], _NEG_INF))
    z_ref[...] = (alpha * z_ref[...][:, 0] + jnp.sum(p, axis=0))[:, None]
    acc_ref[...] = alpha[:, None] * acc_ref[...] + jax.lax.dot_general(
        p,
        msg,
        dimension_numbers=(((0,), (0,)), ((), ())),  # p^T @ msg
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new[:, None]


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_edges", "interpret")
)
def gat_softmax_aggregate_pallas(
    logits: jax.Array,
    msg: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    block_edges: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """out[s] = sum_e softmax_{seg==s}(logits)[e] * msg[e] in ONE kernel.

    Replaces the 3-pass segment-max → exp/normalize → segment-sum sequence:
    running (max, denominator, accumulator) live in revisited output blocks
    and are rescaled flash-attention-style as edge tiles stream through.
    Matches ``alpha = e / max(z, 1e-9)`` from ``_seg_softmax`` exactly, so
    empty segments return 0."""
    m = seg.shape[0]
    d = msg.shape[1]
    n = num_segments
    (seg, logits, msg), m_pad = _pad_edges(
        [seg.astype(jnp.int32), logits, msg], m, block_edges, [-1, 0, 0]
    )
    acc, _, z = pl.pallas_call(
        _gat_kernel,
        grid=(m_pad // block_edges,),
        in_specs=[
            pl.BlockSpec((block_edges,), lambda eb: (eb,)),
            pl.BlockSpec((block_edges,), lambda eb: (eb,)),
            pl.BlockSpec((block_edges, d), lambda eb: (eb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n, d), lambda eb: (0, 0)),
            pl.BlockSpec((n, 1), lambda eb: (0, 0)),
            pl.BlockSpec((n, 1), lambda eb: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(seg, logits, msg)
    return (acc / jnp.maximum(z, 1e-9)).astype(msg.dtype)


# -- segment max -------------------------------------------------------------


def _segmax_kernel(seg_ref, x_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _NEG_INF)

    seg = seg_ref[...]
    x = x_ref[...].astype(jnp.float32)
    member = _onehot(seg, seg >= 0, out_ref.shape[0])
    tile_max = jnp.max(jnp.where(member, x[:, None], _NEG_INF), axis=0)
    out_ref[...] = jnp.maximum(out_ref[...], tile_max[:, None])


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_edges", "interpret")
)
def segment_max_pallas(
    x: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    block_edges: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Per-segment max of x over seg (padding seg=-1 excluded); empty
    segments yield 0.0, matching ``_seg_softmax``'s finite-fix."""
    m = seg.shape[0]
    (seg, x), m_pad = _pad_edges(
        [seg.astype(jnp.int32), x], m, block_edges, [-1, 0]
    )
    out = pl.pallas_call(
        _segmax_kernel,
        grid=(m_pad // block_edges,),
        in_specs=[
            pl.BlockSpec((block_edges,), lambda eb: (eb,)),
            pl.BlockSpec((block_edges,), lambda eb: (eb,)),
        ],
        out_specs=pl.BlockSpec((num_segments, 1), lambda eb: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, 1), jnp.float32),
        interpret=interpret,
    )(seg, x)
    mx = out[:, 0]
    return jnp.where(mx > _NEG_INF * 0.5, mx, 0.0).astype(x.dtype)
