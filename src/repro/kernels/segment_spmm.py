"""Pallas TPU kernel: segment-sum SpMM — the GNN aggregation hotspot.

GNN message passing aggregates E gathered neighbor-message rows into B
destination rows (``out[seg[e]] += msg[e]``).  On GPU this is a scatter-add
(cuSPARSE / atomics); scatters are hostile to the TPU's systolic MXU, so we
adapt the paper's aggregation hotspot TPU-natively (DESIGN.md §3):

    the scatter becomes a block-tiled ONE-HOT MATMUL.  For an edge tile of
    BM messages and a row tile of BN segments, ``onehot[bm, bn] =
    (seg[bm] == row_ids[bn])`` and ``out_tile += onehot^T @ msg_tile`` —
    a (BN × BM) · (BM × D) MXU contraction entirely in VMEM.

Grid is (row_blocks, edge_blocks) with the edge axis innermost; the output
tile is accumulated across the inner axis (revisited output block), written
once zeroed at the first edge block.  ``seg`` must be sorted ascending for
efficiency claims but correctness holds for any order.  Padding rows use
``seg = -1`` (matches no row).

VMEM budget per step: BM·D (msg) + BN·D (out) + BM·BN (onehot) floats —
default BM=BN=128, D tiles of 128..512 keep it well under 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_spmm_pallas"]


def _kernel(seg_ref, msg_ref, out_ref, *, block_rows: int):
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rb = pl.program_id(0)
    seg = seg_ref[...]  # [BM] int32 (global segment ids, -1 = padding)
    msg = msg_ref[...]  # [BM, D]
    row_base = rb * block_rows
    row_ids = row_base + jax.lax.iota(jnp.int32, block_rows)  # [BN]
    onehot = (seg[:, None] == row_ids[None, :]).astype(msg.dtype)  # [BM, BN]
    out_ref[...] += jax.lax.dot_general(
        onehot,
        msg,
        dimension_numbers=(((0,), (0,)), ((), ())),  # onehot^T @ msg
        preferred_element_type=out_ref.dtype,
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_rows", "block_edges", "interpret")
)
def segment_spmm_pallas(
    msg: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    block_rows: int = 128,
    block_edges: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """out[s] = sum over e with seg[e] == s of msg[e].

    msg: [M, D] (M padded to block_edges), seg: [M] int32 (-1 padding).
    num_segments is padded up to block_rows internally; callers slice."""
    m, d = msg.shape
    assert seg.shape == (m,)
    m_pad = -(-m // block_edges) * block_edges
    n_pad = -(-num_segments // block_rows) * block_rows
    if m_pad != m:
        msg = jnp.pad(msg, ((0, m_pad - m), (0, 0)))
        seg = jnp.pad(seg, (0, m_pad - m), constant_values=-1)
    grid = (n_pad // block_rows, m_pad // block_edges)
    out = pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_edges,), lambda rb, eb: (eb,)),
            pl.BlockSpec((block_edges, d), lambda rb, eb: (eb, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda rb, eb: (rb, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), msg.dtype),
        interpret=interpret,
    )(seg.astype(jnp.int32), msg)
    return out[:num_segments]
