"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "segment_spmm_ref",
    "segment_spmm_ragged_ref",
    "gather_spmm_ref",
    "gather_spmm_ragged_ref",
    "gat_softmax_aggregate_ref",
    "segment_max_ref",
    "attention_ref",
    "flash_attention_ref",
    "ssd_scan_ref",
]


def segment_spmm_ref(msg: jax.Array, seg: jax.Array, num_segments: int) -> jax.Array:
    """out[s] = sum_{e: seg[e]==s} msg[e]; seg==-1 rows are dropped."""
    valid = (seg >= 0)[:, None].astype(msg.dtype)
    return jax.ops.segment_sum(
        msg * valid, jnp.maximum(seg, 0), num_segments=num_segments
    )


def segment_spmm_ragged_ref(
    msg: jax.Array, seg: jax.Array, num_segments: int
) -> jax.Array:
    """The ragged kernel skips all-padding tiles, which contribute zero —
    semantics are identical to the dense segment-SpMM."""
    return segment_spmm_ref(msg, seg, num_segments)


def gather_spmm_ref(
    feats: jax.Array, idx: jax.Array, seg: jax.Array, num_segments: int
) -> jax.Array:
    """out[s] = sum_{e: seg[e]==s} feats[idx[e]]; edges with idx or seg
    equal to -1 are dropped (the fused kernel's padding convention)."""
    ok = (idx >= 0) & (seg >= 0)
    msg = jnp.where(ok[:, None], feats[jnp.maximum(idx, 0)], 0)
    return jax.ops.segment_sum(
        msg, jnp.maximum(seg, 0), num_segments=num_segments
    )


def gather_spmm_ragged_ref(
    feats: jax.Array, idx: jax.Array, seg: jax.Array, num_segments: int
) -> jax.Array:
    """Ragged tile-skipping changes nothing semantically."""
    return gather_spmm_ref(feats, idx, seg, num_segments)


def segment_max_ref(x: jax.Array, seg: jax.Array, num_segments: int) -> jax.Array:
    """Per-segment max (padding seg=-1 excluded); empty segments yield 0.0,
    matching the models' ``_seg_softmax`` finite-fix."""
    neg = jnp.where(seg >= 0, x, -jnp.inf)
    mx = jax.ops.segment_max(neg, jnp.maximum(seg, 0), num_segments=num_segments)
    return jnp.where(jnp.isfinite(mx), mx, 0.0).astype(x.dtype)


def gat_softmax_aggregate_ref(
    logits: jax.Array, msg: jax.Array, seg: jax.Array, num_segments: int
) -> jax.Array:
    """3-pass oracle for the one-pass kernel: segment-max, exp/normalize
    with the ``max(z, 1e-9)`` guard from ``_seg_softmax``, weighted
    segment-sum.  Empty segments return 0."""
    ok = seg >= 0
    seg0 = jnp.maximum(seg, 0)
    mx = segment_max_ref(logits.astype(jnp.float32), seg, num_segments)
    e = jnp.where(ok, jnp.exp(logits.astype(jnp.float32) - mx[seg0]), 0.0)
    z = jax.ops.segment_sum(e, seg0, num_segments=num_segments)
    alpha = e / jnp.maximum(z[seg0], 1e-9)
    weighted = jnp.where(ok[:, None], msg.astype(jnp.float32), 0.0) * alpha[:, None]
    return jax.ops.segment_sum(
        weighted, seg0, num_segments=num_segments
    ).astype(msg.dtype)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    kv_offset: int = 0,
) -> jax.Array:
    """Dense single-head attention oracle with causal/window masks."""
    sq, d = q.shape
    skv = k.shape[0]
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) / (d**0.5)
    q_pos = kv_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)


# KRN001 pairs every ``<base>_pallas`` kernel with a ``<base>_ref`` oracle;
# the flash kernel's oracle predates that convention under its dense name.
flash_attention_ref = attention_ref


def ssd_scan_ref(
    x: jax.Array,  # [S, H, P]   inputs per head
    dt: jax.Array,  # [S, H]      softplus'd timestep
    A: jax.Array,  # [H]         negative decay rate
    B: jax.Array,  # [S, G, N]   input projection (G state groups)
    C: jax.Array,  # [S, G, N]   output projection
) -> jax.Array:
    """Sequential SSD (Mamba-2) recurrence oracle:

        state_s = exp(A h dt_s) * state_{s-1} + dt_s * B_s ⊗ x_s
        y_s     = C_s · state_s

    Shapes follow Mamba-2: H heads, P head dim, N state dim, G B/C groups
    (heads per group = H // G).  Runs a lax.scan over time (exact)."""
    S, H, P = x.shape
    G, N = B.shape[1], B.shape[2]
    heads_per_group = H // G
    Bh = jnp.repeat(B, heads_per_group, axis=1)  # [S, H, N]
    Ch = jnp.repeat(C, heads_per_group, axis=1)

    decay = jnp.exp(A[None, :] * dt)  # [S, H]

    def step(state, inp):
        dec, dt_s, x_s, b_s, c_s = inp
        state = state * dec[:, None, None] + (
            dt_s[:, None, None] * x_s[:, :, None] * b_s[:, None, :]
        )  # [H, P, N]
        y = jnp.einsum("hpn,hn->hp", state, c_s)
        return state, y

    init = jnp.zeros((H, P, N), dtype=jnp.float32)
    _, ys = jax.lax.scan(
        step,
        init,
        (
            decay.astype(jnp.float32),
            dt.astype(jnp.float32),
            x.astype(jnp.float32),
            Bh.astype(jnp.float32),
            Ch.astype(jnp.float32),
        ),
    )
    return ys.astype(x.dtype)  # [S, H, P]
