"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["segment_spmm_ref", "attention_ref", "ssd_scan_ref"]


def segment_spmm_ref(msg: jax.Array, seg: jax.Array, num_segments: int) -> jax.Array:
    """out[s] = sum_{e: seg[e]==s} msg[e]; seg==-1 rows are dropped."""
    valid = (seg >= 0)[:, None].astype(msg.dtype)
    return jax.ops.segment_sum(
        msg * valid, jnp.maximum(seg, 0), num_segments=num_segments
    )


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    kv_offset: int = 0,
) -> jax.Array:
    """Dense single-head attention oracle with causal/window masks."""
    sq, d = q.shape
    skv = k.shape[0]
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) / (d**0.5)
    q_pos = kv_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,  # [S, H, P]   inputs per head
    dt: jax.Array,  # [S, H]      softplus'd timestep
    A: jax.Array,  # [H]         negative decay rate
    B: jax.Array,  # [S, G, N]   input projection (G state groups)
    C: jax.Array,  # [S, G, N]   output projection
) -> jax.Array:
    """Sequential SSD (Mamba-2) recurrence oracle:

        state_s = exp(A h dt_s) * state_{s-1} + dt_s * B_s ⊗ x_s
        y_s     = C_s · state_s

    Shapes follow Mamba-2: H heads, P head dim, N state dim, G B/C groups
    (heads per group = H // G).  Runs a lax.scan over time (exact)."""
    S, H, P = x.shape
    G, N = B.shape[1], B.shape[2]
    heads_per_group = H // G
    Bh = jnp.repeat(B, heads_per_group, axis=1)  # [S, H, N]
    Ch = jnp.repeat(C, heads_per_group, axis=1)

    decay = jnp.exp(A[None, :] * dt)  # [S, H]

    def step(state, inp):
        dec, dt_s, x_s, b_s, c_s = inp
        state = state * dec[:, None, None] + (
            dt_s[:, None, None] * x_s[:, :, None] * b_s[:, None, :]
        )  # [H, P, N]
        y = jnp.einsum("hpn,hn->hp", state, c_s)
        return state, y

    init = jnp.zeros((H, P, N), dtype=jnp.float32)
    _, ys = jax.lax.scan(
        step,
        init,
        (
            decay.astype(jnp.float32),
            dt.astype(jnp.float32),
            x.astype(jnp.float32),
            Bh.astype(jnp.float32),
            Ch.astype(jnp.float32),
        ),
    )
    return ys.astype(x.dtype)  # [S, H, P]
