"""Deterministic block-size autotuner for the GNN Pallas kernels.

Block sizes that win depend on the (edges, segments, dim) shape and dtype;
rather than hardcode 128 everywhere, callers can sweep a small fixed
candidate grid once per shape bucket and cache the winner:

* the key is ``(op, shape-bucket, dtype)`` where every dim is rounded up to
  a power of two — exactly the bucketing the inference engine already uses,
  so one sweep covers every batch that lands in the bucket;
* results live in a process-global table consulted by the ``ops.py``
  wrappers at trace time (block sizes are static jit args), and optionally
  in a **content-addressed JSON artifact**: the filename embeds a hash of
  the tuner version + candidate grid, so a stale artifact from an older
  tuner can never be read back as current;
* measurement inputs are built from a fixed seed and candidates are tried
  in a fixed order with ties going to the earlier candidate, so the same
  machine state yields the same choice — and with a cache artifact the
  choice is byte-stable across processes regardless of timer noise.

The sweep itself costs a few kernel launches per (op, bucket, dtype) and
is opt-in (``GLISPConfig(kernel_autotune=True)`` or direct calls here);
everything falls back to ``DEFAULT_CONFIG`` when untuned.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time

import jax.numpy as jnp
import numpy as np

__all__ = [
    "KernelConfig",
    "DEFAULT_CONFIG",
    "TUNE_VERSION",
    "CANDIDATES",
    "tuned_key",
    "get_tuned",
    "autotune",
    "autotune_for_slice",
    "artifact_path",
    "stats",
    "reset",
]

TUNE_VERSION = 1

# fixed candidate grids (order matters: ties resolve to the earlier entry).
# Only segment_spmm tiles the row axis; the fused kernels run a 1-D edge
# grid with the full output resident, so only block_edges is swept there.
_EDGE_CANDIDATES = (64, 128, 256)
_ROW_CANDIDATES = (128, 256)
TUNED_OPS = (
    "segment_spmm",
    "segment_spmm_ragged",
    "gather_spmm",
    "gather_spmm_ragged",
    "gat_softmax_aggregate",
    "segment_max",
)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    block_rows: int = 128
    block_edges: int = 128


DEFAULT_CONFIG = KernelConfig()


def _candidates(op: str) -> tuple[KernelConfig, ...]:
    if op == "segment_spmm":
        return tuple(
            KernelConfig(br, be) for br in _ROW_CANDIDATES for be in _EDGE_CANDIDATES
        )
    return tuple(KernelConfig(128, be) for be in _EDGE_CANDIDATES)


CANDIDATES = {op: _candidates(op) for op in TUNED_OPS}

_TUNED: dict[str, KernelConfig] = {}
_STATS = {"memory_hits": 0, "artifact_hits": 0, "measured": 0}


def _pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def tuned_key(op: str, shape, dtype) -> str:
    """Cache key: op / pow2-bucketed dims / dtype name."""
    dims = "x".join(str(_pow2(d)) for d in shape)
    return f"{op}/{dims}/{jnp.dtype(dtype).name}"


def get_tuned(op: str, shape, dtype) -> KernelConfig | None:
    """Best known config for this shape bucket, or None if never tuned."""
    return _TUNED.get(tuned_key(op, shape, dtype))


def stats() -> dict:
    return dict(_STATS)


def reset(clear_stats: bool = True) -> None:
    """Drop the in-process table (artifacts on disk survive) — test hook."""
    _TUNED.clear()
    if clear_stats:
        for k in _STATS:
            _STATS[k] = 0


# -- content-addressed artifact ---------------------------------------------


def _identity() -> dict:
    return {
        "version": TUNE_VERSION,
        "candidates": {
            op: [dataclasses.asdict(c) for c in cands]
            for op, cands in CANDIDATES.items()
        },
    }


def artifact_path(cache_dir: str) -> str:
    """The artifact name embeds a digest of the tuner identity (version +
    candidate grid), so incompatible tuners read/write different files."""
    digest = hashlib.sha256(
        json.dumps(_identity(), sort_keys=True).encode()
    ).hexdigest()[:16]
    return os.path.join(cache_dir, f"kernel_tune_v{TUNE_VERSION}_{digest}.json")


def _load_artifact(cache_dir: str) -> dict[str, KernelConfig]:
    path = artifact_path(cache_dir)
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return {}
    return {k: KernelConfig(**v) for k, v in raw.get("configs", {}).items()}


def _store_artifact(cache_dir: str, configs: dict[str, KernelConfig]) -> None:
    os.makedirs(cache_dir, exist_ok=True)
    path = artifact_path(cache_dir)
    payload = dict(_identity())
    payload["configs"] = {
        k: dataclasses.asdict(v) for k, v in sorted(configs.items())
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)  # atomic: readers never see a torn file


# -- measurement -------------------------------------------------------------


def _inputs(op: str, shape, dtype):
    """Deterministic measurement inputs at the bucketed shape.  The tail
    quarter of edges is padding so ragged ops exercise their tile skip."""
    edges, segments, dim = (_pow2(d) for d in shape)
    rng = np.random.default_rng(0)
    valid = (3 * edges) // 4
    seg = np.sort(rng.integers(0, segments, edges)).astype(np.int32)
    seg[valid:] = -1
    idx = rng.integers(0, segments, edges).astype(np.int32)
    idx[valid:] = -1
    feats = rng.standard_normal((segments, dim)).astype(np.float32)
    msg = rng.standard_normal((edges, dim)).astype(np.float32)
    logits = rng.standard_normal(edges).astype(np.float32)
    cast = lambda a: jnp.asarray(a, dtype=dtype)  # noqa: E731
    return {
        "seg": jnp.asarray(seg),
        "idx": jnp.asarray(idx),
        "feats": cast(feats),
        "msg": cast(msg),
        "logits": cast(logits),
        "n": segments,
    }


def _call(op: str, inp: dict, cfg: KernelConfig, interpret: bool):
    from repro.kernels import fused_gnn, segment_spmm

    n, be = inp["n"], cfg.block_edges
    if op == "segment_spmm":
        return segment_spmm.segment_spmm_pallas(
            inp["msg"], inp["seg"], n,
            block_rows=cfg.block_rows, block_edges=be, interpret=interpret,
        )
    if op == "segment_spmm_ragged":
        return fused_gnn.segment_spmm_ragged_pallas(
            inp["msg"], inp["seg"], n, block_edges=be, interpret=interpret
        )
    if op == "gather_spmm":
        return fused_gnn.gather_spmm_pallas(
            inp["feats"], inp["idx"], inp["seg"], n,
            block_edges=be, interpret=interpret,
        )
    if op == "gather_spmm_ragged":
        return fused_gnn.gather_spmm_ragged_pallas(
            inp["feats"], inp["idx"], inp["seg"], n,
            block_edges=be, interpret=interpret,
        )
    if op == "gat_softmax_aggregate":
        return fused_gnn.gat_softmax_aggregate_pallas(
            inp["logits"], inp["msg"], inp["seg"], n,
            block_edges=be, interpret=interpret,
        )
    if op == "segment_max":
        return fused_gnn.segment_max_pallas(
            inp["logits"], inp["seg"], n, block_edges=be, interpret=interpret
        )
    raise ValueError(f"unknown tuned op {op!r}")


def _measure(op: str, inp: dict, cfg: KernelConfig, repeats: int, interpret) -> float:
    _call(op, inp, cfg, interpret).block_until_ready()  # compile outside timing
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _call(op, inp, cfg, interpret).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(
    op: str,
    shape,
    dtype,
    *,
    cache_dir: str | None = None,
    repeats: int = 3,
    interpret: bool | None = None,
) -> KernelConfig:
    """Best block config for (op, shape-bucket, dtype): in-process table
    first, then the cache artifact, then a measured sweep (whose winner is
    merged back into the artifact when ``cache_dir`` is given)."""
    if op not in CANDIDATES:
        raise ValueError(f"unknown tuned op {op!r} (have {sorted(CANDIDATES)})")
    key = tuned_key(op, shape, dtype)
    if key in _TUNED:
        _STATS["memory_hits"] += 1
        return _TUNED[key]
    if cache_dir is not None:
        cached = _load_artifact(cache_dir)
        if key in cached:
            _STATS["artifact_hits"] += 1
            _TUNED[key] = cached[key]
            return cached[key]
    if interpret is None:
        from repro.kernels.ops import INTERPRET

        interpret = INTERPRET
    inp = _inputs(op, shape, dtype)
    times = [_measure(op, inp, c, repeats, interpret) for c in CANDIDATES[op]]
    best = CANDIDATES[op][int(np.argmin(times))]  # ties -> earlier candidate
    _STATS["measured"] += 1
    _TUNED[key] = best
    if cache_dir is not None:
        merged = _load_artifact(cache_dir)
        merged[key] = best
        _store_artifact(cache_dir, merged)
    return best


def autotune_for_slice(shapes, dtype, *, cache_dir: str | None = None) -> None:
    """Tune a batch of (op, shape) pairs — the engine calls this with a
    layer slice's kernel shapes before the bucket's first jit trace, so the
    tuned blocks are already in the table when tracing resolves them."""
    for op, shape in shapes:
        autotune(op, shape, dtype, cache_dir=cache_dir)
