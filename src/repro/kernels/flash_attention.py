"""Pallas TPU kernel: blocked flash attention (forward) with causal and
sliding-window masking — the transformer serving/training compute hotspot.

Classic online-softmax tiling adapted to TPU VMEM: grid over (q blocks,
kv blocks) with the kv axis innermost; running max/denominator and the
output accumulator live in the revisited output blocks.  Causal and
sliding-window (SWA) masks are applied inside the tile; fully-masked kv
blocks are still visited but contribute zero (XLA grid pruning of the
upper triangle is a TPU-runtime optimization we skip in interpret mode).

Shapes: q [Sq, D], k/v [Skv, D] for ONE head — callers vmap over
(batch, head) (GQA mapping handled in ops.py).  D should be a multiple of
128 for MXU alignment; block sizes default to 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    *,
    block_q: int,
    block_kv: int,
    causal: bool,
    window: int,
    kv_offset: int,
    scale: float,
    skv_real: int,
):
    qb, kb = pl.program_id(0), pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32) * scale  # [BQ, D]
    k = k_ref[...].astype(jnp.float32)  # [BK, D]
    v = v_ref[...].astype(jnp.float32)  # [BK, D]
    s = q @ k.T  # [BQ, BK]

    # absolute positions: queries live at kv_offset + qb*BQ + i
    q_pos = kv_offset + qb * block_q + jax.lax.iota(jnp.int32, block_q)[:, None]
    k_pos = kb * block_kv + jax.lax.iota(jnp.int32, block_kv)[None, :]
    mask = k_pos < skv_real  # exclude padded keys
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]  # [BQ, 1]
    l_prev = l_ref[...]  # [BQ, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # [BQ, BK]
    # renormalize previous accumulator
    alpha = jnp.exp(m_prev - m_new)  # [BQ, 1]
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    o_ref[...] = o_ref[...] * alpha + (p @ v).astype(o_ref.dtype)
    m_ref[...] = m_new
    l_ref[...] = l_new


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "kv_offset",
        "block_q",
        "block_kv",
        "interpret",
    ),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    kv_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Single-head attention.  q: [Sq, D]; k, v: [Skv, D].

    ``kv_offset`` is the absolute position of q[0] within the kv sequence
    (decode: Sq=1, kv_offset=cache_len-1).  ``window>0`` = sliding window."""
    sq, d = q.shape
    skv = k.shape[0]
    scale = 1.0 / (d**0.5)
    q_pad = -(-sq // block_q) * block_q
    kv_pad = -(-skv // block_kv) * block_kv
    if q_pad != sq:
        q = jnp.pad(q, ((0, q_pad - sq), (0, 0)))
    if kv_pad != skv:
        k = jnp.pad(k, ((0, kv_pad - skv), (0, 0)))
        v = jnp.pad(v, ((0, kv_pad - skv), (0, 0)))
    grid = (q_pad // block_q, kv_pad // block_kv)
    out, m, l = pl.pallas_call(
        functools.partial(
            _kernel,
            block_q=block_q,
            block_kv=block_kv,
            causal=causal,
            window=window,
            kv_offset=kv_offset,
            scale=scale,
            skv_real=skv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda qb, kb: (qb, 0)),
            pl.BlockSpec((block_kv, d), lambda qb, kb: (kb, 0)),
            pl.BlockSpec((block_kv, d), lambda qb, kb: (kb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, d), lambda qb, kb: (qb, 0)),
            pl.BlockSpec((block_q, 1), lambda qb, kb: (qb, 0)),
            pl.BlockSpec((block_q, 1), lambda qb, kb: (qb, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_pad, d), jnp.float32),
            jax.ShapeDtypeStruct((q_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((q_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    out = out / jnp.maximum(l, 1e-30)
    return out[:sq].astype(q.dtype)
