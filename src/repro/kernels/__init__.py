# Pallas TPU kernels for the compute hot-spots (validated interpret=True on
# CPU): segment_spmm (GNN aggregation), flash_attention, ssd_scan (Mamba-2).
from repro.kernels.ops import INTERPRET, gnn_aggregate, mha_attention, ssd_scan

__all__ = ["INTERPRET", "gnn_aggregate", "mha_attention", "ssd_scan"]
