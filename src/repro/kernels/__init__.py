# Pallas TPU kernels for the compute hot-spots (validated interpret=True on
# CPU): segment_spmm (GNN aggregation) plus its fused/ragged variants in
# fused_gnn.py, flash_attention, ssd_scan (Mamba-2).  Block sizes resolve
# through the deterministic autotuner in autotune.py.
from repro.kernels.autotune import DEFAULT_CONFIG, KernelConfig, get_tuned
from repro.kernels.ops import (
    INTERPRET,
    gnn_aggregate,
    gnn_gat_aggregate,
    gnn_gather_aggregate,
    gnn_segment_max,
    mha_attention,
    ssd_scan,
)

__all__ = [
    "INTERPRET",
    "gnn_aggregate",
    "gnn_gather_aggregate",
    "gnn_gat_aggregate",
    "gnn_segment_max",
    "mha_attention",
    "ssd_scan",
    "KernelConfig",
    "DEFAULT_CONFIG",
    "get_tuned",
]
