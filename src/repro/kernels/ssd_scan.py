"""Pallas TPU kernel: Mamba-2 SSD chunked scan (state-space duality).

The SSD recurrence  state_s = exp(a_s)·state_{s-1} + dt_s·B_s⊗x_s,
y_s = C_s·state_s  is block-decomposed into chunks of length L (Dao & Gu
2024): within a chunk the token-token interaction is the L×L matrix
``M[i,j] = (C_i·B_j)·exp(csum_i − csum_j)·dt_j  (j ≤ i)`` — a dense
MXU matmul — while the inter-chunk contribution flows through the carried
[P, N] state.  The grid iterates chunks sequentially (TPU grid order is
sequential, so the state lives in a revisited output block), giving O(S·L)
work in MXU-friendly tiles instead of an elementwise scan.

Single (batch, head) slice per call: x [S, P], a=dt·A [S, 1], dt [S, 1],
B, C [S, N]; vmap over batch/heads in ops.py.  a must be ≤ 0 (A < 0,
dt > 0) so every exp() here is ≤ 1 — no overflow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_scan_pallas"]


def _kernel(a_ref, dt_ref, x_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    cb = pl.program_id(0)

    @pl.when(cb == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[...][:, 0].astype(jnp.float32)  # [L]
    dt = dt_ref[...][:, 0].astype(jnp.float32)  # [L]
    x = x_ref[...].astype(jnp.float32)  # [L, P]
    b = b_ref[...].astype(jnp.float32)  # [L, N]
    c = c_ref[...].astype(jnp.float32)  # [L, N]
    s0 = state_ref[...].astype(jnp.float32)  # [P, N]

    csum = jnp.cumsum(a)  # [L], decreasing (a <= 0)
    # intra-chunk: M[i, j] = (C_i · B_j) * exp(csum_i - csum_j) * dt_j, j <= i
    cb_mat = c @ b.T  # [L, L]
    seg = csum[:, None] - csum[None, :]
    ii = jax.lax.iota(jnp.int32, chunk)
    causal = ii[:, None] >= ii[None, :]
    m = jnp.where(causal, cb_mat * jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
    m = m * dt[None, :]
    y = m @ x  # [L, P]
    # inter-chunk: y_i += exp(csum_i) * C_i · state0^T
    y += jnp.exp(csum)[:, None] * (c @ s0.T)  # [L, P]
    # state update: S = exp(csum[-1])·S0 + Σ_j exp(csum[-1]-csum_j)·dt_j·x_j⊗B_j
    w = jnp.exp(csum[-1] - csum) * dt  # [L]
    s_new = jnp.exp(csum[-1]) * s0 + jax.lax.dot_general(
        x * w[:, None],
        b,
        dimension_numbers=(((0,), (0,)), ((), ())),  # x^T @ B -> [P, N]
        preferred_element_type=jnp.float32,
    )
    y_ref[...] = y.astype(y_ref.dtype)
    state_ref[...] = s_new.astype(state_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jax.Array,  # [S, P]
    a: jax.Array,  # [S]  (= A * dt, <= 0)
    dt: jax.Array,  # [S]
    B: jax.Array,  # [S, N]
    C: jax.Array,  # [S, N]
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [S, P], final_state [P, N])."""
    S, P = x.shape
    N = B.shape[1]
    s_pad = -(-S // chunk) * chunk
    if s_pad != S:
        # pad with a=0 (no decay), dt=0 (no input) => state preserved, y junk
        x = jnp.pad(x, ((0, s_pad - S), (0, 0)))
        a = jnp.pad(a, (0, s_pad - S))
        dt = jnp.pad(dt, (0, s_pad - S))
        B = jnp.pad(B, ((0, s_pad - S), (0, 0)))
        C = jnp.pad(C, ((0, s_pad - S), (0, 0)))
    grid = (s_pad // chunk,)
    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, 1), lambda cb: (cb, 0)),
            pl.BlockSpec((chunk, 1), lambda cb: (cb, 0)),
            pl.BlockSpec((chunk, P), lambda cb: (cb, 0)),
            pl.BlockSpec((chunk, N), lambda cb: (cb, 0)),
            pl.BlockSpec((chunk, N), lambda cb: (cb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((chunk, P), lambda cb: (cb, 0)),
            pl.BlockSpec((P, N), lambda cb: (0, 0)),  # revisited carry
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_pad, P), x.dtype),
            jax.ShapeDtypeStruct((P, N), jnp.float32),
        ],
        interpret=interpret,
    )(a[:, None], dt[:, None], x, B, C)
    return y[:S], state
