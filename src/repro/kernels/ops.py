"""Jit'd public wrappers around the Pallas kernels.

``INTERPRET`` defaults to True (this box is CPU; the kernels execute in
Pallas interpret mode).  On a real TPU set REPRO_PALLAS_INTERPRET=0.
Every wrapper has a matching pure-jnp oracle in ref.py, and tests assert
allclose across shape/dtype sweeps.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.autotune import DEFAULT_CONFIG, get_tuned
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_gnn import (
    gat_softmax_aggregate_pallas,
    gather_spmm_pallas,
    gather_spmm_ragged_pallas,
    segment_max_pallas,
    segment_spmm_ragged_pallas,
)
from repro.kernels.ref import (
    attention_ref,
    gat_softmax_aggregate_ref,
    gather_spmm_ref,
    segment_max_ref,
    segment_spmm_ref,
    ssd_scan_ref,
)
from repro.kernels.segment_spmm import segment_spmm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"

__all__ = [
    "INTERPRET",
    "gnn_aggregate",
    "gnn_gather_aggregate",
    "gnn_gat_aggregate",
    "gnn_segment_max",
    "mha_attention",
    "ssd_scan",
    "segment_spmm_pallas",
    "segment_spmm_ragged_pallas",
    "gather_spmm_pallas",
    "gather_spmm_ragged_pallas",
    "gat_softmax_aggregate_pallas",
    "segment_max_pallas",
    "flash_attention_pallas",
    "ssd_scan_pallas",
    "attention_ref",
    "segment_spmm_ref",
    "gather_spmm_ref",
    "gat_softmax_aggregate_ref",
    "segment_max_ref",
    "ssd_scan_ref",
]


def _blocks(op, shape, dtype, block_rows, block_edges):
    """Resolve block sizes: explicit caller args win, then the autotuner's
    table for this (op, shape-bucket, dtype), then DEFAULT_CONFIG.  Runs at
    trace time (block sizes are static jit args), so a bucket tuned before
    its first trace bakes its winner into the compiled slice."""
    cfg = get_tuned(op, shape, dtype) or DEFAULT_CONFIG
    return (block_rows or cfg.block_rows, block_edges or cfg.block_edges)


def gnn_aggregate(
    msg: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    use_kernel: bool = True,
    block_rows: int | None = None,
    block_edges: int | None = None,
    ragged: bool = True,
) -> jax.Array:
    """Segment-sum of gathered neighbor messages (GNN aggregation hotspot).

    ``ragged=True`` (default) routes to the tile-skipping kernel so the
    engine's power-of-two bucket padding costs mask work, not MXU work."""
    if not use_kernel:
        return segment_spmm_ref(msg, seg, num_segments)
    shape = (msg.shape[0], num_segments, msg.shape[1])
    if ragged:
        _, be = _blocks("segment_spmm_ragged", shape, msg.dtype, None, block_edges)
        return segment_spmm_ragged_pallas(
            msg, seg, num_segments, block_edges=be, interpret=INTERPRET
        )
    br, be = _blocks("segment_spmm", shape, msg.dtype, block_rows, block_edges)
    return segment_spmm_pallas(
        msg, seg, num_segments, block_rows=br, block_edges=be, interpret=INTERPRET
    )


def gnn_gather_aggregate(
    feats: jax.Array,
    idx: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    use_kernel: bool = True,
    block_edges: int | None = None,
    ragged: bool = True,
) -> jax.Array:
    """Fused gather+aggregate: out[s] = sum_{seg[e]==s} feats[idx[e]],
    without materializing the [E, D] message array."""
    if not use_kernel:
        return gather_spmm_ref(feats, idx, seg, num_segments)
    shape = (idx.shape[0], num_segments, feats.shape[1])
    op = "gather_spmm_ragged" if ragged else "gather_spmm"
    _, be = _blocks(op, shape, feats.dtype, None, block_edges)
    fn = gather_spmm_ragged_pallas if ragged else gather_spmm_pallas
    return fn(feats, idx, seg, num_segments, block_edges=be, interpret=INTERPRET)


def gnn_gat_aggregate(
    logits: jax.Array,
    msg: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    use_kernel: bool = True,
    block_edges: int | None = None,
) -> jax.Array:
    """One-pass edge-softmax + weighted aggregate (GAT/HGT inner loop)."""
    if not use_kernel:
        return gat_softmax_aggregate_ref(logits, msg, seg, num_segments)
    shape = (seg.shape[0], num_segments, msg.shape[1])
    _, be = _blocks("gat_softmax_aggregate", shape, msg.dtype, None, block_edges)
    return gat_softmax_aggregate_pallas(
        logits, msg, seg, num_segments, block_edges=be, interpret=INTERPRET
    )


def gnn_segment_max(
    x: jax.Array,
    seg: jax.Array,
    num_segments: int,
    *,
    use_kernel: bool = True,
    block_edges: int | None = None,
) -> jax.Array:
    """Per-segment max with seg=-1 padding excluded; empty segments -> 0."""
    if not use_kernel:
        return segment_max_ref(x, seg, num_segments)
    shape = (seg.shape[0], num_segments, 1)
    _, be = _blocks("segment_max", shape, x.dtype, None, block_edges)
    return segment_max_pallas(
        x, seg, num_segments, block_edges=be, interpret=INTERPRET
    )


def mha_attention(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Skv, Hkv, D]
    v: jax.Array,  # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    window: int = 0,
    kv_offset: int = 0,
    use_kernel: bool = True,
) -> jax.Array:
    """Multi-head attention with GQA (H a multiple of Hkv), batched via vmap
    over (batch, head) pairs of the single-head flash kernel."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kh = kr.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vh = vr.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    fn = flash_attention_pallas if use_kernel else attention_ref
    kwargs = dict(causal=causal, window=window, kv_offset=kv_offset)
    if use_kernel:
        kwargs["interpret"] = INTERPRET
    out = jax.vmap(lambda qq, kk, vv: fn(qq, kk, vv, **kwargs))(qh, kh, vh)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def ssd_scan(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]
    A: jax.Array,  # [H]
    B_: jax.Array,  # [B, S, G, N]
    C: jax.Array,  # [B, S, G, N]
    *,
    chunk: int = 128,
    use_kernel: bool = True,
) -> jax.Array:
    """Batched multi-head SSD scan; returns y [B, S, H, P]."""
    bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    if not use_kernel:
        return jax.vmap(lambda xx, dd, bb, cc: ssd_scan_ref(xx, dd, A, bb, cc))(
            x, dt, B_, C
        )
    Bh = jnp.repeat(B_, rep, axis=2)  # [B, S, H, N]
    Ch = jnp.repeat(C, rep, axis=2)
    a = dt * A[None, None, :]  # [B, S, H]

    def one(xx, aa, dd, bb, cc):
        y, _ = ssd_scan_pallas(xx, aa, dd, bb, cc, chunk=chunk, interpret=INTERPRET)
        return y

    # flatten (batch, head)
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * H, S, P)
    af = a.transpose(0, 2, 1).reshape(bsz * H, S)
    df = dt.transpose(0, 2, 1).reshape(bsz * H, S)
    bf = Bh.transpose(0, 2, 1, 3).reshape(bsz * H, S, N)
    cf = Ch.transpose(0, 2, 1, 3).reshape(bsz * H, S, N)
    yf = jax.vmap(one)(xf, af, df, bf, cf)
    return yf.reshape(bsz, H, S, P).transpose(0, 2, 1, 3)
