"""Unified architecture config for the assigned model pool.

One ``ArchConfig`` describes any of the 6 families (dense / moe / ssm /
hybrid / vlm / audio): a decoder backbone made of a repeating pattern of
layer *specs*.  ``pattern`` lists mixer kinds per layer position modulo its
length, e.g. ["ssm"] for mamba2, ["rglru", "rglru", "local_attn"] for
recurrentgemma, ["attn"] for dense.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig"]


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    num_shared: int = 0  # DeepSeek shared experts (always active)
    expert_d_ff: int = 0  # per-expert hidden (0 -> use d_ff)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01  # GLISP-analogue load-balance loss


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128  # N
    head_dim: int = 64  # P
    num_heads: int = 0  # 0 -> d_inner // head_dim
    num_groups: int = 1  # B/C groups (G)
    expand: int = 2  # d_inner = expand * d_model
    chunk: int = 128
    conv_width: int = 4


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    pattern: tuple = ("attn",)  # mixer kinds, cycled over layers
    activation: str = "swiglu"  # swiglu | geglu
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    window: int = 0  # sliding window for "attn" when >0 (SWA)
    local_window: int = 2048  # window for "local_attn" layers
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # MLA (DeepSeek): latent KV compression; 0 disables
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64  # decoupled rope dims under MLA
    # head padding for clean tensor-parallel tiling (set by the launcher per
    # mesh; dead heads are computed and sliced away before the out-projection
    # — same convention as vocab padding).  0 = no padding.
    q_head_pad: int = 0  # pad num_heads (via padded GQA groups) to this
    kv_head_pad: int = 0  # pad num_kv_heads to this
    tp_size: int = 0  # model-axis size the launcher resolved this config for
    # MoE dispatch groups (launcher sets = data-parallel shard count so the
    # dispatch buffers shard with the batch; 1 = single global dispatch)
    moe_dispatch_groups: int = 1
    # mesh axis name(s) the group axis shards over (launcher-set)
    data_axis_names: tuple = ()
    # input modality: "tokens" (LM) or "embeddings" (vlm/audio stubs feed
    # precomputed patch/frame embeddings of shape [B, S, d_model])
    input_mode: str = "tokens"
    tie_embeddings: bool = True
    # long-context decode strategy: "native" (ssm/hybrid/swa) or "window"
    # (dense archs get a windowed-KV decode variant for long_500k) or "skip"
    long_context: str = "window"
    long_context_window: int = 8192
    dtype: str = "bfloat16"
    # citation for the assigned-pool entry
    source: str = ""

    @property
    def padded_vocab_size(self) -> int:
        """Embedding-table size padded to a multiple of 512 so the vocab dim
        shards over any reasonable model axis (standard practice; the logits
        of padded rows are masked to -inf in forward())."""
        return -(-self.vocab_size // 512) * 512

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_q_heads(self) -> int:
        return self.q_head_pad or self.num_heads

    @property
    def padded_kv_heads(self) -> int:
        return self.kv_head_pad or self.num_kv_heads

    def layer_kinds(self) -> list[str]:
        return [self.pattern[i % len(self.pattern)] for i in range(self.num_layers)]

    def num_params(self) -> int:
        """Analytic parameter count (embeddings + per-layer weights)."""
        d, dh = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d  # embedding (tied head)
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for kind in self.layer_kinds():
            total += self._mixer_params(kind)
            total += self._mlp_params(kind)
            total += 2 * d  # norms
        total += d  # final norm
        return total

    def _mixer_params(self, kind: str) -> int:
        d, dh = self.d_model, self.resolved_head_dim
        h, hkv = self.num_heads, self.num_kv_heads
        if kind in ("attn", "local_attn"):
            if self.kv_lora_rank:  # MLA
                r, rd = self.kv_lora_rank, self.rope_head_dim
                return (
                    d * h * (dh + rd)  # q proj (nope+rope parts)
                    + d * (r + rd)  # kv down + shared rope key
                    + r * h * (dh + dh)  # k/v up
                    + h * dh * d  # out
                )
            return d * h * dh + 2 * d * hkv * dh + h * dh * d
        if kind == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nh = s.num_heads or d_in // s.head_dim
            g, n = s.num_groups, s.state_dim
            return (
                d * (2 * d_in + 2 * g * n + nh)  # in_proj (x, z, B, C, dt)
                + s.conv_width * (d_in + 2 * g * n)
                + 2 * nh  # A, D
                + d_in * d  # out
            )
        if kind == "rglru":
            d_in = d  # RG-LRU width = d_model (simplified Griffin block)
            return d * 2 * d_in + 2 * d_in * d_in + d_in + d_in * d
        raise ValueError(kind)

    def _mlp_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "ssm":
            return 0  # mamba blocks carry no separate MLP
        if self.moe is not None and kind != "ssm":
            e = self.moe
            dff = e.expert_d_ff or self.d_ff
            routed = e.num_experts * 3 * d * dff
            shared = e.num_shared * 3 * d * dff
            router = d * e.num_experts
            return routed + shared + router
        if self.activation == "gelu":  # plain 2-proj MLP (gpt-style)
            return 2 * d * self.d_ff
        return 3 * d * self.d_ff  # gated mlp (swiglu/geglu)
