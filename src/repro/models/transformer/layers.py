"""Transformer building blocks: norms, RoPE, attention (GQA/MQA, MLA,
sliding-window, KV cache), gated MLPs.

Attention dispatch:
  * short sequences — dense masked attention (XLA fuses it fine);
  * long sequences (> ``BLOCKWISE_THRESHOLD``) — blockwise online-softmax
    attention in pure jnp via lax.scan over kv blocks (flash-style memory
    footprint, required for the 32k prefill dry-runs);
  * ``use_kernel=True`` — the Pallas flash kernel (real TPU; interpret mode
    on CPU is for validation, not speed).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer.config import ArchConfig

BLOCKWISE_THRESHOLD = 4096
_BLOCK = 1024

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------



def mm(x, w):
    """Matmul with the weight cast to the activation dtype (bf16 compute with
    fp32 master weights — without this every x(bf16)@W(f32) promotes the whole
    activation stream to f32, doubling memory and HLO bytes)."""
    return x @ w.astype(x.dtype)

def dense_init(key, shape, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / shape[0]) ** 0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(
        jnp.float32
    )


def rms_norm(x, w, eps: float = 1e-6):
    """Variance via an f32-accumulating dot (no materialized f32 copy of x —
    a full-tensor x.astype(f32) makes XLA hoist a whole-stack convert of the
    remat-saved residuals out of the backward scan: 12 GiB at 24×16×4096×2048).
    The full-tensor multiply stays in the activation dtype."""
    var = (
        jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)[
            ..., None
        ]
        / x.shape[-1]
    )
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * scale * w.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] absolute positions."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------


def _dense_attention(q, k, v, *, causal, window, q_offset):
    """q: [B, Sq, H, D]; k, v: [B, Skv, Hkv, D] with Hkv | H — grouped-query
    einsums keep the kv tensors in their native head count (no jnp.repeat:
    a materialized repeat makes GSPMD all-gather the REPEATED kv, multiplying
    collective bytes by H/Hkv)."""
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    q5 = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k).astype(jnp.float32) / (d**0.5)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(b, sq, h, v.shape[-1])


def _blockwise_attention(q, k, v, *, causal, window, q_offset):
    """Flash-style online softmax, lax.scan over kv blocks (pure jnp).
    kv stays in native head count (grouped-query einsums); k and v may have
    different head dims (MLA: d_k = dh + rope, d_v = dh)."""
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    dv = v.shape[-1]
    nb = -(-skv // _BLOCK)
    pad = nb * _BLOCK - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, _BLOCK, hkv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, _BLOCK, hkv, dv).transpose(1, 0, 2, 3, 4)
    qf = q.reshape(b, sq, hkv, g, d).astype(jnp.float32) / (d**0.5)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        o, m, l, blk = carry[0], carry[1], carry[2], carry[3]
        kblk, vblk = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kblk.astype(jnp.float32))
        k_pos = blk * _BLOCK + jnp.arange(_BLOCK)
        mask = k_pos[None, :] < skv
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32)
        )
        return (o_new, m_new, l_new, blk + 1), None

    o0 = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    m0 = jnp.full((b, hkv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    (o, m, l, _), _ = jax.lax.scan(step, (o0, m0, l0, jnp.int32(0)), (kb, vb))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv).astype(q.dtype)


def attention_core(q, k, v, *, causal=True, window=0, q_offset=0, use_kernel=False):
    """kv heads are repeated to match q heads before this call."""
    if use_kernel:
        from repro.kernels.ops import mha_attention

        return mha_attention(
            q, k, v, causal=causal, window=window, kv_offset=q_offset
        )
    if k.shape[1] > BLOCKWISE_THRESHOLD:
        return _blockwise_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    return _dense_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)


def _repeat_kv(k, n_rep):
    return jnp.repeat(k, n_rep, axis=2) if n_rep > 1 else k


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig) -> Params:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    h, hkv = cfg.padded_q_heads, cfg.padded_kv_heads
    if cfg.kv_lora_rank:
        return init_mla(key, cfg)
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * dh)),
        "wk": dense_init(ks[1], (d, hkv * dh)),
        "wv": dense_init(ks[2], (d, hkv * dh)),
        "wo": dense_init(ks[3], (cfg.num_heads * dh, d)),  # real heads only
    }


def _decode_attention(q, k_all, v_all, kpos, pos, window):
    """Dense attention with an explicit key-position mask — used in decode
    where the cache may be a rolling window buffer (slot order ≠ position
    order).  q: [B, 1, H, D]; k_all/v_all: [B, L, Hkv, D] (native kv heads);
    kpos: [L] int32 absolute positions (-1 = empty slot)."""
    b, sq, h, d = q.shape
    hkv = k_all.shape[2]
    g = h // hkv
    q5 = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k_all).astype(jnp.float32) / (d**0.5)
    mask = (kpos >= 0) & (kpos <= pos)
    if window > 0:
        mask &= kpos > pos - window
    s = jnp.where(mask[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_all)
    return o.reshape(b, sq, h, v_all.shape[-1])


def _cache_write(cache_tensor, new, pos, rolling_len):
    """Write S new rows at rolling positions (pos..pos+S-1) mod L along axis 1.

    S == 1 (decode): dynamic_update_slice at pos % L.
    S >= L (prefill past a window cache): the last L tokens replace the whole
        buffer, laid out by a roll so slot (p % L) holds position p.
    1 < S < L (prefill into a fresh cache): contiguous write at pos
        (convention: pos + S <= L — chunked prefill stays within capacity)."""
    s = new.shape[1]
    L = rolling_len
    new = new.astype(cache_tensor.dtype)
    if s == 1:
        starts = (0, pos % L) + (0,) * (cache_tensor.ndim - 2)
        return jax.lax.dynamic_update_slice(cache_tensor, new, starts)
    if s >= L:
        pstart = pos + s - L  # absolute position of the oldest surviving token
        if s % L == 0:
            # phase-aligned (every assigned prefill length is a multiple of
            # the window): identity layout keeps the slot = pos % L invariant
            # WITHOUT a roll — jnp.roll with a traced shift forces GSPMD to
            # all-gather the sequence-sharded cache (EXPERIMENTS.md §Perf)
            return new[:, -L:]
        return jnp.roll(new[:, -L:], shift=pstart % L, axis=1)
    starts = (0, pos) + (0,) * (cache_tensor.ndim - 2)
    return jax.lax.dynamic_update_slice(cache_tensor, new, starts)


def _kpos_write(kpos, pos, s, rolling_len):
    L = rolling_len
    if s == 1:
        return jax.lax.dynamic_update_slice(
            kpos, pos + jnp.arange(1, dtype=kpos.dtype), (pos % L,)
        )
    if s >= L:
        pstart = pos + s - L
        if s % L == 0:
            return pstart + jnp.arange(L, dtype=kpos.dtype)
        return jnp.roll(pstart + jnp.arange(L, dtype=kpos.dtype), pstart % L)
    return jax.lax.dynamic_update_slice(
        kpos, pos + jnp.arange(s, dtype=kpos.dtype), (pos,)
    )


def attention_forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, S, d]
    *,
    positions: jax.Array,  # [B, S] absolute positions of x tokens
    cache: Params | None = None,  # {"k","v": [B,L,Hkv,Dh], "kpos": [L]}
    window: int = 0,
    use_kernel: bool = False,
):
    if cfg.kv_lora_rank:
        return mla_forward(
            p, cfg, x, positions=positions, cache=cache, window=window
        )
    b, s, d = x.shape
    dh = cfg.resolved_head_dim
    h, hkv = cfg.padded_q_heads, cfg.padded_kv_heads
    q = (mm(x, p["wq"])).reshape(b, s, h, dh)
    k = (mm(x, p["wk"])).reshape(b, s, hkv, dh)
    v = (mm(x, p["wv"])).reshape(b, s, hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    # GQA mode for full-sequence attention: when neither the kv-head dim nor
    # the group dim tiles the model axis, kv is replicated by the sharding
    # rules and a LOCAL repeat (free on replicated tensors) gives whole-head
    # q sharding with zero attention collectives.  Decode keeps the grouped
    # einsum (cache may be sequence-sharded; scores are tiny).
    tp = cfg.tp_size
    repeat_mode = bool(
        tp and hkv % tp and (h // hkv) % tp and h % tp == 0 and h != hkv
    )

    def maybe_repeat(kk, vv):
        if repeat_mode:
            return _repeat_kv(kk, h // hkv), _repeat_kv(vv, h // hkv)
        return kk, vv

    def project_out(o):
        """Slice away padded (dead) heads, keeping the real GQA grouping:
        padded layout is (hkv_pad, g_pad, dh); real heads live at
        (kv < hkv_real, g < g_real)."""
        h_real, hkv_real = cfg.num_heads, cfg.num_kv_heads
        if h != h_real or hkv != hkv_real:
            g_pad, g_real = h // hkv, h_real // hkv_real
            o5 = o.reshape(b, s, hkv, g_pad, dh)
            o = o5[:, :, :hkv_real, :g_real].reshape(b, s, h_real, dh)
        return (mm(o.reshape(b, s, h_real * dh), p["wo"])).astype(x.dtype)

    if cache is None:  # training: full-sequence causal (+ optional SWA)
        kr, vr = maybe_repeat(k, v)
        o = attention_core(
            q, kr, vr, causal=True, window=window, use_kernel=use_kernel
        )
        return project_out(o), None

    L = cache["k"].shape[1]
    pos = cache["pos"]
    ck = _cache_write(cache["k"], k, pos, L)
    cv = _cache_write(cache["v"], v, pos, L)
    kpos = _kpos_write(cache["kpos"], pos, s, L)
    new_cache = {"k": ck, "v": cv, "kpos": kpos, "pos": pos + s}
    if s > 1:
        # prefill (pos==0 by convention, contiguous cache): attend over the
        # fresh k/v directly — blockwise for long sequences
        kr, vr = maybe_repeat(k, v)
        o = attention_core(
            q, kr, vr, causal=True, window=window, use_kernel=use_kernel
        )
    else:
        o = _decode_attention(q, ck, cv, kpos, pos, window)
    return project_out(o), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig) -> Params:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    h, r, rd = cfg.num_heads, cfg.kv_lora_rank, cfg.rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * (dh + rd))),
        "w_dkv": dense_init(ks[1], (d, r)),
        "w_krope": dense_init(ks[2], (d, rd)),
        "w_uk": dense_init(ks[3], (r, h * dh)),
        "w_uv": dense_init(ks[4], (r, h * dh)),
        "wo": dense_init(ks[5], (h * dh, d)),
    }


def mla_forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: Params | None = None,  # {"ckv": [B,L,r], "krope": [B,L,rd], "kpos"}
    window: int = 0,
):
    b, s, d = x.shape
    h, dh = cfg.num_heads, cfg.resolved_head_dim
    r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    q = (mm(x, p["wq"])).reshape(b, s, h, dh + rd)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    qh = jnp.concatenate([q_nope, q_rope], axis=-1)

    ckv = mm(x, p["w_dkv"])  # [B, S, r]  — this (plus krope) is ALL that's cached
    krope = apply_rope(
        (mm(x, p["w_krope"]))[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0]  # [B, S, rd]

    def expand_kv(ckv_all, krope_all):
        skv = ckv_all.shape[1]
        k_nope = (mm(ckv_all, p["w_uk"])).reshape(b, skv, h, dh)
        v = (mm(ckv_all, p["w_uv"])).reshape(b, skv, h, dh)
        k = jnp.concatenate(
            [
                k_nope,
                jnp.broadcast_to(
                    krope_all[:, :, None, :], (b, skv, h, rd)
                ).astype(k_nope.dtype),
            ],
            axis=-1,
        )
        return k, v

    if cache is None:
        k, v = expand_kv(ckv, krope)
        o = attention_core(qh, k, v, causal=True, window=window)
        return (mm(o.reshape(b, s, h * dh), p["wo"])).astype(x.dtype), None

    L = cache["ckv"].shape[1]
    pos = cache["pos"]
    c_ckv = _cache_write(cache["ckv"], ckv, pos, L)
    c_kr = _cache_write(cache["krope"], krope, pos, L)
    kpos = _kpos_write(cache["kpos"], pos, s, L)
    new_cache = {"ckv": c_ckv, "krope": c_kr, "kpos": kpos, "pos": pos + s}
    if s > 1:  # prefill: attend over fresh kv
        k, v = expand_kv(ckv, krope)
        o = attention_core(qh, k, v, causal=True, window=window)
    else:
        k, v = expand_kv(c_ckv, c_kr)
        o = _decode_attention(qh, k, v, kpos, pos, window)
    return (mm(o.reshape(b, s, h * dh), p["wo"])).astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, activation: str = "swiglu") -> Params:
    ks = jax.random.split(key, 3)
    if activation == "gelu":  # plain 2-proj MLP (gpt-style)
        return {
            "w_up": dense_init(ks[1], (d, d_ff)),
            "w_down": dense_init(ks[2], (d_ff, d)),
        }
    return {
        "w_gate": dense_init(ks[0], (d, d_ff)),
        "w_up": dense_init(ks[1], (d, d_ff)),
        "w_down": dense_init(ks[2], (d_ff, d)),
    }


def mlp_forward(p: Params, x: jax.Array, activation: str = "swiglu") -> jax.Array:
    if activation == "gelu":
        return (mm(jax.nn.gelu(mm(x, p["w_up"])), p["w_down"])).astype(x.dtype)
    gate = mm(x, p["w_gate"])
    act = jax.nn.silu(gate) if activation == "swiglu" else jax.nn.gelu(gate)
    return (mm(act * mm(x, p["w_up"]), p["w_down"])).astype(x.dtype)
