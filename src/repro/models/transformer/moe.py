"""Mixture-of-Experts with top-k routing and capacity-based dispatch.

This is GLISP's hotspot-load-balancing idea on-device (DESIGN.md §4): the
router's auxiliary loss plays the role AdaDNE's soft balance constraint plays
for graph partitions — work (tokens) must spread evenly over servers
(experts).  Dispatch is GShard/Switch-style with a capacity factor: per
expert at most C = ceil(T·k/E · cf) tokens; overflow tokens fall through on
the residual path.

Sharding intent (configs pick one):
  expert-parallel — experts sharded over the "model" mesh axis (DeepSeek:
      64 routed experts / 16 = 4 per device), dispatch becomes all-to-all;
  tensor-parallel — expert FFN hidden dim sharded over "model" (Mixtral:
      8 experts can't split 16 ways, but d_ff 14336/16 = 896 can).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer.config import ArchConfig, MoEConfig
from repro.models.transformer.layers import Params, dense_init

__all__ = ["init_moe", "moe_forward"]


def init_moe(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    e: MoEConfig = cfg.moe
    dff = e.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e.num_experts), scale=0.02),
        "w_gate": dense_init(ks[1], (e.num_experts, d, dff)),
        "w_up": dense_init(ks[2], (e.num_experts, d, dff)),
        "w_down": dense_init(ks[3], (e.num_experts, dff, d)),
    }
    if e.num_shared:
        sks = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sks[0], (d, e.num_shared * dff)),
            "w_up": dense_init(sks[1], (d, e.num_shared * dff)),
            "w_down": dense_init(sks[2], (e.num_shared * dff, d)),
        }
    return p


def moe_forward(
    p: Params, cfg: ArchConfig, x: jax.Array, activation: str = "swiglu"
) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y, aux_loss).

    Dispatch runs in ``cfg.moe_dispatch_groups`` independent token groups
    (the launcher sets it to the data-parallel shard count): routing,
    capacity and the dispatch buffers all carry a leading group axis that
    GSPMD shards with the batch — without it the [E, C_global, d] dispatch
    buffer is REPLICATED per device and all-reduced every layer (the 10 TB/
    step pathology of the baseline; EXPERIMENTS.md §Perf)."""
    e: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    G = max(1, getattr(cfg, "moe_dispatch_groups", 1))
    if t % G:
        G = 1
    tg = t // G
    xt = x.reshape(G, tg, d)
    act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu

    logits = jnp.einsum(
        "gtd,de->gte", xt, p["router"].astype(xt.dtype)
    ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [G, Tg, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, e.top_k)  # [G, Tg, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # ---- load-balance auxiliary loss (Switch-style, per group) ------------
    me = probs.mean(axis=1)  # [G, E]
    ce = jax.nn.one_hot(gate_idx[..., 0], e.num_experts).mean(axis=1)
    aux = (me * ce).sum(-1).mean() * e.num_experts * e.aux_loss_weight

    # ---- capacity dispatch (within each group) -----------------------------
    cap = max(1, int(tg * e.top_k / e.num_experts * e.capacity_factor))
    flat_idx = gate_idx.reshape(G, tg * e.top_k)  # expert of each slot
    slot_onehot = jax.nn.one_hot(flat_idx, e.num_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(slot_onehot, axis=1) * slot_onehot - 1).max(-1)  # [G, Tk]
    keep = pos < cap
    tok_of_slot = jnp.repeat(jnp.arange(tg), e.top_k)  # same for every group
    gate_of_slot = gate_vals.reshape(G, tg * e.top_k)
    gidx = jnp.arange(G)[:, None]

    def shard_g(t, expert_dim: bool = False):
        """Pin the group axis to the data mesh axes — the scatter-built
        dispatch buffer otherwise stays REPLICATED under GSPMD.  For
        expert-parallel archs (E % tp == 0, e.g. DeepSeek 64/16) the expert
        dim is co-sharded over "model" so the dispatch einsum is the
        all-to-all, not a resharding fight against the constraint."""
        if G > 1 and cfg.data_axis_names:
            from jax.sharding import PartitionSpec as _P

            ep = (
                expert_dim
                and cfg.tp_size
                and e.num_experts % cfg.tp_size == 0
            )
            dims = ["model" if (ep and i == 1) else None for i in range(1, t.ndim)]
            spec = _P(cfg.data_axis_names, *dims)
            return jax.lax.with_sharding_constraint(t, spec)
        return t

    xe = jnp.zeros((G, e.num_experts, cap, d), dtype=x.dtype)
    xe = xe.at[gidx, flat_idx, jnp.clip(pos, 0, cap - 1)].add(
        jnp.where(keep[..., None], xt[:, tok_of_slot], 0).astype(x.dtype)
    )
    xe = shard_g(xe, expert_dim=True)
    # expert FFN (batched einsum over groups × experts)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(xe.dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(xe.dtype))
    ye = jnp.einsum(
        "gecf,efd->gecd", act(h) * u, p["w_down"].astype(xe.dtype)
    )  # [G, E, C, d]
    # combine back to tokens
    y_slots = ye[gidx, flat_idx, jnp.clip(pos, 0, cap - 1)]  # [G, Tk, d]
    y_slots = jnp.where(keep[..., None], y_slots, 0) * gate_of_slot[
        ..., None
    ].astype(x.dtype)
    yt = jax.vmap(
        lambda ys: jax.ops.segment_sum(ys, tok_of_slot, num_segments=tg)
    )(y_slots)
    yt = shard_g(yt)  # reduce at token granularity, not dispatch-slot

    if e.num_shared:
        sp = p["shared"]
        yt = yt + (
            act(xt @ sp["w_gate"].astype(xt.dtype))
            * (xt @ sp["w_up"].astype(xt.dtype))
        ) @ sp["w_down"].astype(xt.dtype)
    return yt.reshape(b, s, d).astype(x.dtype), aux
