"""Decoder backbone assembly: embedding → scanned layer stages → norm → head.

Layers repeat in ``cfg.pattern`` periods; consecutive periods share a
``lax.scan`` over stacked parameters (one period of HLO per stage regardless
of depth — essential for 52/60-layer dry-run compile times).  A trailing
partial period becomes its own stage.

Three entry points share one forward:
    ``forward(params, cfg, inputs)``                      — training
    ``forward(params, cfg, inputs, cache, pos)``          — prefill (S>1)
    ``forward(params, cfg, inputs, cache, pos)``          — decode (S=1)

``inputs`` is int32 tokens [B, S] for LM archs or precomputed embeddings
[B, S, d] for the vlm/audio stubs (cfg.input_mode == "embeddings").
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer.config import ArchConfig
from repro.models.transformer.layers import (
    Params,
    attention_forward,
    dense_init,
    init_attention,
    init_mlp,
    mlp_forward,
    rms_norm,
)
from repro.models.transformer.moe import init_moe, moe_forward
from repro.models.transformer.ssm import (
    init_mamba2,
    init_rglru,
    mamba2_forward,
    rglru_forward,
)

__all__ = [
    "stage_plan",
    "init_params",
    "init_cache",
    "forward",
    "lm_loss",
    "param_count",
]


# ---------------------------------------------------------------------------
# plan & init
# ---------------------------------------------------------------------------


def stage_plan(cfg: ArchConfig) -> list[tuple[tuple[str, ...], int]]:
    period = len(cfg.pattern)
    reps, rem = divmod(cfg.num_layers, period)
    stages: list[tuple[tuple[str, ...], int]] = []
    if reps:
        stages.append((tuple(cfg.pattern), reps))
    if rem:
        stages.append((tuple(cfg.pattern[:rem]), 1))
    return stages


def _has_mlp(cfg: ArchConfig, kind: str) -> bool:
    return kind != "ssm"  # mamba blocks carry their own gating, no MLP


def _init_layer(key, cfg: ArchConfig, kind: str) -> Params:
    kmix, kmlp = jax.random.split(key)
    p: Params = {"norm1": jnp.ones((cfg.d_model,), jnp.float32)}
    if kind in ("attn", "local_attn"):
        p["mixer"] = init_attention(kmix, cfg)
    elif kind == "ssm":
        p["mixer"] = init_mamba2(kmix, cfg)
    elif kind == "rglru":
        p["mixer"] = init_rglru(kmix, cfg)
    else:
        raise ValueError(kind)
    if _has_mlp(cfg, kind):
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["mlp"] = init_moe(kmlp, cfg) if cfg.moe is not None else init_mlp(
            kmlp, cfg.d_model, cfg.d_ff, cfg.activation
        )
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    keys = jax.random.split(key, len(stage_plan(cfg)) + 2)
    params: Params = {
        "embed": dense_init(keys[0], (cfg.padded_vocab_size, cfg.d_model), scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "stages": [],
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], (cfg.d_model, cfg.padded_vocab_size))
    for si, (kinds, reps) in enumerate(stage_plan(cfg)):
        skey = keys[si + 2]
        stacked = []
        for ki, kind in enumerate(kinds):
            lkeys = jax.random.split(jax.random.fold_in(skey, ki), reps)
            layers = [_init_layer(lk, cfg, kind) for lk in lkeys]
            stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layers))
        params["stages"].append(stacked)
    return params


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def _init_layer_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    if kind in ("attn", "local_attn"):
        if cfg.kv_lora_rank:
            return {
                "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
                "kpos": jnp.full((max_len,), -1, jnp.int32),
                "pos": jnp.int32(0),
            }
        dh = cfg.resolved_head_dim
        return {
            "k": jnp.zeros((batch, max_len, cfg.padded_kv_heads, dh), dtype),
            "v": jnp.zeros((batch, max_len, cfg.padded_kv_heads, dh), dtype),
            "kpos": jnp.full((max_len,), -1, jnp.int32),
            "pos": jnp.int32(0),
        }
    if kind == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = s.num_heads or d_in // s.head_dim
        return {
            "state": jnp.zeros((batch, nh, s.head_dim, s.state_dim), jnp.float32),
            "conv": jnp.zeros(
                (batch, s.conv_width - 1, d_in + 2 * s.num_groups * s.state_dim),
                dtype,
            ),
            "pos": jnp.int32(0),
        }
    if kind == "rglru":
        return {
            "state": jnp.zeros((batch, cfg.d_model), jnp.float32),
            "conv": jnp.zeros((batch, 3, cfg.d_model), dtype),
            "pos": jnp.int32(0),
        }
    raise ValueError(kind)


def cache_len_for(cfg: ArchConfig, kind: str, seq_len: int) -> int:
    """Cache capacity per attention kind: local windows cap it; the
    long-context window variant caps full attention too."""
    if kind == "local_attn":
        return min(seq_len, cfg.local_window)
    if kind == "attn":
        if cfg.window > 0:
            return min(seq_len, cfg.window)
        return seq_len
    return 1  # ssm/rglru keep O(1) state; length unused


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    caches = []
    for kinds, reps in stage_plan(cfg):
        stage_caches = []
        for kind in kinds:
            one = _init_layer_cache(cfg, kind, batch, cache_len_for(cfg, kind, max_len))
            stage_caches.append(
                jax.tree.map(lambda x: jnp.stack([x] * reps), one)
                if reps > 1
                else jax.tree.map(lambda x: x[None], one)
            )
        caches.append(stage_caches)
    return caches


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _layer_forward(
    lp: Params,
    cfg: ArchConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    cache: Params | None,
    use_kernel: bool = False,
):
    aux = jnp.float32(0.0)
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if kind in ("attn", "local_attn"):
        window = cfg.local_window if kind == "local_attn" else cfg.window
        y, new_cache = attention_forward(
            lp["mixer"],
            cfg,
            h,
            positions=positions,
            cache=cache,
            window=window,
            use_kernel=use_kernel,
        )
    elif kind == "ssm":
        y, new_cache = mamba2_forward(lp["mixer"], cfg, h, cache=cache)
    elif kind == "rglru":
        y, new_cache = rglru_forward(lp["mixer"], cfg, h, cache=cache)
    else:
        raise ValueError(kind)
    x = (x + y).astype(x.dtype)
    if _has_mlp(cfg, kind):
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.moe is not None:
            y, aux = moe_forward(lp["mlp"], cfg, h, cfg.activation)
        else:
            y = mlp_forward(lp["mlp"], h, cfg.activation)
        x = (x + y).astype(x.dtype)
    return x, new_cache, aux


def _stage_forward(
    sp: list,
    cfg: ArchConfig,
    kinds: tuple[str, ...],
    x: jax.Array,
    positions: jax.Array,
    caches: list | None,
    remat: bool,
    use_kernel: bool,
    unroll: bool = False,
):
    reps = jax.tree.leaves(sp[0])[0].shape[0]

    def body(carry, xs):
        h, aux = carry
        layer_params, layer_caches = xs
        new_caches = []
        for ki, kind in enumerate(kinds):
            lc = None if layer_caches is None else layer_caches[ki]
            h, nc, a = _layer_forward(
                layer_params[ki], cfg, kind, h, positions, lc, use_kernel
            )
            new_caches.append(nc)
        return (h, aux + a), (new_caches if caches is not None else 0)

    fn = jax.checkpoint(body) if remat else body
    if reps == 1:
        # avoid scan overhead for singleton stages
        lp = [jax.tree.map(lambda t: t[0], p) for p in sp]
        lc = (
            None
            if caches is None
            else [jax.tree.map(lambda t: t[0], c) for c in caches]
        )
        (x, aux), ys = fn((x, jnp.float32(0.0)), (lp, lc))
        new_caches = (
            None
            if caches is None
            else [jax.tree.map(lambda t: t[None], c) for c in ys]
        )
        return x, aux, new_caches
    xs = (sp, caches if caches is not None else None)
    (x, aux), ys = jax.lax.scan(
        fn, (x, jnp.float32(0.0)), xs, unroll=reps if unroll else 1
    )
    new_caches = ys if caches is not None else None
    return x, aux, new_caches


def forward(
    params: Params,
    cfg: ArchConfig,
    inputs: jax.Array,
    cache: list | None = None,
    pos: jax.Array | int = 0,
    *,
    remat: bool = False,
    use_kernel: bool = False,
    last_only: bool = False,
    unroll: bool = False,
):
    """Returns (logits [B, S, V] (or [B, 1, V] if last_only), aux, new_cache)."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.input_mode == "tokens":
        x = params["embed"][inputs].astype(dtype)
    else:
        x = inputs.astype(dtype)
    b, s = x.shape[0], x.shape[1]
    positions = (jnp.asarray(pos) + jnp.arange(s))[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (b, s))

    aux_total = jnp.float32(0.0)
    new_caches = [] if cache is not None else None
    for si, (kinds, reps) in enumerate(stage_plan(cfg)):
        st_cache = None if cache is None else cache[si]
        x, aux, nc = _stage_forward(
            params["stages"][si],
            cfg,
            kinds,
            x,
            positions,
            st_cache,
            remat,
            use_kernel,
            unroll,
        )
        aux_total += aux
        if new_caches is not None:
            new_caches.append(nc)
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["head"]
    )
    logits = (x @ head.astype(dtype)).astype(jnp.float32)
    if cfg.padded_vocab_size != cfg.vocab_size:  # mask padded vocab rows
        logits = jnp.where(
            jnp.arange(cfg.padded_vocab_size) < cfg.vocab_size, logits, -1e30
        )
    return logits, aux_total, new_caches


def lm_loss(
    params: Params,
    cfg: ArchConfig,
    inputs: jax.Array,
    targets: jax.Array,
    *,
    remat: bool = True,
    z_loss: float = 1e-4,
    unroll: bool = False,
):
    logits, aux, _ = forward(params, cfg, inputs, remat=remat, unroll=unroll)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - tgt_logit).mean()
    return nll + aux + z_loss * jnp.square(logz).mean(), (nll, aux)
