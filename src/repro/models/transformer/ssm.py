"""State-space & linear-recurrence mixers: Mamba-2 (SSD) and RG-LRU (Griffin/
RecurrentGemma).

Both expose (train/prefill) full-sequence forward plus an O(1)-state decode
step — these are the natively sub-quadratic paths used by long_500k.

The SSD train path is the chunked block decomposition (pure-jnp mirror of
kernels/ssd_scan.py, lax.scan over chunks with MXU-friendly intra-chunk
matmuls).  RG-LRU uses an associative scan (log-depth on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer.config import ArchConfig, SSMConfig
from repro.models.transformer.layers import Params, dense_init, mm

__all__ = [
    "init_mamba2",
    "mamba2_forward",
    "init_rglru",
    "rglru_forward",
    "ssd_chunked_jnp",
]


# ---------------------------------------------------------------------------
# chunked SSD (jnp mirror of the Pallas kernel)
# ---------------------------------------------------------------------------


def ssd_chunked_jnp(x, a, dt, B, C, *, chunk: int = 128, init_state=None):
    """x: [Bz, S, H, P]; a, dt: [Bz, S, H]; B, C: [Bz, S, G, N] in GROUP form
    (G divides H) — the head expansion happens per chunk inside the scan step
    so no [Bz, S, H, N] materialization.  Returns (y, final_state[Bz,H,P,N])."""
    bz, S, H, P = x.shape
    G, N = B.shape[-2], B.shape[-1]
    reps = H // G
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // chunk
    # reshape to chunks, move chunk axis first for scan
    def to_chunks(t):
        return t.reshape((bz, nc, chunk) + t.shape[2:]).swapaxes(0, 1)

    xc, ac, dtc, Bc, Cc = map(to_chunks, (x, a, dt, B, C))

    def step(state, inp):
        xk, ak, dk, bk, ck = inp  # [Bz, L, H, ...]; bk/ck [Bz, L, G, N]
        bk = jnp.repeat(bk, reps, axis=2)  # -> [Bz, L, H, N] (chunk-local)
        ck = jnp.repeat(ck, reps, axis=2)
        ak = ak.astype(jnp.float32)
        csum = jnp.cumsum(ak, axis=1)  # [Bz, L, H]
        cb = jnp.einsum("blhn,bmhn->bhlm", ck.astype(jnp.float32), bk.astype(jnp.float32))
        seg = csum[:, :, None] - csum[:, None, :]  # [Bz, L, L, H]
        ii = jnp.arange(xk.shape[1])
        causal = ii[:, None] >= ii[None, :]
        decay = jnp.where(causal[None, :, :, None], jnp.exp(jnp.where(causal[None, :, :, None], seg, 0.0)), 0.0)
        m = cb * decay.transpose(0, 3, 1, 2) * dk.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        y = jnp.einsum("bhlm,bmhp->blhp", m, xk.astype(jnp.float32))
        # inter-chunk
        y += jnp.exp(csum)[..., None] * jnp.einsum(
            "blhn,bhpn->blhp", ck.astype(jnp.float32), state
        )
        # state update
        w = jnp.exp(csum[:, -1:, :] - csum) * dk.astype(jnp.float32)  # [Bz, L, H]
        state = jnp.exp(csum[:, -1])[:, :, None, None] * state + jnp.einsum(
            "blhp,blhn->bhpn", xk.astype(jnp.float32) * w[..., None], bk.astype(jnp.float32)
        )
        return state, y

    if init_state is None:
        init_state = jnp.zeros((bz, H, P, N), jnp.float32)
    state, yc = jax.lax.scan(step, init_state, (xc, ac, dtc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(bz, S + pad, H, P)[:, :S]
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    s: SSMConfig = cfg.ssm
    d_in = s.expand * d
    nh = s.num_heads or d_in // s.head_dim
    g, n = s.num_groups, s.state_dim
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * g * n + nh)),
        "conv": dense_init(ks[1], (s.conv_width, d_in + 2 * g * n), scale=0.2),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_in, d)),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv1d.  x: [B, S, C]; w: [W, C].
    state: [B, W-1, C] trailing context (decode).  Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        # glint: disable=JAX004 -- conv kernel width is an architecture
        # constant (weight shape), not a data-dependent length
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1) :] if width > 1 else None
    return y.astype(x.dtype), new_state


def mamba2_forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, S, d]
    *,
    cache: Params | None = None,  # {"state": [B,H,P,N], "conv": [B,W-1,C]}
):
    s: SSMConfig = cfg.ssm
    b, S, d = x.shape
    d_in = s.expand * d
    nh = s.num_heads or d_in // s.head_dim
    g, n, ph = s.num_groups, s.state_dim, s.head_dim

    zxbcdt = mm(x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * g * n], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(jax.nn.silu(xbc), p["conv"], conv_state)
    xin, B_, C_ = jnp.split(xbc, [d_in, d_in + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,nh]
    A = -jnp.exp(p["A_log"])  # [nh]
    a = dt * A  # [B,S,nh] <= 0

    xh = xin.reshape(b, S, nh, ph)
    Bg = B_.reshape(b, S, g, n)
    Cg = C_.reshape(b, S, g, n)

    init_state = cache["state"] if cache is not None else None
    if S == 1 and cache is not None:
        # decode: one recurrence step, no chunking
        Bh = jnp.repeat(Bg[:, 0], nh // g, axis=1)  # [B, nh, n]
        Ch = jnp.repeat(Cg[:, 0], nh // g, axis=1)
        st = init_state
        dec = jnp.exp(a[:, 0]).astype(jnp.float32)  # [B, nh]
        st = st * dec[..., None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn",
            xh[:, 0].astype(jnp.float32),
            Bh.astype(jnp.float32),
            dt[:, 0],
        )
        y = jnp.einsum("bhpn,bhn->bhp", st, Ch.astype(jnp.float32))[:, None]
        state = st
    else:
        y, state = ssd_chunked_jnp(xh, a, dt, Bg, Cg, chunk=s.chunk, init_state=init_state)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, S, d_in).astype(x.dtype)
    # gated RMSNorm then out
    yz = y * jax.nn.silu(z)
    var = (
        jnp.einsum("...d,...d->...", yz, yz, preferred_element_type=jnp.float32)[
            ..., None
        ]
        / yz.shape[-1]
    )
    yz = yz * jax.lax.rsqrt(var + 1e-6).astype(yz.dtype) * p["norm_w"].astype(yz.dtype)
    out = mm(yz, p["out_proj"]).astype(x.dtype)
    new_cache = (
        {"state": state, "conv": new_conv, "pos": cache["pos"] + S}
        if cache is not None
        else None
    )
    return out, new_cache


# ---------------------------------------------------------------------------
# RG-LRU block (RecurrentGemma / Griffin recurrent block)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d)),  # u branch + gate branch
        "conv": dense_init(ks[1], (4, d), scale=0.2),
        "w_ig": dense_init(ks[2], (d, d)),  # input gate
        "w_rg": dense_init(ks[3], (d, d)),  # recurrence gate
        "lam": jnp.full((d,), 2.2, jnp.float32),  # softplus^-1-ish init
        "out_proj": dense_init(ks[4], (d, d)),
    }


def rglru_forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, S, d]
    *,
    cache: Params | None = None,  # {"state": [B,d], "conv": [B,3,d]}
):
    b, S, d = x.shape
    ug = mm(x, p["in_proj"])
    u, gate = jnp.split(ug, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, p["conv"], conv_state)

    i_g = jax.nn.sigmoid(mm(u, p["w_ig"])).astype(jnp.float32)
    r_g = jax.nn.sigmoid(mm(u, p["w_rg"])).astype(jnp.float32)
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r_g  # [B,S,d] <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9, 1.0))
    bterm = beta * (i_g * u.astype(jnp.float32))

    if S == 1 and cache is not None:
        h = a[:, 0] * cache["state"] + bterm[:, 0]
        hs = h[:, None]
        state = h
    else:
        init = (
            cache["state"]
            if cache is not None
            else jnp.zeros((b, d), jnp.float32)
        )
        # first-order linear recurrence via associative scan (log-depth)
        # h_t = a_t * h_{t-1} + b_t ; fold the init into b_1
        b0 = bterm.at[:, 0].add(a[:, 0] * init)

        def op(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        a_sc, h_sc = jax.lax.associative_scan(op, (a, b0), axis=1)
        hs = h_sc
        state = h_sc[:, -1]
    y = hs.astype(x.dtype) * jax.nn.gelu(gate)
    out = mm(y, p["out_proj"]).astype(x.dtype)
    new_cache = (
        {"state": state, "conv": new_conv, "pos": cache["pos"] + S}
        if cache is not None
        else None
    )
    return out, new_cache
