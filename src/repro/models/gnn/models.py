"""GNN models in JAX: GCN, GraphSAGE, GAT, HGT — the models GLISP evaluates
(paper Table IV trains all on 3 stacked layers, hidden 256, GAT 4 heads;
the RelNet KGE encoder is a 2-layer HGT).

All layers aggregate over padded edge lists (dst_pos, src_pos, etype) with
-1 padding; the segment-sum hotspot goes through kernels.gnn_aggregate
(Pallas on TPU, jnp oracle otherwise).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.ops import (
    gather_spmm_ref,
    gnn_aggregate,
    gnn_gat_aggregate,
    gnn_gather_aggregate,
    gnn_segment_max,
    segment_spmm_ref,
)

GNN_KINDS = ("gcn", "sage", "gat", "hgt")

Params = dict[str, Any]


def _seg_sum(msg, seg, n, use_kernel):
    if use_kernel:
        return gnn_aggregate(msg, seg, n)
    return segment_spmm_ref(msg, seg, n)


def _gather_seg_sum(h, idx, seg, n, use_kernel):
    """out[s] = sum_{seg[e]==s} h[idx[e]] — fused gather+aggregate when the
    kernel is on (no [E, D] message array), masked jnp gather otherwise."""
    if use_kernel:
        return gnn_gather_aggregate(h, idx, seg, n)
    return gather_spmm_ref(h, idx, seg, n)


def _seg_count(seg, n, use_kernel=False):
    ones = (seg >= 0).astype(jnp.float32)[:, None]
    return _seg_sum(ones, seg, n, use_kernel)  # [n,1]


def _seg_softmax(logits, seg, n, use_kernel=False):
    """Softmax over edges grouped by seg (padding seg=-1 excluded)."""
    if use_kernel:
        mx = gnn_segment_max(logits, seg, n)
    else:
        neg = jnp.where(seg >= 0, logits, -jnp.inf)
        mx = jax.ops.segment_max(neg, jnp.maximum(seg, 0), num_segments=n)
        mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    e = jnp.where(seg >= 0, jnp.exp(logits - mx[jnp.maximum(seg, 0)]), 0.0)
    z = _seg_sum(e[:, None], seg, n, use_kernel)[:, 0]
    return e / jnp.maximum(z[jnp.maximum(seg, 0)], 1e-9)


def _seg_softmax_aggregate(logits, msg, seg, n, use_kernel):
    """out[s] = sum_e softmax_{seg==s}(logits)[e] * msg[e] — the GAT/HGT
    per-head inner loop.  One Pallas kernel when enabled, the original
    3-pass ``_seg_softmax`` + ``_seg_sum`` otherwise."""
    if use_kernel:
        return gnn_gat_aggregate(logits, msg, seg, n)
    alpha = _seg_softmax(logits, seg, n, use_kernel)
    return _seg_sum(msg * alpha[:, None], seg, n, use_kernel)


class GNNModel:
    def __init__(
        self,
        kind: str,
        in_dim: int,
        hidden: int = 256,
        num_layers: int = 3,
        num_classes: int = 16,
        num_heads: int = 4,
        num_etypes: int = 4,
        use_kernel: bool = False,
    ):
        assert kind in GNN_KINDS
        self.kind = kind
        self.in_dim = in_dim
        self.hidden = hidden
        self.num_layers = num_layers
        self.num_classes = num_classes
        self.num_heads = num_heads
        self.num_etypes = num_etypes
        self.use_kernel = use_kernel

    # -- init ---------------------------------------------------------------
    def init(self, key) -> Params:
        dims = [self.in_dim] + [self.hidden] * self.num_layers
        layers = []
        for k in range(self.num_layers):
            lk = jax.random.fold_in(key, k)
            din, dout = dims[k], dims[k + 1]
            scale = (1.0 / din) ** 0.5
            if self.kind == "gcn":
                p = {"w": jax.random.normal(lk, (din, dout)) * scale,
                     "b": jnp.zeros((dout,))}
            elif self.kind == "sage":
                p = {"w": jax.random.normal(lk, (2 * din, dout)) * scale,
                     "b": jnp.zeros((dout,))}
            elif self.kind == "gat":
                h = self.num_heads
                dh = dout // h
                k1, k2, k3 = jax.random.split(lk, 3)
                p = {
                    "w": jax.random.normal(k1, (din, h * dh)) * scale,
                    "a_dst": jax.random.normal(k2, (h, dh)) * 0.1,
                    "a_src": jax.random.normal(k3, (h, dh)) * 0.1,
                }
            elif self.kind == "hgt":
                h, e = self.num_heads, self.num_etypes
                dh = dout // h
                k1, k2, k3, k4, k5 = jax.random.split(lk, 5)
                p = {
                    "wq": jax.random.normal(k1, (din, h * dh)) * scale,
                    "wk": jax.random.normal(k2, (e, din, h * dh)) * scale,
                    "wv": jax.random.normal(k3, (e, din, h * dh)) * scale,
                    "wo": jax.random.normal(k4, (h * dh, dout)) * scale,
                    "wskip": jax.random.normal(k5, (din, dout)) * scale,
                }
            layers.append(p)
        ko = jax.random.fold_in(key, 999)
        return {
            "layers": layers,
            "out": jax.random.normal(ko, (self.hidden, self.num_classes))
            * (1.0 / self.hidden) ** 0.5,
        }

    # -- single layer ---------------------------------------------------------
    def layer(
        self, p: Params, k: int, h: jax.Array, dst, src, etype, cnt=None
    ) -> jax.Array:
        """``cnt`` is the optional precomputed in-degree column ([n, 1],
        valid-edge counts per destination) — static per batch, so callers
        with a ``GNNBatch.layer_cnt`` pass it instead of recomputing the
        segment-count here on every layer call."""
        n = h.shape[0]
        ok = src >= 0
        if self.kind in ("gcn", "sage"):
            # fused path gathers h[src] inside the kernel's edge tiles
            agg = _gather_seg_sum(h, src, dst, n, self.use_kernel)
            if cnt is None:
                cnt = _seg_count(dst, n, self.use_kernel)
            if self.kind == "gcn":
                return jax.nn.relu(((agg + h) / (cnt + 1.0)) @ p["w"] + p["b"])
            return jax.nn.relu(
                jnp.concatenate([h, agg / jnp.maximum(cnt, 1.0)], axis=1) @ p["w"]
                + p["b"]
            )
        if self.kind == "gat":
            heads, dh = p["a_dst"].shape
            z = (h @ p["w"]).reshape(n, heads, dh)
            zsrc = jnp.where(ok[:, None, None], z[jnp.maximum(src, 0)], 0.0)
            zdst = jnp.where((dst >= 0)[:, None, None], z[jnp.maximum(dst, 0)], 0.0)
            e = jax.nn.leaky_relu(
                (zdst * p["a_dst"]).sum(-1) + (zsrc * p["a_src"]).sum(-1), 0.2
            )  # [E, H]
            out = []
            for hd in range(heads):  # few heads; keeps segment ops 2-D
                out.append(
                    _seg_softmax_aggregate(
                        e[:, hd], zsrc[:, hd], dst, n, self.use_kernel
                    )
                )
            return jax.nn.elu(jnp.concatenate(out, axis=1))
        if self.kind == "hgt":
            heads = self.num_heads
            dout = p["wo"].shape[0] // heads
            q = (h @ p["wq"]).reshape(n, heads, dout)
            wk = p["wk"][etype]  # [E, din, h*dh]
            wv = p["wv"][etype]
            ke = jnp.einsum("ed,edf->ef", h[jnp.maximum(src, 0)], wk).reshape(
                -1, heads, dout
            )
            ve = jnp.einsum("ed,edf->ef", h[jnp.maximum(src, 0)], wv).reshape(
                -1, heads, dout
            )
            qd = q[jnp.maximum(dst, 0)]
            att = (qd * ke).sum(-1) / (dout**0.5)  # [E, H]
            out = []
            for hd in range(heads):
                msg = jnp.where(ok[:, None], ve[:, hd], 0.0)
                out.append(
                    _seg_softmax_aggregate(att[:, hd], msg, dst, n, self.use_kernel)
                )
            agg = jnp.concatenate(out, axis=1) @ p["wo"]
            return jax.nn.gelu(agg + h @ p["wskip"])
        raise ValueError(self.kind)

    # -- full apply --------------------------------------------------------------
    def apply(self, params: Params, batch) -> jax.Array:
        """batch: GNNBatch (feats/valid/layer_* as jnp arrays).  When the
        batch carries precomputed per-layer degree columns (``layer_cnt``,
        built host-side in ``subgraph_to_batch``), GCN/SAGE skip the
        per-layer segment-count entirely."""
        h = batch.feats
        cnts = getattr(batch, "layer_cnt", None)
        for k in range(self.num_layers):
            h = self.layer(
                params["layers"][k],
                k,
                h,
                batch.layer_dst[k],
                batch.layer_src[k],
                batch.layer_etype[k],
                cnt=None if cnts is None else cnts[k],
            )
            h = h * batch.valid[:, None]
        return h[batch.seed_pos] @ params["out"]

    def loss(self, params: Params, batch) -> jax.Array:
        logits = self.apply(params, batch)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, batch.labels[:, None], axis=-1)[:, 0]
        return (logz - tgt).mean()

    def embed_layer_fn(self, params: Params, k: int, *, use_kernel: bool | None = None):
        """Adapter for the layerwise inference engine: one slice of the model
        as (k, h_self, h_nbr, seg[, etype]) -> h_new (numpy in/out).

        The returned callable carries two engine-facing attributes:

        * ``fn.jax(h_self, h_nbr, seg, etype, *, use_kernel=...)`` — the pure
          traceable slice on jnp arrays with ``seg == -1`` padding, which the
          bucketed engine wraps in ``jax.jit`` so each (layer, shape-bucket)
          pair compiles once and stays device-resident.
        * ``fn.needs_etype`` — True for hgt, whose per-edge relation
          projections need the sampled edges' type ids.

        Covers all four evaluated kinds (gcn/sage/gat/hgt); aggregation goes
        through :func:`repro.kernels.ops.gnn_aggregate` when ``use_kernel``
        (defaulting to the model's flag) is set."""
        p = params["layers"][k]
        kind = self.kind
        heads = self.num_heads
        default_kernel = self.use_kernel if use_kernel is None else use_kernel

        def jax_fn(h_self, h_nbr, seg, etype, *, use_kernel=default_kernel):
            n = h_self.shape[0]
            seg = seg.astype(jnp.int32)
            ok = seg >= 0
            if kind == "gcn":
                agg = _seg_sum(h_nbr, seg, n, use_kernel)
                cnt = _seg_count(seg, n, use_kernel) + 1.0
                return jax.nn.relu(((agg + h_self) / cnt) @ p["w"] + p["b"])
            if kind == "sage":
                agg = _seg_sum(h_nbr, seg, n, use_kernel)
                cnt = jnp.maximum(_seg_count(seg, n, use_kernel), 1.0)
                return jax.nn.relu(
                    jnp.concatenate([h_self, agg / cnt], axis=1) @ p["w"] + p["b"]
                )
            if kind == "gat":
                hh, dh = p["a_dst"].shape
                z = (h_self @ p["w"]).reshape(n, hh, dh)
                zsrc = (h_nbr @ p["w"]).reshape(-1, hh, dh)
                zsrc = jnp.where(ok[:, None, None], zsrc, 0.0)
                zdst = z[jnp.maximum(seg, 0)]
                e = jax.nn.leaky_relu(
                    (zdst * p["a_dst"]).sum(-1) + (zsrc * p["a_src"]).sum(-1), 0.2
                )  # [E, H]
                out = []
                for hd in range(hh):
                    out.append(
                        _seg_softmax_aggregate(e[:, hd], zsrc[:, hd], seg, n, use_kernel)
                    )
                return jax.nn.elu(jnp.concatenate(out, axis=1))
            if kind == "hgt":
                dout = p["wo"].shape[0] // heads
                q = (h_self @ p["wq"]).reshape(n, heads, dout)
                et = jnp.maximum(etype.astype(jnp.int32), 0)
                wk = p["wk"][et]  # [E, din, h*dh]
                wv = p["wv"][et]
                ke = jnp.einsum("ed,edf->ef", h_nbr, wk).reshape(-1, heads, dout)
                ve = jnp.einsum("ed,edf->ef", h_nbr, wv).reshape(-1, heads, dout)
                qd = q[jnp.maximum(seg, 0)]
                att = (qd * ke).sum(-1) / (dout**0.5)  # [E, H]
                out = []
                for hd in range(heads):
                    msg = jnp.where(ok[:, None], ve[:, hd], 0.0)
                    out.append(
                        _seg_softmax_aggregate(att[:, hd], msg, seg, n, use_kernel)
                    )
                agg = jnp.concatenate(out, axis=1) @ p["wo"]
                return jax.nn.gelu(agg + h_self @ p["wskip"])
            raise ValueError(kind)

        def fn(_k, h_self, h_nbr, seg, etype=None):
            m = h_nbr.shape[0]
            sg = jnp.asarray(seg, jnp.int32) if m else jnp.zeros(0, jnp.int32)
            et = (
                jnp.asarray(etype, jnp.int32)
                if etype is not None and m
                else jnp.zeros(m, jnp.int32)
            )
            return jax.device_get(
                jax_fn(jnp.asarray(h_self), jnp.asarray(h_nbr), sg, et)
            )

        def kernel_shapes(num_edges, num_vertices, in_dim):
            """(op, (edges, segments, dim)) tuples this slice dispatches at
            the given bucket — the engine hands them to the autotuner before
            a bucket's first jit trace so tuned blocks bake into the compile."""
            if kind in ("gcn", "sage"):
                return [
                    ("segment_spmm_ragged", (num_edges, num_vertices, in_dim)),
                    ("segment_spmm_ragged", (num_edges, num_vertices, 1)),
                ]
            dh = p["a_dst"].shape[1] if kind == "gat" else p["wo"].shape[0] // heads
            return [("gat_softmax_aggregate", (num_edges, num_vertices, dh))]

        fn.jax = jax_fn
        fn.needs_etype = kind == "hgt"
        fn.kernel_shapes = kernel_shapes
        return fn
