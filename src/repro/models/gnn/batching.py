"""Host-side conversion: SampledSubgraph -> padded fixed-shape GNNBatch.

XLA needs static shapes; sampled subgraphs are ragged.  We bucket-pad the
vertex table and per-layer edge lists to multiples (power-of-two-ish) so jit
recompiles only on bucket changes — this is the TPU adaptation of the
paper's dynamic subgraph feeding (DESIGN.md §3).

Layer-k edge list = concat of hops 0..K-1-k (a vertex first reached at depth
d carries its sampled one-hop edges at hop d; see core/inference/engine.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.core.sampling.service import SampledSubgraph
from repro.core.storage import as_feature_source
from repro.utils import round_up

__all__ = ["GNNBatch", "subgraph_to_batch"]


@jax.tree_util.register_dataclass
@dataclass
class GNNBatch:
    feats: np.ndarray  # [V, F] float32, padded
    valid: np.ndarray  # [V] bool
    seed_pos: np.ndarray  # [B] int32 position of seeds in the table
    labels: np.ndarray  # [B] int32
    # per GNN layer k: (dst_pos [Ek], src_pos [Ek], etype [Ek]) padded, -1 pad
    layer_dst: list
    layer_src: list
    layer_etype: list
    # per layer k: [V, 1] float32 valid-edge in-degree per destination —
    # static for the batch, so it's counted ONCE here (host-side bincount)
    # instead of once per GCN/SAGE layer call; None = compute in-model
    layer_cnt: list | None = None

    @property
    def num_vertices(self) -> int:
        return self.feats.shape[0]


def _bucket(n: int, quantum: int = 256) -> int:
    return max(quantum, round_up(n, quantum))


def subgraph_to_batch(
    sub: SampledSubgraph,
    feats,  # [N, F] ndarray or a repro.core.storage.FeatureSource
    labels: np.ndarray | None,
    num_layers: int,
    edge_types_lookup=None,  # optional fn (src_gid, dst_gid) -> etype
    edge_types: np.ndarray | None = None,  # global per-edge type table
    vertex_quantum: int = 256,
    edge_quantum: int = 1024,
) -> GNNBatch:
    src = as_feature_source(feats)
    verts = sub.all_vertices()  # unique sorted gids
    vpad = _bucket(verts.shape[0], vertex_quantum)
    table = np.zeros((vpad, src.dim), dtype=np.float32)
    table[: verts.shape[0]] = src.gather(verts)
    valid = np.zeros(vpad, dtype=bool)
    valid[: verts.shape[0]] = True

    seed_pos = np.searchsorted(verts, sub.seeds).astype(np.int32)
    lab = (
        labels[sub.seeds].astype(np.int32)
        if labels is not None
        else np.zeros(sub.seeds.shape[0], np.int32)
    )

    K = num_layers
    layer_dst, layer_src, layer_et, layer_cnt = [], [], [], []
    for k in range(K):
        hops = sub.hops[: K - k]
        src = np.concatenate([h.src for h in hops]) if hops else np.zeros(0, np.int64)
        dst = np.concatenate([h.dst for h in hops]) if hops else np.zeros(0, np.int64)
        eid = (
            np.concatenate([h.eid for h in hops])
            if hops and all(h.eid is not None for h in hops)
            else None
        )
        epad = _bucket(src.shape[0], edge_quantum)
        d_pos = np.full(epad, -1, dtype=np.int32)
        s_pos = np.full(epad, -1, dtype=np.int32)
        et = np.zeros(epad, dtype=np.int32)
        d_pos[: src.shape[0]] = np.searchsorted(verts, src)  # aggregation target
        s_pos[: src.shape[0]] = np.searchsorted(verts, dst)  # message source
        if src.shape[0]:
            if edge_types is not None and eid is not None:
                # direct: sampled edge ids index the global edge-type table
                et[: src.shape[0]] = edge_types[eid]
            elif edge_types_lookup is not None:
                et[: src.shape[0]] = edge_types_lookup(src, dst)
        layer_dst.append(d_pos)
        layer_src.append(s_pos)
        layer_et.append(et)
        layer_cnt.append(
            np.bincount(d_pos[d_pos >= 0], minlength=vpad)
            .astype(np.float32)
            .reshape(vpad, 1)
        )
    return GNNBatch(
        feats=table,
        valid=valid,
        seed_pos=seed_pos,
        labels=lab,
        layer_dst=layer_dst,
        layer_src=layer_src,
        layer_etype=layer_et,
        layer_cnt=layer_cnt,
    )
