from repro.models.gnn.batching import GNNBatch, subgraph_to_batch
from repro.models.gnn.models import GNNModel, GNN_KINDS

__all__ = ["GNNBatch", "subgraph_to_batch", "GNNModel", "GNN_KINDS"]
