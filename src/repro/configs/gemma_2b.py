"""Gemma-2B [arXiv:2403.08295]: 18L, d_model 2048, 8 heads with MQA (kv=1),
head_dim 256, GeGLU d_ff 16384, vocab 256000."""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    long_context="window",  # full attention: long_500k uses windowed-KV decode
    source="arXiv:2403.08295",
)

REDUCED = ArchConfig(
    name="gemma-2b-reduced",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=1,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    activation="geglu",
    dtype="float32",
    source="arXiv:2403.08295",
)
