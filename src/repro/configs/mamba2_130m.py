"""Mamba2-130M [arXiv:2405.21060]: 24L, d_model 768, attention-free SSD,
ssm_state 128, vocab 50280.  d_inner = 2*768 = 1536, 24 heads of P=64."""
from repro.models.transformer.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    pattern=("ssm",),
    ssm=SSMConfig(state_dim=128, head_dim=64, num_groups=1, expand=2, chunk=128),
    long_context="native",  # O(1) state decode
    source="arXiv:2405.21060",
)

REDUCED = ArchConfig(
    name="mamba2-130m-reduced",
    family="ssm",
    num_layers=2,
    d_model=256,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    pattern=("ssm",),
    ssm=SSMConfig(state_dim=32, head_dim=32, num_groups=1, expand=2, chunk=32),
    dtype="float32",
    source="arXiv:2405.21060",
)
