"""Granite-20B-Code [arXiv:2405.04324]: 52L, d_model 6144, 48 heads MQA
(kv=1), d_ff 24576, vocab 49152, llama-style."""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",
    long_context="window",
    source="arXiv:2405.04324",
)

REDUCED = ArchConfig(
    name="granite-20b-reduced",
    family="dense",
    num_layers=2,
    d_model=384,
    num_heads=6,
    num_kv_heads=1,
    d_ff=768,
    vocab_size=512,
    activation="gelu",
    dtype="float32",
    source="arXiv:2405.04324",
)
