"""Granite-3.0-2B [hf:ibm-granite/granite-3.0-2b-base]: 40L, d_model 2048,
32 heads GQA kv=8, d_ff 8192, vocab 49155."""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    long_context="window",
    source="hf:ibm-granite/granite-3.0-2b-base",
)

REDUCED = ArchConfig(
    name="granite-3-2b-reduced",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    dtype="float32",
    source="hf:ibm-granite/granite-3.0-2b-base",
)
