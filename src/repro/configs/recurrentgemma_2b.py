"""RecurrentGemma-2B [arXiv:2402.19427]: 26L, d_model 2560, 10 heads MQA
(kv=1, head_dim 256), d_ff 7680, vocab 256000.  Griffin pattern: two RG-LRU
recurrent blocks then one local-attention block (1:2), window 2048."""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    activation="geglu",
    pattern=("rglru", "rglru", "local_attn"),
    local_window=2048,
    long_context="native",  # RG-LRU state + bounded local window
    source="arXiv:2402.19427",
)

REDUCED = ArchConfig(
    name="recurrentgemma-2b-reduced",
    family="hybrid",
    num_layers=3,  # one full (rec, rec, attn) period
    d_model=256,
    num_heads=4,
    num_kv_heads=1,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    activation="geglu",
    pattern=("rglru", "rglru", "local_attn"),
    local_window=64,
    dtype="float32",
    source="arXiv:2402.19427",
)
