"""InternLM2-1.8B [arXiv:2403.17297]: 24L, d_model 2048, 16 heads GQA kv=8,
d_ff 8192, vocab 92544."""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    long_context="window",
    source="arXiv:2403.17297",
)

REDUCED = ArchConfig(
    name="internlm2-1.8b-reduced",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    dtype="float32",
    source="arXiv:2403.17297",
)
