"""DeepSeek-V2-Lite-16B [arXiv:2405.04434]: 27L, d_model 2048, 16 heads with
MLA (kv_lora 512, decoupled rope head 64), MoE: 64 routed experts top-6 +
2 shared, expert d_ff 1408, vocab 102400.  (The full V2 has 160 routed
experts; Lite has 64 — we follow the Lite assignment.  V2's dense first
layer is simplified to all-MoE, noted in DESIGN.md.)"""
from repro.models.transformer.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    kv_lora_rank=512,
    rope_head_dim=64,
    moe=MoEConfig(
        num_experts=64, top_k=6, num_shared=2, expert_d_ff=1408,
        capacity_factor=1.25,
    ),
    long_context="window",
    source="arXiv:2405.04434",
)

REDUCED = ArchConfig(
    name="deepseek-v2-lite-reduced",
    family="moe",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    head_dim=64,
    d_ff=128,
    vocab_size=512,
    kv_lora_rank=64,
    rope_head_dim=32,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared=1, expert_d_ff=128,
                  capacity_factor=2.0),
    dtype="float32",
    source="arXiv:2405.04434",
)
