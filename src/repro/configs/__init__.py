"""Architecture registry: one module per assigned arch (--arch <id>).

Each module defines CONFIG (the exact assigned configuration, source cited)
and REDUCED (same family at smoke-test scale: ≤2 layers·d_model≤512·≤4
experts, used by per-arch CPU smoke tests)."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "gemma_2b",
    "granite_3_2b",
    "mamba2_130m",
    "granite_20b",
    "internlm2_1_8b",
    "llava_next_34b",
    "recurrentgemma_2b",
    "deepseek_v2_lite_16b",
    "mixtral_8x7b",
    "musicgen_medium",
]

# canonical dashed ids from the assignment
DASHED = {i.replace("_", "-"): i for i in ARCH_IDS}
DASHED["internlm2-1.8b"] = "internlm2_1_8b"
DASHED["granite-3-2b"] = "granite_3_2b"


def get_config(arch: str, reduced: bool = False):
    mod_name = DASHED.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False):
    return {a: get_config(a, reduced) for a in ARCH_IDS}
