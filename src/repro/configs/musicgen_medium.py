"""MusicGen-medium [arXiv:2306.05284]: 48L decoder-only over EnCodec tokens,
d_model 1536, 24 heads MHA (kv=24), d_ff 6144, vocab 2048 (codebook size).
Audio frontend (EnCodec conv codec) is STUBBED — input_specs() feeds
precomputed frame embeddings [B, S, d_model] (assignment carve-out)."""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    activation="gelu",
    input_mode="embeddings",
    long_context="window",
    source="arXiv:2306.05284",
)

REDUCED = ArchConfig(
    name="musicgen-medium-reduced",
    family="audio",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    activation="gelu",
    input_mode="embeddings",
    dtype="float32",
    source="arXiv:2306.05284",
)
