"""GNN experiment configs — the paper's own workloads (Table II/IV, Fig. 9-15).

Each entry reproduces one of GLISP's evaluation settings at laptop scale:
dataset stand-in, partition count, model, fanouts (the paper uses [15,10,5]
with hidden 256, 3 layers, GAT 4 heads; RelNet uses a 2-layer HGT-128 KGE).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class GNNExperimentConfig:
    name: str
    dataset: str
    num_parts: int
    model: str = "sage"  # gcn | sage | gat | hgt
    hidden: int = 256
    num_layers: int = 3
    num_heads: int = 4
    fanouts: tuple = (15, 10, 5)
    feat_dim: int = 64
    num_classes: int = 16
    batch_size: int = 256
    partitioner: str = "adadne"  # adadne | dne | hash2d | random | ldg
    weighted: bool = False
    direction: str = "out"


    def to_glisp_config(self, **overrides):
        """System half of this experiment as a ``repro.api.GLISPConfig``
        (the model half stays here: model/hidden/num_layers/num_heads)."""
        from repro.api import GLISPConfig

        sampler = "edge_cut" if self.partitioner == "ldg" else "gather_apply"
        cfg = GLISPConfig(
            num_parts=self.num_parts,
            partitioner=self.partitioner,  # validate() rejects unknown names
            sampler=sampler,
            fanouts=tuple(self.fanouts),
            weighted=self.weighted,
            direction=self.direction,
            batch_size=self.batch_size,
        )
        if overrides:
            cfg = cfg.replace(**overrides)
        return cfg.validate()


GNN_CONFIGS = {
    "gcn-products": GNNExperimentConfig(
        name="gcn-products", dataset="ogbn-products", num_parts=2, model="gcn"
    ),
    "sage-products": GNNExperimentConfig(
        name="sage-products", dataset="ogbn-products", num_parts=2, model="sage"
    ),
    "gat-products": GNNExperimentConfig(
        name="gat-products", dataset="ogbn-products", num_parts=2, model="gat"
    ),
    "sage-paper": GNNExperimentConfig(
        name="sage-paper", dataset="ogbn-paper", num_parts=8, model="sage"
    ),
    "hgt-relnet": GNNExperimentConfig(
        name="hgt-relnet",
        dataset="relnet",
        num_parts=8,
        model="hgt",
        hidden=128,
        num_layers=2,
        fanouts=(10, 5),
    ),
}


def get_gnn_config(name: str) -> GNNExperimentConfig:
    return GNN_CONFIGS[name]
