"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6-mistral-7b-hf, 34B variant]: 60L,
d_model 7168, 56 heads GQA kv=8, d_ff 20480, vocab 64000.  VLM: the
ViT/SigLIP vision tower + projector is STUBBED — input_specs() feeds
precomputed anyres patch embeddings [B, S, d_model] (assignment carve-out)."""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    input_mode="embeddings",
    long_context="window",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

REDUCED = ArchConfig(
    name="llava-next-34b-reduced",
    family="vlm",
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    input_mode="embeddings",
    dtype="float32",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
