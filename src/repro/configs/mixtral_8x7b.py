"""Mixtral-8x7B [arXiv:2401.04088]: 32L, d_model 4096, 32 heads GQA kv=8,
MoE 8 experts top-2 with d_ff 14336, vocab 32000, sliding-window attention
(window 4096)."""
from repro.models.transformer.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    window=4096,  # native SWA
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=14336,
                  capacity_factor=1.25),
    long_context="native",  # SWA bounds the KV cache
    source="arXiv:2401.04088",
)

REDUCED = ArchConfig(
    name="mixtral-8x7b-reduced",
    family="moe",
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    window=64,
    moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=256, capacity_factor=2.0),
    dtype="float32",
    source="arXiv:2401.04088",
)
