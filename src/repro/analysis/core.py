"""The glint rule engine: stdlib-``ast`` static analysis for GLISP.

The analyzer exists because the system's headline correctness claims —
bit-identical results under any interleaving (keyed randomness, PR 3) and
one jit compile per (layer, bucket) (shape bucketing, PR 2) — are
*conventions*: nothing in Python stops the next change from calling a
global-state RNG, iterating a ``set`` into a result, or padding a jit input
to a data-dependent length.  Each convention is encoded here as a ``Rule``
over a parsed AST, so the properties are machine-checked in CI instead of
review-checked.

Design mirrors the rest of the codebase: rules live in a ``RULES``
:class:`~repro.utils.Registry` keyed by rule id (``DET001`` ...), each rule
is a small object with ``check(ctx) -> findings``, and a shared
:class:`FileContext` owns the parse tree plus the cross-rule helpers
(import-alias resolution, parent links, jit-scope detection, suppression
pragmas).  Per-line suppression is ``# glint: disable=DET001`` (or a bare
``# glint: disable`` for every rule) and every suppression in this repo
must carry a justification comment.
"""
from __future__ import annotations

import ast
import io
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator

from repro.utils import Registry

__all__ = [
    "RULES",
    "Rule",
    "Finding",
    "FileContext",
    "Report",
    "SKIP_MARKER",
    "PARSE_ERROR_ID",
    "PRAGMA_REASON_ID",
    "active_rules",
    "check_source",
    "check_file",
    "iter_python_files",
    "run_checks",
]

RULES: Registry = Registry("lint rule")

#: drop a file with this name into a directory to exclude the whole subtree
#: from directory scans (used by the known-bad self-test corpus; explicitly
#: named files are always checked)
SKIP_MARKER = ".glint-skip"

#: pseudo-rule id for files the engine cannot parse
PARSE_ERROR_ID = "E001"

#: pseudo-rule id for a ``glint: disable`` pragma with no justification text
PRAGMA_REASON_ID = "E002"

_SUPPRESS_ALL = "*"


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    name: str
    path: str
    line: int
    col: int
    message: str

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "name": self.name,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
        }

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}[{self.name}] {self.message}"

    def sort_key(self):
        return (self.path, self.line, self.col, self.rule)


class Rule:
    """Base class: subclass, set the class attributes, implement ``check``.

    Register instances with ``@RULES.register("DETxxx")`` (the decorator
    form works on classes too: register the instance, not the class)."""

    id: str = "GLINT000"
    name: str = "base-rule"
    family: str = "engine"  # determinism | jax | kernels | project
    rationale: str = ""

    def check(self, ctx: "FileContext") -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, ctx: "FileContext", node, message: str) -> Finding:
        line = getattr(node, "lineno", 0)
        col = getattr(node, "col_offset", 0)
        return Finding(self.id, self.name, ctx.path, line, col, message)


def register_rule(cls):
    """Class decorator: instantiate and register under the rule's id."""
    RULES.register(cls.id, cls())
    return cls


# ---------------------------------------------------------------------------
# FileContext: one parsed file + the helpers every rule shares
# ---------------------------------------------------------------------------

_JIT_NAMES = ("jax.jit", "jax.pjit", "jax.experimental.pjit.pjit")


class FileContext:
    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = str(path).replace("\\", "/")
        self.source = source
        self.tree = tree
        self.lines = source.splitlines()
        self._parents: dict | None = None
        self._imports: dict | None = None
        self._suppress: dict | None = None
        self._pragma_issues: list | None = None
        self._jit_scopes: dict | None = None
        self._fn_assigns: dict | None = None

    # True for library code (rules about internal call discipline apply
    # only there; examples/benchmarks may exercise deprecated surfaces)
    @property
    def is_library(self) -> bool:
        return "repro" in Path(self.path).parts

    # -- structural helpers --------------------------------------------
    @property
    def parents(self) -> dict:
        if self._parents is None:
            self._parents = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents

    def parent(self, node) -> ast.AST | None:
        return self.parents.get(node)

    def ancestors(self, node) -> Iterator[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def enclosing_function(self, node) -> ast.AST | None:
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def calls(self) -> Iterator[ast.Call]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                yield node

    # -- import-alias resolution ---------------------------------------
    @property
    def import_map(self) -> dict:
        """Local name -> canonical dotted prefix (``np`` -> ``numpy``,
        ``from numpy import random as nr`` -> ``nr: numpy.random``)."""
        if self._imports is None:
            m: dict[str, str] = {}
            for node in ast.walk(self.tree):
                if isinstance(node, ast.Import):
                    for a in node.names:
                        if a.asname:
                            m[a.asname] = a.name
                        else:
                            root = a.name.split(".")[0]
                            m[root] = root
                elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                    for a in node.names:
                        m[a.asname or a.name] = f"{node.module}.{a.name}"
            self._imports = m
        return self._imports

    def resolve(self, node) -> str | None:
        """Canonical dotted name of a Name/Attribute chain, or None.

        ``np.random.rand`` -> ``numpy.random.rand`` given ``import numpy as
        np``.  Roots that were never imported resolve with their literal
        name (callers match on known module prefixes)."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(self.import_map.get(node.id, node.id))
        return ".".join(reversed(parts))

    # -- suppression pragmas -------------------------------------------
    @property
    def suppressions(self) -> dict:
        """line number -> set of suppressed rule ids (or ``{"*"}``).

        Pragma grammar: ``# glint: disable=DET001,JAX004 -- justification``
        (or a bare ``# glint: disable -- justification`` for every rule).
        A trailing pragma applies to its own line; a pragma on a standalone
        comment line applies to the next code line (so long statements can
        carry a multi-line justification above them).  The justification is
        any text after the id list; pragmas without one are recorded in
        :attr:`pragma_issues` and reported as ``E002``."""
        if self._suppress is None:
            sup: dict[int, set] = {}
            issues: list[tuple[int, int]] = []
            try:
                tokens = tokenize.generate_tokens(io.StringIO(self.source).readline)
                for tok in tokens:
                    if tok.type != tokenize.COMMENT:
                        continue
                    text = tok.string
                    marker = "glint:"
                    if marker not in text:
                        continue
                    directive = text.split(marker, 1)[1].strip()
                    if not directive.startswith("disable"):
                        continue
                    rest = directive[len("disable"):].strip()
                    if rest.startswith("="):
                        ids_part, _, reason = rest[1:].lstrip().partition(" ")
                        ids = {
                            r.strip().upper()
                            for r in ids_part.split(",")
                            if r.strip()
                        }
                    else:
                        ids, reason = {_SUPPRESS_ALL}, rest
                    if not reason.strip().strip("-—:(").strip():
                        issues.append((tok.start[0], tok.start[1]))
                    sup.setdefault(self._pragma_target(tok.start[0]), set()).update(ids)
            except tokenize.TokenError:
                pass
            self._suppress = sup
            self._pragma_issues = issues
        return self._suppress

    @property
    def pragma_issues(self) -> list:
        """(line, col) of each disable pragma lacking a justification."""
        self.suppressions  # populate
        return self._pragma_issues

    def _pragma_target(self, line: int) -> int:
        """Line a pragma at ``line`` suppresses: itself for a trailing
        pragma, else the next non-blank non-comment line."""
        text = self.lines[line - 1] if line - 1 < len(self.lines) else ""
        if not text.strip().startswith("#"):
            return line
        for nxt in range(line + 1, len(self.lines) + 1):
            stripped = self.lines[nxt - 1].strip()
            if stripped and not stripped.startswith("#"):
                return nxt
        return line

    def suppressed(self, finding: Finding) -> bool:
        ids = self.suppressions.get(finding.line)
        return bool(ids) and (_SUPPRESS_ALL in ids or finding.rule.upper() in ids)

    # -- jit-scope detection -------------------------------------------
    @property
    def jit_scopes(self) -> dict:
        """Function defs that run under ``jax.jit`` -> set of static param
        names.  Detects: ``@jax.jit`` / ``@functools.partial(jax.jit, ...)``
        decorators, ``jax.jit(fn, ...)`` calls naming a module-level
        function, and the project's traceable-slice convention
        ``layer.jax = fn`` (the engine jits ``layer_fn.jax``)."""
        if self._jit_scopes is None:
            scopes: dict[ast.AST, set] = {}
            defs: dict[str, list] = {}
            for node in ast.walk(self.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    defs.setdefault(node.name, []).append(node)
                    for dec in node.decorator_list:
                        statics = self._jit_decorator_statics(dec)
                        if statics is not None:
                            scopes[node] = statics
            for node in ast.walk(self.tree):
                if isinstance(node, ast.Call) and self.resolve(node.func) in _JIT_NAMES:
                    if node.args and isinstance(node.args[0], ast.Name):
                        for fn in defs.get(node.args[0].id, ()):
                            scopes.setdefault(fn, set()).update(
                                _static_names(node.keywords)
                            )
                elif isinstance(node, ast.Assign):
                    # `fn.jax = jax_fn`: jax_fn is jit'd by the engine
                    for tgt in node.targets:
                        if (
                            isinstance(tgt, ast.Attribute)
                            and tgt.attr == "jax"
                            and isinstance(node.value, ast.Name)
                        ):
                            for fn in defs.get(node.value.id, ()):
                                scopes.setdefault(fn, set())
            self._jit_scopes = scopes
        return self._jit_scopes

    def _jit_decorator_statics(self, dec) -> set | None:
        """Static param names if ``dec`` is a jit-ish decorator, else None."""
        if self.resolve(dec) in _JIT_NAMES:
            return set()
        if isinstance(dec, ast.Call):
            if self.resolve(dec.func) in _JIT_NAMES:
                return _static_names(dec.keywords)
            if self.resolve(dec.func) == "functools.partial" and dec.args:
                if self.resolve(dec.args[0]) in _JIT_NAMES:
                    return _static_names(dec.keywords)
        return None

    def in_jit_scope(self, node) -> ast.AST | None:
        """The nearest enclosing jit-scoped function def, if any (nested
        defs inside a jit-scoped function are jit-scoped too)."""
        cur = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if cur in self.jit_scopes:
                    return cur
            cur = self.parent(cur)
        return None

    # -- simple local dataflow -----------------------------------------
    def name_assignment(self, node, name: str):
        """The RHS of the last simple ``name = <expr>`` assignment in the
        function (or module) enclosing ``node`` — one-level resolution for
        shape/bucket provenance checks."""
        if self._fn_assigns is None:
            self._fn_assigns = {}
        scope = self.enclosing_function(node) or self.tree
        if scope not in self._fn_assigns:
            amap: dict[str, ast.AST] = {}
            for n in ast.walk(scope):
                if isinstance(n, ast.Assign):
                    for tgt in n.targets:
                        if isinstance(tgt, ast.Name):
                            amap[tgt.id] = n.value
                elif isinstance(n, ast.AnnAssign) and n.value is not None:
                    if isinstance(n.target, ast.Name):
                        amap[n.target.id] = n.value
            self._fn_assigns[scope] = amap
        return self._fn_assigns[scope].get(name)


def _static_names(keywords) -> set:
    """Param names listed in a ``static_argnames=`` keyword, if constant."""
    out: set = set()
    for kw in keywords or ():
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            out.add(v.value)
        elif isinstance(v, (ast.Tuple, ast.List)):
            for el in v.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    out.add(el.value)
    return out


# ---------------------------------------------------------------------------
# Report + engine entry points
# ---------------------------------------------------------------------------


@dataclass
class Report:
    findings: list = field(default_factory=list)  # unsuppressed, gating
    suppressed: list = field(default_factory=list)
    files_checked: int = 0
    rule_ids: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings

    def counts(self) -> dict:
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return dict(sorted(out.items()))

    def to_dict(self) -> dict:
        return {
            "version": 1,
            "ok": self.ok,
            "files_checked": self.files_checked,
            "rules": list(self.rule_ids),
            "counts": self.counts(),
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
        }


def active_rules(select=None, ignore=None) -> list:
    """Registered rule instances, filtered by id/name, ordered by id."""
    sel = {s.strip().upper() for s in select} if select else None
    ign = {s.strip().upper() for s in ignore} if ignore else set()

    def wanted(rule) -> bool:
        keys = {rule.id.upper(), rule.name.upper(), rule.family.upper()}
        if keys & ign:
            return False
        return sel is None or bool(keys & sel)

    rules = [RULES.get(rid) for rid in RULES]
    return sorted((r for r in rules if wanted(r)), key=lambda r: r.id)


def check_source(
    source: str, path: str = "<string>", rules=None
) -> tuple[list, list]:
    """Run ``rules`` over one source string -> (findings, suppressed)."""
    rules = active_rules() if rules is None else rules
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        f = Finding(
            PARSE_ERROR_ID,
            "parse-error",
            str(path).replace("\\", "/"),
            exc.lineno or 0,
            exc.offset or 0,
            f"file does not parse: {exc.msg}",
        )
        return [f], []
    ctx = FileContext(path, source, tree)
    findings: list[Finding] = []
    suppressed: list[Finding] = []
    for rule in rules:
        for f in rule.check(ctx):
            (suppressed if ctx.suppressed(f) else findings).append(f)
    # pragma hygiene is engine-level and cannot be pragma-suppressed
    for line, col in ctx.pragma_issues:
        findings.append(
            Finding(
                PRAGMA_REASON_ID,
                "pragma-without-reason",
                ctx.path,
                line,
                col,
                "glint: disable pragma has no justification; append one "
                "after the rule ids (e.g. `disable=DET001 -- why`)",
            )
        )
    findings.sort(key=Finding.sort_key)
    suppressed.sort(key=Finding.sort_key)
    return findings, suppressed


def check_file(path, rules=None) -> tuple[list, list]:
    source = Path(path).read_text(encoding="utf-8")
    return check_source(source, path=str(path), rules=rules)


def iter_python_files(paths) -> list:
    """Expand files/directories into a sorted, de-duplicated .py file list.

    Directory scans prune ``__pycache__`` and any subtree holding a
    ``SKIP_MARKER`` file; explicitly named files are always included."""
    seen: set = set()
    out: list[Path] = []
    skip_cache: dict[Path, bool] = {}

    def _skipped(d: Path) -> bool:
        if d not in skip_cache:
            skip_cache[d] = d.name == "__pycache__" or (d / SKIP_MARKER).exists()
        return skip_cache[d]

    def _add(f: Path) -> None:
        key = f.resolve()
        if key not in seen:
            seen.add(key)
            out.append(f)

    for p in paths:
        p = Path(p)
        if p.is_file():
            if p.suffix == ".py":
                _add(p)
        elif p.is_dir():
            if _skipped(p):
                continue
            for f in sorted(p.rglob("*.py")):
                rel = f.relative_to(p)
                dirs = [p / Path(*rel.parts[: i + 1]) for i in range(len(rel.parts) - 1)]
                if any(_skipped(d) for d in dirs):
                    continue
                _add(f)
    return out


def run_checks(paths, *, select=None, ignore=None) -> Report:
    """Analyze ``paths`` (files and/or directories) with the active rules.

    The library entry point behind ``python -m repro.analysis``; returns a
    :class:`Report` whose ``ok`` is the CI gate condition."""
    rules = active_rules(select=select, ignore=ignore)
    report = Report(rule_ids=[r.id for r in rules])
    for f in iter_python_files(paths):
        found, sup = check_file(f, rules=rules)
        report.findings.extend(found)
        report.suppressed.extend(sup)
        report.files_checked += 1
    report.findings.sort(key=Finding.sort_key)
    report.suppressed.sort(key=Finding.sort_key)
    return report
