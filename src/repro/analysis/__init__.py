"""``repro.analysis`` — determinism & JAX-hygiene static analysis (glint).

A stdlib-``ast`` rule engine that machine-checks the conventions GLISP's
correctness claims rest on: keyed randomness (no global RNG state), stable
iteration orders, pure-jnp jit bodies, bucketed shapes, and the project's
registry/shim discipline.  Gates CI via::

    python -m repro.analysis src tests benchmarks examples

and is a library like the other subsystems::

    from repro.analysis import run_checks
    report = run_checks(["src"])
    assert report.ok, report.findings

Per-line suppression: ``# glint: disable=DET001 -- justification`` (the
justification is mandatory; E002 flags pragmas without one).  Add a rule
by subclassing :class:`Rule` and decorating with ``@register_rule``.  The
runtime companion
:func:`recompile_guard` asserts the engine's one-compile-per-
(layer, bucket) bound over any block of inference calls.
"""
from repro.analysis.core import (
    PARSE_ERROR_ID,
    PRAGMA_REASON_ID,
    RULES,
    SKIP_MARKER,
    FileContext,
    Finding,
    Report,
    Rule,
    active_rules,
    check_file,
    check_source,
    iter_python_files,
    register_rule,
    run_checks,
)
from repro.analysis.reporters import render_json, render_rule_catalog, render_text
from repro.analysis.runtime import RecompileError, RecompileReport, recompile_guard
import repro.analysis.rules  # noqa: F401  (registers every rule in RULES)

__all__ = [
    "RULES",
    "Rule",
    "Finding",
    "FileContext",
    "Report",
    "SKIP_MARKER",
    "PARSE_ERROR_ID",
    "PRAGMA_REASON_ID",
    "register_rule",
    "active_rules",
    "check_source",
    "check_file",
    "iter_python_files",
    "run_checks",
    "render_text",
    "render_json",
    "render_rule_catalog",
    "RecompileError",
    "RecompileReport",
    "recompile_guard",
]
