"""Render a :class:`~repro.analysis.core.Report` as text or JSON.

The JSON document (``--format json --out glint_report.json``) is the CI
artifact uploaded next to the ``BENCH_*.json`` files; the text form is the
human gate output.
"""
from __future__ import annotations

import json

from repro.analysis.core import RULES, Report, active_rules

__all__ = ["render_text", "render_json", "render_rule_catalog"]


def render_text(report: Report, *, show_suppressed: bool = False) -> str:
    lines = [f.render() for f in report.findings]
    if show_suppressed and report.suppressed:
        lines.append("-- suppressed (pragma'd, non-gating) --")
        lines.extend(f.render() + "  [suppressed]" for f in report.suppressed)
    counts = report.counts()
    by_rule = (
        " (" + ", ".join(f"{r}: {n}" for r, n in counts.items()) + ")"
        if counts
        else ""
    )
    lines.append(
        f"glint: {len(report.findings)} finding(s){by_rule}, "
        f"{len(report.suppressed)} suppressed, "
        f"{report.files_checked} file(s), "
        f"{len(report.rule_ids)} rule(s)"
    )
    return "\n".join(lines)


def render_json(report: Report) -> str:
    return json.dumps(report.to_dict(), indent=2, sort_keys=False)


def render_rule_catalog() -> str:
    """The ``--list-rules`` output: every registered rule with family and
    rationale, grouped deterministically by id."""
    out = []
    for rule in active_rules():
        out.append(f"{rule.id}  {rule.name}  [{rule.family}]")
        for line in rule.rationale.split(". "):
            line = line.strip().rstrip(".")
            if line:
                out.append(f"    {line}.")
    return "\n".join(out)
