"""Runtime companion to the static pass: the jit-recompile guard.

The static rules keep *new* code from introducing shape leaks; this guard
checks the claim at runtime — the bucketed inference engine compiles each
(layer, vertex-bucket, edge-bucket) slice at most once over the engine's
lifetime.  The engine counts actual retraces (the wrapped python callable
runs once per jit cache miss), so the guard compares observed compiles
against the number of *new* distinct shape triples in the guarded region:

    with recompile_guard(system) as rec:
        system.infer_layerwise(layer_fns, workdir)
        system.infer_layerwise(layer_fns, workdir)   # same shapes: 0 compiles
    assert rec.compiles == rec.new_shapes

Accepts a :class:`LayerwiseInferenceEngine` or a :class:`GLISPSystem`
(whose cached ``infer_engine`` may not exist until the first call inside
the guard).
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass

__all__ = ["RecompileError", "RecompileReport", "recompile_guard"]


class RecompileError(AssertionError):
    """The bucketed engine compiled more slices than it saw new shapes."""


@dataclass
class RecompileReport:
    """Filled in when the guarded block exits cleanly."""

    compiles: int = 0  # jit retraces observed in the guarded region
    new_shapes: int = 0  # new distinct (layer, Bp, Ep) triples in region
    bound: int = 0  # allowed compiles: new_shapes + extra


def _engine_of(target):
    """The engine holding the jit caches: the target itself, or a
    GLISPSystem's cached engine (None before the first inference call)."""
    if target is None or hasattr(target, "jit_trace_count"):
        return target
    return getattr(target, "infer_engine", None)


def _counters(target) -> tuple[int, int]:
    engine = _engine_of(target)
    if engine is None:
        return 0, 0
    return engine.jit_trace_count(), engine.shape_count()


@contextmanager
def recompile_guard(target, *, extra: int = 0):
    """Assert the one-compile-per-(layer, bucket) bound over a block.

    ``extra`` widens the bound for intentional recompiles (e.g. an engine
    rebuilt with different jit options mid-guard).  Raises
    :class:`RecompileError` on a clean exit that exceeded the bound; the
    yielded :class:`RecompileReport` carries the counts either way."""
    report = RecompileReport()
    traces0, shapes0 = _counters(target)
    yield report
    traces1, shapes1 = _counters(target)
    # an engine swapped mid-guard starts its counters at zero; clamp the
    # baseline so the comparison stays on the live engine's cache
    report.compiles = traces1 - min(traces0, traces1)
    report.new_shapes = shapes1 - min(shapes0, shapes1)
    report.bound = report.new_shapes + extra
    if report.compiles > report.bound:
        raise RecompileError(
            f"bucketed engine compiled {report.compiles} jit slice(s) for "
            f"{report.new_shapes} new (layer, bucket) shape(s) "
            f"(bound {report.bound}): a shape is leaking past the bucketer "
            "or a jit cache is being rebuilt"
        )
