"""JAX hygiene rules (JAX0xx).

The bucketed inference engine's performance claim — one jit compile per
(layer, bucket), one transfer each way per batch — survives only if traced
code stays traced: no host syncs inside jit, no fresh jit caches per loop
iteration, hashable static args, and padded shapes that come from the
bucketers (power-of-two / quantum round-up), not raw data-dependent
lengths.  Each rule here flags one way that contract erodes.
"""
from __future__ import annotations

import ast

from repro.analysis.core import FileContext, Rule, register_rule

__all__ = [
    "HostSyncInJit",
    "JitInLoop",
    "NonHashableStaticArg",
    "UnbucketedPad",
]

# numpy dtype/scalar constructors that are legitimate inside traced code
# (they build constants/dtypes, not host round-trips)
_NP_OK_IN_JIT = {
    "float16",
    "float32",
    "float64",
    "int8",
    "int16",
    "int32",
    "int64",
    "uint8",
    "uint16",
    "uint32",
    "uint64",
    "bool_",
    "dtype",
    "pi",
    "inf",
    "nan",
}

_JIT_NAMES = ("jax.jit", "jax.pjit")


def _static_safe(node, statics: set) -> bool:
    """True when an expression is safe to concretize under jit: it reads
    only static metadata (.shape/.ndim/.size/.dtype, len()) , static-arg
    names, or constants — never traced array *values*."""
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("shape", "ndim", "size", "dtype"):
            return True
        if (
            isinstance(n, ast.Call)
            and isinstance(n.func, ast.Name)
            and n.func.id == "len"
        ):
            return True
    names = {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}
    return names <= statics


@register_rule
class HostSyncInJit(Rule):
    id = "JAX001"
    name = "host-sync-in-jit"
    family = "jax"
    rationale = (
        ".item()/float()/bool()/np.asarray on a traced value forces a "
        "device sync + host round trip at trace time and usually a "
        "ConcretizationTypeError; inside a jit-compiled layer slice it "
        "breaks the one-transfer-per-batch contract.  Keep jit bodies pure "
        "jnp; concretize only static metadata (.shape, static args)."
    )

    def check(self, ctx: FileContext):
        for fn, statics in ctx.jit_scopes.items():
            args = fn.args
            all_params = (
                [a.arg for a in args.posonlyargs]
                + [a.arg for a in args.args]
                + [a.arg for a in args.kwonlyargs]
            )
            static_names = set(statics) | {
                p for p in all_params if p in ("self", "cls")
            }
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                if isinstance(node.func, ast.Attribute) and node.func.attr in (
                    "item",
                    "tolist",
                ):
                    yield self.finding(
                        ctx,
                        node,
                        f".{node.func.attr}() inside a jit-traced function "
                        "forces a host sync",
                    )
                    continue
                dn = ctx.resolve(node.func)
                if dn and dn.startswith("numpy."):
                    leaf = dn.split(".", 1)[1]
                    if leaf not in _NP_OK_IN_JIT and not leaf.startswith("random."):
                        yield self.finding(
                            ctx,
                            node,
                            f"np.{leaf} inside a jit-traced function "
                            "concretizes the tracer; use jnp",
                        )
                    continue
                if (
                    isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int", "bool")
                    and node.args
                    and not _static_safe(node.args[0], static_names)
                ):
                    yield self.finding(
                        ctx,
                        node,
                        f"{node.func.id}(...) on a (possibly traced) value "
                        "inside a jit-traced function forces a host sync; "
                        "only static metadata (.shape, static args) may be "
                        "concretized",
                    )


@register_rule
class JitInLoop(Rule):
    id = "JAX002"
    name = "jit-in-loop"
    family = "jax"
    rationale = (
        "jax.jit(fn) inside a loop builds a fresh compilation cache every "
        "iteration, so nothing is ever reused — the exact failure mode the "
        "(layer, bucket) single-compile design exists to prevent.  Hoist "
        "the jit out of the loop (the engine keys its jitted slices by "
        "layer once, then reuses them for every bucket)."
    )

    def check(self, ctx: FileContext):
        for call in ctx.calls():
            dn = ctx.resolve(call.func)
            is_jit = dn in _JIT_NAMES or (
                dn == "functools.partial"
                and call.args
                and ctx.resolve(call.args[0]) in _JIT_NAMES
            )
            if not is_jit:
                continue
            for anc in ctx.ancestors(call):
                if isinstance(anc, (ast.For, ast.AsyncFor, ast.While)):
                    yield self.finding(
                        ctx,
                        call,
                        "jax.jit called inside a loop recompiles every "
                        "iteration; hoist it out and reuse the jitted "
                        "callable",
                    )
                    break
                if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    # a jit at function scope is the cached-per-object
                    # pattern (e.g. the engine's per-layer slices); only
                    # flag loops *inside* the same function
                    break


_MUTABLE_CALLS = {"list", "dict", "set", "bytearray"}


def _mutable_literal(node) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp)):
        return True
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in _MUTABLE_CALLS
    )


@register_rule
class NonHashableStaticArg(Rule):
    id = "JAX003"
    name = "nonhashable-static-arg"
    family = "jax"
    rationale = (
        "jit static args are hashed into the compilation-cache key; a "
        "list/dict/set default (or argument) raises 'unhashable type' at "
        "call time — or worse, a custom __hash__ silently aliases cache "
        "entries.  Use tuples / frozen dataclasses for static args."
    )

    def check(self, ctx: FileContext):
        for fn, statics in ctx.jit_scopes.items():
            if not statics:
                continue
            args = fn.args
            named = [a.arg for a in args.posonlyargs] + [a.arg for a in args.args]
            # defaults align with the tail of the positional params
            pos_defaults = list(zip(named[len(named) - len(args.defaults):], args.defaults))
            kw_defaults = [
                (a.arg, d)
                for a, d in zip(args.kwonlyargs, args.kw_defaults)
                if d is not None
            ]
            for pname, default in pos_defaults + kw_defaults:
                if pname in statics and _mutable_literal(default):
                    yield self.finding(
                        ctx,
                        default,
                        f"static arg {pname!r} of a jit-compiled function "
                        "has a non-hashable (mutable) default; use a tuple "
                        "or frozen value",
                    )


# helpers whose output is an approved padded/bucketed length
_BUCKET_HELPERS = {
    "round_up",
    "ceil_div",
    "pow2_ceil",
    "_pow2_ceil",
    "_bucket",
    "_vertex_bucket",
    "_edge_bucket",
    "next_power_of_2",
    "bit_length",
}
_BUCKETY_NAME_PARTS = ("pad", "quantum", "bucket", "cap")
_PAD_FNS = {"numpy.pad", "jax.numpy.pad"}


@register_rule
class UnbucketedPad(Rule):
    id = "JAX004"
    name = "unbucketed-pad"
    family = "jax"
    rationale = (
        "Padding a jit input to a raw data-dependent length (x.shape[0], "
        "len(batch), ...) makes every distinct input size a distinct "
        "compiled program — unbounded recompilation.  Pad lengths must "
        "come through the bucketers: round_up / _pow2_ceil / the engine's "
        "_vertex_bucket/_edge_bucket, or an explicit quantum."
    )

    def check(self, ctx: FileContext):
        for call in ctx.calls():
            fn = call.func
            dn = ctx.resolve(fn)
            leaf = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else None
            )
            if leaf == "pad_to" and len(call.args) >= 2:
                if not self._bucketed(ctx, call, call.args[1]):
                    yield self.finding(
                        ctx,
                        call.args[1],
                        "pad_to length is a raw data-dependent value; route "
                        "it through round_up/_pow2_ceil or a *_quantum so "
                        "shapes stay bucketed",
                    )
            elif dn in _PAD_FNS and len(call.args) >= 2:
                for expr in self._width_exprs(call.args[1]):
                    if not self._bucketed(ctx, call, expr):
                        yield self.finding(
                            ctx,
                            expr,
                            "pad width is a raw data-dependent value; derive "
                            "it from a bucketed length (round_up/_pow2_ceil) "
                            "so shapes stay bucketed",
                        )

    @staticmethod
    def _width_exprs(widths):
        """Non-constant leaf expressions of a pad-width spec."""
        if isinstance(widths, (ast.Tuple, ast.List)):
            for el in widths.elts:
                yield from UnbucketedPad._width_exprs(el)
        elif not isinstance(widths, ast.Constant):
            yield widths

    def _bucketed(self, ctx: FileContext, call, expr, depth: int = 1) -> bool:
        """An expression produces a bucketed length if any term is a
        constant-only expression, an approved helper call, ceil-style
        floor-div/shift arithmetic, a bucket-named variable, or (one level
        deep) a name assigned from one of those."""
        if isinstance(expr, ast.Constant):
            return True
        for n in ast.walk(expr):
            if isinstance(n, (ast.FloorDiv, ast.LShift)):
                return True
            if isinstance(n, ast.Call):
                f = n.func
                leaf = f.attr if isinstance(f, ast.Attribute) else (
                    f.id if isinstance(f, ast.Name) else None
                )
                if leaf in _BUCKET_HELPERS:
                    return True
            if isinstance(n, ast.Name) and any(
                part in n.id.lower() for part in _BUCKETY_NAME_PARTS
            ):
                return True
        if depth > 0:
            for n in ast.walk(expr):
                if isinstance(n, ast.Name):
                    rhs = ctx.name_assignment(call, n.id)
                    if rhs is not None and self._bucketed(ctx, call, rhs, depth - 1):
                        return True
        return False
