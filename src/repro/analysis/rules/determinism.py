"""Determinism rules (DET0xx).

GLISP's reproducibility contract is *keyed* randomness: every random draw
is derived from an explicit ``(seed, request, hop, server, chunk)`` key, so
results are bit-identical under any interleaving, prefetch depth, or
service sharing.  These rules flag the ways Python code silently breaks
that contract: process-global RNG state, hash-order iteration, and wall
clock / filesystem enumeration feeding computed values.
"""
from __future__ import annotations

import ast

from repro.analysis.core import FileContext, Rule, register_rule

__all__ = [
    "UnseededGlobalRng",
    "SetIteration",
    "WallclockValue",
    "UnkeyedSubmit",
]

# numpy.random attributes that are fine: explicitly seeded constructors and
# bit generators.  Everything else on the module (`rand`, `seed`, `shuffle`,
# ...) mutates or reads the hidden global MT19937 state.
_NP_RANDOM_OK = {
    "default_rng",
    "Generator",
    "SeedSequence",
    "BitGenerator",
    "RandomState",  # legacy but explicitly seedable; flag only global fns
    "PCG64",
    "PCG64DXSM",
    "Philox",
    "SFC64",
    "MT19937",
}

# stdlib random: only the explicitly-seeded instance constructor is allowed
# (SystemRandom is *designed* to be irreproducible)
_PY_RANDOM_OK = {"Random"}


@register_rule
class UnseededGlobalRng(Rule):
    id = "DET001"
    name = "unseeded-global-rng"
    family = "determinism"
    rationale = (
        "Global-state RNG calls (np.random.rand, random.shuffle, ...) share "
        "one hidden stream across the whole process, so results depend on "
        "call order, thread/process scheduling and unrelated code.  Use "
        "np.random.default_rng(seed) / random.Random(seed), or derive a key "
        "the way the sampling service does (np.random.SeedSequence)."
    )

    def check(self, ctx: FileContext):
        for call in ctx.calls():
            dn = ctx.resolve(call.func)
            if dn is None:
                continue
            parts = dn.split(".")
            if (
                len(parts) == 3
                and parts[:2] == ["numpy", "random"]
                and parts[2] not in _NP_RANDOM_OK
            ):
                yield self.finding(
                    ctx,
                    call,
                    f"np.random.{parts[2]} uses process-global RNG state; "
                    "use np.random.default_rng(seed) or a SeedSequence key",
                )
            elif (
                len(parts) == 2
                and parts[0] == "random"
                and parts[1] not in _PY_RANDOM_OK
            ):
                yield self.finding(
                    ctx,
                    call,
                    f"random.{parts[1]} uses process-global RNG state; "
                    "use random.Random(seed)",
                )


def _is_setish(node) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in ("set", "frozenset")
    )


# order-independent reductions: consuming a set through these is fine
_ORDER_FREE = {"sorted", "len", "sum", "min", "max", "any", "all", "bool", "set", "frozenset"}
# order-preserving consumers: a set here leaks hash order into the result
_ORDER_SENSITIVE = {"list", "tuple", "enumerate", "reversed", "iter", "map", "filter", "zip"}
_ORDER_SENSITIVE_DOTTED = {"numpy.array", "numpy.asarray", "numpy.fromiter"}


@register_rule
class SetIteration(Rule):
    id = "DET002"
    name = "set-iteration"
    family = "determinism"
    rationale = (
        "Set iteration order follows the hash seed and insertion history, "
        "not a stable order, so any value built by iterating a set can "
        "differ between runs/processes.  Sort first (sorted(...)) or use "
        "np.unique, which is already sorted."
    )

    _MSG = (
        "iterating a set leaks hash order into the result; wrap in "
        "sorted(...) or use np.unique"
    )

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.For) and _is_setish(node.iter):
                yield self.finding(ctx, node.iter, self._MSG)
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.DictComp)):
                for comp in node.generators:
                    if _is_setish(comp.iter):
                        yield self.finding(ctx, comp.iter, self._MSG)
            elif isinstance(node, ast.Call):
                dn = ctx.resolve(node.func)
                sensitive = dn in _ORDER_SENSITIVE_DOTTED or (
                    isinstance(node.func, ast.Name)
                    and node.func.id in _ORDER_SENSITIVE
                ) or (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "join"
                )
                if sensitive:
                    for arg in node.args:
                        if _is_setish(arg):
                            yield self.finding(ctx, arg, self._MSG)


# always nondeterministic as *values* (wall clock, uuid, os entropy)
_VALUE_FNS = {
    "time.time": "time.perf_counter for timing, or pass timestamps in explicitly",
    "time.time_ns": "time.perf_counter_ns for timing",
    "datetime.datetime.now": "pass timestamps in explicitly",
    "datetime.datetime.utcnow": "pass timestamps in explicitly",
    "datetime.datetime.today": "pass timestamps in explicitly",
    "datetime.date.today": "pass dates in explicitly",
    "uuid.uuid1": "a content hash (repro.utils.stable_hash64) or uuid5 over stable inputs",
    "uuid.uuid4": "a content hash (repro.utils.stable_hash64) or uuid5 over stable inputs",
    "os.urandom": "a seeded np.random.default_rng",
}

# OS-order directory enumeration: fine when reduced order-free (sorted, len,
# emptiness tests), hash-order hazard when the listing order reaches a value
_LISTING_FNS = {"os.listdir", "os.scandir", "os.walk", "glob.glob", "glob.iglob"}


@register_rule
class WallclockValue(Rule):
    id = "DET003"
    name = "wallclock-value"
    family = "determinism"
    rationale = (
        "time.time()/uuid4()/os.listdir() feed OS state into computed "
        "values: runs stop being reproducible and cache keys stop being "
        "content-addressed.  Directory listings are OS-order; sort them.  "
        "Relative timing should use time.perf_counter (allowed)."
    )

    def check(self, ctx: FileContext):
        for call in ctx.calls():
            dn = ctx.resolve(call.func)
            if dn in _VALUE_FNS:
                yield self.finding(
                    ctx,
                    call,
                    f"{dn}() is nondeterministic as a value; use "
                    f"{_VALUE_FNS[dn]}",
                )
            elif dn in _LISTING_FNS and not self._order_free(ctx, call):
                yield self.finding(
                    ctx,
                    call,
                    f"{dn}() returns entries in OS order; wrap in sorted(...) "
                    "(or reduce order-free: len/emptiness)",
                )

    @staticmethod
    def _order_free(ctx: FileContext, call: ast.Call) -> bool:
        parent = ctx.parent(call)
        if isinstance(parent, ast.Call) and isinstance(parent.func, ast.Name):
            if parent.func.id in _ORDER_FREE:
                return True
        if isinstance(parent, ast.UnaryOp) and isinstance(parent.op, ast.Not):
            return True
        if isinstance(parent, (ast.If, ast.While, ast.Assert)) and parent.test is call:
            return True
        return False


@register_rule
class UnkeyedSubmit(Rule):
    id = "DET004"
    name = "unkeyed-submit"
    family = "determinism"
    rationale = (
        "SamplingService.submit without an explicit key= falls back to a "
        "service-assigned sequence key, so the draw depends on what else "
        "shares the service and in what order.  Library code must thread a "
        "caller-owned key (the pipeline's (seed, batch_index), the engine's "
        "(seed, layer, part) ...) so results survive any interleaving."
    )

    def check(self, ctx: FileContext):
        if not ctx.is_library:
            return
        for call in ctx.calls():
            fn = call.func
            named_submit = (
                isinstance(fn, ast.Attribute) and fn.attr == "submit"
            ) or (isinstance(fn, ast.Name) and fn.id == "submit")
            if not named_submit or not call.args:
                continue
            has_key = any(kw.arg in ("key", None) for kw in call.keywords)
            if not has_key:
                yield self.finding(
                    ctx,
                    call,
                    "submit(...) without an explicit key=; pass a "
                    "caller-owned RNG key so the request stream is "
                    "independent of service sharing",
                )
