"""Project-invariant rules (PRJ0xx).

These encode GLISP-repo conventions the earlier PRs established: errors
are never swallowed silently outside finalizers, deprecated shims are for
*external* callers only (library code uses the replacement surfaces), and
every registry key a config or call site names must actually be registered
— config validation and the live registries must not drift.
"""
from __future__ import annotations

import ast

from repro.analysis.core import FileContext, Rule, register_rule

__all__ = [
    "SilentExceptPass",
    "DeprecatedShimCall",
    "ConfigRegistryDrift",
    "BlockingWaitNoTimeout",
    "UnboundedRequestQueue",
    "MultiprocessingHygiene",
]


_BROAD = {"Exception", "BaseException"}


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    if isinstance(t, ast.Name):
        return t.id in _BROAD
    if isinstance(t, ast.Tuple):
        return any(isinstance(e, ast.Name) and e.id in _BROAD for e in t.elts)
    return False


def _body_is_silent(body) -> bool:
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
            continue  # docstring / `...`
        return False
    return True


@register_rule
class SilentExceptPass(Rule):
    id = "PRJ001"
    name = "silent-except-pass"
    family = "project"
    rationale = (
        "`except Exception: pass` swallows every failure — including the "
        "determinism bugs the rest of this analyzer looks for — with no "
        "trace.  Narrow to the exceptions the block can actually raise and "
        "log them; only __del__ finalizers (where raising is unusable) are "
        "exempt."
    )

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not (_is_broad(node) and _body_is_silent(node.body)):
                continue
            fn = ctx.enclosing_function(node)
            if fn is not None and fn.name == "__del__":
                continue
            yield self.finding(
                ctx,
                node,
                "broad except with a silent body swallows all errors; "
                "narrow the exception types and log at debug "
                "(only __del__ is exempt)",
            )


# deprecated surfaces (kept one release for external callers) and the shim
# modules that define them — the only library files allowed to mention them
_SHIM_CALLS = {
    "adadne": "PARTITIONERS.get('adadne').partition(...)",
    "distributed_ne": "PARTITIONERS.get('dne').partition(...)",
    "TwoLevelCache": "repro.core.storage.HybridCache",
    "ChunkedEmbeddingStore": "repro.core.storage.DFSTier",
}
_SHIM_FILES = (
    "repro/core/partition/dne.py",
    "repro/core/inference/cache.py",
    "repro/core/inference/store.py",
    "repro/core/storage/store.py",
    "repro/core/sampling/service.py",
    "repro/api/backends.py",
)


@register_rule
class DeprecatedShimCall(Rule):
    id = "PRJ002"
    name = "deprecated-shim-call"
    family = "project"
    rationale = (
        "backend.sample(), TwoLevelCache, ChunkedEmbeddingStore and the "
        "free-function partitioners survive only as deprecation shims for "
        "external callers.  Library code calling a shim re-entrenches the "
        "old surface and dodges the replacements' contracts (keyed submit, "
        "tiered storage, PartitionPlan scorecards)."
    )

    def check(self, ctx: FileContext):
        if not ctx.is_library:
            return
        if ctx.path.endswith(_SHIM_FILES):
            return
        for call in ctx.calls():
            fn = call.func
            leaf = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else None
            )
            if leaf in _SHIM_CALLS:
                yield self.finding(
                    ctx,
                    call,
                    f"{leaf} is a deprecated shim; library code should use "
                    f"{_SHIM_CALLS[leaf]}",
                )
            elif isinstance(fn, ast.Attribute) and fn.attr == "sample":
                yield self.finding(
                    ctx,
                    call,
                    ".sample(...) is the deprecated submit-and-wait shim; "
                    "library code should submit(seeds, spec, key=...) and "
                    "take ticket.result()",
                )


# config field -> registry holding its legal values
_FIELD_REGISTRIES = {
    "partitioner": "PARTITIONERS",
    "sampler": "SAMPLERS",
    "reorder": "REORDERS",
    "cache_policy": "CACHE_POLICIES",
    "storage_tiers": "STORAGE_TIERS",
}


@register_rule
class ConfigRegistryDrift(Rule):
    id = "PRJ003"
    name = "config-registry-drift"
    family = "project"
    rationale = (
        "GLISPConfig's registry-named fields and any literal "
        "REGISTRY.get('name') lookup are promises about what is "
        "registered; when a registry entry is renamed the promise silently "
        "breaks at a distant call site.  This rule resolves every literal "
        "key against the *live* registries at lint time."
    )

    def _registries(self) -> dict | None:
        try:
            from repro.api import backends
        except ImportError:
            return None  # analyzing a foreign tree: nothing to resolve
        return {
            name: getattr(backends, name)
            for name in sorted(set(_FIELD_REGISTRIES.values()))
            if hasattr(backends, name)
        }

    def check(self, ctx: FileContext):
        registries = None
        for node in ast.walk(ctx.tree):
            # literal lookups: PARTITIONERS.get("name") anywhere
            if isinstance(node, ast.Call):
                fn = node.func
                if (
                    isinstance(fn, ast.Attribute)
                    and fn.attr == "get"
                    and isinstance(fn.value, ast.Name)
                    and fn.value.id in _FIELD_REGISTRIES.values()
                    and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)
                ):
                    if self._in_raises_block(ctx, node):
                        continue  # tests asserting the unknown-key error
                    if registries is None:
                        registries = self._registries()
                        if registries is None:
                            return
                    reg = registries.get(fn.value.id)
                    key = node.args[0].value
                    if reg is not None and key not in reg:
                        yield self.finding(
                            ctx,
                            node.args[0],
                            f"{fn.value.id}.get({key!r}): no such entry "
                            f"(registered: {', '.join(reg.names())})",
                        )
            # GLISPConfig field defaults
            elif isinstance(node, ast.ClassDef) and node.name == "GLISPConfig":
                if registries is None:
                    registries = self._registries()
                    if registries is None:
                        return
                yield from self._check_defaults(ctx, node, registries)

    @staticmethod
    def _in_raises_block(ctx, node) -> bool:
        for anc in ctx.ancestors(node):
            if isinstance(anc, (ast.With, ast.AsyncWith)):
                for item in anc.items:
                    ce = item.context_expr
                    if (
                        isinstance(ce, ast.Call)
                        and ctx.resolve(ce.func) == "pytest.raises"
                    ):
                        return True
        return False

    def _check_defaults(self, ctx, cls, registries):
        for stmt in cls.body:
            if not (
                isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
                and stmt.value is not None
            ):
                continue
            reg = registries.get(_FIELD_REGISTRIES.get(stmt.target.id, ""))
            if reg is None:
                continue
            values = (
                stmt.value.elts
                if isinstance(stmt.value, (ast.Tuple, ast.List))
                else [stmt.value]
            )
            for v in values:
                if (
                    isinstance(v, ast.Constant)
                    and isinstance(v.value, str)
                    and v.value not in reg
                ):
                    yield self.finding(
                        ctx,
                        v,
                        f"GLISPConfig.{stmt.target.id} default {v.value!r} "
                        f"is not registered "
                        f"(registered: {', '.join(reg.names())})",
                    )


def _queue_like(recv: ast.expr) -> bool:
    """Does the receiver *name* look like a queue (``q``, ``cmd_q``,
    ``work_queue``, ``self._data_q``)?  Name-based on purpose: dict.get
    and registry .get calls stay out of scope."""
    name = None
    if isinstance(recv, ast.Name):
        name = recv.id
    elif isinstance(recv, ast.Attribute):
        name = recv.attr
    if name is None:
        return False
    low = name.lower()
    return low == "q" or low.endswith("_q") or "queue" in low


@register_rule
class BlockingWaitNoTimeout(Rule):
    id = "PRJ004"
    name = "blocking-wait-no-timeout"
    family = "project"
    rationale = (
        "a bare ticket.result() or queue.get() in library code blocks "
        "forever when the producing server/worker dies — exactly the hang "
        "the fault-tolerance layer exists to prevent.  Pass timeout= "
        "(timeout=None is fine: it states the unbounded wait is deliberate "
        "or defers to a configured deadline) so a dead peer surfaces as an "
        "exception instead of a wedged process."
    )

    def check(self, ctx: FileContext):
        if not ctx.is_library:
            return
        for call in ctx.calls():
            fn = call.func
            if not isinstance(fn, ast.Attribute):
                continue
            if call.args or any(kw.arg == "timeout" for kw in call.keywords):
                continue
            if fn.attr == "result":
                yield self.finding(
                    ctx,
                    call,
                    ".result() without timeout= blocks forever if the "
                    "request never completes; pass timeout= (None to defer "
                    "to the configured deadline)",
                )
            elif fn.attr == "get" and _queue_like(fn.value):
                yield self.finding(
                    ctx,
                    call,
                    "queue .get() without timeout= hangs if the producer "
                    "died; poll with timeout= and check the worker is alive",
                )


# constructors whose no-argument form is an unbounded FIFO
_UNBOUNDED_QUEUES = {
    "queue.Queue",
    "queue.LifoQueue",
    "queue.PriorityQueue",
    "multiprocessing.Queue",
    "multiprocessing.JoinableQueue",
}


@register_rule
class UnboundedRequestQueue(Rule):
    id = "PRJ005"
    name = "unbounded-request-queue"
    family = "project"
    rationale = (
        "an unbounded request buffer turns overload into unbounded memory "
        "growth and unbounded queueing delay — by the time anything "
        "surfaces, every queued request has already missed its deadline.  "
        "Library queues must carry a capacity: pass maxsize=/maxlen=, or "
        "enforce an explicit admission bound that REJECTS (like "
        "repro.serve.RequestQueue) and suppress with the justification."
    )

    def check(self, ctx: FileContext):
        if not ctx.is_library:
            return
        for call in ctx.calls():
            target = ctx.resolve(call.func)
            if target in _UNBOUNDED_QUEUES:
                # a positional arg or maxsize= states the bound
                if call.args or any(
                    kw.arg == "maxsize" for kw in call.keywords
                ):
                    continue
                yield self.finding(
                    ctx,
                    call,
                    f"{target}() without maxsize is an unbounded buffer; "
                    "bound it or shed load explicitly at admission",
                )
            elif target == "queue.SimpleQueue":
                yield self.finding(
                    ctx,
                    call,
                    "queue.SimpleQueue cannot be bounded at all; use "
                    "queue.Queue(maxsize=...) for request buffering",
                )
            elif target == "collections.deque":
                if any(kw.arg == "maxlen" for kw in call.keywords) or len(
                    call.args
                ) >= 2:
                    continue
                if self._assigned_to_queue_name(ctx, call):
                    yield self.finding(
                        ctx,
                        call,
                        "deque used as a queue with no maxlen; bound it or "
                        "enforce an explicit admission-depth check",
                    )

    @staticmethod
    def _assigned_to_queue_name(ctx: FileContext, call: ast.Call) -> bool:
        """Only deques *named* like queues are in scope — scratch deques
        (visit stacks, sliding windows) are legitimate unbounded uses."""
        parent = ctx.parent(call)
        if isinstance(parent, ast.Assign):
            return any(_queue_like(t) for t in parent.targets)
        if isinstance(parent, ast.AnnAssign):
            return _queue_like(parent.target)
        return False


# receivers whose ``.Process`` attribute is the multiprocessing ctor:
# the module itself or a start-method context (``mp.get_context("fork")``
# conventionally lands in a name like ``ctx``)
_MP_RECEIVERS = ("mp", "multiprocessing", "ctx", "context")

# receiver names that denote a child process handle; thread handles
# (``t``, ``thread``) stay out of scope — a daemon thread dies with the
# interpreter, an unjoined child process does not
_PROC_NAMES = ("proc", "worker", "child", "popen", "subproc")


def _recv_name(recv: ast.expr) -> str | None:
    if isinstance(recv, ast.Name):
        return recv.id
    if isinstance(recv, ast.Attribute):
        return recv.attr
    return None


def _proc_like(recv: ast.expr) -> bool:
    name = _recv_name(recv)
    return name is not None and any(p in name.lower() for p in _PROC_NAMES)


@register_rule
class MultiprocessingHygiene(Rule):
    id = "PRJ006"
    name = "multiprocessing-hygiene"
    family = "project"
    rationale = (
        "a child process spawned without daemon=True outlives a crashed "
        "parent as an orphan holding its pipe fds open, and a bare "
        ".join()/.wait() on a process handle blocks forever when the child "
        "wedges instead of exiting — the distributed tier's crash-recovery "
        "contract requires every spawn to state daemon= and every reap to "
        "carry a timeout= bound (suppress with the justification where the "
        "child is provably already dead, e.g. after SIGKILL)."
    )

    def check(self, ctx: FileContext):
        if not ctx.is_library:
            return
        for call in ctx.calls():
            fn = call.func
            if not isinstance(fn, ast.Attribute):
                continue
            if fn.attr == "Process":
                resolved = ctx.resolve(fn) or ""
                recv = _recv_name(fn.value) or ""
                if resolved != "multiprocessing.Process" and not any(
                    m in recv.lower() for m in _MP_RECEIVERS
                ):
                    continue  # some other .Process attribute
                if any(kw.arg == "daemon" for kw in call.keywords):
                    continue
                yield self.finding(
                    ctx,
                    call,
                    "Process(...) without daemon=: an orphaned child "
                    "outlives a crashed parent; state daemon= explicitly",
                )
            elif fn.attr in ("join", "wait") and _proc_like(fn.value):
                if call.args or any(
                    kw.arg == "timeout" for kw in call.keywords
                ):
                    continue
                yield self.finding(
                    ctx,
                    call,
                    f".{fn.attr}() on a process handle without timeout= "
                    "blocks forever if the child wedges; bound the reap "
                    "with timeout=",
                )
