"""Kernel-hygiene rules (KRN0xx).

The Pallas kernels are the one place the repo's numerics are hand-written
instead of derived from jnp, so each one carries two obligations the rest
of the test suite depends on: an ``interpret`` parameter plumbed into the
``pl.pallas_call`` (so the CPU CI boxes and the property tests can run the
exact kernel body without TPU lowering), and a same-named ``*_ref`` jnp
oracle exported from ``repro.kernels.ref`` (so allclose checks have a
ground truth).  KRN001 machine-checks both.
"""
from __future__ import annotations

import ast

from repro.analysis.core import FileContext, Rule, register_rule

__all__ = ["PallasKernelHygiene"]


def _is_pallas_call(call: ast.Call) -> bool:
    fn = call.func
    leaf = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else None
    )
    return leaf == "pallas_call"


def _has_param(fn: ast.AST, name: str) -> bool:
    args = fn.args
    every = (
        list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
    )
    return any(a.arg == name for a in every)


@register_rule
class PallasKernelHygiene(Rule):
    id = "KRN001"
    name = "pallas-kernel-hygiene"
    family = "kernels"
    rationale = (
        "every pl.pallas_call must plumb an `interpret` parameter from its "
        "enclosing function (hardcoding it strands CPU CI and the property "
        "tests on one execution mode), and every public *_pallas wrapper "
        "must have a same-named *_ref jnp oracle exported from "
        "repro.kernels.ref — a kernel without an oracle is hand-written "
        "numerics nothing can allclose against.  Resolved against the "
        "*live* ref module, like PRJ003 resolves live registries."
    )

    def _ref_module(self):
        try:
            from repro.kernels import ref
        except ImportError:
            return None  # analyzing a foreign tree: nothing to resolve
        return ref

    def check(self, ctx: FileContext):
        if not ctx.is_library:
            return
        calls = [c for c in ctx.calls() if _is_pallas_call(c)]
        if not calls:
            return
        ref = self._ref_module()
        for call in calls:
            yield from self._check_interpret(ctx, call)
        if ref is None:
            return
        for node in ctx.tree.body:
            if not isinstance(node, ast.FunctionDef):
                continue
            if not node.name.endswith("_pallas") or node.name.startswith("_"):
                continue
            oracle = node.name[: -len("_pallas")] + "_ref"
            if not hasattr(ref, oracle):
                yield self.finding(
                    ctx,
                    node,
                    f"{node.name} has no oracle: export {oracle} from "
                    "repro.kernels.ref so property tests can allclose "
                    "the kernel against a jnp ground truth",
                )

    def _check_interpret(self, ctx: FileContext, call: ast.Call):
        fn = ctx.enclosing_function(call)
        if fn is None:
            yield self.finding(
                ctx,
                call,
                "pl.pallas_call at module scope cannot plumb interpret=; "
                "wrap it in a function taking an `interpret` parameter",
            )
            return
        kw = next((k for k in call.keywords if k.arg == "interpret"), None)
        if kw is None:
            yield self.finding(
                ctx,
                call,
                "pl.pallas_call without interpret=; plumb the enclosing "
                "function's `interpret` parameter through so CPU CI can "
                "run the kernel body in interpret mode",
            )
        elif isinstance(kw.value, ast.Constant):
            yield self.finding(
                ctx,
                call,
                "pl.pallas_call hardcodes interpret=; pass the enclosing "
                "function's `interpret` parameter instead of a constant",
            )
        elif not _has_param(fn, "interpret"):
            yield self.finding(
                ctx,
                call,
                f"{fn.name} passes interpret= but takes no `interpret` "
                "parameter; callers must be able to choose the execution "
                "mode per call",
            )
