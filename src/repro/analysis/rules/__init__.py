"""Rule modules; importing this package registers every rule in ``RULES``."""
from repro.analysis.rules import determinism, jax_hygiene, kernels, project  # noqa: F401
