"""CLI: ``python -m repro.analysis [paths...]`` — the CI lint gate.

Exit code 0 when no unsuppressed finding survives, 1 otherwise (2 for
usage errors).  ``--format json --out glint_report.json`` writes the
machine-readable report (always written, even when gating fails, so CI can
upload it as an artifact)."""
from __future__ import annotations

import argparse
import sys

from repro.analysis.core import run_checks
from repro.analysis.reporters import render_json, render_rule_catalog, render_text


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="glint: determinism & JAX-hygiene static analysis for GLISP",
    )
    ap.add_argument("paths", nargs="*", default=["src"], help="files or directories (default: src)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None, help="also write the report (in --format) to this file")
    ap.add_argument("--select", default=None, help="comma-separated rule ids/names/families to run")
    ap.add_argument("--ignore", default=None, help="comma-separated rule ids/names/families to skip")
    ap.add_argument("--show-suppressed", action="store_true", help="list pragma-suppressed findings too")
    ap.add_argument("--list-rules", action="store_true", help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(render_rule_catalog())
        return 0

    select = args.select.split(",") if args.select else None
    ignore = args.ignore.split(",") if args.ignore else None
    report = run_checks(args.paths or ["src"], select=select, ignore=ignore)

    rendered = (
        render_json(report)
        if args.format == "json"
        else render_text(report, show_suppressed=args.show_suppressed)
    )
    if args.out:
        with open(args.out, "w") as f:
            f.write(rendered + "\n")
        # keep the gate's text summary visible even when the report file
        # carries the full JSON
        print(render_text(report, show_suppressed=args.show_suppressed))
    else:
        print(rendered)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
