"""RelNet-style KGE training (paper §IV-D): a 2-layer HGT encoder over the
GLISP sampling service + feed-forward link-prediction decoder, trained on
positive edges with head/tail-corrupted negatives — the paper's large-scale
scalability workload at laptop scale.

    PYTHONPATH=src python examples/kge_relnet.py --steps 60
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import adadne
from repro.core.sampling import GatherApplyClient, SamplingServer, VertexRouter
from repro.graph import build_partitions, named_dataset
from repro.models.gnn import GNNModel, subgraph_to_batch
from repro.train.optim import AdamWConfig, adamw_init, adamw_update

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--batch-edges", type=int, default=128)
ap.add_argument("--hidden", type=int, default=128)
ap.add_argument("--scale", type=float, default=0.08)
args = ap.parse_args()

g = named_dataset("relnet", feat_dim=64, scale=args.scale)
P = 8
print(f"relnet stand-in: {g.num_vertices} vertices, {g.num_edges} edges, {P} partitions")
ep = adadne(g, P, seed=0)
parts = build_partitions(g, ep, P)
client = GatherApplyClient(
    [SamplingServer(p, seed=0) for p in parts], VertexRouter(g, ep, P), seed=0
)

# encoder: 2-layer HGT (paper: hidden 128); decoder: 2-layer FFN on [h_u, h_v]
enc = GNNModel("hgt", 64, hidden=args.hidden, num_layers=2,
               num_classes=args.hidden, num_etypes=g.num_edge_types)
key = jax.random.PRNGKey(0)
params = {
    "enc": enc.init(key),
    "dec": {
        "w1": jax.random.normal(key, (2 * args.hidden, args.hidden)) * 0.05,
        "w2": jax.random.normal(jax.random.fold_in(key, 1), (args.hidden, 1)) * 0.05,
    },
}
opt_state = adamw_init(params)
opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=args.steps)


def score(dec, hu, hv):
    z = jnp.concatenate([hu, hv], axis=-1)
    return (jax.nn.gelu(z @ dec["w1"]) @ dec["w2"])[:, 0]


def loss_fn(params, batch, pos_u, pos_v, neg_u, neg_v):
    h = enc.apply({"layers": params["enc"]["layers"], "out": params["enc"]["out"]}, batch)
    s_pos = score(params["dec"], h[pos_u], h[pos_v])
    s_neg = score(params["dec"], h[neg_u], h[neg_v])
    # logistic link-prediction loss
    return -(jax.nn.log_sigmoid(s_pos).mean() + jax.nn.log_sigmoid(-s_neg).mean())


@jax.jit
def train_step(params, opt_state, batch, pu, pv, nu, nv):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, pu, pv, nu, nv)
    params, opt_state, _ = adamw_update(params, grads, opt_state, opt_cfg)
    return params, opt_state, loss


def etype_lookup(src, dst):
    return ((g.vertex_types[src] * 7 + g.vertex_types[dst] * 3) % g.num_edge_types)


rng = np.random.default_rng(0)
t0 = time.perf_counter()
losses = []
for step in range(args.steps):
    eidx = rng.choice(g.num_edges, args.batch_edges, replace=False)
    pos = np.stack([g.src[eidx], g.dst[eidx]], 1)
    # negatives: corrupt head or tail with a random vertex
    neg = pos.copy()
    corrupt_head = rng.random(args.batch_edges) < 0.5
    rand_v = rng.integers(0, g.num_vertices, args.batch_edges)
    neg[corrupt_head, 0] = rand_v[corrupt_head]
    neg[~corrupt_head, 1] = rand_v[~corrupt_head]
    seeds = np.unique(np.concatenate([pos.reshape(-1), neg.reshape(-1)]))
    sub = client.sample_khop(seeds, [10, 5], direction="out")
    batch = subgraph_to_batch(sub, g.vertex_feats, None, 2,
                              edge_types_lookup=etype_lookup)
    verts = sub.all_vertices()
    # hgt returns per-seed outputs; we need full-table embeddings -> use
    # seed_pos covering every vertex we score
    lookup = {int(v): i for i, v in enumerate(verts)}
    batch.seed_pos = np.searchsorted(verts, np.arange(len(verts))[: 1]).astype(np.int32)
    bj = jax.tree.map(jnp.asarray, batch)
    # positions of scored endpoints in the padded table
    pu = jnp.asarray(np.searchsorted(verts, pos[:, 0]))
    pv = jnp.asarray(np.searchsorted(verts, pos[:, 1]))
    nu = jnp.asarray(np.searchsorted(verts, neg[:, 0]))
    nv = jnp.asarray(np.searchsorted(verts, neg[:, 1]))

    # encoder applied over the full table: reuse apply but take hidden states
    def full_loss(params):
        h = bj.feats
        for k in range(enc.num_layers):
            h = enc.layer(params["enc"]["layers"][k], k, h,
                          bj.layer_dst[k], bj.layer_src[k], bj.layer_etype[k])
            h = h * bj.valid[:, None]
        s_pos = score(params["dec"], h[pu], h[pv])
        s_neg = score(params["dec"], h[nu], h[nv])
        return -(jax.nn.log_sigmoid(s_pos).mean()
                 + jax.nn.log_sigmoid(-s_neg).mean())

    loss, grads = jax.value_and_grad(full_loss)(params)
    params, opt_state, _ = adamw_update(params, grads, opt_state, opt_cfg)
    losses.append(float(loss))
    if step % 10 == 0:
        print(f"step {step:3d} loss {losses[-1]:.4f}")

dt = time.perf_counter() - t0
print(f"\n{args.steps} steps in {dt:.1f}s ({args.steps/dt:.2f} steps/s)")
print(f"loss {losses[0]:.4f} -> {np.mean(losses[-5:]):.4f}")
wl = client.server_workloads()
print(f"sampling server balance max/min: {wl.max()/wl.min():.3f}")
assert np.mean(losses[-5:]) < losses[0], "KGE loss must decrease"
print("OK")
