"""Full-graph inference demo (paper §III-D): layerwise engine vs naive
samplewise on the same trained model — reports the redundancy eliminated,
chunk reads, dynamic-cache hit ratio, and modeled retrieval speedup of the
tiered ``HybridCache`` with each reorder algorithm and eviction policy.
The system (partitioner + sampling service) comes from the facade; the
reorder algorithm and cache policy are swapped per run through
``infer_layerwise(reorder=..., cache_policy=...)``.

    PYTHONPATH=src python examples/layerwise_inference.py
"""
import tempfile
import time

import numpy as np

from repro.api import GLISPConfig, GLISPSystem
from repro.core.inference import samplewise_inference
from repro.core.storage import IOCost
from repro.graph import power_law_graph

g = power_law_graph(12000, avg_degree=8, seed=1, feat_dim=32)
system = GLISPSystem.build(g, GLISPConfig(
    num_parts=4, partitioner="adadne", fanouts=(10, 10), dynamic_frac=0.1,
))

rng = np.random.default_rng(0)
W = [rng.standard_normal((64, 32)).astype(np.float32) * 0.3 for _ in range(2)]


def make_layer(k):
    def layer(_k, h_self, h_nbr, seg):
        agg = np.zeros_like(h_self)
        cnt = np.zeros(h_self.shape[0])
        if h_nbr.shape[0]:
            np.add.at(agg, seg, h_nbr)
            np.add.at(cnt, seg, 1.0)
        agg /= np.maximum(cnt, 1)[:, None]
        return np.tanh(np.concatenate([h_self, agg], 1) @ W[k])
    return layer


layers = [make_layer(0), make_layer(1)]
cost = IOCost()

print("reorder | policy   | chunk reads | dyn hit | modeled speedup vs raw DFS")
for alg, policy in (
    ("NS", "fifo"), ("DS", "fifo"), ("PS", "fifo"),
    ("PDS", "fifo"), ("PDS", "locality"),
):
    with tempfile.TemporaryDirectory() as td:
        # numpy layer fns run through the vectorized gather without jit;
        # GNNModel.embed_layer_fn slices would additionally get the
        # shape-bucketed device-resident path (mode/jit/use_kernel knobs)
        res = system.infer_layerwise(
            layers, td, chunk_rows=512, out_dims=[32, 32],
            reorder=alg, cache_policy=policy, batch_size=512,
        )
    reads = res.total_chunk_reads() + sum(s.cache.fill_chunks for s in res.layer_stats)
    baseline = (res.total_chunk_reads() + res.total_dynamic_hits()) * cost.dfs_ms
    speedup = baseline / max(res.modeled_io_ms(cost), 1e-9)
    print(f"{alg:7s} | {policy:8s} | {reads:11d} | "
          f"{res.dynamic_hit_ratio():7.2%} | {speedup:6.2f}x")

# redundancy vs samplewise on a slice
targets = rng.choice(g.num_vertices, 1024, replace=False)
t0 = time.perf_counter()
_, st = samplewise_inference(g, system.client, layers, g.vertex_feats, targets,
                             fanouts=[10, 10], batch_size=64)
t_sw = time.perf_counter() - t0
per_target_sw = st["vertices_computed"] / targets.shape[0]
print(f"\nsamplewise computes {per_target_sw:.1f} vertex-layers per target;")
print(f"layerwise computes exactly {len(layers)} -> "
      f"{per_target_sw/len(layers):.1f}x redundancy eliminated")
