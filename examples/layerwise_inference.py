"""Full-graph inference demo (paper §III-D): layerwise engine vs naive
samplewise on the same trained model — reports the redundancy eliminated,
chunk reads, dynamic-cache hit ratio, and modeled retrieval speedup of the
two-level cache with each reorder algorithm.

    PYTHONPATH=src python examples/layerwise_inference.py
"""
import tempfile
import time

import numpy as np

from repro.core.inference import LayerwiseInferenceEngine, samplewise_inference
from repro.core.inference.store import IOCost
from repro.core.partition import adadne
from repro.core.sampling import GatherApplyClient, SamplingServer, VertexRouter
from repro.graph import build_partitions, power_law_graph

g = power_law_graph(12000, avg_degree=8, seed=1, feat_dim=32)
P = 4
ep = adadne(g, P, seed=0)
parts = build_partitions(g, ep, P)
client = GatherApplyClient(
    [SamplingServer(p, seed=0) for p in parts], VertexRouter(g, ep, P), seed=0
)

rng = np.random.default_rng(0)
W = [rng.standard_normal((64, 32)).astype(np.float32) * 0.3 for _ in range(2)]


def make_layer(k):
    def layer(_k, h_self, h_nbr, seg):
        agg = np.zeros_like(h_self)
        cnt = np.zeros(h_self.shape[0])
        if h_nbr.shape[0]:
            np.add.at(agg, seg, h_nbr)
            np.add.at(cnt, seg, 1.0)
        agg /= np.maximum(cnt, 1)[:, None]
        return np.tanh(np.concatenate([h_self, agg], 1) @ W[k])
    return layer


layers = [make_layer(0), make_layer(1)]
cost = IOCost()

print("reorder | chunk reads | dyn hit | modeled speedup vs raw DFS")
for alg in ("NS", "DS", "PS", "PDS"):
    with tempfile.TemporaryDirectory() as td:
        eng = LayerwiseInferenceEngine(
            g, client, layers, g.vertex_feats, td, fanouts=[10, 10],
            chunk_rows=512, out_dims=[32, 32], reorder_alg=alg,
            batch_size=512, dynamic_frac=0.1,
        )
        res = eng.run()
    reads = res.total_chunk_reads() + sum(s.cache.fill_chunks for s in res.layer_stats)
    baseline = (res.total_chunk_reads() + res.total_dynamic_hits()) * cost.dfs_ms
    speedup = baseline / max(res.modeled_io_ms(cost), 1e-9)
    print(f"{alg:7s} | {reads:11d} | {res.dynamic_hit_ratio():7.2%} | {speedup:6.2f}x")

# redundancy vs samplewise on a slice
targets = rng.choice(g.num_vertices, 1024, replace=False)
t0 = time.perf_counter()
_, st = samplewise_inference(g, client, layers, g.vertex_feats, targets,
                             fanouts=[10, 10], batch_size=64)
t_sw = time.perf_counter() - t0
per_target_sw = st["vertices_computed"] / targets.shape[0]
print(f"\nsamplewise computes {per_target_sw:.1f} vertex-layers per target;")
print(f"layerwise computes exactly {len(layers)} -> "
      f"{per_target_sw/len(layers):.1f}x redundancy eliminated")
