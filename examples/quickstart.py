"""Quickstart: the whole GLISP pipeline on a synthetic power-law graph,
driven entirely through the unified facade (repro.api).

    PYTHONPATH=src python examples/quickstart.py

1. generate a power-law graph
2. GLISPSystem.build — AdaDNE vertex-cut partitioning + Gather-Apply
   sampling service, all resolved by registry name from GLISPConfig
3. sample K-hop subgraphs through the async request-plan service
   (submit -> SampleTicket -> result), with in-flight requests
   overlapping hop levels on the shared SamplingService
4. train GraphSAGE with the prefetching batch pipeline (host sampling
   overlaps the jit'd train step; the pipeline keeps `inflight` sample
   requests riding on the service at once)
5. run layerwise full-graph inference with the two-level cache + PDS
6. lint the library with the glint static analyzer (repro.analysis) —
   the same determinism/JAX-hygiene gate CI runs
7. chaos: rebuild the system with replicated servers and a deterministic
   fault plan knocking primaries over — retries and failovers redraw from
   the same keyed RNG, so the sampled subgraph is bit-identical
8. online serving: GLISPSystem.server() batches live "embed these
   vertices" requests into the engine's compiled shape buckets, with
   bounded admission, deadlines and P50/P99 SLO metrics
"""
import tempfile
import time

import numpy as np

from repro.api import GLISPConfig, GLISPSystem, SamplingSpec
from repro.graph import power_law_graph
from repro.models.gnn import GNNModel
from repro.train.optim import AdamWConfig

print("== 1. generate graph ==")
g = power_law_graph(8000, avg_degree=10, seed=0, feat_dim=32, num_classes=0)
g.labels = g.vertex_types.astype(np.int32)
g.vertex_feats[:, :3] = 0
g.vertex_feats[np.arange(g.num_vertices), g.labels] += 2.0
print(f"   {g.num_vertices} vertices, {g.num_edges} edges, "
      f"max degree {int((g.out_degrees()+g.in_degrees()).max())}")

print("== 2. build the GLISP system ==")
config = GLISPConfig(
    num_parts=4,
    partitioner="adadne",
    sampler="gather_apply",
    fanouts=(10, 5),
    batch_size=256,
    prefetch=2,          # background sampling overlaps the train step
    reorder="pds",
    cache_policy="fifo",
)
t0 = time.perf_counter()
system = GLISPSystem.build(g, config)
m = system.partition_metrics()
print(f"   RF={m['RF']:.3f} VB={m['VB']:.3f} EB={m['EB']:.3f} "
      f"({time.perf_counter()-t0:.2f}s)")

print("== 3. sample through the async request-plan service ==")
# blocking convenience: submit-and-wait in one call
sub = system.sample(np.arange(64), fanouts=[15, 10, 5])
print(f"   3-hop sample of 64 seeds: {sub.num_edges} edges, "
      f"{sub.all_vertices().shape[0]} vertices")
# the ticket API: several requests ride in flight on the one service;
# the scheduler overlaps their hops and coalesces shared frontier seeds,
# and per-request RNG keys keep every result bit-reproducible
spec = SamplingSpec(fanouts=(15, 10, 5))
tickets = [
    system.submit(np.arange(lo, lo + 64), spec, key=(lo,))
    for lo in (0, 64, 128)
]
print(f"   {system.service.inflight()} requests in flight ...")
subs = [t.result() for t in tickets]
stats = system.service.stats()
print(f"   {sum(s.num_edges for s in subs)} edges over {len(subs)} tickets | "
      f"service stats: {stats.requests} dispatches, "
      f"{stats.seeds} seeds, {stats.edges_returned} edges returned")

print("== 4. train GraphSAGE (prefetching pipeline) ==")
ids = np.arange(g.num_vertices)
model = GNNModel("sage", 32, hidden=64, num_layers=2, num_classes=3)
trainer = system.train(
    model, ids[:6000], epochs=2,
    opt=AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=200),
)
log = trainer.log
acc = trainer.evaluate(ids[6000:])
print(f"   loss {log.losses[0]:.3f} -> {log.losses[-1]:.3f}, test acc {acc:.3f}")
print(f"   host sampling {log.sample_time:.1f}s overlapped with "
      f"device compute {log.compute_time:.1f}s")

print("== 5. layerwise full-graph inference ==")
layer_fns = [model.embed_layer_fn(trainer.params, k) for k in range(2)]
with tempfile.TemporaryDirectory() as td:
    t0 = time.perf_counter()
    res = system.infer_layerwise(
        layer_fns, td, fanouts=[10, 5], chunk_rows=1024, out_dims=[64, 64]
    )
    dt = time.perf_counter() - t0
print(f"   embeddings for all {g.num_vertices} vertices in {dt:.1f}s | "
      f"chunk reads {res.total_chunk_reads()} | "
      f"dynamic hit ratio {res.dynamic_hit_ratio():.2%}")

print("== 6. static analysis (glint) ==")
# The conventions everything above relies on — keyed randomness, stable
# iteration orders, pure-jnp jit bodies, bucketed pad shapes — are
# machine-checked by repro.analysis.  `run_checks` is the library entry
# point behind `python -m repro.analysis src tests benchmarks examples`
# (the CI gate); here we lint the analyzer's own package so the demo works
# from any working directory.
import os

import repro.analysis
from repro.analysis import run_checks

report = run_checks([os.path.dirname(repro.analysis.__file__)])
print(f"   {report.files_checked} files, {len(report.rule_ids)} rules -> "
      f"{len(report.findings)} findings, {len(report.suppressed)} suppressed")
assert report.ok, "\n".join(f.render() for f in report.findings)

print("== 7. chaos: failover without changing a single sample ==")
# Two replicas per partition; a deterministic fault plan takes every
# primary (replica 0) down in bursts.  Dispatch RNG is keyed by
# (request, hop, partition) — not by attempt or replica — so the rerouted
# run redraws the exact same neighbors the clean run drew.
from repro.api import FaultPlan, FaultSpec, RetryPolicy

chaos_cfg = GLISPConfig(
    num_parts=4,
    fanouts=(10, 5),
    server_replicas=2,
    fault_plan=FaultPlan(
        seed=13, sites=(("server.*.0", FaultSpec(p=0.5, burst=4, limit=8)),)
    ),
    retry_policy=RetryPolicy(max_attempts=4, base_delay_s=0.0),
    ticket_timeout=30.0,
)
chaotic = GLISPSystem.build(g, chaos_cfg)
spec = SamplingSpec(fanouts=(10, 5))
clean_sub = system.submit(np.arange(64), spec, key=(0xC4A05,)).result(timeout=30.0)
chaos_sub = chaotic.submit(np.arange(64), spec, key=(0xC4A05,)).result(timeout=30.0)
cstats = chaotic.service.stats()
identical = all(
    np.array_equal(a.src, b.src) and np.array_equal(a.dst, b.dst)
    for a, b in zip(clean_sub.hops, chaos_sub.hops)
)
assert identical and not chaos_sub.degraded
health = chaotic.server_health()
print(f"   {cstats.retries} retries, {cstats.failovers} failovers, "
      f"{sum(1 for s in health.values() if s != 'up')} replicas "
      f"quarantined -> subgraph bit-identical: {identical}")

print("== 8. online serving over the inference artifact ==")
# Serving recomputes only the final layer per request, so it needs the
# layerwise stores on disk — rerun inference into a directory that
# outlives this block (section 5's TemporaryDirectory is already gone).
serve_dir = tempfile.mkdtemp(prefix="quickstart_serve_")
system.infer_layerwise(
    layer_fns, serve_dir, fanouts=[10, 5], chunk_rows=1024, out_dims=[64, 64]
)
server = system.server(max_batch_delay_ms=0.0)
rng = np.random.default_rng(0)
rids = [
    server.submit(rng.choice(g.num_vertices, size=5, replace=False))
    for _ in range(12)
]
server.drain()  # continuous batching: several requests per compiled slice
responses = [server.response(r) for r in rids]
assert all(r.status == "ok" for r in responses)
snap = server.stats.snapshot()
print(f"   {snap['completed']} responses in {snap['batches']} batches "
      f"({responses[0].embeddings.shape[1]}-dim rows) | "
      f"P50 {snap['latency']['p50_ms']:.1f} ms "
      f"P99 {snap['latency']['p99_ms']:.1f} ms | "
      f"bucket occupancy {snap['occupancy']:.2f}")

print("== 9. distributed sampling workers + data-parallel training ==")
# dist_transport="mp" forks one worker process per partition; each owns
# that partition's sampling servers and answers framed dispatches over a
# pipe (dist_transport="socket" runs the same frames over a socketpair).
# Dispatch RNG is keyed by (request, hop, partition) — never by which
# process answers — so the remote system redraws exactly the sample its
# in-process twin draws.
twin_cfg = dict(num_parts=2, fanouts=(10, 5), seed=3)
inproc = GLISPSystem.build(g, GLISPConfig(**twin_cfg))
dist_system = GLISPSystem.build(g, GLISPConfig(dist_transport="mp", **twin_cfg))
local_sub = inproc.submit(np.arange(64), spec, key=(0xD157,)).result(timeout=30.0)
remote_sub = dist_system.submit(np.arange(64), spec, key=(0xD157,)).result(
    timeout=30.0
)
assert all(
    np.array_equal(a.src, b.src)
    and np.array_equal(a.dst, b.dst)
    and np.array_equal(a.eid, b.eid)
    for a, b in zip(local_sub.hops, remote_sub.hops)
)
workers_up = sum(
    1 for k, v in dist_system.server_health().items()
    if k.startswith("worker.") and v == "up"
)
print(f"   {workers_up} worker processes up -> remote sample bit-identical "
      f"to in-process: True")

# the data-parallel trainer shards the train step over the mesh's data
# axis (one sampling client per shard, params replicated); with one host
# device this is a 1-shard mesh — benchmarks/distributed.py forces 4 CPU
# devices via XLA_FLAGS and sweeps 1/2/4 shards.  reference=True runs an
# unsharded twin step on the same stacked batches for an equivalence check.
dp = dist_system.dp_trainer(model, np.arange(256), batch_size=32, reference=True)
dp_log = dp.train(epochs=1, log_every=1, max_steps=4)
assert np.allclose(dp_log.losses, dp_log.ref_losses, rtol=1e-5)
print(f"   {dp.num_shards}-shard dp loss {dp_log.losses[0]:.3f} -> "
      f"{dp_log.losses[-1]:.3f} (matches single-device reference)")
dist_system.close()  # joins the forked workers (bounded, then escalates)
print("done.")
