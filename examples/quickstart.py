"""Quickstart: the whole GLISP pipeline on a synthetic power-law graph.

    PYTHONPATH=src python examples/quickstart.py

1. generate a power-law graph
2. partition with AdaDNE (vertex-cut, balanced)
3. launch the Gather-Apply sampling service
4. train GraphSAGE for one epoch
5. run layerwise full-graph inference with the two-level cache + PDS
"""
import tempfile
import time

import numpy as np

from repro.core.inference import LayerwiseInferenceEngine
from repro.core.partition import adadne
from repro.core.sampling import GatherApplyClient, SamplingServer, VertexRouter
from repro.graph import build_partitions, partition_metrics, power_law_graph
from repro.models.gnn import GNNModel
from repro.train import GNNTrainer
from repro.train.optim import AdamWConfig

P = 4

print("== 1. generate graph ==")
g = power_law_graph(8000, avg_degree=10, seed=0, feat_dim=32, num_classes=0)
g.labels = g.vertex_types.astype(np.int32)
g.vertex_feats[:, :3] = 0
g.vertex_feats[np.arange(g.num_vertices), g.labels] += 2.0
print(f"   {g.num_vertices} vertices, {g.num_edges} edges, "
      f"max degree {int((g.out_degrees()+g.in_degrees()).max())}")

print("== 2. AdaDNE vertex-cut partitioning ==")
t0 = time.perf_counter()
ep = adadne(g, P, seed=0)
parts = build_partitions(g, ep, P)
m = partition_metrics(parts, g.num_vertices)
print(f"   RF={m['RF']:.3f} VB={m['VB']:.3f} EB={m['EB']:.3f} "
      f"({time.perf_counter()-t0:.2f}s)")

print("== 3. Gather-Apply sampling service ==")
client = GatherApplyClient(
    [SamplingServer(p, seed=0) for p in parts], VertexRouter(g, ep, P), seed=0
)
sub = client.sample_khop(np.arange(64), [15, 10, 5])
print(f"   3-hop sample of 64 seeds: {sub.num_edges} edges, "
      f"{sub.all_vertices().shape[0]} vertices")

print("== 4. train GraphSAGE ==")
ids = np.arange(g.num_vertices)
model = GNNModel("sage", 32, hidden=64, num_layers=2, num_classes=3)
trainer = GNNTrainer(model, client, g, [10, 5], ids[:6000], batch_size=256,
                     opt=AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=200))
log = trainer.train(epochs=2)
acc = trainer.evaluate(ids[6000:])
print(f"   loss {log.losses[0]:.3f} -> {log.losses[-1]:.3f}, test acc {acc:.3f}")

print("== 5. layerwise full-graph inference ==")
params = trainer.params
layer_fns = [model.embed_layer_fn(params, k) for k in range(2)]
with tempfile.TemporaryDirectory() as td:
    eng = LayerwiseInferenceEngine(
        g, client, layer_fns, g.vertex_feats, td, fanouts=[10, 5],
        chunk_rows=1024, out_dims=[64, 64], reorder_alg="PDS",
    )
    t0 = time.perf_counter()
    res = eng.run()
    dt = time.perf_counter() - t0
print(f"   embeddings for all {g.num_vertices} vertices in {dt:.1f}s | "
      f"chunk reads {res.total_chunk_reads()} | "
      f"dynamic hit ratio {res.dynamic_hit_ratio():.2%}")
print("done.")
