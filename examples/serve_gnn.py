"""Online GNN serving end-to-end: ``GLISPSystem.server()`` under Zipf load.

    PYTHONPATH=src python examples/serve_gnn.py
    PYTHONPATH=src python examples/serve_gnn.py --requests 200 --window 16

Builds the system, runs layerwise inference once (the offline artifact),
then drives the serving tier with a Zipf-popularity client: continuous
batching into the engine's compiled shape buckets, printed P50/P99, and a
degraded-response demo (a fault plan that drops sampling replicas — the
server answers with ``degraded=True`` instead of failing).
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.api import FaultPlan, FaultSpec, GLISPConfig, GLISPSystem, RetryPolicy
from repro.graph import power_law_graph
from repro.models.gnn import GNNModel

ap = argparse.ArgumentParser()
ap.add_argument("--vertices", type=int, default=3000)
ap.add_argument("--requests", type=int, default=100)
ap.add_argument("--window", type=int, default=8, help="in-flight requests")
ap.add_argument("--zipf", type=float, default=1.3)
args = ap.parse_args()

FEAT, HIDDEN, LAYERS = 16, 32, 2

print("== build + offline layerwise inference ==")
g = power_law_graph(args.vertices, avg_degree=8, seed=7, feat_dim=FEAT, num_classes=4)
system = GLISPSystem.build(g, GLISPConfig(num_parts=4, fanouts=(10, 5), seed=0))
model = GNNModel("sage", FEAT, hidden=HIDDEN, num_layers=LAYERS)
params = model.init(jax.random.PRNGKey(0))
fns = [model.embed_layer_fn(params, k) for k in range(LAYERS)]
workdir = tempfile.mkdtemp(prefix="serve_gnn_")
system.infer_layerwise(fns, workdir, out_dims=[HIDDEN, HIDDEN])
print(f"   embeddings on disk under {workdir}")

print("== online serving: Zipf traffic, continuous batching ==")
server = system.server(queue_depth=args.window, max_batch_delay_ms=0.0,
                       deadline_ms=None)
rng = np.random.default_rng(0)
ranks = np.arange(1, g.num_vertices + 1, dtype=np.float64) ** -args.zipf
popularity = ranks / ranks.sum()
requests = [
    np.unique(rng.choice(g.num_vertices, size=rng.integers(1, 9), p=popularity))
    for _ in range(args.requests)
]

inflight, nxt, done = [], 0, 0
while done < len(requests):
    while nxt < len(requests) and len(inflight) < args.window:
        inflight.append(server.submit(requests[nxt]))
        nxt += 1
    server.step(force=True)
    for rid in list(inflight):
        resp = server.response(rid)
        if resp is not None:
            assert resp.status == "ok" and resp.embeddings.shape[1] == HIDDEN
            inflight.remove(rid)
            done += 1

snap = server.stats.snapshot()
lat = snap["latency"]
print(f"   {snap['completed']} responses, {snap['batches']} batches "
      f"(mean {server.stats.mean_batch_requests():.1f} requests/batch)")
print(f"   P50 {lat['p50_ms']:.2f} ms   P99 {lat['p99_ms']:.2f} ms")
print(f"   bucket occupancy {snap['occupancy']:.2f}  "
      f"cache hits {snap['cache_hit_ratios']}")

print("== degraded responses under a fault plan ==")
faulty = GLISPSystem.build(
    g,
    GLISPConfig(
        num_parts=4,
        fanouts=(10, 5),
        seed=0,
        # every sampling replica drops gathers often enough that some
        # dispatches exhaust their retries -> partial (degraded) samples
        fault_plan=FaultPlan(seed=3, sites=(("server.*", FaultSpec(p=0.9)),)),
        retry_policy=RetryPolicy(max_attempts=1),
    ),
)
faulty.infer_layerwise(fns, tempfile.mkdtemp(prefix="serve_gnn_deg_"),
                       out_dims=[HIDDEN, HIDDEN])
deg_server = faulty.server(deadline_ms=None)
degraded = 0
for verts in requests[:20]:
    resp = deg_server.call(verts)
    assert resp.status == "ok"  # degraded, not dead: embeddings still come back
    degraded += resp.degraded
print(f"   {degraded}/20 responses flagged degraded=True "
      f"(partial sampling, explicit — never silent)")
print("done.")
