"""End-to-end driver: train a 3-layer GraphSAGE (~100M-parameter-class
pipeline at configurable scale) for a few hundred steps on the GLISP stack,
with checkpointing and workload-balance reporting.  The full system is
assembled by the facade; ``--prefetch`` controls the background sampling
depth (0 = serial sample-then-step).

    PYTHONPATH=src python examples/train_gnn_e2e.py --steps 200
"""
import argparse
import time

import numpy as np

from repro.api import GLISPConfig, GLISPSystem
from repro.graph import named_dataset
from repro.models.gnn import GNNModel
from repro.train import save_checkpoint
from repro.train.optim import AdamWConfig

ap = argparse.ArgumentParser()
ap.add_argument("--dataset", default="ogbn-paper")
ap.add_argument("--scale", type=float, default=0.2)
ap.add_argument("--parts", type=int, default=8)
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=256)
ap.add_argument("--hidden", type=int, default=256)
ap.add_argument("--prefetch", type=int, default=2)
ap.add_argument("--partitioner", default="adadne")
ap.add_argument("--ckpt", default="/tmp/glisp_sage.npz")
args = ap.parse_args()

g = named_dataset(args.dataset, feat_dim=64, num_classes=0, scale=args.scale)
g.labels = (g.vertex_types % 4).astype(np.int32)
g.vertex_feats[:, :4] = 0
g.vertex_feats[np.arange(g.num_vertices), g.labels] += 2.0
print(f"{args.dataset}: {g.num_vertices} vertices {g.num_edges} edges")

system = GLISPSystem.build(g, GLISPConfig(
    num_parts=args.parts,
    partitioner=args.partitioner,
    fanouts=(15, 10, 5),
    batch_size=args.batch,
    prefetch=args.prefetch,
))
model = GNNModel("sage", 64, hidden=args.hidden, num_layers=3, num_classes=4)
ids = np.arange(g.num_vertices)
n_train = int(0.8 * len(ids))
epochs = max(1, args.steps * args.batch // n_train)
t0 = time.perf_counter()
trainer = system.train(
    model, ids[:n_train], epochs=epochs, log_every=10,
    opt=AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps),
)
dt = time.perf_counter() - t0
log = trainer.log
acc = trainer.evaluate(ids[n_train:])
wl = system.server_workloads()
print(f"steps={len(log.steps)*10} wall={dt:.1f}s "
      f"(sample {log.sample_time:.1f}s / compute {log.compute_time:.1f}s, "
      f"prefetch={args.prefetch})")
print(f"loss {log.losses[0]:.3f} -> {log.losses[-1]:.3f} | test acc {acc:.3f}")
print(f"server workload balance (max/min): {wl.max()/wl.min():.3f}")
save_checkpoint(args.ckpt, {"params": trainer.params}, step=args.steps)
print("checkpoint ->", args.ckpt)
