"""Serve a small model with batched requests: prefill + decode loop over the
KV/state cache, for any assigned architecture (reduced config on CPU).

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b
    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-130m --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.specs import make_decode_step, make_prefill_step
from repro.models.transformer.model import init_cache, init_params

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma-2b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen", type=int, default=16)
args = ap.parse_args()

cfg = get_config(args.arch, reduced=True)
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
max_len = args.prompt_len + args.gen
cache = init_cache(cfg, args.batch, max_len)

if cfg.input_mode == "embeddings":
    prompt = jax.random.normal(key, (args.batch, args.prompt_len, cfg.d_model))
else:
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

prefill = jax.jit(make_prefill_step(cfg))
decode = jax.jit(make_decode_step(cfg))

t0 = time.perf_counter()
logits, cache = prefill(params, cache, {"inputs": prompt})
jax.block_until_ready(logits)
t_pref = time.perf_counter() - t0

tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)
out = [tok]
t0 = time.perf_counter()
for i in range(args.gen):
    pos = args.prompt_len + i
    if cfg.input_mode == "embeddings":
        inp = jax.random.normal(jax.random.fold_in(key, i), (args.batch, 1, cfg.d_model))
    else:
        inp = out[-1][:, None]
    logits, cache = decode(params, cache, {"inputs": inp}, jnp.int32(pos))
    out.append(jnp.argmax(logits[:, : cfg.vocab_size], axis=-1))
jax.block_until_ready(out[-1])
t_dec = (time.perf_counter() - t0) / args.gen

print(f"{cfg.name}: prefill({args.prompt_len}) {t_pref*1e3:.1f} ms | "
      f"decode {t_dec*1e3:.2f} ms/token (batch {args.batch})")
print("greedy tokens[b=0]:", [int(t[0]) for t in out])
