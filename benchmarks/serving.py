"""Online-serving benchmark: Zipf traffic against ``GLISPSystem.server()``.

A closed-loop harness drives the serving tier at several offered loads
(concurrent in-flight requests).  Request popularity is Zipf-distributed
over the vertex set — the paper's power-law assumption as live traffic —
so the serving cache's fast tiers absorb the hot head.  Per load we
report throughput, P50/P99 latency (the online P² estimator, cross-checked
against exact percentiles), batch occupancy (real rows vs padded bucket
rows), and the per-tier cache hit ratios.

End-of-run asserts, per ISSUE 8:

- batch occupancy at the highest load beats the single-request baseline
  (continuous batching actually fills the padded buckets);
- responses at every load are bit-identical per request to the load-1
  run (batching never changes results);
- a repeat of the highest load after warmup triggers ZERO jit retraces
  (``recompile_guard``): serving rides the engine's existing buckets.

Results land in ``BENCH_serving.json`` (``--out``); ``--smoke`` shrinks
the workload for CI.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

from benchmarks.common import dataset, emit, glisp_system

RESULTS: dict = {}

FANOUTS = (10, 5)
ZIPF_A = 1.3  # popularity skew exponent
MAX_REQ_VERTS = 8


def _emit(name: str, value: float) -> None:
    RESULTS[name] = float(value)
    emit(name, value)


def _flag(name: str, ok: bool) -> None:
    RESULTS[name] = bool(ok)
    emit(name, 1.0 if ok else 0.0)


def _zipf_requests(g, num_requests: int, seed: int = 0) -> list[np.ndarray]:
    """Deterministic Zipf-popularity request stream: rank r is vertex
    ``perm[r]`` with weight ``(r+1)^-a``, so a few hot vertices dominate."""
    rng = np.random.default_rng(seed)
    n = g.num_vertices
    w = (np.arange(1, n + 1, dtype=np.float64)) ** -ZIPF_A
    p = w / w.sum()
    perm = rng.permutation(n)
    sizes = rng.integers(1, MAX_REQ_VERTS + 1, size=num_requests)
    return [
        perm[np.unique(rng.choice(n, size=s, p=p))] for s in sizes
    ]


def _serve_closed_loop(server, requests: list[np.ndarray], window: int):
    """Closed loop at a fixed offered load: keep ``window`` requests in
    flight, flush whenever the window is full (the engine would otherwise
    idle).  Returns (responses by request id, wall seconds)."""
    responses: list = [None] * len(requests)
    inflight: list[int] = []
    nxt = 0
    t0 = time.perf_counter()
    while nxt < len(requests) or inflight:
        while nxt < len(requests) and len(inflight) < window:
            inflight.append(server.submit(requests[nxt]))
            nxt += 1
        server.step(force=True)
        for rid in list(inflight):
            resp = server.response(rid)
            if resp is not None:
                responses[rid] = resp
                inflight.remove(rid)
    return responses, time.perf_counter() - t0


def _build_served_system(g, parts: int, feat_dim: int):
    import jax

    from repro.models.gnn import GNNModel

    system = glisp_system(g, parts, fanouts=FANOUTS)
    model = GNNModel("sage", feat_dim, hidden=16, num_layers=2)
    params = model.init(jax.random.PRNGKey(0))
    fns = [model.embed_layer_fn(params, k) for k in range(2)]
    wd = tempfile.mkdtemp(prefix="bench_serving_")
    system.infer_layerwise(fns, wd, out_dims=[16, 16], chunk_rows=512)
    return system


def bench_loads(system, requests: list[np.ndarray], loads: list[int]):
    baseline = None  # load-1 responses, the bit-identity reference
    for window in loads:
        server = system.server(
            queue_depth=max(window, 1), max_batch_delay_ms=0.0, deadline_ms=None
        )
        responses, wall = _serve_closed_loop(server, requests, window)
        assert all(r is not None and r.status == "ok" for r in responses)
        lat = np.array([r.latency_ms for r in responses])
        st = server.stats
        tag = f"load{window}"
        _emit(f"{tag}/throughput_rps", len(requests) / wall)
        _emit(f"{tag}/p50_ms", st.latency.p50)
        _emit(f"{tag}/p99_ms", st.latency.p99)
        _emit(f"{tag}/p50_exact_ms", float(np.percentile(lat, 50)))
        _emit(f"{tag}/p99_exact_ms", float(np.percentile(lat, 99)))
        _emit(f"{tag}/occupancy", st.occupancy())
        _emit(f"{tag}/edge_occupancy", st.edge_occupancy())
        _emit(f"{tag}/mean_batch_requests", st.mean_batch_requests())
        _emit(f"{tag}/batches", st.batches)
        for tier, ratio in st.cache_hit_ratios.items():
            _emit(f"{tag}/cache_hit/{tier}", ratio)
        # the online P2 estimator must track the exact percentile
        exact = float(np.percentile(lat, 50))
        _flag(
            f"{tag}/p50_estimator_sane",
            abs(st.latency.p50 - exact) <= max(1.0, 2.0 * exact),
        )
        assert st.timed_out == 0 and st.rejected == 0
        if baseline is None:
            baseline = responses
        else:
            identical = all(
                np.array_equal(a.embeddings, b.embeddings)
                for a, b in zip(baseline, responses)
            )
            _flag(f"{tag}/bit_identical_vs_solo", identical)
    return baseline


def bench_recompile(system, requests: list[np.ndarray], window: int) -> None:
    """Repeat the highest load on the warmed engine: zero new retraces."""
    from repro.analysis import recompile_guard

    with recompile_guard(system) as rec:
        server = system.server(
            queue_depth=window, max_batch_delay_ms=0.0, deadline_ms=None
        )
        _serve_closed_loop(server, requests, window)
    _emit("warm/jit_retraces", rec.compiles)
    _emit("warm/new_shapes", rec.new_shapes)
    _flag("warm/zero_retraces", rec.compiles == 0)


def run(smoke: bool = False, out_json: str | None = "BENCH_serving.json"):
    scale = 0.02 if smoke else 0.10
    feat_dim = 8
    num_requests = 48 if smoke else 256
    loads = [1, 4, 16] if smoke else [1, 8, 32]
    g = dataset("wikikg90m", scale=scale, feat_dim=feat_dim)
    system = _build_served_system(g, 4, feat_dim)
    requests = _zipf_requests(g, num_requests, seed=0)

    bench_loads(system, requests, loads)
    bench_recompile(system, requests, loads[-1])

    if out_json:
        with open(out_json, "w") as fh:
            json.dump(RESULTS, fh, indent=2, sort_keys=True)
        print(f"wrote {out_json}")
    top = f"load{loads[-1]}"
    assert RESULTS[f"{top}/bit_identical_vs_solo"], (
        "batched responses diverged from the solo baseline"
    )
    assert RESULTS[f"{top}/occupancy"] > RESULTS["load1/occupancy"], (
        "batching did not improve bucket occupancy over single-request "
        f"serving: {RESULTS[f'{top}/occupancy']:.3f} vs "
        f"{RESULTS['load1/occupancy']:.3f}"
    )
    assert RESULTS["warm/zero_retraces"], (
        f"warm serving retraced {RESULTS['warm/jit_retraces']:.0f} slices"
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI workload")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_json=args.out)
