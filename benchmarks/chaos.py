"""Fault-injection overhead and recovery benchmarks.

Three measurements on identical keyed request streams:

- **failover** — a clean system vs one whose primary replicas are forced
  down mid-run (``server.*.0`` burst faults, two replicas per partition).
  Retry and failover redraw from per-dispatch keyed RNG, so both runs MUST
  produce bit-identical subgraphs; we report the wall-clock overhead of
  rerouting plus the retry/failover counters from ``service.stats()``.
- **recovery** — a process-mode ``BatchPipeline`` whose prefetch worker is
  SIGKILLed mid-epoch; we time the respawn-and-replay gap until the next
  batch arrives and check the full batch stream against a fault-free run.
- **overhead** — sampling with no fault machinery vs an armed-but-silent
  plan (``p=0.0`` everywhere).  The injection hooks must cost <2% when
  disabled; the assertion allows generous CI-timing slack.

Results land in ``BENCH_faults.json`` (``--out``); ``--smoke`` shrinks the
workload for CI (mirroring ``BENCH_sampling.json``).
"""
from __future__ import annotations

import argparse
import json
import multiprocessing
import time

import numpy as np

from benchmarks.common import dataset, emit

RESULTS: dict = {}

FANOUTS = (10, 5)
FORK = "fork" in multiprocessing.get_all_start_methods()


def _emit(name: str, value: float) -> None:
    RESULTS[name] = float(value)
    emit(name, value)


def _flag(name: str, ok: bool) -> None:
    RESULTS[name] = bool(ok)
    emit(name, 1.0 if ok else 0.0)


def _build(g, parts: int, **overrides):
    from repro.api import GLISPConfig, GLISPSystem

    return GLISPSystem.build(
        g, GLISPConfig(num_parts=parts, fanouts=FANOUTS, seed=0, **overrides)
    )


def _same_subgraph(a, b) -> bool:
    if len(a.hops) != len(b.hops):
        return False
    return all(
        np.array_equal(ha.src, hb.src) and np.array_equal(ha.dst, hb.dst)
        for ha, hb in zip(a.hops, b.hops)
    )


def _seed_batches(g, num_batches: int, batch: int):
    rng = np.random.default_rng(0)
    return [
        np.sort(rng.choice(g.num_vertices, batch, replace=False))
        for _ in range(num_batches)
    ]


def _sample_all(system, batches, tag: int):
    from repro.api import SamplingSpec

    spec = SamplingSpec(fanouts=FANOUTS)
    t0 = time.perf_counter()
    subs = [
        system.submit(s, spec, key=(tag, i)).result(timeout=30.0)
        for i, s in enumerate(batches)
    ]
    return subs, time.perf_counter() - t0


def bench_failover(g, parts: int, batches) -> None:
    from repro.api import FaultPlan, FaultSpec, RetryPolicy

    clean = _build(g, parts, server_replicas=2)
    subs_clean, wall_clean = _sample_all(clean, batches, 0xFA11)

    # every primary replica fails in long bursts: the circuit breaker trips
    # and traffic reroutes to replica 1, which must redraw the same samples
    plan = FaultPlan(
        seed=7, sites=(("server.*.0", FaultSpec(p=0.3, burst=8, limit=8)),)
    )
    chaotic = _build(
        g,
        parts,
        server_replicas=2,
        fault_plan=plan,
        retry_policy=RetryPolicy(max_attempts=4, base_delay_s=0.0),
    )
    subs_chaos, wall_chaos = _sample_all(chaotic, batches, 0xFA11)

    identical = all(
        _same_subgraph(a, b) for a, b in zip(subs_clean, subs_chaos)
    )
    _flag("failover/bit_identical", identical)
    stats = chaotic.service.stats()
    _emit("failover/retries", stats.retries)
    _emit("failover/failovers", stats.failovers)
    _emit("failover/clean_wall_s", wall_clean)
    _emit("failover/chaos_wall_s", wall_chaos)
    _emit("failover/latency_overhead", wall_chaos / max(wall_clean, 1e-9))
    _flag("failover/exercised", stats.failovers > 0)


def bench_recovery(g, parts: int) -> None:
    from repro.api.pipeline import BatchPipeline

    _flag("recovery/fork_available", FORK)
    if not FORK:
        return

    def _pipe(system, **kw):
        return BatchPipeline(
            system.backend,
            g,
            np.arange(0, 512),
            list(FANOUTS),
            len(FANOUTS),
            batch_size=64,
            seed=3,
            **kw,
        )

    ref = []
    for seeds, batch in _pipe(_build(g, parts), prefetch=0).batches(1):
        ref.append((np.asarray(seeds).copy(), np.asarray(batch.feats).copy()))

    got = []
    gap = 0.0
    pipe = _pipe(_build(g, parts), prefetch=1, workers="process")
    try:
        kill_at = len(ref) // 2
        t_kill = None
        for i, (seeds, batch) in enumerate(pipe.batches(1)):
            if t_kill is not None:
                gap = time.perf_counter() - t_kill
                t_kill = None
            got.append(
                (np.asarray(seeds).copy(), np.asarray(batch.feats).copy())
            )
            if i == kill_at:
                pipe._proc.kill()  # simulate an OOM-killed prefetch worker
                t_kill = time.perf_counter()
    finally:
        pipe.close()

    identical = len(got) == len(ref) and all(
        np.array_equal(sa, sb) and np.array_equal(fa, fb)
        for (sa, fa), (sb, fb) in zip(ref, got)
    )
    _flag("recovery/bit_identical", identical)
    _emit("recovery/respawns", pipe.respawn_count)
    _emit("recovery/respawn_gap_s", gap)


def bench_overhead_disabled(g, parts: int, batches) -> None:
    from repro.api import FaultPlan, FaultSpec, RetryPolicy

    bare = _build(g, parts)
    _sample_all(bare, batches, 0x0FF)  # warm caches/JIT before timing
    subs_bare, wall_bare = _sample_all(bare, batches, 0x0FF)

    # armed plan that never fires: every injection hook runs, no faults
    silent = _build(
        g,
        parts,
        fault_plan=FaultPlan(seed=1, sites=(("*", FaultSpec(p=0.0)),)),
        retry_policy=RetryPolicy(max_attempts=3),
    )
    _sample_all(silent, batches, 0x0FF)
    subs_silent, wall_silent = _sample_all(silent, batches, 0x0FF)

    identical = all(
        _same_subgraph(a, b) for a, b in zip(subs_bare, subs_silent)
    )
    _flag("overhead/bit_identical", identical)
    _emit("overhead/bare_wall_s", wall_bare)
    _emit("overhead/armed_wall_s", wall_silent)
    ratio = wall_silent / max(wall_bare, 1e-9)
    _emit("overhead/armed_over_bare", ratio)
    # target <1.02; assert with generous slack for noisy CI runners
    _flag("overhead/within_budget", ratio <= 1.15)


def run(smoke: bool = False, out_json: str | None = "BENCH_faults.json"):
    scale = 0.02 if smoke else 0.10
    parts = 4
    num_batches = 8 if smoke else 32
    batch = 128 if smoke else 512
    g = dataset("wikikg90m", scale=scale, feat_dim=8)
    batches = _seed_batches(g, num_batches, batch)

    bench_failover(g, parts, batches)
    bench_recovery(g, parts)
    bench_overhead_disabled(g, parts, batches)

    if out_json:
        with open(out_json, "w") as fh:
            json.dump(RESULTS, fh, indent=2, sort_keys=True)
        print(f"wrote {out_json}")
    assert RESULTS["failover/bit_identical"], "failover result diverged"
    assert RESULTS["failover/exercised"], "chaos plan never forced a failover"
    assert RESULTS["overhead/bit_identical"], "armed-but-silent run diverged"
    assert RESULTS["overhead/within_budget"], (
        "disabled-injection overhead exceeded budget: "
        f"{RESULTS['overhead/armed_over_bare']:.3f}x"
    )
    if RESULTS["recovery/fork_available"]:
        assert RESULTS["recovery/bit_identical"], "respawned run diverged"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI workload")
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_json=args.out)
