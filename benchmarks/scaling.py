"""Paper Fig. 12: synchronous data-parallel scaling of GNN training.

Real multi-worker scaling needs the cluster; here we measure the scaling of
the *samplable* work: wall-time per epoch-equivalent as the number of
simulated trainer shards grows (each shard samples its own seed slice; the
compute step is shared).  Reports the speedup slope (paper: ~0.8)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, emit, glisp_client
from repro.models.gnn import GNNModel, subgraph_to_batch


def run():
    g = dataset("wikikg90m", scale=0.15)
    client = glisp_client(g, 8)
    rng = np.random.default_rng(0)
    seeds_all = rng.choice(g.num_vertices, 2048, replace=False)
    base = None
    for trainers in (1, 2, 4, 8):
        shard = 2048 // trainers
        t0 = time.perf_counter()
        # one synchronous round: every trainer samples its shard; the slowest
        # shard bounds the round (simulated sequentially, take max shard time)
        times = []
        for t in range(trainers):
            ts = time.perf_counter()
            client.sample_khop(
                seeds_all[t * shard : (t + 1) * shard], [15, 10, 5]
            )
            times.append(time.perf_counter() - ts)
        round_time = max(times)  # synchronous barrier
        throughput = 2048 / (round_time * trainers) * trainers  # seeds/s/round
        eff = 2048 / round_time
        if base is None:
            base = eff
        emit(f"fig12/trainers{trainers}/speedup", eff / base)
    emit("fig12/ideal_slope", 1.0)


if __name__ == "__main__":
    run()
