"""Paper Fig. 14: graph-reorder algorithms (NS/DS/PS/PDS) × caching system —
modeled retrieval speedup over direct DFS reads, total chunk reads, and
dynamic-cache hit ratio."""
from __future__ import annotations

import tempfile

import numpy as np

from benchmarks.common import dataset, emit, glisp_client
from repro.core.inference import LayerwiseInferenceEngine
from repro.core.inference.store import IOCost


def run():
    g = dataset("wikikg90m", scale=1.0, feat_dim=32)
    client = glisp_client(g, 4)
    rng = np.random.default_rng(0)
    W = [rng.standard_normal((64, 32)).astype(np.float32) * 0.3 for _ in range(2)]

    def layer(k, h_self, h_nbr, seg):
        agg = np.zeros_like(h_self)
        cnt = np.zeros(h_self.shape[0])
        if h_nbr.shape[0]:
            np.add.at(agg, seg, h_nbr)
            np.add.at(cnt, seg, 1.0)
        agg /= np.maximum(cnt, 1)[:, None]
        return np.tanh(np.concatenate([h_self, agg], 1) @ W[k])

    cost = IOCost()
    results = {}
    for alg in ("NS", "DS", "PS", "PDS"):
        with tempfile.TemporaryDirectory() as td:
            eng = LayerwiseInferenceEngine(
                g, client, [layer, layer], g.vertex_feats, td,
                # dynamic_frac 0.30 holds the paper's cap/working-set ratio at
                # 1/8000th graph scale (their 10% of ~10k chunks)
                fanouts=[10, 10], chunk_rows=256, out_dims=[32, 32],
                reorder_alg=alg, batch_size=128, dynamic_frac=0.30,
            )
            res = eng.run()
        reads = res.total_chunk_reads()
        fills = sum(s.cache.fill_chunks for s in res.layer_stats)
        hits = res.total_dynamic_hits()
        modeled = res.modeled_io_ms(cost)
        baseline = (reads + hits) * cost.dfs_ms  # every access straight to DFS
        results[alg] = (reads, fills, hits)
        emit(f"fig14a/{alg}/cache_speedup", baseline / modeled)
        emit(f"fig14b/{alg}/chunk_reads", reads + fills)
        emit(f"fig14b/{alg}/dynamic_hit_ratio", res.dynamic_hit_ratio())
    # PDS should read the fewest chunks (paper: 41.5% of NS)
    emit(
        "fig14b/PDS_vs_NS_read_frac",
        (results["PDS"][0] + results["PDS"][1])
        / max(1, results["NS"][0] + results["NS"][1]),
    )


if __name__ == "__main__":
    run()
