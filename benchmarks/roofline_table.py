"""Collect experiments/dryrun/*.json into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m benchmarks.roofline_table [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str):
    rows = []
    for fn in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.2f}"


def table(rows, mesh="16x16"):
    out = [
        "| arch | shape | mem/dev GiB | compute ms | memory ms | collective ms "
        "| dominant | useful/HLO flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh or r.get("skipped"):
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{r['memory']['peak_bytes_per_device']/2**30:.2f} | "
            f"{fmt_ms(rf['compute_s'])} | {fmt_ms(rf['memory_s'])} | "
            f"{fmt_ms(rf['collective_s'])} | {rf['dominant'].replace('_s','')} | "
            f"{rf['useful_flops_ratio']:.2f} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(args.dir)
    print(table(rows, args.mesh))
    # summary: worst roofline fraction / most collective-bound
    scored = []
    for r in rows:
        if r.get("mesh") != args.mesh or r.get("skipped"):
            continue
        rf = r["roofline"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / bound if bound else 0
        coll_frac = rf["collective_s"] / bound if bound else 0
        scored.append((r["arch"], r["shape"], frac, coll_frac, rf["dominant"]))
    print("\n# lowest compute fraction (worst roofline):")
    for a, s, f, c, d in sorted(scored, key=lambda x: x[2])[:5]:
        print(f"#   {a} × {s}: compute/bound={f:.2f} dominant={d}")
    print("# most collective-bound:")
    for a, s, f, c, d in sorted(scored, key=lambda x: -x[3])[:5]:
        print(f"#   {a} × {s}: collective/bound={c:.2f}")


if __name__ == "__main__":
    main()
