"""The async sampling service vs the blocking surface.

Three measurements on identical request streams:

- **overlap** — N seed batches sampled one-at-a-time through the blocking
  ``system.sample`` shim vs submitted as a sliding in-flight window on the
  ``SamplingService``.  Same per-request RNG keys on two identically-seeded
  systems, so both paths MUST produce bit-identical subgraphs; we report
  wall-clock (async must not be slower) and the modeled parallel work,
  where overlapping in-flight requests shares scheduling rounds and lowers
  modeled cluster latency.
- **coalescing** — requests with overlapping frontiers with the duplicate-
  seed coalescer on vs off: results bit-equal, dispatch accounting
  (per-seed request overhead) drops.
- Results land in ``BENCH_sampling.json`` (``--out``); ``--smoke`` shrinks
  the workload for CI (mirroring ``BENCH_inference.json``).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import dataset, emit

RESULTS: dict = {}

FANOUTS = (10, 5)


def _emit(name: str, value: float) -> None:
    RESULTS[name] = float(value)
    emit(name, value)


def _build(g, parts: int, **overrides):
    from repro.api import GLISPConfig, GLISPSystem

    return GLISPSystem.build(
        g, GLISPConfig(num_parts=parts, fanouts=FANOUTS, seed=0, **overrides)
    )


def _same_subgraph(a, b) -> bool:
    if len(a.hops) != len(b.hops):
        return False
    return all(
        np.array_equal(ha.src, hb.src) and np.array_equal(ha.dst, hb.dst)
        for ha, hb in zip(a.hops, b.hops)
    )


def _seed_batches(g, num_batches: int, batch: int):
    rng = np.random.default_rng(0)
    return [
        np.sort(rng.choice(g.num_vertices, batch, replace=False))
        for _ in range(num_batches)
    ]


def bench_overlap(g, parts: int, batches, window: int) -> None:
    from repro.api import SamplingSpec

    spec = SamplingSpec(fanouts=FANOUTS)
    keys = [(0xB0B, i) for i in range(len(batches))]

    # blocking: submit-and-wait one request at a time (the old surface)
    blocking = _build(g, parts)
    t0 = time.perf_counter()
    subs_blocking = [
        blocking.submit(s, spec, key=k).result()
        for s, k in zip(batches, keys)
    ]
    wall_blocking = time.perf_counter() - t0

    # async: a sliding window of `window` requests in flight
    asyncs = _build(g, parts)
    t0 = time.perf_counter()
    subs_async = []
    inflight = []
    it = iter(zip(batches, keys))
    while True:
        while len(inflight) < window:
            nxt = next(it, None)
            if nxt is None:
                break
            inflight.append(asyncs.submit(nxt[0], spec, key=nxt[1]))
        if not inflight:
            break
        subs_async.append(inflight.pop(0).result())
    wall_async = time.perf_counter() - t0

    identical = all(
        _same_subgraph(a, b) for a, b in zip(subs_blocking, subs_async)
    )
    RESULTS["overlap/bit_identical"] = bool(identical)
    emit("overlap/bit_identical", 1.0 if identical else 0.0)
    _emit("overlap/blocking_wall_s", wall_blocking)
    _emit("overlap/async_wall_s", wall_async)
    _emit("overlap/blocking_parallel_work", blocking.service.parallel_work)
    _emit("overlap/async_parallel_work", asyncs.service.parallel_work)
    _emit(
        "overlap/parallel_work_win",
        blocking.service.parallel_work
        / max(asyncs.service.parallel_work, 1e-9),
    )
    no_slower = wall_async <= wall_blocking * 1.15  # same draws, small slack
    RESULTS["overlap/async_no_slower"] = bool(no_slower)
    emit("overlap/async_no_slower", 1.0 if no_slower else 0.0)


def bench_coalescing(g, parts: int, batches) -> None:
    from repro.api import SamplingSpec

    spec = SamplingSpec(fanouts=FANOUTS)
    # overlapping frontiers: consecutive batches share half their seeds
    shared = [
        np.union1d(a[: a.shape[0] // 2], b[: b.shape[0] // 2])
        for a, b in zip(batches, batches[1:])
    ] or batches
    keys = [(0xC0A, i) for i in range(len(shared))]

    stats = {}
    subs = {}
    for coalesce in (True, False):
        system = _build(g, parts, coalesce=coalesce)
        tickets = [
            system.submit(s, spec, key=k) for s, k in zip(shared, keys)
        ]
        subs[coalesce] = [t.result() for t in tickets]
        stats[coalesce] = system.service.stats()
    identical = all(
        _same_subgraph(a, b) for a, b in zip(subs[True], subs[False])
    )
    RESULTS["coalesce/bit_identical"] = bool(identical)
    emit("coalesce/bit_identical", 1.0 if identical else 0.0)
    _emit("coalesce/seeds_dispatched_on", stats[True].seeds)
    _emit("coalesce/seeds_dispatched_off", stats[False].seeds)
    _emit(
        "coalesce/dispatch_savings",
        1.0 - stats[True].seeds / max(stats[False].seeds, 1),
    )


def run(smoke: bool = False, out_json: str | None = "BENCH_sampling.json"):
    scale = 0.02 if smoke else 0.12
    parts = 4
    num_batches = 8 if smoke else 48
    batch = 128 if smoke else 512
    window = 4
    g = dataset("wikikg90m", scale=scale, feat_dim=8)
    batches = _seed_batches(g, num_batches, batch)

    bench_overlap(g, parts, batches, window)
    bench_coalescing(g, parts, batches)

    if out_json:
        with open(out_json, "w") as fh:
            json.dump(RESULTS, fh, indent=2, sort_keys=True)
        print(f"wrote {out_json}")
    assert RESULTS["overlap/bit_identical"], "async result diverged"
    assert RESULTS["coalesce/bit_identical"], "coalesced result diverged"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI workload")
    ap.add_argument("--out", default="BENCH_sampling.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_json=args.out)
