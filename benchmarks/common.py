"""Shared benchmark helpers: dataset prep, system cache, CSV emission.

All pipelines are constructed through the unified facade —
``GLISPSystem.build(g, GLISPConfig(...))`` — never by hand-wiring servers
and routers.  ``glisp_client`` / ``edgecut_client`` return the underlying
``SamplingService`` (the legacy client role) for benchmarks that poke
workload counters directly.
"""
from __future__ import annotations

import numpy as np

from repro.api import GLISPConfig, GLISPSystem

_CACHE: dict = {}

# display name (CSV rows) -> registry name
PARTITIONERS = {
    "AdaDNE": "adadne",
    "DistributedNE": "dne",
    "Hash2D": "hash2d",
    "Random": "random",
}


def emit(name: str, value: float, derived: str = "") -> None:
    print(f"{name},{value:.3f},{derived}", flush=True)


def dataset(name: str, scale: float = 0.25, feat_dim: int = 32, num_classes: int = 8):
    from repro.graph import named_dataset

    key = ("ds", name, scale, feat_dim, num_classes)
    if key not in _CACHE:
        _CACHE[key] = named_dataset(
            name, feat_dim=feat_dim, num_classes=num_classes, seed=0, scale=scale
        )
    return _CACHE[key]


def glisp_system(
    g, parts: int, alg: str = "AdaDNE", seed: int = 0, **overrides
) -> GLISPSystem:
    key = ("sys", id(g), alg, parts, seed, tuple(sorted(overrides.items())))
    if key not in _CACHE:
        _CACHE[key] = GLISPSystem.build(
            g,
            GLISPConfig(
                num_parts=parts,
                partitioner=PARTITIONERS.get(alg, alg),
                sampler="gather_apply",
                seed=seed,
                **overrides,
            ),
        )
    return _CACHE[key]


def edgecut_system(
    g, parts: int, seed: int = 0, direction: str | None = None, **overrides
) -> GLISPSystem:
    """DistDGL-style baseline system; ``direction`` picks which one-hop the
    owner answers locally (edges follow that endpoint's owner).  Defaults to
    the stack-wide ``DEFAULT_DIRECTION`` so GLISP-vs-baseline comparisons
    sample the SAME neighborhoods; pass ``direction="in"`` for the strict
    DistDGL in-edges-local layout."""
    if direction is None:
        from repro.api import DEFAULT_DIRECTION

        direction = DEFAULT_DIRECTION
    key = ("ecsys", id(g), parts, seed, direction, tuple(sorted(overrides.items())))
    if key not in _CACHE:
        _CACHE[key] = GLISPSystem.build(
            g,
            GLISPConfig(
                num_parts=parts,
                partitioner="ldg",
                sampler="edge_cut",
                direction=direction,
                seed=seed,
                **overrides,
            ),
        )
    return _CACHE[key]


def partition(g, alg: str, parts: int, seed: int = 0):
    """(edge_assignment, seconds) for one partitioner via the registry —
    times the algorithm alone, no servers/routers built."""
    from repro.api import PARTITIONERS as REGISTRY

    key = ("part", id(g), alg, parts, seed)
    if key not in _CACHE:
        import time

        fn = REGISTRY.get(PARTITIONERS.get(alg, alg))
        t0 = time.perf_counter()
        plan = fn(g, parts, seed=seed)
        _CACHE[key] = (plan.edge_parts, time.perf_counter() - t0)
    return _CACHE[key]


def glisp_client(g, parts: int, alg: str = "AdaDNE", seed: int = 0):
    return glisp_system(g, parts, alg, seed).client


def edgecut_client(g, parts: int, seed: int = 0, direction: str | None = None):
    return edgecut_system(g, parts, seed, direction).client
