"""Shared benchmark helpers: dataset prep, partition cache, CSV emission."""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.partition import (
    adadne,
    distributed_ne,
    edge_cut_to_edge_assignment,
    hash2d_partition,
    ldg_edge_cut,
    random_edge_partition,
)
from repro.core.sampling import (
    EdgeCutClient,
    GatherApplyClient,
    SamplingServer,
    VertexRouter,
)
from repro.graph import build_partitions, named_dataset

_CACHE: dict = {}


def emit(name: str, value: float, derived: str = "") -> None:
    print(f"{name},{value:.3f},{derived}", flush=True)


def dataset(name: str, scale: float = 0.25, feat_dim: int = 32, num_classes: int = 8):
    key = ("ds", name, scale, feat_dim, num_classes)
    if key not in _CACHE:
        _CACHE[key] = named_dataset(
            name, feat_dim=feat_dim, num_classes=num_classes, seed=0, scale=scale
        )
    return _CACHE[key]


PARTITIONERS = {
    "AdaDNE": adadne,
    "DistributedNE": distributed_ne,
    "Hash2D": hash2d_partition,
    "Random": random_edge_partition,
}


def partition(g, alg: str, parts: int, seed: int = 0):
    key = ("part", id(g), alg, parts, seed)
    if key not in _CACHE:
        t0 = time.perf_counter()
        ep = PARTITIONERS[alg](g, parts, seed=seed)
        _CACHE[key] = (ep, time.perf_counter() - t0)
    return _CACHE[key]


def glisp_client(g, parts: int, alg: str = "AdaDNE", seed: int = 0):
    key = ("client", id(g), alg, parts, seed)
    if key not in _CACHE:
        ep, _ = partition(g, alg, parts, seed)
        built = build_partitions(g, ep, parts)
        _CACHE[key] = GatherApplyClient(
            [SamplingServer(p, seed=seed) for p in built],
            VertexRouter(g, ep, parts),
            seed=seed,
        )
    return _CACHE[key]


def edgecut_client(g, parts: int, seed: int = 0):
    key = ("ecclient", id(g), parts, seed)
    if key not in _CACHE:
        vp = ldg_edge_cut(g, parts, seed=seed)
        built = build_partitions(g, edge_cut_to_edge_assignment(g, vp), parts)
        _CACHE[key] = EdgeCutClient(
            [SamplingServer(p, seed=seed, cost_model="scan") for p in built],
            vp.astype(np.int64),
            seed=seed,
        )
    return _CACHE[key]
