"""Paper Table II: RF / VB / EB / runtime of ParMETIS-stand-in (LDG edge-cut),
DistributedNE and AdaDNE across datasets and partition counts."""
from __future__ import annotations

import time

from benchmarks.common import dataset, emit
from repro.core.partition import adadne, distributed_ne, ldg_edge_cut
from repro.graph.metrics import (
    metrics_from_edge_assignment,
    metrics_from_vertex_assignment,
)

CASES = [
    ("ogbn-products", 2),
    ("ogbn-products", 4),
    ("wikikg90m", 8),
    ("twitter-2010", 8),
    ("ogbn-paper", 8),
]


def run():
    for ds, parts in CASES:
        g = dataset(ds)
        for alg_name, fn, edge_cut in (
            ("LDG(edge-cut)", ldg_edge_cut, True),
            ("DistributedNE", distributed_ne, False),
            ("AdaDNE", adadne, False),
        ):
            t0 = time.perf_counter()
            assign = fn(g, parts, seed=0)
            dt = time.perf_counter() - t0
            m = (
                metrics_from_vertex_assignment(g, assign, parts)
                if edge_cut
                else metrics_from_edge_assignment(g, assign, parts)
            )
            tag = f"table2/{ds}/p{parts}/{alg_name}"
            emit(tag + "/RF", m["RF"])
            emit(tag + "/VB", m["VB"])
            emit(tag + "/EB", m["EB"])
            emit(tag + "/time_s", dt)


if __name__ == "__main__":
    run()
