"""Partitioning subsystem benchmarks -> ``BENCH_partition.json``.

Four measurements (mirroring the BENCH_inference/BENCH_sampling pattern):

- **table2** (full mode only) — paper Table II: RF / VB / EB / runtime of the
  ParMETIS-stand-in (LDG edge-cut), DistributedNE and AdaDNE across datasets
  and partition counts.
- **quality** — wall-clock, replication factor and vertex/edge balance
  (VS/ES) per registered partitioner on one power-law graph, including the
  sequential ``*_loop`` reference entries.
- **speedup** — lockstep-vectorized AdaDNE vs the sequential loop
  implementation on the benchmark graph; the refactor's contract is >=5x
  wall-clock at equal-or-better RF/VB/EB (asserted in full mode, reported
  always).
- **cache** — two ``GLISPSystem.build`` calls with ``partition_cache_dir``
  set: the second must report a cache hit with near-zero partition seconds.

``--smoke`` shrinks the workload for CI and skips the Table II sweep.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

from benchmarks.common import dataset, emit
from repro.core.partition import PARTITIONERS, ldg_edge_cut
from repro.graph import power_law_graph
from repro.graph.metrics import (
    metrics_from_edge_assignment,
    metrics_from_vertex_assignment,
)

RESULTS: dict = {}

CASES = [
    ("ogbn-products", 2),
    ("ogbn-products", 4),
    ("wikikg90m", 8),
    ("twitter-2010", 8),
    ("ogbn-paper", 8),
]

QUALITY_ALGS = ("adadne", "adadne_loop", "dne", "dne_loop", "ldg", "hash2d", "random")


def _emit(name: str, value: float) -> None:
    RESULTS[name] = float(value)
    emit(name, value)


def bench_table2():
    for ds, parts in CASES:
        g = dataset(ds)
        for alg_name, fn, edge_cut in (
            ("LDG(edge-cut)", ldg_edge_cut, True),
            ("DistributedNE", lambda g, p, seed: PARTITIONERS.get("dne").partition(g, p, seed=seed).edge_parts, False),
            ("AdaDNE", lambda g, p, seed: PARTITIONERS.get("adadne").partition(g, p, seed=seed).edge_parts, False),
        ):
            t0 = time.perf_counter()
            assign = fn(g, parts, seed=0)
            dt = time.perf_counter() - t0
            m = (
                metrics_from_vertex_assignment(g, assign, parts)
                if edge_cut
                else metrics_from_edge_assignment(g, assign, parts)
            )
            tag = f"table2/{ds}/p{parts}/{alg_name}"
            _emit(tag + "/RF", m["RF"])
            _emit(tag + "/VB", m["VB"])
            _emit(tag + "/EB", m["EB"])
            _emit(tag + "/time_s", dt)


def bench_quality(g, parts: int):
    """Wall-clock + scorecard per registered partitioner (one plan each)."""
    for name in QUALITY_ALGS:
        pt = PARTITIONERS.get(name)
        t0 = time.perf_counter()
        plan = pt.partition(g, parts, seed=0)
        dt = time.perf_counter() - t0
        tag = f"quality/p{parts}/{name}"
        _emit(tag + "/time_s", dt)
        _emit(tag + "/RF", plan.replication_factor)
        _emit(tag + "/VB", plan.vertex_balance)
        _emit(tag + "/EB", plan.edge_balance)


def bench_speedup(g, parts: int, require: bool):
    """Lockstep-vectorized AdaDNE vs the sequential loop reference."""
    wall = {}
    plans = {}
    for name in ("adadne", "adadne_loop"):
        pt = PARTITIONERS.get(name)
        t0 = time.perf_counter()
        plans[name] = pt.partition(g, parts, seed=0)
        wall[name] = time.perf_counter() - t0
        _emit(f"speedup/p{parts}/{name}/time_s", wall[name])
    ratio = wall["adadne_loop"] / max(wall["adadne"], 1e-9)
    _emit(f"speedup/p{parts}/lockstep_vs_loop", ratio)
    fast, ref = plans["adadne"], plans["adadne_loop"]
    _emit(f"speedup/p{parts}/RF_lockstep", fast.replication_factor)
    _emit(f"speedup/p{parts}/RF_loop", ref.replication_factor)
    # equal-or-better quality within a small statistical slack
    quality_ok = (
        fast.replication_factor <= ref.replication_factor * 1.05
        and fast.vertex_balance <= ref.vertex_balance * 1.10
        and fast.edge_balance <= ref.edge_balance * 1.10
    )
    RESULTS[f"speedup/p{parts}/quality_ok"] = bool(quality_ok)
    emit(f"speedup/p{parts}/quality_ok", 1.0 if quality_ok else 0.0)
    RESULTS[f"speedup/p{parts}/target_met"] = bool(ratio >= 5.0)
    emit(f"speedup/p{parts}/target_met", 1.0 if ratio >= 5.0 else 0.0)
    assert quality_ok, "lockstep AdaDNE quality regressed vs the loop reference"
    if require:
        assert ratio >= 5.0, f"lockstep speedup {ratio:.2f}x below the 5x target"


def bench_cache(g, parts: int):
    """Second build with a partition cache must skip repartitioning."""
    from repro.api import GLISPConfig, GLISPSystem

    cache_dir = tempfile.mkdtemp(prefix="glisp-bench-pcache-")
    try:
        cfg = GLISPConfig(
            num_parts=parts, fanouts=(4,), partition_cache_dir=cache_dir
        )
        cold = GLISPSystem.build(g, cfg)
        warm = GLISPSystem.build(g, cfg)
        _emit("cache/cold_partition_s", cold.partition_seconds)
        _emit("cache/warm_partition_s", warm.partition_seconds)
        _emit(
            "cache/speedup",
            cold.partition_seconds / max(warm.partition_seconds, 1e-9),
        )
        RESULTS["cache/hit"] = bool(warm.partition_cache_hit)
        emit("cache/hit", 1.0 if warm.partition_cache_hit else 0.0)
        assert warm.partition_cache_hit, "second build missed the plan cache"
        assert (warm.plan.edge_parts == cold.plan.edge_parts).all()
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)


def run(smoke: bool = False, out_json: str | None = "BENCH_partition.json"):
    if not smoke:
        bench_table2()
    # the per-partitioner scorecard runs on a smaller graph than the
    # speedup case: the loop references in QUALITY_ALGS are the slow part
    gq = power_law_graph(60_000 if smoke else 120_000, avg_degree=8, seed=3)
    bench_quality(gq, 8)
    # lockstep-vs-loop at P=32, where the sequential implementation's
    # per-partition Python overhead is the scalability wall the lockstep
    # rewrite removes; sized so the >=5x contract holds with margin
    gs = power_law_graph(120_000 if smoke else 240_000, avg_degree=8, seed=3)
    bench_speedup(gs, 32, require=not smoke)
    bench_cache(gq, 8)

    if out_json:
        with open(out_json, "w") as fh:
            json.dump(RESULTS, fh, indent=2, sort_keys=True)
        print(f"wrote {out_json}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI workload")
    ap.add_argument("--out", default="BENCH_partition.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_json=args.out)
