"""Fused GNN kernel suite benchmark: wall-clock + achieved-vs-peak roofline.

Measures, at a padded bucket shape like the inference engine dispatches:

* fused ``gather_spmm_pallas`` vs the unfused gather → ``segment_spmm_pallas``
  sequence (the fusion win: no materialized [E, D] message array, and a 1-D
  edge grid instead of re-reading every edge tile once per row block);
* the ragged variant on a padding-heavy batch (3/4 padding), where all-pad
  tiles cost one predicate instead of a matmul;
* the one-pass ``gat_softmax_aggregate_pallas`` vs the 3-pass
  segment-max → normalize → weighted-sum kernel sequence it replaces;
* the deterministic autotuner (measured sweep, then in-memory and artifact
  cache hits);
* per-kernel analytic FLOPs/bytes from ``launch.roofline.kernel_roofline``
  so every wall-clock is stated against the hardware bound.

Everything asserts allclose against the jnp oracles in ``kernels/ref.py``.
Wall-clocks here are Pallas **interpret mode** on CPU (this box), so
absolute ``frac_of_peak`` numbers are tiny; the *relative* wins (fused vs
unfused, ragged vs dense, one-pass vs 3-pass) are the grid-step and
traffic savings that carry to hardware, and the analytic bounds in the
report are hardware truths.  Results land in ``BENCH_kernels.json``.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

from benchmarks.common import emit

RESULTS: dict = {}


def _emit(name: str, value) -> None:
    RESULTS[name] = value if isinstance(value, (bool, dict, str)) else float(value)
    emit(name, value if not isinstance(value, (dict, str)) else 0.0)


def _bench(fn, *args, reps: int = 3) -> float:
    fn(*args).block_until_ready()  # compile outside timing
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _inputs(E: int, N: int, D: int, valid: int, rng):
    import jax.numpy as jnp

    feats = jnp.asarray(rng.standard_normal((N, D)).astype(np.float32))
    idx = rng.integers(0, N, E).astype(np.int32)
    seg = np.sort(rng.integers(0, N, E)).astype(np.int32)
    idx[valid:] = -1
    seg[valid:] = -1
    logits = jnp.asarray(rng.standard_normal(E).astype(np.float32))
    return feats, jnp.asarray(idx), jnp.asarray(seg), logits


def run(smoke: bool = False, out_json: str | None = "BENCH_kernels.json"):
    import jax
    import jax.numpy as jnp

    from repro.kernels import autotune as at
    from repro.kernels.fused_gnn import (
        gat_softmax_aggregate_pallas,
        gather_spmm_pallas,
        gather_spmm_ragged_pallas,
    )
    from repro.kernels.ops import INTERPRET
    from repro.kernels.ref import gat_softmax_aggregate_ref, gather_spmm_ref
    from repro.kernels.segment_spmm import segment_spmm_pallas
    from repro.launch.roofline import kernel_roofline

    E, N, D = (1024, 128, 16) if smoke else (8192, 1024, 64)
    rng = np.random.default_rng(0)
    feats, idx, seg, logits = _inputs(E, N, D, valid=E, rng=rng)
    shape = {"edges": E, "segments": N, "dim": D, "feat_rows": N}

    # --- fused gather+aggregate vs the unfused sequence -------------------
    @jax.jit
    def unfused(feats, idx, seg):
        ok = (idx >= 0) & (seg >= 0)
        msg = jnp.where(ok[:, None], feats[jnp.maximum(idx, 0)], 0.0)
        return segment_spmm_pallas(msg, seg, N, interpret=INTERPRET)

    @jax.jit
    def oracle(feats, idx, seg):
        return gather_spmm_ref(feats, idx, seg, N)

    def fused(feats, idx, seg):
        return gather_spmm_pallas(feats, idx, seg, N, interpret=INTERPRET)

    ref = oracle(feats, idx, seg)
    assert np.allclose(np.asarray(fused(feats, idx, seg)), ref, rtol=1e-4, atol=1e-5)
    t_unfused = _bench(unfused, feats, idx, seg)
    t_fused = _bench(fused, feats, idx, seg)
    t_oracle = _bench(oracle, feats, idx, seg)
    _emit("gather_spmm/unfused_s", t_unfused)
    _emit("gather_spmm/fused_s", t_fused)
    _emit("gather_spmm/jnp_oracle_s", t_oracle)
    _emit("gather_spmm/fused_speedup_vs_unfused", t_unfused / t_fused)
    for op, wall in (
        ("unfused_gather_spmm", t_unfused),
        ("gather_spmm", t_fused),
    ):
        _emit(f"roofline/{op}", kernel_roofline(op, shape, wall))

    # --- ragged variant on a padding-heavy bucket (3/4 padding) -----------
    _, idx_q, seg_q, _ = _inputs(E, N, D, valid=E // 4, rng=rng)

    def fused_dense_q(feats, idx, seg):
        return gather_spmm_pallas(feats, idx, seg, N, interpret=INTERPRET)

    def fused_ragged_q(feats, idx, seg):
        return gather_spmm_ragged_pallas(feats, idx, seg, N, interpret=INTERPRET)

    ref_q = np.asarray(oracle(feats, idx_q, seg_q))
    assert np.allclose(
        np.asarray(fused_ragged_q(feats, idx_q, seg_q)), ref_q, rtol=1e-4, atol=1e-5
    )
    t_dense_q = _bench(fused_dense_q, feats, idx_q, seg_q)
    t_ragged_q = _bench(fused_ragged_q, feats, idx_q, seg_q)
    _emit("ragged/dense_s", t_dense_q)
    _emit("ragged/ragged_s", t_ragged_q)
    _emit("ragged/speedup_on_3quarters_padding", t_dense_q / t_ragged_q)
    _emit(
        "roofline/gather_spmm_ragged",
        kernel_roofline("gather_spmm_ragged", {**shape, "valid_edges": E // 4}, t_ragged_q),
    )

    # --- one-pass GAT softmax+aggregate vs the 3-pass it replaces ---------
    msg = jnp.take(feats, jnp.maximum(idx, 0), axis=0)

    # The exact pre-fusion kernel path from models.py: jnp segment-max, then
    # two 2-D-grid segment_spmm calls (one for the softmax denominator, one
    # for the weighted sum) — 3 passes over the edge array.
    @jax.jit
    def three_pass(logits, msg, seg):
        ok = seg >= 0
        seg0 = jnp.maximum(seg, 0)
        mx = jax.ops.segment_max(
            jnp.where(ok, logits, -jnp.inf), seg0, num_segments=N
        )
        mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
        e = jnp.where(ok, jnp.exp(logits - mx[seg0]), 0.0)
        z = segment_spmm_pallas(e[:, None], seg, N, interpret=INTERPRET)[:, 0]
        alpha = e / jnp.maximum(z[seg0], 1e-9)
        return segment_spmm_pallas(msg * alpha[:, None], seg, N, interpret=INTERPRET)

    def one_pass(logits, msg, seg):
        return gat_softmax_aggregate_pallas(logits, msg, seg, N, interpret=INTERPRET)

    ref_gat = np.asarray(gat_softmax_aggregate_ref(logits, msg, seg, N))
    assert np.allclose(
        np.asarray(one_pass(logits, msg, seg)), ref_gat, rtol=1e-4, atol=1e-5
    )
    assert np.allclose(
        np.asarray(three_pass(logits, msg, seg)), ref_gat, rtol=1e-4, atol=1e-5
    )
    t3 = _bench(three_pass, logits, msg, seg)
    t1 = _bench(one_pass, logits, msg, seg)
    _emit("gat/three_pass_s", t3)
    _emit("gat/one_pass_s", t1)
    _emit("gat/one_pass_speedup", t3 / t1)
    _emit("roofline/gat_softmax_aggregate", kernel_roofline("gat_softmax_aggregate", shape, t1))

    # --- deterministic autotuner ------------------------------------------
    at.reset()
    tune_shape = (E, N, D)
    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        cfg1 = at.autotune("gather_spmm_ragged", tune_shape, np.float32, cache_dir=td)
        t_sweep = time.perf_counter() - t0
        cfg2 = at.autotune("gather_spmm_ragged", tune_shape, np.float32, cache_dir=td)
        assert cfg1 == cfg2 and at.stats()["memory_hits"] == 1
        at.reset(clear_stats=False)  # fresh process simulation
        cfg3 = at.autotune("gather_spmm_ragged", tune_shape, np.float32, cache_dir=td)
        assert cfg3 == cfg1 and at.stats()["artifact_hits"] == 1
    _emit("autotune/sweep_s", t_sweep)
    _emit("autotune/chosen_block_edges", cfg1.block_edges)
    _emit("autotune/measured", at.stats()["measured"])
    _emit("autotune/memory_hits", at.stats()["memory_hits"])
    _emit("autotune/artifact_hits", at.stats()["artifact_hits"])
    at.reset()

    # --- acceptance: fused beats unfused; ragged beats dense on padding ---
    # Perf gates hold at benchmark scale; smoke runs are too small for the
    # wall-clock deltas to clear timer noise, so smoke only checks numerics.
    if not smoke:
        assert t_fused < t_unfused, (
            f"fused gather+aggregate ({t_fused:.4f}s) must beat the unfused "
            f"gather->segment_spmm sequence ({t_unfused:.4f}s)"
        )
        assert t_ragged_q < t_dense_q, (
            f"ragged kernel ({t_ragged_q:.4f}s) must beat dense ({t_dense_q:.4f}s) "
            "on a 3/4-padding bucket"
        )
        assert t1 < t3, (
            f"one-pass GAT kernel ({t1:.4f}s) must beat the 3-pass sequence ({t3:.4f}s)"
        )

    if out_json:
        with open(out_json, "w") as f:
            json.dump(RESULTS, f, indent=2, sort_keys=True)
        print(f"wrote {out_json}")
    return RESULTS


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny scale for CI")
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_json=args.out)
