"""Paper Table III: graph-server memory footprint — GLISP's compact
structure vs the per-etype + explicit-local-id layout of existing systems."""
from __future__ import annotations

from benchmarks.common import dataset, emit, partition
from repro.graph import build_partitions
from repro.graph.graph import naive_partition_memory_bytes

CASES = ["ogbn-products", "wikikg90m", "twitter-2010", "ogbn-paper"]


def run():
    for ds in CASES:
        g = dataset(ds)
        ep, _ = partition(g, "AdaDNE", 4)
        parts = build_partitions(g, ep, 4)
        glisp = sum(p.memory_bytes() for p in parts)
        naive = naive_partition_memory_bytes(g, ep, 4)
        emit(f"table3/{ds}/GLISP_MB", glisp / 2**20)
        emit(f"table3/{ds}/NaiveLayout_MB", naive / 2**20)
        emit(f"table3/{ds}/ratio", naive / glisp)


if __name__ == "__main__":
    run()
