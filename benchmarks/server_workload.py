"""Paper Fig. 10: normalized per-server workload, balanced seeds — GLISP vs
DistDGL-style; plus the GLISP-P0 worst case (all seeds from partition 0)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, edgecut_client, emit, glisp_client

CASES = [("wikikg90m", 8), ("twitter-2010", 8), ("ogbn-paper", 8)]
FANOUTS = [15, 10, 5]


def run():
    rng = np.random.default_rng(2)
    for ds, parts in CASES:
        g = dataset(ds)
        gl = glisp_client(g, parts)
        # strict DistDGL layout (in-edges local), sampled with "in" below
        ec = edgecut_client(g, parts, direction="in")
        seeds = rng.choice(g.num_vertices, 1024, replace=False)
        for name, client, direction in (("GLISP", gl, "out"), ("DistDGL", ec, "in")):
            client.reset_stats()
            client.sample_khop(seeds, FANOUTS, weighted=True, direction=direction)
            wl = client.server_workloads()
            norm = wl / wl.min()
            emit(f"fig10/{ds}/{name}/max_norm_load", norm.max())
            emit(f"fig10/{ds}/{name}/std_norm_load", norm.std())
        # worst case: all seeds hosted on partition 0
        gl.reset_stats()
        p0 = gl.servers[0].part
        seeds0 = p0.local_to_global(
            rng.choice(p0.num_vertices, min(1024, p0.num_vertices), replace=False)
        )
        gl.sample_khop(seeds0, FANOUTS, weighted=True, direction="out")
        wl = gl.server_workloads()
        emit(f"fig10/{ds}/GLISP-P0/max_norm_load", (wl / wl.min()).max())


if __name__ == "__main__":
    run()
