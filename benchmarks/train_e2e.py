"""Paper Table IV + Fig. 11: test accuracy and end-to-end training speed of
GCN/GraphSAGE/GAT on the GLISP pipeline vs the edge-cut pipeline."""
from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, edgecut_client, emit, glisp_client
from repro.models.gnn import GNNModel
from repro.train import GNNTrainer
from repro.train.optim import AdamWConfig


def _prep(g, classes=3):
    """Homophilous learnable labels: community (LDG cluster) id, plus a weak
    per-vertex feature signal — GCN/GAT learn from neighborhoods, SAGE from
    both."""
    from repro.core.partition import ldg_edge_cut

    g.labels = ldg_edge_cut(g, classes, seed=9).astype(np.int32)
    g.vertex_feats[:, :classes] = 0
    g.vertex_feats[np.arange(g.num_vertices), g.labels] += 1.5
    return g


def run():
    # power-law dataset with community structure (GCN/GAT need homophily)
    g = _prep(dataset("ogbn-paper", scale=0.12))
    ids = np.arange(g.num_vertices)
    rng = np.random.default_rng(0)
    rng.shuffle(ids)
    n_train = int(0.7 * len(ids))
    for model_kind in ("gcn", "sage", "gat"):
        res = {}
        for sys_name, client, direction in (
            ("GLISP", glisp_client(g, 2), "out"),
            ("EdgeCut", edgecut_client(g, 2), "in"),
        ):
            model = GNNModel(model_kind, g.vertex_feats.shape[1], hidden=64,
                             num_layers=3, num_classes=3)
            tr = GNNTrainer(
                model, client, g, [15, 10, 5], ids[:n_train], batch_size=256,
                direction=direction,
                opt=AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=200),
            )
            client.parallel_work = client.total_work = 0.0
            log = tr.train(epochs=1, log_every=10)
            acc = tr.evaluate(ids[n_train:], batches=4)
            res[sys_name] = (log, client.parallel_work, client.total_work, acc)
            emit(f"table4/{model_kind}/{sys_name}/test_acc", acc)
        # e2e speedup model: common compute time, shared serial cost per work
        # unit, sampling latency = parallel (max-over-servers) work
        (lg, pg, tg, _), (le, pe, te, _) = res["GLISP"], res["EdgeCut"]
        unit = (lg.sample_time + le.sample_time) / max(tg + te, 1e-9)
        compute = 0.5 * (lg.compute_time + le.compute_time)
        t_glisp = compute + pg * unit
        t_ec = compute + pe * unit
        steps = max(1, n_train // 256)
        emit(f"fig11/{model_kind}/GLISP/steps_per_s", steps / t_glisp)
        emit(f"fig11/{model_kind}/EdgeCut/steps_per_s", steps / t_ec)
        emit(f"fig11/{model_kind}/e2e_speedup", t_ec / t_glisp)
        emit(f"fig11/{model_kind}/sampling_speedup", pe / max(pg, 1e-9))


if __name__ == "__main__":
    run()
