"""Paper Table IV + Fig. 11: test accuracy and end-to-end training speed of
GCN/GraphSAGE/GAT on the GLISP pipeline vs the edge-cut pipeline, plus the
prefetching batch pipeline vs the serial sample-then-step path.

All systems are assembled via ``GLISPSystem.build`` (benchmarks/common.py).

The prefetch comparison emulates the accelerator deployment on a CPU-only
box with an explicit host/device core split: the training process (XLA) is
pinned to core 0 in BOTH modes, and the prefetch sampling worker gets core 1
— on real hardware the device computes off-CPU so this split is free, while
here XLA would otherwise saturate every core and leave the sampler nothing
to overlap into.  The split must be installed before XLA spins up its
thread pool, hence a fresh subprocess.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import dataset, edgecut_system, emit, glisp_system
from repro.api import GLISPConfig, GLISPSystem
from repro.models.gnn import GNNModel
from repro.train.optim import AdamWConfig


def _prep(g, classes=3):
    """Homophilous learnable labels: community (LDG cluster) id, plus a weak
    per-vertex feature signal — GCN/GAT learn from neighborhoods, SAGE from
    both."""
    from repro.core.partition import ldg_edge_cut

    g.labels = ldg_edge_cut(g, classes, seed=9).astype(np.int32)
    g.vertex_feats[:, :classes] = 0
    g.vertex_feats[np.arange(g.num_vertices), g.labels] += 1.5
    return g


def _opt(steps=200):
    return AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=steps)


def run_system_comparison(g, ids, n_train):
    for model_kind in ("gcn", "sage", "gat"):
        res = {}
        for sys_name, system in (
            ("GLISP", glisp_system(g, 2, fanouts=(15, 10, 5))),
            ("EdgeCut", edgecut_system(g, 2, fanouts=(15, 10, 5))),
        ):
            model = GNNModel(model_kind, g.vertex_feats.shape[1], hidden=64,
                             num_layers=3, num_classes=3)
            tr = system.trainer(model, ids[:n_train], opt=_opt(), prefetch=0)
            system.reset_stats()
            log = tr.train(epochs=1, log_every=10)
            acc = tr.evaluate(ids[n_train:], batches=4)
            client = system.client
            res[sys_name] = (log, client.parallel_work, client.total_work, acc)
            emit(f"table4/{model_kind}/{sys_name}/test_acc", acc)
        # e2e speedup model: common compute time, shared serial cost per work
        # unit, sampling latency = parallel (max-over-servers) work
        (lg, pg, tg, _), (le, pe, te, _) = res["GLISP"], res["EdgeCut"]
        unit = (lg.sample_time + le.sample_time) / max(tg + te, 1e-9)
        compute = 0.5 * (lg.compute_time + le.compute_time)
        t_glisp = compute + pg * unit
        t_ec = compute + pe * unit
        steps = max(1, n_train // 256)
        emit(f"fig11/{model_kind}/GLISP/steps_per_s", steps / t_glisp)
        emit(f"fig11/{model_kind}/EdgeCut/steps_per_s", steps / t_ec)
        emit(f"fig11/{model_kind}/e2e_speedup", t_ec / t_glisp)
        emit(f"fig11/{model_kind}/sampling_speedup", pe / max(pg, 1e-9))


def _pin_host_device_split():
    """Pin this (XLA) process to core 0, reserving core 1 for the sampling
    worker.  Returns the worker's core set, or None when the box has a
    single core (no split possible — overlap then has nothing to run on)."""
    if not hasattr(os, "sched_setaffinity"):
        return None
    cores = sorted(os.sched_getaffinity(0))
    if len(cores) < 2:
        return None
    os.sched_setaffinity(0, {cores[0]})
    return (cores[1],)


def run_prefetch_comparison(g, ids, n_train, reps=3):
    """Measured wall-clock of one epoch: serial sample-then-step vs the
    double-buffered prefetching pipeline.  Each mode gets a freshly built,
    identically seeded system, so the two batch streams are bit-identical;
    an untimed warm-up epoch excludes XLA compilation from both.  Epochs
    alternate serial/prefetch for ``reps`` rounds and the MIN wall per mode
    is compared — the container shares its host, so min-of-paired-runs
    filters neighbor noise out of both sides equally."""
    worker_cores = _pin_host_device_split()
    trainers = {}
    for mode, depth in (("serial", 0), ("prefetch", 2)):
        system = GLISPSystem.build(g, GLISPConfig(
            num_parts=2, fanouts=(15, 10, 5), batch_size=256,
            prefetch=depth, seed=0,
        ))
        model = GNNModel("sage", g.vertex_feats.shape[1], hidden=64,
                         num_layers=3, num_classes=3)
        tr = system.trainer(model, ids[:n_train], opt=_opt(400),
                            worker_cores=worker_cores)
        tr.train(epochs=1, log_every=10**9)  # warm-up: compile all buckets
        trainers[mode] = tr
    walls = {mode: [] for mode in trainers}
    splits = {}
    for _ in range(reps):
        for mode, tr in trainers.items():
            s0, c0 = tr.pipeline.sample_time, tr.log.compute_time
            t0 = time.perf_counter()
            log = tr.train(epochs=1, log_every=10**9)
            walls[mode].append(time.perf_counter() - t0)
            splits[mode] = (log.sample_time - s0, log.compute_time - c0)
    for mode in trainers:
        emit(f"pipeline/{mode}/wall_s", min(walls[mode]))
        emit(f"pipeline/{mode}/sample_s", splits[mode][0])
        emit(f"pipeline/{mode}/compute_s", splits[mode][1])
    emit(
        "pipeline/prefetch_speedup",
        min(walls["serial"]) / min(walls["prefetch"]),
    )


def _bench_data():
    # power-law dataset with community structure (GCN/GAT need homophily)
    g = _prep(dataset("ogbn-paper", scale=0.12))
    ids = np.arange(g.num_vertices)
    rng = np.random.default_rng(0)
    rng.shuffle(ids)
    return g, ids, int(0.7 * len(ids))


def run_prefetch_comparison_subprocess():
    """Re-exec the prefetch section in a fresh process: the host/device core
    split must be installed before XLA creates its intra-op thread pool."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")] if p
    )
    subprocess.run(
        [sys.executable, "-m", "benchmarks.train_e2e", "--prefetch-only"],
        env=env,
        cwd=root,
        check=True,
    )


def run():
    g, ids, n_train = _bench_data()
    run_system_comparison(g, ids, n_train)
    run_prefetch_comparison_subprocess()


if __name__ == "__main__":
    if "--prefetch-only" in sys.argv:
        run_prefetch_comparison(*_bench_data())
    else:
        run()
