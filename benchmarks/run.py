"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig9,...]

Prints ``name,value,derived`` CSV rows (one per metric).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("table2", "benchmarks.partition_quality"),
    ("fig9", "benchmarks.sampling_speed"),
    ("fig10", "benchmarks.server_workload"),
    ("table3", "benchmarks.memory_footprint"),
    ("table4+fig11", "benchmarks.train_e2e"),
    ("fig12", "benchmarks.scaling"),
    ("fig13", "benchmarks.inference_speedup"),
    ("fig14", "benchmarks.reorder_cache"),
    ("fig15", "benchmarks.cache_policy"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated suite prefixes")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]

    print("name,value,derived")
    failures = []
    for tag, module in SUITES:
        if only and not any(tag.startswith(o) or o in tag for o in only):
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
            print(f"# {tag} done in {time.perf_counter()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(tag)
            traceback.print_exc()
            print(f"# {tag} FAILED", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
