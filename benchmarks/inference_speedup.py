"""Paper Fig. 13: layerwise full-graph inference vs naive samplewise — vertex
embedding and link prediction tasks.  Speedup measured on (a) vertex-layer
computations eliminated and (b) wall time at this scale."""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import dataset, emit, glisp_client


def _layers(fdim, hidden, rng):
    Ws = [rng.standard_normal((2 * d_in, d_out)).astype(np.float32) * 0.3
          for d_in, d_out in ((fdim, hidden), (hidden, hidden))]

    def make(k):
        def layer(_k, h_self, h_nbr, seg):
            agg = np.zeros_like(h_self)
            cnt = np.zeros(h_self.shape[0])
            if h_nbr.shape[0]:
                np.add.at(agg, seg, h_nbr)
                np.add.at(cnt, seg, 1.0)
            agg /= np.maximum(cnt, 1)[:, None]
            return np.tanh(np.concatenate([h_self, agg], 1) @ Ws[k])
        return layer

    return [make(0), make(1)], hidden


def run():
    from repro.core.inference import LayerwiseInferenceEngine, samplewise_inference

    g = dataset("wikikg90m", scale=0.12, feat_dim=32)
    client = glisp_client(g, 4)
    rng = np.random.default_rng(0)
    layers, hidden = _layers(32, 32, rng)

    # --- vertex embedding task (all vertices) -----------------------------
    td_ctx = tempfile.TemporaryDirectory()
    td = td_ctx.name
    t0 = time.perf_counter()
    eng = LayerwiseInferenceEngine(
        g, client, layers, g.vertex_feats, td, fanouts=[10, 10],
        chunk_rows=2048, out_dims=[32, 32],
    )
    res = eng.run()
    t_layer = time.perf_counter() - t0
    lw_compute = res.vertices_computed()

    # samplewise on a 1/16 slice, extrapolated (full run is the point of the
    # paper: it's too slow)
    slice_n = g.num_vertices // 16
    targets = rng.choice(g.num_vertices, slice_n, replace=False)
    t0 = time.perf_counter()
    _, st = samplewise_inference(
        g, client, layers, g.vertex_feats, targets, fanouts=[10, 10],
        batch_size=64,
    )
    t_sw = (time.perf_counter() - t0) * 16
    emit("fig13/vertex_embedding/layerwise_s", t_layer)
    emit("fig13/vertex_embedding/samplewise_s_extrap", t_sw)
    emit("fig13/vertex_embedding/wall_speedup", t_sw / t_layer)
    emit(
        "fig13/vertex_embedding/compute_speedup",
        (st["vertices_computed"] * 16) / lw_compute,
    )

    # --- link prediction task (both endpoints per edge => 2x redundancy) ---
    n_edges = 4096
    eidx = rng.choice(g.num_edges, n_edges, replace=False)
    pairs = np.stack([g.src[eidx], g.dst[eidx]], 1)
    # layerwise: all endpoint embeddings already in the store -> reads only
    t0 = time.perf_counter()
    emb_u = res.final_store.read_rows_direct(res.newid[pairs[:, 0]])
    emb_v = res.final_store.read_rows_direct(res.newid[pairs[:, 1]])
    scores = (emb_u * emb_v).sum(1)
    t_link_layer = time.perf_counter() - t0 + t_layer  # store build amortized
    # samplewise: K-hop per endpoint
    t0 = time.perf_counter()
    uniq = np.unique(pairs[:1024].reshape(-1))
    _, st2 = samplewise_inference(
        g, client, layers, g.vertex_feats, uniq, fanouts=[10, 10], batch_size=64
    )
    t_link_sw = (time.perf_counter() - t0) * (2 * n_edges / uniq.shape[0])
    emit("fig13/link_prediction/wall_speedup", t_link_sw / t_link_layer)
    td_ctx.cleanup()


if __name__ == "__main__":
    run()
