"""Paper Fig. 13: layerwise full-graph inference vs naive samplewise — vertex
embedding and link prediction tasks.  Speedup measured on (a) vertex-layer
computations eliminated and (b) wall time at this scale.

Also tracks the engine's own perf trajectory: the same model slices run
through the pre-optimization engine (``mode="reference"``: per-vertex
slice-and-concatenate gathers, eager per-batch layer calls) and the
device-resident shape-bucketed jit engine (``mode="bucketed"``), on two
identically-seeded systems so both sample the exact same neighborhoods and
the final stores must be allclose.  Results land in ``BENCH_inference.json``
(``--out``); ``--smoke`` shrinks the dataset for CI.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

from benchmarks.common import dataset, emit, glisp_client

RESULTS: dict = {}


def _emit(name: str, value: float) -> None:
    RESULTS[name] = float(value)
    emit(name, value)


def _model_layers(fdim: int, hidden: int):
    import jax

    from repro.models.gnn import GNNModel

    model = GNNModel("sage", fdim, hidden=hidden, num_layers=2)
    params = model.init(jax.random.PRNGKey(0))
    return [model.embed_layer_fn(params, k) for k in range(2)]


def _engine_trajectory(g, layers, hidden: int) -> None:
    """Before/after wall-clock of the layerwise engine on identical inputs.

    One-time jax platform init is warmed up outside both timings; each mode
    then pays its own tracing/compilation costs inside its timing — for the
    pre-PR reference path that is a fresh eager trace per batch shape, for
    the bucketed path one jit compile per (layer, bucket).  ``batch_size``
    is set so every partition runs several batches (the production shape of
    a full-graph job), identically for both modes."""
    import jax
    import jax.numpy as jnp

    from repro.api import GLISPConfig, GLISPSystem

    jnp.zeros(8).block_until_ready()  # backend/platform init off both clocks
    cfg = GLISPConfig(num_parts=4, fanouts=(10, 10), seed=0)
    common = dict(
        fanouts=[10, 10], chunk_rows=2048, out_dims=[hidden, hidden],
        batch_size=1024,
    )
    stores = {}
    for mode in ("reference", "bucketed"):
        # a fresh identically-seeded system per mode: both engines issue the
        # same sample_khop call sequence, so the sampled neighborhoods (and
        # therefore the final embeddings) are identical
        system = GLISPSystem.build(g, cfg)
        td_ctx = tempfile.TemporaryDirectory()
        t0 = time.perf_counter()
        res = system.infer_layerwise(layers, td_ctx.name, mode=mode, **common)
        dt = time.perf_counter() - t0
        _emit(f"engine/{mode}_s", dt)
        if mode == "bucketed":
            _emit("engine/slice_compiles", res.slice_compiles)
        stores[mode] = (
            res.final_store.read_rows_direct(
                res.newid[np.arange(g.num_vertices)]
            ),
            td_ctx,
        )
    a, b = stores["reference"][0], stores["bucketed"][0]
    ok = np.allclose(a, b, rtol=1e-4, atol=1e-5)
    RESULTS["engine/allclose"] = bool(ok)
    emit("engine/allclose", 1.0 if ok else 0.0)
    _emit(
        "engine/wall_speedup",
        RESULTS["engine/reference_s"] / max(RESULTS["engine/bucketed_s"], 1e-9),
    )
    for _, ctx in stores.values():
        ctx.cleanup()


def run(smoke: bool = False, out_json: str | None = "BENCH_inference.json"):
    from repro.core.inference import LayerwiseInferenceEngine, samplewise_inference

    scale = 0.02 if smoke else 0.12
    hidden = 32
    g = dataset("wikikg90m", scale=scale, feat_dim=32)
    client = glisp_client(g, 4)
    rng = np.random.default_rng(0)
    layers = _model_layers(32, hidden)

    # --- engine before/after (the perf trajectory) ------------------------
    _engine_trajectory(g, layers, hidden)

    # --- vertex embedding task (all vertices) -----------------------------
    td_ctx = tempfile.TemporaryDirectory()
    td = td_ctx.name
    t0 = time.perf_counter()
    eng = LayerwiseInferenceEngine(
        g, client, layers, g.vertex_feats, td, fanouts=[10, 10],
        chunk_rows=2048, out_dims=[hidden, hidden],
    )
    res = eng.run()
    t_layer = time.perf_counter() - t0
    lw_compute = res.vertices_computed()

    # samplewise on a 1/16 slice, extrapolated (full run is the point of the
    # paper: it's too slow)
    slice_n = g.num_vertices // 16
    targets = rng.choice(g.num_vertices, slice_n, replace=False)
    t0 = time.perf_counter()
    _, st = samplewise_inference(
        g, client, layers, g.vertex_feats, targets, fanouts=[10, 10],
        batch_size=64,
    )
    t_sw = (time.perf_counter() - t0) * 16
    _emit("fig13/vertex_embedding/layerwise_s", t_layer)
    _emit("fig13/vertex_embedding/samplewise_s_extrap", t_sw)
    _emit("fig13/vertex_embedding/wall_speedup", t_sw / t_layer)
    _emit(
        "fig13/vertex_embedding/compute_speedup",
        (st["vertices_computed"] * 16) / lw_compute,
    )

    # --- link prediction task (both endpoints per edge => 2x redundancy) ---
    n_edges = 512 if smoke else 4096
    eidx = rng.choice(g.num_edges, n_edges, replace=False)
    pairs = np.stack([g.src[eidx], g.dst[eidx]], 1)
    # layerwise: all endpoint embeddings already in the store -> reads only
    t0 = time.perf_counter()
    emb_u = res.final_store.read_rows_direct(res.newid[pairs[:, 0]])
    emb_v = res.final_store.read_rows_direct(res.newid[pairs[:, 1]])
    scores = (emb_u * emb_v).sum(1)
    t_link_layer = time.perf_counter() - t0 + t_layer  # store build amortized
    # samplewise: K-hop per endpoint
    t0 = time.perf_counter()
    uniq = np.unique(pairs[: n_edges // 4].reshape(-1))
    _, st2 = samplewise_inference(
        g, client, layers, g.vertex_feats, uniq, fanouts=[10, 10], batch_size=64
    )
    t_link_sw = (time.perf_counter() - t0) * (2 * n_edges / uniq.shape[0])
    _emit("fig13/link_prediction/wall_speedup", t_link_sw / t_link_layer)
    td_ctx.cleanup()

    if out_json:
        with open(out_json, "w") as f:
            json.dump(RESULTS, f, indent=2, sort_keys=True)
        print(f"wrote {out_json}")
    return RESULTS


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny scale for CI")
    ap.add_argument("--out", default="BENCH_inference.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_json=args.out)
