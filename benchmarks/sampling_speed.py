"""Paper Fig. 9: K-hop subgraph sampling throughput (uniform & weighted),
GLISP Gather-Apply client vs the DistDGL-style edge-cut client.

The in-process simulation is serial, so raw wall time double-counts GLISP's
parallel fan-out.  We therefore report (a) the serial wall throughput for
transparency and (b) the *modeled parallel* throughput: per hop the cluster
pays max-over-servers work; a shared cost-per-work-unit calibrated from the
combined serial runs converts work to time (same convention for both
systems, so the comparison isolates the paper's claim: load balance)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, edgecut_client, emit, glisp_client

CASES = [("ogbn-products", 2), ("wikikg90m", 8), ("twitter-2010", 8)]
FANOUTS = [15, 10, 5]


def _run(client, n_vertices, weighted, direction, batches=12, batch=96):
    rng = np.random.default_rng(1)
    client.parallel_work = client.total_work = 0.0
    t0 = time.perf_counter()
    total = 0
    for _ in range(batches):
        seeds = rng.choice(n_vertices, batch, replace=False)
        client.sample_khop(seeds, FANOUTS, weighted=weighted, direction=direction)
        total += batch
    wall = time.perf_counter() - t0
    return total, wall, client.parallel_work, client.total_work


def run():
    for ds, parts in CASES:
        g = dataset(ds)
        gl = glisp_client(g, parts)
        # strict DistDGL layout (in-edges local), sampled with "in" below
        ec = edgecut_client(g, parts, direction="in")
        for weighted in (False, True):
            kind = "weighted" if weighted else "uniform"
            n_g, w_g, pw_g, tw_g = _run(gl, g.num_vertices, weighted, "out")
            n_e, w_e, pw_e, tw_e = _run(ec, g.num_vertices, weighted, "in")
            emit(f"fig9/{ds}/{kind}/GLISP_serial_seeds_per_s", n_g / w_g)
            emit(f"fig9/{ds}/{kind}/EdgeCut_serial_seeds_per_s", n_e / w_e)
            # shared cost per work unit from the combined serial measurement
            unit = (w_g + w_e) / max(tw_g + tw_e, 1e-9)
            t_g, t_e = pw_g * unit, pw_e * unit
            emit(f"fig9/{ds}/{kind}/GLISP_parallel_seeds_per_s", n_g / t_g)
            emit(f"fig9/{ds}/{kind}/EdgeCut_parallel_seeds_per_s", n_e / t_e)
            emit(f"fig9/{ds}/{kind}/modeled_speedup", t_e / t_g * (n_g / n_e))


if __name__ == "__main__":
    run()
