"""Distributed-tier benchmark: forked sampling workers + data parallelism.

Phase 1 sweeps the worker count (1/2/4 forked sampling-server processes,
``dist_transport="mp"``) over a fixed request workload and reports
sampling throughput plus the client-observed dispatch-latency
distribution (P50/P95).  Every remote configuration is checked
bit-identical, request by request, against its in-process twin — the
transport must change WHERE sampling runs, never what it returns.

Phase 2 runs the data-parallel trainer over the remote backend on a
host-device mesh (1/2/4 data shards), reporting step throughput.  The
sharded step is checked against the unsharded single-device reference
step on the same stacked batches (``reference=True``): losses must agree
to float tolerance.

End-of-run asserts, per ISSUE 9:

- every worker count answered bit-identically to in-process sampling;
- the dp train-step losses match the single-device reference.

Results land in ``BENCH_distributed.json`` (``--out``); ``--smoke``
shrinks the workload for CI but keeps the full 1/2/4 sweeps.
"""
from __future__ import annotations

import os

# the dp phase wants several host devices; XLA reads this before the
# first jax import, so it must be set at module load
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
).strip()

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402

from benchmarks.common import dataset, emit, glisp_system  # noqa: E402
from repro.api import GLISPConfig, GLISPSystem  # noqa: E402
from repro.core.sampling.service import SampleRequest, SamplingSpec  # noqa: E402

RESULTS: dict = {}

FANOUTS = (15, 10)
WORKER_SWEEP = (1, 2, 4)
SHARD_SWEEP = (1, 2, 4)


def _emit(name: str, value: float) -> None:
    RESULTS[name] = float(value)
    emit(name, value)


def _flag(name: str, ok: bool) -> None:
    RESULTS[name] = bool(ok)
    emit(name, 1.0 if ok else 0.0)


def _remote_system(g, parts: int, **overrides) -> GLISPSystem:
    """A fresh forked-worker system per call — deliberately NOT the shared
    ``glisp_system`` cache, since this benchmark closes its pools."""
    return GLISPSystem.build(
        g,
        GLISPConfig(
            num_parts=parts,
            partitioner="adadne",
            sampler="gather_apply",
            seed=0,
            dist_transport="mp",
            **overrides,
        ),
    )


def _requests(g, n: int, seeds_per: int):
    rng = np.random.default_rng(42)
    spec = SamplingSpec(fanouts=FANOUTS)
    return [
        SampleRequest(
            seeds=rng.choice(g.num_vertices, size=seeds_per, replace=False),
            spec=spec,
            key=(0xD15B, i),
        )
        for i in range(n)
    ]


def _drive(system, requests) -> tuple[list, float]:
    subs, t0 = [], time.perf_counter()
    for req in requests:
        subs.append(system.backend.submit(req).result(timeout=120.0))
    return subs, time.perf_counter() - t0


def _same_sub(a, b) -> bool:
    if len(a.hops) != len(b.hops) or a.degraded != b.degraded:
        return False
    return all(
        np.array_equal(ha.src, hb.src)
        and np.array_equal(ha.dst, hb.dst)
        and np.array_equal(ha.eid, hb.eid)
        for ha, hb in zip(a.hops, b.hops)
    )


def bench_workers(g, requests) -> None:
    p50s, modeled = {}, {}
    for workers in WORKER_SWEEP:
        local = glisp_system(g, workers)
        baseline, _ = _drive(local, requests)
        remote = _remote_system(g, workers)
        try:
            _drive(remote, requests[: max(2, len(requests) // 8)])  # warmup
            remote.backend.service.dispatcher.drain_latencies()
            remote.reset_stats()
            subs, secs = _drive(remote, requests)
            lat = remote.backend.service.dispatcher.drain_latencies()
            stats = remote.backend.stats()
            tag = f"workers{workers}"
            p50s[workers] = float(np.percentile(lat, 50))
            modeled[workers] = stats.modeled_parallel_work
            _emit(f"{tag}/throughput_req_s", len(requests) / secs)
            _emit(f"{tag}/dispatches", len(lat))
            _emit(f"{tag}/dispatch_p50_ms", p50s[workers])
            _emit(f"{tag}/dispatch_p95_ms", float(np.percentile(lat, 95)))
            _emit(f"{tag}/modeled_parallel_work", stats.modeled_parallel_work)
            _emit(f"{tag}/modeled_total_work", stats.modeled_total_work)
            _emit(f"{tag}/measured_round_s", stats.measured_round_seconds)
            _flag(
                f"{tag}/bit_identical_vs_inproc",
                all(_same_sub(a, b) for a, b in zip(baseline, subs)),
            )
        finally:
            remote.close()
    # gather-style sampling replicates per-seed work onto every partition
    # holding one of the seed's edges, so the achievable dispatch speedup
    # is P/RF, not P — the work model (modeled_parallel_work, the per-round
    # MAX across servers) predicts it and the measured per-dispatch
    # latency should track that prediction
    base = WORKER_SWEEP[0]
    for workers in WORKER_SWEEP[1:]:
        tag = f"workers{workers}"
        _emit(
            f"{tag}/modeled_dispatch_speedup",
            modeled[base] / modeled[workers] if modeled[workers] else 0.0,
        )
        _emit(
            f"{tag}/measured_dispatch_speedup",
            p50s[base] / p50s[workers] if p50s[workers] else 0.0,
        )


def bench_data_parallel(g, steps: int) -> None:
    from repro.launch.mesh import make_local_mesh
    from repro.models.gnn.models import GNNModel

    system = _remote_system(g, 2)
    try:
        ids = np.arange(min(4096, g.num_vertices), dtype=np.int64)
        for shards in SHARD_SWEEP:
            model = GNNModel(
                "sage", g.vertex_feats.shape[1], hidden=32, num_layers=2,
                num_classes=int(g.labels.max()) + 1,
            )
            tr = system.dp_trainer(
                model,
                ids,
                mesh=make_local_mesh(shards),
                batch_size=128,
                reference=True,
            )
            log = tr.train(epochs=1, log_every=1, max_steps=steps)
            tag = f"shards{shards}"
            total = log.sample_time + log.compute_time
            _emit(f"{tag}/steps_per_s", len(log.losses) / total)
            _emit(f"{tag}/final_loss", log.losses[-1])
            _flag(
                f"{tag}/loss_matches_reference",
                bool(
                    np.allclose(log.losses, log.ref_losses, rtol=1e-5, atol=1e-6)
                ),
            )
    finally:
        system.close()


def run(smoke: bool = False, out_json: str | None = "BENCH_distributed.json"):
    # full mode needs per-dispatch sampling work that dwarfs the ~1 ms IPC
    # overhead, or worker parallelism cannot show: a dense graph and large
    # keyed requests (2048 seeds, 15x10 fanout ~ hundreds of ms of numpy
    # sampling per request, split across the workers)
    scale = 0.02 if smoke else 0.25
    num_requests = 12 if smoke else 24
    seeds_per = 48 if smoke else 2048
    dp_steps = 3 if smoke else 10
    name = "wikikg90m" if smoke else "twitter-2010"
    g = dataset(name, scale=scale, feat_dim=16)

    bench_workers(g, _requests(g, num_requests, seeds_per))
    bench_data_parallel(g, dp_steps)

    if out_json:
        with open(out_json, "w") as fh:
            json.dump(RESULTS, fh, indent=2, sort_keys=True)
        print(f"wrote {out_json}")
    for workers in WORKER_SWEEP:
        assert RESULTS[f"workers{workers}/bit_identical_vs_inproc"], (
            f"{workers}-worker remote sampling diverged from in-process"
        )
    if not smoke:
        top = WORKER_SWEEP[-1]
        speedup = RESULTS[f"workers{top}/measured_dispatch_speedup"]
        assert speedup > 1.0, (
            f"{top} workers did not reduce dispatch latency "
            f"(speedup {speedup:.2f}); smoke-sized workloads are exempt"
        )
    for shards in SHARD_SWEEP:
        assert RESULTS[f"shards{shards}/loss_matches_reference"], (
            f"{shards}-shard dp losses diverged from the single-device "
            "reference step"
        )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI workload")
    ap.add_argument("--out", default="BENCH_distributed.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_json=args.out)
