"""Paper Fig. 15 + the tiered storage sweep.

Four measurements:

- **fig15a** — interior-vertex percentage under AdaDNE across datasets.
- **fig15b** — LRU vs FIFO dynamic-cache hit ratio through the layerwise
  engine (the historic figure, now via the ``HybridCache`` stack).
- **sweep** — tier configurations × eviction policies through the engine:
  per-tier hit ratios, DFS fill chunks and the modeled ``IOCost`` rollup
  for each ``storage_tiers``/``tier_capacities``/``cache_policy`` combo.
- **trace** — a PDS-reordered access trace (contiguous active-partition
  window + one-shot far boundary chunks, the §III-D reuse pattern): the
  locality-aware policy must beat FIFO's modeled retrieval time, asserted
  so CI catches a regression.

Results land in ``BENCH_cache.json`` (``--out``); ``--smoke`` shrinks the
workload for CI (mirroring ``BENCH_inference.json`` / ``BENCH_sampling.json``).
"""
from __future__ import annotations

import argparse
import json
import tempfile

import numpy as np

from benchmarks.common import dataset, emit, glisp_client, partition
from repro.core.inference import LayerwiseInferenceEngine
from repro.core.storage import DFSTier, HybridCache, IOCost, build_tiers
from repro.graph import build_partitions

RESULTS: dict = {}

CASES = [("ogbn-products", 2), ("wikikg90m", 4), ("twitter-2010", 4)]


def _emit(name: str, value: float) -> None:
    RESULTS[name] = float(value)
    emit(name, value)


def _layer(rng, fdim: int, out: int):
    W = rng.standard_normal((2 * fdim, out)).astype(np.float32) * 0.3

    def layer(k, h_self, h_nbr, seg):
        agg = np.zeros_like(h_self)
        if h_nbr.shape[0]:
            np.add.at(agg, seg, h_nbr)
        return np.tanh(np.concatenate([h_self, agg], 1) @ W)

    return layer


def bench_fig15a(scale: float) -> None:
    for ds, parts in CASES:
        g = dataset(ds, scale=scale)
        ep, _ = partition(g, "AdaDNE", parts)
        built = build_partitions(g, ep, parts)
        interior = np.concatenate([p.interior_mask() for p in built])
        _emit(f"fig15a/{ds}/interior_pct", 100.0 * interior.mean())


def bench_engine_sweep(scale: float) -> None:
    """Tier stacks × policies through the layerwise engine (fig15b is the
    two-policy slice of this sweep)."""
    g = dataset("wikikg90m", scale=scale, feat_dim=32)
    client = glisp_client(g, 4)
    layer = _layer(np.random.default_rng(0), 32, 32)
    cost = IOCost()
    sweep = [
        ("mem_disk", ("memory", "disk"), (), "fifo"),
        ("mem_disk", ("memory", "disk"), (), "lru"),
        ("mem_disk", ("memory", "disk"), (), "locality"),
        ("disk_only", ("disk",), (), "fifo"),
        ("mem_cap8_disk", ("memory", "disk"), (8, 0), "fifo"),
        ("mem_cap8_disk", ("memory", "disk"), (8, 0), "locality"),
    ]
    for stack_name, tiers, caps, policy in sweep:
        with tempfile.TemporaryDirectory() as td:
            res = LayerwiseInferenceEngine(
                g, client, [layer], g.vertex_feats, td, fanouts=[10],
                chunk_rows=256, out_dims=[32], reorder_alg="PDS",
                batch_size=128, dynamic_frac=0.30, policy=policy,
                storage_tiers=tiers, tier_capacities=caps,
            ).run()
        key = f"sweep/{stack_name}/{policy}"
        _emit(f"{key}/hit_ratio", res.dynamic_hit_ratio())
        _emit(f"{key}/fill_chunks",
              sum(s.cache.fill_chunks for s in res.layer_stats))
        _emit(f"{key}/modeled_io_ms", res.modeled_io_ms(cost))
        if stack_name == "mem_disk" and policy in ("fifo", "lru"):
            _emit(f"fig15b/{policy}/hit_ratio", res.dynamic_hit_ratio())


def bench_pds_trace(num_chunks: int, repeats: int) -> None:
    """The acceptance trace: after the PDS reorder the active partition is a
    contiguous chunk window re-swept while far boundary chunks stream
    through once each.  Locality-aware eviction must keep the window hot
    and beat FIFO's modeled retrieval time."""
    chunk_rows, dim = 64, 8
    window = max(2, num_chunks // 8)  # active partition chunks [0, window)
    capacity = window + 1
    far = list(range(num_chunks // 2, num_chunks))
    trace: list[int] = []
    for i in range(len(far) * repeats):
        trace += list(range(window)) + [far[(i * 7) % len(far)]]
    trace += list(range(window))
    cost = IOCost()
    modeled = {}
    for policy in ("fifo", "lru", "locality"):
        with tempfile.TemporaryDirectory() as td:
            store = DFSTier(td, num_chunks * chunk_rows, dim, chunk_rows)
            store.write_rows(
                np.arange(store.num_rows),
                np.zeros((store.num_rows, dim), np.float32),
            )
            cache = HybridCache(
                store,
                build_tiers(
                    ("memory", "disk"), chunk_rows, dim,
                    capacities=(capacity, 0),
                ),
                policy=policy,
            )
            cache.fill(
                cache.plan_fill(
                    np.arange(store.num_rows),
                    focus_rows=np.arange(window * chunk_rows),
                )
            )
            for c in trace:
                cache.read_rows(np.arange(c * chunk_rows, c * chunk_rows + 4))
            modeled[policy] = cache.stats.modeled_time_ms(cost)
            _emit(f"trace/{policy}/modeled_io_ms", modeled[policy])
            _emit(f"trace/{policy}/dynamic_hit_ratio",
                  cache.stats.dynamic_hit_ratio)
    _emit("trace/locality_speedup_vs_fifo",
          modeled["fifo"] / modeled["locality"])
    assert modeled["locality"] < modeled["fifo"], (
        f"locality policy must beat fifo on the PDS trace: {modeled}"
    )


def run(smoke: bool = False, out_json: str | None = None) -> dict:
    scale = 0.25 if smoke else 1.0
    bench_fig15a(scale)
    bench_engine_sweep(scale)
    bench_pds_trace(
        num_chunks=32 if smoke else 128, repeats=1 if smoke else 4
    )
    if out_json:
        with open(out_json, "w") as f:
            json.dump(RESULTS, f, indent=2, sort_keys=True)
        print(f"wrote {out_json}")
    return RESULTS


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny scale for CI")
    ap.add_argument("--out", default="BENCH_cache.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out_json=args.out)
