"""Paper Fig. 15: (a) interior-vertex percentage under AdaDNE across
datasets; (b) LRU vs FIFO dynamic-cache hit ratio."""
from __future__ import annotations

import tempfile

import numpy as np

from benchmarks.common import dataset, emit, glisp_client, partition
from repro.core.inference import LayerwiseInferenceEngine
from repro.core.inference.cache import CachePolicy
from repro.graph import build_partitions

CASES = [("ogbn-products", 2), ("wikikg90m", 4), ("twitter-2010", 4)]


def run():
    for ds, parts in CASES:
        g = dataset(ds, scale=1.0)
        ep, _ = partition(g, "AdaDNE", parts)
        built = build_partitions(g, ep, parts)
        interior = np.concatenate([p.interior_mask() for p in built])
        emit(f"fig15a/{ds}/interior_pct", 100.0 * interior.mean())

    g = dataset("wikikg90m", scale=1.0, feat_dim=32)
    client = glisp_client(g, 4)
    rng = np.random.default_rng(0)
    W = rng.standard_normal((64, 32)).astype(np.float32) * 0.3

    def layer(k, h_self, h_nbr, seg):
        agg = np.zeros_like(h_self)
        if h_nbr.shape[0]:
            np.add.at(agg, seg, h_nbr)
        return np.tanh(np.concatenate([h_self, agg], 1) @ W)

    for policy in (CachePolicy.LRU, CachePolicy.FIFO):
        with tempfile.TemporaryDirectory() as td:
            eng = LayerwiseInferenceEngine(
                g, client, [layer], g.vertex_feats, td, fanouts=[10],
                chunk_rows=256, out_dims=[32], reorder_alg="PDS",
                batch_size=128, dynamic_frac=0.30, policy=policy,
            )
            res = eng.run()
        emit(f"fig15b/{policy.value}/hit_ratio", res.dynamic_hit_ratio())


if __name__ == "__main__":
    run()
