"""Sampling service: algorithm distributions, Gather-Apply correctness,
load-balance accounting."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal envs: deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st
from scipy.stats import chisquare

from repro.core.sampling import EdgeCutClient, SamplingServer
from repro.core.sampling.algorithms import algorithm_a_es, algorithm_d, uniform_sample


def test_algorithm_d_marginals():
    """Every index equally likely (chi-square, n=30 k=6)."""
    rng = np.random.default_rng(0)
    counts = np.zeros(30)
    trials = 3000
    for _ in range(trials):
        idx = algorithm_d(30, 6, rng)
        assert idx.shape == (6,)
        assert (np.diff(idx) > 0).all()  # increasing order, no repeats
        counts[idx] += 1
    _, p = chisquare(counts)
    assert p > 1e-4, (p, counts)


def test_algorithm_d_edge_cases():
    rng = np.random.default_rng(1)
    assert algorithm_d(5, 0, rng).shape == (0,)
    assert (algorithm_d(5, 5, rng) == np.arange(5)).all()
    assert (algorithm_d(5, 9, rng) == np.arange(5)).all()
    for _ in range(50):
        out = algorithm_d(100, 1, rng)
        assert 0 <= out[0] < 100


def test_uniform_sample_matches_vitter_distribution():
    """Vectorized path and Vitter's Algorithm D draw the same distribution."""
    rng1, rng2 = np.random.default_rng(2), np.random.default_rng(3)
    c1, c2 = np.zeros(20), np.zeros(20)
    for _ in range(3000):
        c1[uniform_sample(20, 4, rng1, use_vitter=False)] += 1
        c2[uniform_sample(20, 4, rng2, use_vitter=True)] += 1
    # both uniform: compare each against uniform expectation
    for c in (c1, c2):
        _, p = chisquare(c)
        assert p > 1e-4


def test_a_es_top1_frequencies():
    """P(top-1 = i) == w_i / Σw for A-ES."""
    rng = np.random.default_rng(4)
    w = np.array([1.0, 2.0, 4.0, 8.0])
    counts = np.zeros(4)
    trials = 20000
    for _ in range(trials):
        idx, _ = algorithm_a_es(w, 1, rng)
        counts[idx[0]] += 1
    expected = w / w.sum() * trials
    _, p = chisquare(counts, expected)
    assert p > 1e-4, (counts, expected)


def test_a_es_zero_weight_excluded():
    rng = np.random.default_rng(5)
    w = np.array([0.0, 1.0, 0.0, 1.0])
    for _ in range(100):
        idx, sc = algorithm_a_es(w, 2, rng)
        assert set(idx.tolist()) == {1, 3}


def test_full_fanout_returns_all_neighbors(small_graph, sampling_client):
    """fanout >= global degree => every neighbor returned exactly once per
    edge (the Gather-Apply merge is lossless)."""
    rng = np.random.default_rng(6)
    seeds = rng.choice(small_graph.num_vertices, 40, replace=False)
    sub = sampling_client.sample_khop(seeds, [10**9], direction="out")
    hop = sub.hops[0]
    for v in seeds:
        got = sorted(hop.dst[hop.src == v].tolist())
        want = sorted(small_graph.neighbors(int(v), "out").tolist())
        assert got == want, f"vertex {v}"


def test_weighted_full_fanout(small_graph, sampling_client):
    seeds = np.arange(30)
    sub = sampling_client.sample_khop(seeds, [10**9], weighted=True, direction="out")
    hop = sub.hops[0]
    for v in seeds:
        got = sorted(hop.dst[hop.src == v].tolist())
        want = sorted(small_graph.neighbors(int(v), "out").tolist())
        assert got == want


def test_fanout_respected(small_graph, sampling_client):
    seeds = np.arange(100)
    for weighted in (False, True):
        sub = sampling_client.sample_khop(seeds, [5, 3], weighted=weighted)
        for f, hop in zip([5, 3], sub.hops):
            if hop.src.shape[0] == 0:
                continue
            _, counts = np.unique(hop.src, return_counts=True)
            assert counts.max() <= f


def test_sampled_edges_are_real(small_graph, sampling_client):
    seeds = np.arange(50)
    sub = sampling_client.sample_khop(seeds, [8, 4])
    edge_set = set(zip(small_graph.src.tolist(), small_graph.dst.tolist()))
    for hop in sub.hops:
        for s, d in zip(hop.src.tolist(), hop.dst.tolist()):
            assert (s, d) in edge_set


def test_in_direction_sampling(small_graph, sampling_client):
    seeds = np.arange(30)
    sub = sampling_client.sample_khop(seeds, [10**9], direction="in")
    hop = sub.hops[0]
    for v in seeds[:10]:
        got = sorted(hop.dst[hop.src == v].tolist())
        want = sorted(small_graph.neighbors(int(v), "in").tolist())
        assert got == want


def test_workload_accounting(sampling_client):
    sampling_client.reset_stats()
    sampling_client.sample_khop(np.arange(200), [10, 5], weighted=True)
    wl = sampling_client.server_workloads()
    assert (wl > 0).all()
    sampling_client.reset_stats()
    assert sampling_client.server_workloads().sum() == 0


def test_glisp_balances_better_than_edge_cut(small_graph):
    """Fig. 10: normalized workload spread of the Gather-Apply client is
    tighter than the DistDGL-style edge-cut client on a power-law graph."""
    from repro.core.partition import adadne, ldg_edge_cut, edge_cut_to_edge_assignment
    from repro.core.sampling import GatherApplyClient, VertexRouter
    from repro.graph import build_partitions

    g = small_graph
    P = 4
    ep = adadne(g, P, seed=1)
    parts = build_partitions(g, ep, P)
    glisp = GatherApplyClient(
        [SamplingServer(p, seed=0) for p in parts], VertexRouter(g, ep, P), seed=0
    )
    vp = ldg_edge_cut(g, P, seed=1)
    # strict DistDGL layout: in-edges local to the owner, sampled with "in"
    ec_parts = build_partitions(
        g, edge_cut_to_edge_assignment(g, vp, local_direction="in"), P
    )
    ec = EdgeCutClient(
        [SamplingServer(p, seed=0) for p in ec_parts], vp.astype(np.int64), seed=0
    )
    rng = np.random.default_rng(0)
    seeds = rng.choice(g.num_vertices, 512, replace=False)
    glisp.sample_khop(seeds, [15, 10, 5], weighted=True, direction="out")
    ec.sample_khop(seeds, [15, 10, 5], weighted=True, direction="in")
    wl_g = glisp.server_workloads()
    wl_e = ec.server_workloads()
    imb_g = wl_g.max() / wl_g.min()
    imb_e = wl_e.max() / wl_e.min()
    assert imb_g < imb_e, (imb_g, imb_e)


@settings(max_examples=15, deadline=None)
@given(f=st.integers(1, 20), seed=st.integers(0, 100))
def test_property_weighted_topk_merge(f, seed):
    """Distributed A-ES == single-machine A-ES given identical scores: global
    top-f of per-server top-f equals top-f of the union."""
    rng = np.random.default_rng(seed)
    n = 50
    scores = rng.random(n)
    shards = np.array_split(np.arange(n), 3)
    local_top = []
    for sh in shards:
        order = sh[np.argsort(-scores[sh])][:f]
        local_top.extend(order.tolist())
    merged = sorted(local_top, key=lambda i: -scores[i])[:f]
    want = np.argsort(-scores)[:f].tolist()
    assert merged == want
