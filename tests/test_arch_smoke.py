"""Per-assigned-architecture smoke tests (requirement f): REDUCED variant of
each family — one forward + one train step (or decode for embedding archs)
on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer.model import forward, init_cache, init_params, lm_loss
from repro.train.optim import AdamWConfig, adamw_init, adamw_update

B, S = 2, 32


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_step(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.num_layers <= 3 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    if cfg.input_mode == "embeddings":
        inp = jax.random.normal(key, (B, S, cfg.d_model))
    else:
        inp = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    tgt = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    logits, aux, _ = forward(params, cfg, inp)
    assert logits.shape == (B, S, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits[..., : cfg.vocab_size]).all()), "NaN/inf in logits"

    # one AdamW train step
    opt = adamw_init(params)
    (loss, _), grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, inp, tgt), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    new_params, opt, info = adamw_update(params, grads, opt, AdamWConfig(lr=1e-3))
    assert np.isfinite(float(info["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-130m", "recurrentgemma-2b",
                                  "mixtral-8x7b", "deepseek-v2-lite-16b"])
def test_reduced_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    cache = init_cache(cfg, B, 64)
    if cfg.input_mode == "embeddings":
        tok = jax.random.normal(key, (B, 1, cfg.d_model))
    else:
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, _, cache = forward(params, cfg, tok, cache, 0)
    assert logits.shape == (B, 1, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits[..., : cfg.vocab_size]).all())
