"""The unified system facade: registries, config validation, build round-trip,
prefetching pipeline determinism, backend protocol parity, eid threading."""
import inspect

import numpy as np
import pytest

from repro.api import (
    CACHE_POLICIES,
    PARTITIONERS,
    REORDERS,
    SAMPLERS,
    DEFAULT_DIRECTION,
    BatchPipeline,
    GLISPConfig,
    GLISPSystem,
    Registry,
    SamplerBackend,
)
from repro.core.sampling.service import MAX_PARTS


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------


def test_registry_unknown_name_lists_known():
    reg = Registry("widget")
    reg.register("a", 1)
    reg.register("b", 2)
    with pytest.raises(ValueError, match="unknown widget 'c'.*a, b"):
        reg.get("c")


def test_registry_duplicate_and_case_insensitive():
    reg = Registry("widget")
    reg.register("Foo", 1)
    assert reg.get("foo") == 1
    assert reg.get("FOO") == 1
    with pytest.raises(ValueError, match="already registered"):
        reg.register("foo", 2)


def test_builtin_registries_populated():
    assert {"adadne", "dne", "hash2d", "random", "ldg"} <= set(PARTITIONERS.names())
    assert {"gather_apply", "edge_cut"} <= set(SAMPLERS.names())
    assert "pds" in REORDERS and REORDERS.get("pds") == "PDS"
    assert {"fifo", "lru"} <= set(CACHE_POLICIES.names())


def test_config_validation_errors(small_graph):
    with pytest.raises(ValueError, match="unknown partitioner"):
        GLISPConfig(partitioner="metis").validate()
    with pytest.raises(ValueError, match="unknown sampler backend"):
        GLISPConfig(sampler="rpc").validate()
    with pytest.raises(ValueError, match="direction"):
        GLISPConfig(direction="sideways").validate()
    with pytest.raises(ValueError, match="num_parts"):
        GLISPConfig(num_parts=MAX_PARTS + 1).validate()
    with pytest.raises(ValueError, match="fanouts"):
        GLISPConfig(fanouts=(10, 0)).validate()
    with pytest.raises(ValueError, match="unknown partitioner"):
        GLISPSystem.build(small_graph, GLISPConfig(partitioner="metis"))


# ---------------------------------------------------------------------------
# facade round-trip
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def api_graph():
    from repro.graph import power_law_graph

    g = power_law_graph(1200, avg_degree=8, seed=11, feat_dim=16, num_classes=4)
    g.labels = g.vertex_types.astype(np.int32)
    return g


@pytest.fixture(scope="module")
def glisp_system(api_graph):
    return GLISPSystem.build(
        api_graph, GLISPConfig(num_parts=4, fanouts=(8, 4), batch_size=128)
    )


def test_build_roundtrip(api_graph, glisp_system):
    s = glisp_system
    assert len(s.partitions) == 4
    assert sum(p.num_edges for p in s.partitions) == api_graph.num_edges
    assert isinstance(s.backend, SamplerBackend)
    m = s.partition_metrics()
    assert m["RF"] >= 1.0 and m["EB"] >= 1.0
    # full-fanout sample through the facade is lossless (Gather-Apply merge)
    seeds = np.arange(20)
    sub = s.sample(seeds, fanouts=[10**9])
    hop = sub.hops[0]
    for v in seeds:
        got = sorted(hop.dst[hop.src == v].tolist())
        want = sorted(api_graph.neighbors(int(v), "out").tolist())
        assert got == want


def test_facade_train_smoke(api_graph, glisp_system):
    from repro.models.gnn import GNNModel
    from repro.train.optim import AdamWConfig

    g = api_graph
    g.vertex_feats[:, :3] = 0
    g.vertex_feats[np.arange(g.num_vertices), g.labels] += 2.0
    model = GNNModel("sage", 16, hidden=32, num_layers=2, num_classes=3)
    tr = glisp_system.train(
        model,
        np.arange(900),
        epochs=1,
        opt=AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50),
    )
    assert len(tr.log.losses) > 0
    assert np.isfinite(tr.log.losses).all()


def test_backend_reset_stats_clears_work(glisp_system):
    glisp_system.sample(np.arange(50))
    assert glisp_system.client.total_work > 0
    glisp_system.reset_stats()
    assert glisp_system.client.total_work == 0.0
    assert glisp_system.client.parallel_work == 0.0
    assert glisp_system.server_workloads().sum() == 0


# ---------------------------------------------------------------------------
# prefetching pipeline
# ---------------------------------------------------------------------------


def _collect(pipeline, epochs=2):
    out = []
    for seeds, batch in pipeline.batches(epochs):
        out.append((seeds, batch))
    return out


def test_prefetch_loader_determinism(api_graph):
    # two identically-seeded systems: server/client RNG streams must match,
    # so each gets its own backend (they are stateful across draws)
    cfg = GLISPConfig(num_parts=4, fanouts=(8, 4), batch_size=128)
    ids = np.arange(1000)
    serial = GLISPSystem.build(api_graph, cfg).loader(
        ids, num_layers=2, prefetch=0, seed=5
    )
    prefetched = GLISPSystem.build(api_graph, cfg).loader(
        ids, num_layers=2, prefetch=3, seed=5
    )
    bs = _collect(serial)
    bp = _collect(prefetched)
    assert len(bs) == len(bp) > 0
    for (seeds_s, batch_s), (seeds_p, batch_p) in zip(bs, bp):
        np.testing.assert_array_equal(seeds_s, seeds_p)
        np.testing.assert_array_equal(batch_s.feats, batch_p.feats)
        np.testing.assert_array_equal(batch_s.labels, batch_p.labels)
        for k in range(2):
            np.testing.assert_array_equal(batch_s.layer_dst[k], batch_p.layer_dst[k])
            np.testing.assert_array_equal(batch_s.layer_src[k], batch_p.layer_src[k])
            np.testing.assert_array_equal(batch_s.layer_etype[k], batch_p.layer_etype[k])


def test_prefetch_propagates_producer_errors(api_graph, glisp_system):
    pl = glisp_system.loader(np.arange(500), num_layers=2, prefetch=2)

    def boom(seeds):
        raise RuntimeError("producer failed")

    pl.make_batch = boom
    with pytest.raises(RuntimeError, match="producer failed"):
        list(pl.batches(1))


# ---------------------------------------------------------------------------
# backend protocol parity
# ---------------------------------------------------------------------------


def test_gather_apply_edge_cut_parity(api_graph):
    """Both backends answer the SAME protocol call with the SAME default
    direction, and at full fanout return identical one-hop edge sets."""
    g = api_graph
    ga = GLISPSystem.build(g, GLISPConfig(num_parts=3, fanouts=(8,)))
    ec = GLISPSystem.build(
        g,
        GLISPConfig(num_parts=3, partitioner="ldg", sampler="edge_cut", fanouts=(8,)),
    )
    seeds = np.arange(40)
    for system in (ga, ec):
        sub = system.sample(seeds, fanouts=[10**9])  # config default direction
        hop = sub.hops[0]
        edges = set(zip(hop.src.tolist(), hop.dst.tolist()))
        want = {
            (int(v), int(n))
            for v in seeds
            for n in g.neighbors(int(v), DEFAULT_DIRECTION)
        }
        assert edges == want, system.config.sampler
    # the unified default is carried by both raw client signatures too
    from repro.core.sampling import EdgeCutClient, GatherApplyClient

    for cls in (GatherApplyClient, EdgeCutClient):
        sig = inspect.signature(cls.sample_khop)
        assert sig.parameters["direction"].default == DEFAULT_DIRECTION, cls


def test_fanout_respected_via_protocol(api_graph):
    ec = GLISPSystem.build(
        api_graph,
        GLISPConfig(num_parts=3, partitioner="ldg", sampler="edge_cut"),
    )
    sub = ec.sample(np.arange(100), fanouts=[5, 3])
    for f, hop in zip([5, 3], sub.hops):
        if hop.src.shape[0]:
            _, counts = np.unique(hop.src, return_counts=True)
            assert counts.max() <= f


# ---------------------------------------------------------------------------
# num_parts > 64 guard
# ---------------------------------------------------------------------------


def test_vertex_router_rejects_too_many_parts():
    from repro.core.sampling import VertexRouter
    from repro.graph import power_law_graph

    g = power_law_graph(200, avg_degree=4, seed=0)
    ep = np.zeros(g.num_edges, dtype=np.int64)
    with pytest.raises(ValueError, match="at most 64"):
        VertexRouter(g, ep, MAX_PARTS + 1)
    # boundary: exactly 64 is fine
    VertexRouter(g, ep, MAX_PARTS)


def test_assign_inference_owners_rejects_too_many_parts():
    from repro.core.inference import assign_inference_owners

    mask = np.ones(16, dtype=np.uint64)
    with pytest.raises(ValueError, match="at most 64"):
        assign_inference_owners(mask, MAX_PARTS + 1)


# ---------------------------------------------------------------------------
# edge ids carried through Gather/Apply
# ---------------------------------------------------------------------------


def test_eids_survive_apply(api_graph, glisp_system):
    g = api_graph
    for weighted in (False, True):
        sub = glisp_system.sample(np.arange(64), fanouts=[6, 4], weighted=weighted)
        for hop in sub.hops:
            assert hop.eid is not None
            assert hop.eid.shape == hop.src.shape
            # each carried id names the exact sampled edge in the global graph
            np.testing.assert_array_equal(g.src[hop.eid], hop.src)
            np.testing.assert_array_equal(g.dst[hop.eid], hop.dst)


def test_experiment_config_bridge():
    from repro.configs.gnn import get_gnn_config

    cfg = get_gnn_config("sage-products").to_glisp_config(num_parts=2)
    assert cfg.partitioner == "adadne"
    assert cfg.sampler == "gather_apply"
    assert cfg.fanouts == (15, 10, 5)
    assert cfg.num_parts == 2
    cfg.validate()


def test_batch_edge_types_from_eids(api_graph, glisp_system):
    from repro.models.gnn.batching import subgraph_to_batch

    g = api_graph
    sub = glisp_system.sample(np.arange(64), fanouts=[6, 4])
    batch = subgraph_to_batch(
        sub, g.vertex_feats, g.labels, num_layers=2, edge_types=g.edge_types
    )
    # layer K-1 aggregates hop 0 only; check its etypes match the global table
    hop = sub.hops[0]
    n = hop.src.shape[0]
    np.testing.assert_array_equal(
        batch.layer_etype[1][:n], g.edge_types[hop.eid].astype(np.int32)
    )
