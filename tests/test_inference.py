"""Layerwise inference engine: equivalence with samplewise, cache semantics,
reorder effect on chunk reads, bucketed-vs-reference engine equivalence, and
the CSR-offset gather property."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal envs: deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.inference import (
    ChunkedEmbeddingStore,
    LayerwiseInferenceEngine,
    TwoLevelCache,
    assign_inference_owners,
    csr_gather,
    samplewise_inference,
)
from repro.core.inference.cache import CachePolicy


def _mean_layer(W):
    def layer(_k, h_self, h_nbr, seg):
        agg = np.zeros_like(h_self)
        cnt = np.zeros(h_self.shape[0])
        if h_nbr.shape[0]:
            np.add.at(agg, seg, h_nbr)
            np.add.at(cnt, seg, 1.0)
        agg = agg / np.maximum(cnt, 1)[:, None]
        return np.tanh(np.concatenate([h_self, agg], axis=1) @ W)
    return layer


@pytest.fixture(scope="module")
def layers():
    rng = np.random.default_rng(0)
    return [
        _mean_layer(rng.standard_normal((32, 16)).astype(np.float32) * 0.3)
        for _ in range(2)
    ]


def test_layerwise_equals_samplewise_full_fanout(
    small_graph, sampling_client, layers, tmp_path
):
    BIG = 10**9
    eng = LayerwiseInferenceEngine(
        small_graph, sampling_client, layers, small_graph.vertex_feats,
        str(tmp_path), fanouts=[BIG, BIG], chunk_rows=128, out_dims=[16, 16],
    )
    res = eng.run()
    targets = np.arange(48)
    sw, _ = samplewise_inference(
        small_graph, sampling_client, layers, small_graph.vertex_feats,
        targets, fanouts=[BIG, BIG],
    )
    lw = res.final_store.read_rows_direct(res.newid[targets])
    np.testing.assert_allclose(lw, sw, rtol=1e-4, atol=1e-5)


def test_samplewise_redundancy(small_graph, sampling_client, layers):
    """Samplewise recomputes shared neighbors: vertex-layer computations for
    all N targets exceed the layerwise count (K·N)."""
    targets = np.arange(small_graph.num_vertices)[:500]
    _, st = samplewise_inference(
        small_graph, sampling_client, layers, small_graph.vertex_feats,
        targets, fanouts=[10, 10], batch_size=32,
    )
    layerwise_cost_for_targets = 2 * targets.shape[0]
    assert st["vertices_computed"] > 1.5 * layerwise_cost_for_targets


def test_owner_assignment(small_graph, sampling_client):
    owner = assign_inference_owners(sampling_client.router.mask, 4)
    assert owner.shape == (small_graph.num_vertices,)
    assert owner.min() >= 0 and owner.max() < 4
    counts = np.bincount(owner, minlength=4)
    # interior vertices are pinned to their partition; greedy balancing of the
    # boundary bounds the skew by the partition vertex balance
    assert counts.max() / counts.min() < 3.0


def test_store_roundtrip(tmp_path):
    store = ChunkedEmbeddingStore(str(tmp_path / "s"), 1000, 8, chunk_rows=64)
    rows = np.arange(0, 1000, 3)
    vals = np.random.default_rng(0).standard_normal((rows.shape[0], 8)).astype(np.float32)
    store.write_rows(rows, vals)
    got = store.read_rows_direct(rows)
    np.testing.assert_array_equal(got, vals)


def test_static_cache_guarantee(tmp_path):
    """After fill_static, reads never touch the DFS store again."""
    store = ChunkedEmbeddingStore(str(tmp_path / "s"), 512, 4, chunk_rows=32)
    store.write_rows(np.arange(512), np.ones((512, 4), np.float32))
    cache = TwoLevelCache(store, CachePolicy.FIFO, dynamic_frac=0.2)
    need = np.arange(0, 512, 2)
    cache.fill_static(need)
    dfs_reads_after_fill = store.stats.chunk_reads
    for _ in range(5):
        cache.read_rows(need)
    assert store.stats.chunk_reads == dfs_reads_after_fill  # 100% static hit
    # repeated reads of a working set within the dynamic capacity -> mem hits
    for _ in range(5):
        cache.read_rows(np.arange(0, 64))  # chunks 0-1, capacity is 3
    assert cache.stats.dynamic_hits >= 8


def test_fifo_eviction(tmp_path):
    store = ChunkedEmbeddingStore(str(tmp_path / "s"), 320, 4, chunk_rows=32)
    store.write_rows(np.arange(320), np.zeros((320, 4), np.float32))
    cache = TwoLevelCache(store, CachePolicy.FIFO, dynamic_frac=0.2)  # cap = 2
    cache.fill_static(np.arange(320))
    assert cache.dynamic_capacity == 2
    cache.read_rows(np.arange(0, 32))     # chunk 0
    cache.read_rows(np.arange(32, 64))    # chunk 1
    cache.read_rows(np.arange(64, 96))    # chunk 2 -> evicts 0
    st0 = cache.stats.static_reads
    cache.read_rows(np.arange(0, 32))     # chunk 0 again -> miss
    assert cache.stats.static_reads == st0 + 1


@settings(max_examples=20, deadline=None)
@given(n=st.integers(0, 40), seed=st.integers(0, 10_000))
def test_csr_gather_matches_naive(n, seed):
    """Property: the vectorized CSR-offset gather equals the naive
    per-segment slice-and-concatenate gather."""
    rng = np.random.default_rng(seed)
    values = rng.integers(0, 1000, size=500)
    starts = np.sort(rng.integers(0, 400, size=n))
    ends = np.minimum(starts + rng.integers(0, 20, size=n), values.shape[0])
    counts = ends - starts
    got = csr_gather(values, starts, counts)
    want = (
        np.concatenate([values[a:b] for a, b in zip(starts, ends)])
        if n
        else values[:0]
    )
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("kind", ["gcn", "sage", "gat", "hgt"])
def test_bucketed_engine_matches_reference(
    kind, small_graph, sampling_client, tmp_path
):
    """The device-resident shape-bucketed jit engine produces the same
    embeddings as the pre-optimization reference engine for every evaluated
    model kind (full fanout makes sampling deterministic across runs)."""
    import jax

    from repro.models.gnn import GNNModel

    model = GNNModel(kind, 16, hidden=16, num_layers=2, num_heads=2)
    params = model.init(jax.random.PRNGKey(0))
    fns = [model.embed_layer_fn(params, k) for k in range(2)]
    BIG = 10**9
    kw = dict(fanouts=[BIG, BIG], chunk_rows=128, out_dims=[16, 16])
    ref = LayerwiseInferenceEngine(
        small_graph, sampling_client, fns, small_graph.vertex_feats,
        str(tmp_path / "ref"), mode="reference", **kw,
    ).run()
    new = LayerwiseInferenceEngine(
        small_graph, sampling_client, fns, small_graph.vertex_feats,
        str(tmp_path / "new"), mode="bucketed", batch_size=512, **kw,
    ).run()
    ids = np.arange(small_graph.num_vertices)
    a = ref.final_store.read_rows_direct(ref.newid[ids])
    b = new.final_store.read_rows_direct(new.newid[ids])
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
    assert new.slice_compiles > 0  # the jit path actually ran


def test_full_chunk_write_skips_read_modify_write(tmp_path):
    """A write covering every row of a chunk stores the values directly;
    partial writes still preserve the untouched rows."""
    store = ChunkedEmbeddingStore(str(tmp_path / "s"), 100, 4, chunk_rows=32)
    rng = np.random.default_rng(0)
    vals = rng.standard_normal((100, 4)).astype(np.float32)
    store.write_rows(np.arange(100), vals)  # full chunks incl. ragged last
    np.testing.assert_array_equal(store.read_rows_direct(np.arange(100)), vals)
    patch = np.full((2, 4), 7.0, np.float32)
    store.write_rows(np.array([1, 5]), patch)  # partial -> RMW path
    got = store.read_rows_direct(np.arange(100))
    assert (got[[1, 5]] == 7.0).all()
    keep = np.setdiff1d(np.arange(100), [1, 5])
    np.testing.assert_array_equal(got[keep], vals[keep])


def test_pds_reduces_chunk_reads(small_graph, sampling_client, layers, tmp_path):
    """Fig. 14b: PDS ordering reads no more chunks than natural order."""
    reads = {}
    for alg in ("NS", "PDS"):
        eng = LayerwiseInferenceEngine(
            small_graph, sampling_client, layers, small_graph.vertex_feats,
            str(tmp_path / alg), fanouts=[10, 10], chunk_rows=64,
            out_dims=[16, 16], reorder_alg=alg, batch_size=256,
            dynamic_frac=0.1,
        )
        res = eng.run()
        reads[alg] = res.total_chunk_reads() + sum(
            s.cache.fill_chunks for s in res.layer_stats
        )
    assert reads["PDS"] <= reads["NS"], reads


def test_engine_reuse_no_recompile_across_calls(small_graph, tmp_path):
    """Repeat ``infer_layerwise`` calls with identical arguments reuse one
    engine (GLISPSystem caches it by resolved-parameter signature), so the
    second call re-runs entirely out of the jit caches: zero retraces,
    which ``recompile_guard`` asserts against the (layer, bucket) bound."""
    import jax

    from repro.analysis import recompile_guard
    from repro.api import GLISPConfig, GLISPSystem
    from repro.models.gnn import GNNModel

    system = GLISPSystem.build(
        small_graph, GLISPConfig(num_parts=4, fanouts=(8, 4))
    )
    model = GNNModel("sage", 16, hidden=16, num_layers=2)
    params = model.init(jax.random.PRNGKey(0))
    fns = [model.embed_layer_fn(params, k) for k in range(2)]
    wd = str(tmp_path / "emb")
    kw = dict(chunk_rows=128, out_dims=[16, 16], batch_size=512)
    assert system.infer_engine is None
    with recompile_guard(system) as rec:
        system.infer_layerwise(fns, wd, **kw)
        engine = system.infer_engine
        assert engine is not None and engine.jit_trace_count() > 0
        with recompile_guard(system) as rec2:
            system.infer_layerwise(fns, wd, **kw)
        assert system.infer_engine is engine  # same engine, same jit caches
        assert (rec2.compiles, rec2.new_shapes) == (0, 0)
    assert rec.compiles == rec.new_shapes > 0

    # a different resolved signature must NOT reuse the cached engine
    system.infer_layerwise(fns, str(tmp_path / "emb2"), **kw)
    assert system.infer_engine is not engine


def test_layer_stats_padding_counters(small_graph, sampling_client, tmp_path):
    """The bucketed engine accounts real vs padded rows per layer: the
    waste the ragged kernels' tile skip saves is visible in LayerStats."""
    import jax

    from repro.models.gnn import GNNModel

    model = GNNModel("sage", 16, hidden=16, num_layers=2)
    params = model.init(jax.random.PRNGKey(0))
    fns = [model.embed_layer_fn(params, k) for k in range(2)]
    eng = LayerwiseInferenceEngine(
        small_graph, sampling_client, fns, small_graph.vertex_feats,
        str(tmp_path), fanouts=[10, 10], chunk_rows=128, out_dims=[16, 16],
        batch_size=256,
    )
    res = eng.run()
    for s in res.layer_stats:
        assert 0 < s.batch_rows <= s.padded_rows
        assert 0 < s.batch_edges <= s.padded_edges
        assert 0.0 < s.occupancy() <= 1.0
        assert 0.0 < s.edge_occupancy() <= 1.0
        # batches land in (vertex-bucket, edge-bucket) bins; the bin counts
        # must add up to the dispatched batches and every bin is a padded
        # shape (at least as large as one real row)
        assert sum(s.bucket_batches.values()) >= 1
        for bp, ep in s.bucket_batches:
            assert bp >= 1 and ep >= 1 and bp <= eng.batch_size


def test_engine_kernel_autotune_before_first_trace(
    small_graph, sampling_client, tmp_path
):
    """kernel_autotune=True sweeps each advertised (op, shape) before the
    bucket's first jit trace, so tuned blocks bake into the one compile per
    (layer, bucket) — recompile_guard still holds with kernels enabled."""
    import os

    import jax

    from repro.analysis import recompile_guard
    from repro.kernels import autotune as at
    from repro.models.gnn import GNNModel

    at.reset()
    try:
        model = GNNModel("sage", 16, hidden=16, num_layers=2)
        params = model.init(jax.random.PRNGKey(0))
        fns = [model.embed_layer_fn(params, k) for k in range(2)]
        cache = str(tmp_path / "tune")
        eng = LayerwiseInferenceEngine(
            small_graph, sampling_client, fns, small_graph.vertex_feats,
            str(tmp_path / "emb"), fanouts=[8, 4], chunk_rows=128,
            out_dims=[16, 16], batch_size=512, use_kernel=True,
            kernel_autotune=True, kernel_cache_dir=cache,
        )
        with recompile_guard(eng) as rec:
            res = eng.run()
        assert res.slice_compiles > 0
        assert rec.compiles == rec.new_shapes  # one compile per (layer, bucket)
        assert at.stats()["measured"] > 0
        assert os.path.exists(at.artifact_path(cache))
        import json as _json

        configs = _json.load(open(at.artifact_path(cache)))["configs"]
        assert any(k.startswith("segment_spmm_ragged/") for k in configs)
        # a second run re-uses both the tuned table and the jit caches
        with recompile_guard(eng) as rec2:
            eng.run()
        assert (rec2.compiles, rec2.new_shapes) == (0, 0)
    finally:
        at.reset()
