"""Deterministic fault injection, replica failover, tiered-storage
fallback, and crash-safe pipelines/training (the robustness layer).

The central property under test: every recovery path — retry, replica
failover, tier fall-through, worker respawn, checkpoint resume — is
**bit-identical by construction** to the fault-free run, because all
sampling randomness is keyed by request/batch (never by attempt, replica,
or wall clock) and all fault decisions are keyed by ``(seed, site,
invocation)``.  Degraded results are flagged, never silent.
"""
import os
import signal
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.faults import (
    CircuitBreaker,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    RetryPolicy,
    as_injector,
)

FORK = os.name == "posix"


# ---------------------------------------------------------------------------
# FaultPlan / FaultInjector
# ---------------------------------------------------------------------------
def test_fault_plan_deterministic():
    plan = FaultPlan.bernoulli(0.3, site="server.*", seed=42)
    a = [plan.injector().should_fail("server.0.0") for _ in range(1)]  # noqa: F841
    inj1, inj2 = plan.injector(), plan.injector()
    seq1 = [inj1.should_fail("server.0.0") for _ in range(200)]
    seq2 = [inj2.should_fail("server.0.0") for _ in range(200)]
    assert seq1 == seq2
    assert any(seq1) and not all(seq1)  # p=0.3 over 200 draws
    # distinct sites draw independent streams
    inj3 = plan.injector()
    interleaved = []
    for _ in range(200):
        interleaved.append(inj3.should_fail("server.0.0"))
        inj3.should_fail("server.1.0")  # does not perturb server.0.0
    assert interleaved == seq1


def test_fault_spec_burst_and_limit():
    plan = FaultPlan.bernoulli(1.0, burst=3, limit=3, seed=0)
    inj = plan.injector()
    seq = [inj.should_fail("x") for _ in range(6)]
    # one trigger fails 3 consecutive invocations, then the limit is spent
    assert seq == [True, True, True, False, False, False]
    assert inj.total_failures() == 3
    assert inj.counters()["x"] == {"invocations": 6, "failures": 3}


def test_unmatched_site_costs_nothing():
    inj = FaultPlan.bernoulli(1.0, site="disk.*").injector()
    assert not inj.should_fail("server.0.0")
    assert inj.invocations == {}  # unmatched sites are not even counted
    with pytest.raises(InjectedFault) as ei:
        for _ in range(5):
            inj.fire("disk.read")
    assert ei.value.site == "disk.read"


def test_first_match_wins():
    plan = FaultPlan(
        seed=0,
        sites=(
            ("server.0.1", FaultSpec(p=1.0)),
            ("server.*", FaultSpec(p=0.0)),
        ),
    )
    inj = plan.injector()
    assert inj.should_fail("server.0.1")
    assert not inj.should_fail("server.0.0")


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan.bernoulli(1.5)
    with pytest.raises(ValueError):
        FaultPlan.bernoulli(0.5, burst=0)
    with pytest.raises(ValueError):
        FaultPlan.bernoulli(0.5, limit=-1)
    with pytest.raises(TypeError):
        FaultPlan(sites=(("server.*", 0.5),))
    with pytest.raises(TypeError):
        as_injector("not a plan")
    assert as_injector(None) is None
    inj = FaultPlan.bernoulli(0.5).injector()
    assert as_injector(inj) is inj  # pass-through shares counters
    rt = FaultPlan.bernoulli(0.25, site="a.*", seed=3, burst=2, limit=9)
    assert rt.to_dict() == {
        "seed": 3,
        "sites": [["a.*", {"p": 0.25, "burst": 2, "limit": 9}]],
    }


# ---------------------------------------------------------------------------
# RetryPolicy / CircuitBreaker
# ---------------------------------------------------------------------------
def test_retry_policy_backoff():
    pol = RetryPolicy(max_attempts=5, base_delay_s=0.01, max_delay_s=0.05)
    assert [pol.backoff(a) for a in (1, 2, 3, 4, 5)] == [
        0.01,
        0.02,
        0.04,
        0.05,
        0.05,
    ]
    assert RetryPolicy().backoff(3) == 0.0  # default: instant retries
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0).validate()
    with pytest.raises(ValueError):
        RetryPolicy(multiplier=0.5).validate()
    with pytest.raises(ValueError):
        RetryPolicy(base_delay_s=-1).validate()
    # a spent deadline skips the sleep entirely
    t0 = time.monotonic()
    pol.sleep(5, deadline=time.monotonic() - 1.0)
    assert time.monotonic() - t0 < 0.04


def test_circuit_breaker_cycle():
    br = CircuitBreaker(threshold=2, cooldown=3)
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "closed"
    br.record_failure()  # 2nd consecutive -> opens
    assert br.state == "open" and br.opens == 1
    assert not br.allow()
    assert not br.allow()
    assert br.allow()  # cooldown spent: half-open probe admitted
    assert br.state == "half_open"
    br.record_failure()  # probe failed -> re-opens immediately
    assert br.state == "open" and br.opens == 2
    for _ in range(2):
        br.allow()
    assert br.allow()
    br.record_success()  # probe succeeded -> closed
    assert br.state == "closed" and br.allow()


# ---------------------------------------------------------------------------
# Sampling failover
# ---------------------------------------------------------------------------
def _service(graph, partitioned, **kw):
    from repro.core.sampling import SamplingServer, VertexRouter
    from repro.core.sampling.service import GatherApplyRouting, SamplingService

    ep, parts = partitioned
    return SamplingService(
        [SamplingServer(p, seed=0) for p in parts],
        GatherApplyRouting(VertexRouter(graph, ep, 4)),
        seed=0,
        **kw,
    )


def _spec(fanouts=(6, 3)):
    from repro.core.sampling.service import SamplingSpec

    return SamplingSpec(fanouts=tuple(fanouts))


def _assert_same_subgraph(a, b):
    np.testing.assert_array_equal(a.seeds, b.seeds)
    assert len(a.hops) == len(b.hops)
    for ha, hb in zip(a.hops, b.hops):
        np.testing.assert_array_equal(ha.src, hb.src)
        np.testing.assert_array_equal(ha.dst, hb.dst)


SEEDS = np.arange(100, 260)


@settings(max_examples=5, deadline=None)
@given(
    p=st.floats(min_value=0.05, max_value=0.6),
    chaos_seed=st.integers(min_value=0, max_value=10_000),
    burst=st.integers(min_value=1, max_value=2),
)
def test_chaos_sampling_bit_identical(small_graph, partitioned, p, chaos_seed, burst):
    """Any Bernoulli fault schedule whose per-site limit stays under the
    breaker threshold recovers by retry alone, bit-identically: the
    per-dispatch RNG is keyed by (request, hop, partition), never by
    attempt, so a redraw after an injected fault is the same draw."""
    clean = _service(small_graph, partitioned)
    want = clean.submit(SEEDS, _spec(), key=(7, 0)).result(timeout=30)

    # limit=2 < CircuitBreaker.threshold=3: no quarantine, and every
    # dispatch recovers within max_attempts=4 on the primary alone
    plan = FaultPlan.bernoulli(
        p, site="server.*", seed=chaos_seed, burst=burst, limit=2
    )
    chaotic = _service(
        small_graph,
        partitioned,
        fault_plan=plan,
        retry_policy=RetryPolicy(max_attempts=4),
    )
    got = chaotic.submit(SEEDS, _spec(), key=(7, 0)).result(timeout=30)
    _assert_same_subgraph(want, got)
    assert not got.degraded and got.lost_dispatches == 0
    stats = chaotic.stats()
    assert stats.retries == chaotic.faults.total_failures()
    assert stats.degraded == 0


def test_failover_to_replica_bit_identical(small_graph, partitioned):
    """A burst long enough to trip the primary's breaker reroutes to the
    replica; replicas share the primary's partition data and the RNG key
    is replica-independent, so the reroute is invisible in the result."""
    clean = _service(small_graph, partitioned)
    want = clean.submit(SEEDS, _spec(), key=(9, 0)).result(timeout=30)

    plan = FaultPlan.bernoulli(0.3, site="server.*.0", seed=5, burst=8, limit=8)
    chaotic = _service(
        small_graph,
        partitioned,
        replicas=2,
        fault_plan=plan,
        retry_policy=RetryPolicy(max_attempts=3),
    )
    got = chaotic.submit(SEEDS, _spec(), key=(9, 0)).result(timeout=30)
    _assert_same_subgraph(want, got)
    assert not got.degraded
    stats = chaotic.stats()
    assert stats.failovers > 0  # replicas actually served dispatches
    assert chaotic.faults.total_failures() > 0


def test_degraded_is_flagged_never_silent(small_graph, partitioned):
    plan = FaultPlan.bernoulli(1.0, site="server.*")  # unlimited failures
    svc = _service(
        small_graph,
        partitioned,
        fault_plan=plan,
        # 4 attempts: the 3rd consecutive failure trips each breaker, so
        # the run also demonstrates quarantine under sustained failure
        retry_policy=RetryPolicy(max_attempts=4),
    )
    sub = svc.submit(SEEDS[:40], _spec((4,)), key=(1, 0)).result(timeout=30)
    assert sub.degraded and sub.lost_dispatches > 0
    assert all(h.src.shape[0] == 0 for h in sub.hops)  # nothing served...
    assert svc.stats().degraded == sub.lost_dispatches  # ...and counted
    health = svc.server_health()
    assert set(health.values()) <= {"up", "quarantined"}
    assert any(v == "quarantined" for v in health.values())


def test_sample_timeout(small_graph, partitioned, monkeypatch):
    from repro.core.sampling.service import SampleTimeout

    svc = _service(small_graph, partitioned, ticket_timeout=0.05)
    ticket = svc.submit(SEEDS[:8], _spec((4,)), key=(2, 0))
    monkeypatch.setattr(
        svc, "_advance_round", lambda deadline=None: time.sleep(0.01)
    )
    with pytest.raises(SampleTimeout):
        ticket.result()  # falls back to the service-level ticket_timeout
    monkeypatch.undo()
    assert ticket.result(timeout=30) is not None  # still completable


# ---------------------------------------------------------------------------
# Storage: checksums, retry, tier fall-through
# ---------------------------------------------------------------------------
def _filled_store(path, rows=256, dim=4, chunk_rows=32, **kw):
    from repro.core.storage import DFSTier

    store = DFSTier(str(path), rows, dim, chunk_rows=chunk_rows, **kw)
    vals = np.arange(rows * dim, dtype=np.float32).reshape(rows, dim)
    store.write_rows(np.arange(rows), vals)
    return store, vals


def test_disk_tier_missing_chunk_error(tmp_path):
    from repro.core.storage import ChunkReadError, DiskTier

    tier = DiskTier(32, 4, path=str(tmp_path / "d"))
    with pytest.raises(ChunkReadError, match=r"tier_000042\.npy"):
        tier.read_chunk(42)


def test_disk_tier_truncated_file_error(tmp_path):
    from repro.core.storage import ChunkReadError, DiskTier

    tier = DiskTier(32, 4, path=str(tmp_path / "d"))
    block = np.ones((32, 4), dtype=np.float32)
    tier.write_chunk(3, block)
    fn = tier._chunk_file(3)
    with open(fn, "r+b") as fh:
        fh.truncate(os.path.getsize(fn) // 2)
    with pytest.raises(ChunkReadError, match="truncated or corrupt"):
        tier.read_chunk(3)


def test_disk_tier_partial_write_cleanup(tmp_path, monkeypatch):
    from repro.core.storage import DiskTier
    from repro.core.storage import tiers as tiers_mod

    tier = DiskTier(32, 4, path=str(tmp_path / "d"))
    good = np.full((32, 4), 7.0, dtype=np.float32)
    tier.write_chunk(1, good)

    def exploding_save(fh, block):
        fh.write(b"\x93NUMPY partial garbage")
        raise OSError("disk full")

    monkeypatch.setattr(tiers_mod.np, "save", exploding_save)
    with pytest.raises(OSError, match="disk full"):
        tier.write_chunk(1, np.zeros((32, 4), dtype=np.float32))
    monkeypatch.undo()
    # no partial temp file left behind, previous good chunk intact
    leftovers = [f for f in sorted(os.listdir(tmp_path / "d")) if f.endswith(".tmp")]
    assert leftovers == []
    np.testing.assert_array_equal(tier.read_chunk(1), good)
    assert 1 in tier  # still accounted as held


def test_disk_tier_checksum_detects_corruption(tmp_path):
    from repro.core.storage import ChunkReadError, DiskTier

    plan = FaultPlan.bernoulli(1.0, site="disk.corrupt", limit=1)
    tier = DiskTier(32, 4, path=str(tmp_path / "d"), faults=plan.injector())
    block = np.arange(128, dtype=np.float32).reshape(32, 4)
    tier.write_chunk(0, block)
    with pytest.raises(ChunkReadError, match="checksum"):
        tier.read_chunk(0)  # bit-flip injected, checksum catches it
    np.testing.assert_array_equal(tier.read_chunk(0), block)  # limit spent


def test_dfs_store_checksum_detects_corruption(tmp_path):
    from repro.core.storage import ChunkCorruptionError

    plan = FaultPlan.bernoulli(1.0, site="dfs.corrupt", limit=1)
    store, vals = _filled_store(tmp_path / "s", faults=plan.injector())
    with pytest.raises(ChunkCorruptionError):
        store.read_chunk(0)
    np.testing.assert_array_equal(store.read_chunk(0), vals[:32])


def test_dfs_store_partial_write_cleanup(tmp_path, monkeypatch):
    from repro.core.storage import store as store_mod

    store, vals = _filled_store(tmp_path / "s")
    monkeypatch.setattr(
        store_mod.np,
        "save",
        lambda fh, block: (_ for _ in ()).throw(OSError("disk full")),
    )
    with pytest.raises(OSError, match="disk full"):
        store.write_chunk(0, np.zeros((32, 4), dtype=np.float32))
    monkeypatch.undo()
    assert not [f for f in sorted(os.listdir(tmp_path / "s")) if f.endswith(".tmp")]
    np.testing.assert_array_equal(store.read_chunk(0), vals[:32])


def test_hybrid_cache_falls_through_dead_tier(tmp_path):
    from repro.core.storage import DiskTier, HybridCache, MemoryTier

    store, vals = _filled_store(tmp_path / "s")
    # disk tier always fails its reads; memory tier is tiny so most reads
    # land on disk first and must fall through to the DFS store
    plan = FaultPlan.bernoulli(1.0, site="disk.read")
    tiers = [
        MemoryTier(32, 4, capacity=1),
        DiskTier(32, 4, path=str(tmp_path / "d"), faults=plan.injector()),
    ]
    cache = HybridCache(
        store, tiers, policy="fifo", retry_policy=RetryPolicy(max_attempts=2)
    )
    cache.fill_for(np.arange(256))
    for c in (0, 3, 5, 7, 2, 6):
        rows = np.arange(c * 32, c * 32 + 8)
        np.testing.assert_array_equal(cache.read_rows(rows), vals[rows])
    s = cache.stats
    assert s.failovers > 0  # dead tier dropped chunks, store served them
    assert s.retries > 0
    assert s.as_dict()["failovers"] == s.failovers


def test_hybrid_cache_retry_recovers_transient(tmp_path):
    from repro.core.storage import DiskTier, HybridCache, MemoryTier

    store, vals = _filled_store(tmp_path / "s")
    # at most 1 failure per plan-limit: the in-tier retry always recovers
    plan = FaultPlan.bernoulli(0.5, site="disk.read", seed=11, limit=1)
    tiers = [
        MemoryTier(32, 4, capacity=1),
        DiskTier(32, 4, path=str(tmp_path / "d"), faults=plan.injector()),
    ]
    cache = HybridCache(
        store, tiers, policy="fifo", retry_policy=RetryPolicy(max_attempts=3)
    )
    cache.fill_for(np.arange(256))
    for c in (0, 3, 5, 7, 2, 6):
        rows = np.arange(c * 32, c * 32 + 8)
        np.testing.assert_array_equal(cache.read_rows(rows), vals[rows])
    assert cache.stats.retries >= 1
    assert cache.stats.failovers == 0  # retry recovered; nothing fell through


def test_store_read_retries_through_cache(tmp_path):
    from repro.core.storage import DiskTier, HybridCache, MemoryTier

    plan = FaultPlan.bernoulli(1.0, site="dfs.read", limit=1)
    store, vals = _filled_store(tmp_path / "s", faults=plan.injector())
    cache = HybridCache(
        store,
        [MemoryTier(32, 4, capacity=2), DiskTier(32, 4)],
        policy="fifo",
        retry_policy=RetryPolicy(max_attempts=2),
    )
    rows = np.arange(8)
    np.testing.assert_array_equal(cache.read_rows(rows), vals[rows])
    assert cache.stats.store_retries == 1


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------
def _tree():
    return {
        "params": {
            "w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "layers": [np.ones(4, dtype=np.float32), np.zeros(2, np.float32)],
        },
        "opt": {"mu": np.full(3, 0.5, dtype=np.float32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    from repro.train.checkpoint import load_checkpoint, save_checkpoint

    path = str(tmp_path / "ck")
    final = save_checkpoint(path, _tree(), step=17)
    assert final.endswith(".npz") and os.path.exists(final)
    tree, step = load_checkpoint(path, _tree())
    assert step == 17
    np.testing.assert_array_equal(
        np.asarray(tree["params"]["w"]), _tree()["params"]["w"]
    )
    np.testing.assert_array_equal(
        np.asarray(tree["params"]["layers"][0]), np.ones(4)
    )


def test_checkpoint_atomic_on_crash(tmp_path, monkeypatch):
    from repro.train import checkpoint as ck

    path = str(tmp_path / "ck.npz")
    ck.save_checkpoint(path, _tree(), step=1)

    monkeypatch.setattr(
        ck.os,
        "replace",
        lambda a, b: (_ for _ in ()).throw(OSError("crash mid-rename")),
    )
    with pytest.raises(OSError, match="crash mid-rename"):
        ck.save_checkpoint(path, _tree(), step=2)
    monkeypatch.undo()
    # the old checkpoint survives untouched; no temp litter
    assert not [f for f in sorted(os.listdir(tmp_path)) if f.endswith(".tmp")]
    _, step = ck.load_checkpoint(path, _tree())
    assert step == 1


def test_checkpoint_errors(tmp_path):
    from repro.train.checkpoint import (
        CheckpointError,
        load_checkpoint,
        save_checkpoint,
    )

    with pytest.raises(CheckpointError, match="no checkpoint file"):
        load_checkpoint(str(tmp_path / "absent"), _tree())

    path = str(tmp_path / "ck")
    save_checkpoint(path, _tree())

    bigger = _tree()
    bigger["params"]["extra"] = np.zeros(3)
    with pytest.raises(CheckpointError, match="missing key 'params/extra'"):
        load_checkpoint(path, bigger)

    smaller = _tree()
    del smaller["opt"]
    with pytest.raises(CheckpointError, match="structure mismatch"):
        load_checkpoint(path, smaller)

    reshaped = _tree()
    reshaped["params"]["w"] = np.zeros((3, 2), dtype=np.float32)
    with pytest.raises(CheckpointError, match="shape mismatch at 'params/w'"):
        load_checkpoint(path, reshaped)

    with open(str(tmp_path / "junk.npz"), "wb") as fh:
        fh.write(b"not an npz")
    with pytest.raises(CheckpointError, match="unreadable"):
        load_checkpoint(str(tmp_path / "junk"), _tree())


# ---------------------------------------------------------------------------
# Crash-safe pipelines
# ---------------------------------------------------------------------------
def _pipeline(graph, partitioned, prefetch, **kw):
    from repro.api.pipeline import BatchPipeline

    svc = _service(graph, partitioned)
    return BatchPipeline(
        svc,
        graph,
        np.arange(0, 500),
        [4, 4],
        2,
        batch_size=64,
        prefetch=prefetch,
        seed=3,
        **kw,
    )


def _collect(pipe, epochs):
    out = []
    for seeds, batch in pipe.batches(epochs):
        out.append((np.asarray(seeds).copy(), np.asarray(batch.feats).copy()))
    return out


@pytest.mark.skipif(not FORK, reason="process-mode pipeline needs fork")
def test_worker_kill_respawn_bit_identical(small_graph, partitioned):
    base = _pipeline(small_graph, partitioned, 0)
    ref = _collect(base, 1) + _collect(base, 1)  # two runs, shared state

    pipe = _pipeline(small_graph, partitioned, 1, workers="process")
    got = _collect(pipe, 1)  # run 1 completes normally
    for i, (seeds, batch) in enumerate(pipe.batches(1)):  # run 2 crashes
        got.append((np.asarray(seeds).copy(), np.asarray(batch.feats).copy()))
        if i == 2:
            pipe._proc.kill()  # simulate an OOM-killed worker mid-epoch
            time.sleep(0.2)
    pipe.close()

    assert pipe.respawn_count == 1
    assert len(got) == len(ref)
    for (s1, f1), (s2, f2) in zip(ref, got):
        np.testing.assert_array_equal(s1, s2)
        np.testing.assert_array_equal(f1, f2)


@pytest.mark.skipif(not FORK, reason="process-mode pipeline needs fork")
def test_worker_crash_budget_exhausted(small_graph, partitioned):
    pipe = _pipeline(
        small_graph, partitioned, 1, workers="process", worker_respawns=0
    )
    with pytest.raises(RuntimeError, match="prefetch worker died"):
        for i, _ in enumerate(pipe.batches(1)):
            if i == 1:
                pipe._proc.kill()
                time.sleep(0.2)
    pipe.close()


@pytest.mark.skipif(not FORK, reason="process-mode pipeline needs fork")
def test_close_escalates_to_kill_on_wedged_worker(small_graph, partitioned):
    from repro.api.pipeline import BatchPipeline

    class WedgedPipeline(BatchPipeline):
        def _worker_loop(self):  # ignores stop commands AND SIGTERM
            signal.signal(signal.SIGTERM, signal.SIG_IGN)
            while True:
                time.sleep(0.2)

    svc = _service(small_graph, partitioned)
    pipe = WedgedPipeline(
        svc,
        small_graph,
        np.arange(0, 128),
        [4],
        1,
        batch_size=64,
        prefetch=1,
        workers="process",
        seed=0,
    )
    pipe._ensure_worker()
    proc = pipe._proc
    assert proc.is_alive()
    time.sleep(0.3)  # let the child install its SIGTERM ignore
    t0 = time.monotonic()
    pipe.close(timeout=0.5)
    elapsed = time.monotonic() - t0
    assert not proc.is_alive()  # SIGKILL got it despite the SIGTERM ignore
    assert elapsed < 5.0  # bounded, not the old indefinite join
    pipe.close()  # idempotent


# ---------------------------------------------------------------------------
# Crash-safe training: checkpoint/resume and chaos bit-identity
# ---------------------------------------------------------------------------
def _trainer(graph, partitioned, **kw):
    from repro.models.gnn import GNNModel
    from repro.train import GNNTrainer

    model = GNNModel(
        "sage", graph.vertex_feats.shape[1], hidden=16, num_layers=2, num_classes=4
    )
    svc = kw.pop("service", None) or _service(graph, partitioned)
    return GNNTrainer(
        model,
        svc,
        graph,
        [4, 4],
        np.arange(0, 512),
        batch_size=128,
        seed=0,
        prefetch=0,
        **kw,
    )


def _leaves(params):
    import jax

    return [np.asarray(x) for x in jax.tree.leaves(params)]


def test_crash_and_resume_bitwise_identical(small_graph, partitioned, tmp_path):
    # uninterrupted reference run: 6 steps
    a = _trainer(small_graph, partitioned)
    a.train(epochs=2, max_steps=6)

    # crashed run: auto-checkpoints every 2 steps, dies after step 3
    b = _trainer(
        small_graph,
        partitioned,
        checkpoint_dir=str(tmp_path),
        checkpoint_every=2,
    )
    b.train(epochs=2, max_steps=3)  # checkpoint on disk holds step 2

    # fresh process: resume from the checkpoint and finish the run
    c = _trainer(
        small_graph,
        partitioned,
        checkpoint_dir=str(tmp_path),
        checkpoint_every=2,
    )
    assert c.resume() == 2
    c.train(epochs=2, max_steps=6)

    for wa, wc in zip(_leaves(a.params), _leaves(c.params)):
        np.testing.assert_array_equal(wa, wc)
    for oa, oc in zip(_leaves(a.opt_state), _leaves(c.opt_state)):
        np.testing.assert_array_equal(oa, oc)


def test_trainer_checkpoint_config_validation(small_graph, partitioned):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        _trainer(small_graph, partitioned, checkpoint_every=2)


def test_chaos_training_bit_identical(small_graph, partitioned):
    a = _trainer(small_graph, partitioned)
    a.train(epochs=1, max_steps=4)

    plan = FaultPlan.bernoulli(0.3, site="server.*", seed=77, limit=2)
    chaotic = _service(
        small_graph,
        partitioned,
        fault_plan=plan,
        retry_policy=RetryPolicy(max_attempts=4),
    )
    b = _trainer(small_graph, partitioned, service=chaotic)
    b.train(epochs=1, max_steps=4)

    assert chaotic.faults.total_failures() > 0  # chaos actually happened
    for wa, wb in zip(_leaves(a.params), _leaves(b.params)):
        np.testing.assert_array_equal(wa, wb)


def test_chaos_inference_bit_identical(small_graph, partitioned, tmp_path):
    """Layerwise inference over a chaotic system (sampling faults with a
    replica + storage-tier faults with retries) matches the clean system's
    embeddings exactly."""
    from repro.api import GLISPConfig, GLISPSystem

    def run(cfg, wd):
        import jax

        from repro.models.gnn import GNNModel

        system = GLISPSystem.build(small_graph, cfg)
        model = GNNModel("sage", 16, hidden=16, num_layers=2)
        params = model.init(jax.random.PRNGKey(0))
        fns = [model.embed_layer_fn(params, k) for k in range(2)]
        res = system.infer_layerwise(fns, wd)
        targets = np.arange(64)
        return res.final_store.read_rows_direct(res.newid[targets]), system

    base = GLISPConfig(num_parts=4, fanouts=(6, 3), chunk_rows=128)
    clean, _ = run(base, str(tmp_path / "clean"))
    plan = FaultPlan(
        seed=13,
        sites=(
            ("server.*", FaultSpec(p=0.2, limit=2)),
            ("disk.read", FaultSpec(p=0.3, limit=4)),
            ("memory.read", FaultSpec(p=0.1, limit=2)),
        ),
    )
    chaotic_cfg = base.replace(
        fault_plan=plan,
        server_replicas=2,
        retry_policy=RetryPolicy(max_attempts=4),
    )
    chaos, system = run(chaotic_cfg, str(tmp_path / "chaos"))
    np.testing.assert_array_equal(clean, chaos)
    assert system.service.faults.total_failures() >= 0  # injector armed


# ---------------------------------------------------------------------------
# Config threading
# ---------------------------------------------------------------------------
def test_config_fault_knobs_validate_and_serialize():
    from repro.api import GLISPConfig

    cfg = GLISPConfig(
        fault_plan=FaultPlan.bernoulli(0.1, site="server.*"),
        retry_policy=RetryPolicy(max_attempts=2),
        ticket_timeout=5.0,
        server_replicas=2,
        checkpoint_every=10,
        checkpoint_dir="/tmp/ck",
    ).validate()
    d = cfg.to_dict()
    assert d["fault_plan"]["sites"] == [
        ["server.*", {"p": 0.1, "burst": 1, "limit": None}]
    ]
    assert d["retry_policy"]["max_attempts"] == 2

    with pytest.raises(ValueError):
        GLISPConfig(server_replicas=0).validate()
    with pytest.raises(ValueError):
        GLISPConfig(ticket_timeout=0.0).validate()
    with pytest.raises(ValueError):
        GLISPConfig(worker_respawns=-1).validate()
    with pytest.raises(ValueError):
        GLISPConfig(checkpoint_every=5).validate()  # no checkpoint_dir
    with pytest.raises(TypeError):
        GLISPConfig(fault_plan="server.*").validate()
    with pytest.raises(TypeError):
        GLISPConfig(retry_policy={"max_attempts": 2}).validate()


def test_system_threads_fault_knobs(small_graph):
    from repro.api import GLISPConfig, GLISPSystem

    plan = FaultPlan.bernoulli(0.05, site="server.*", limit=1)
    system = GLISPSystem.build(
        small_graph,
        GLISPConfig(
            num_parts=4,
            fanouts=(4, 4),
            fault_plan=plan,
            retry_policy=RetryPolicy(max_attempts=5),
            ticket_timeout=60.0,
            server_replicas=2,
        ),
    )
    svc = system.service
    assert svc.retry_policy.max_attempts == 5
    assert svc.ticket_timeout == 60.0
    assert isinstance(svc.faults, FaultInjector)
    assert len(system.server_health()) == 8  # 4 parts x 2 replicas
    sub = system.sample(np.arange(64))
    assert not sub.degraded
