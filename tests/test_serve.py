"""Online serving tier: batching determinism, SLO semantics, admission.

The load-bearing property: a request's embeddings are **bit-identical**
whether it was served solo or packed into any batch mix — per-request
sampling keys plus row-independent padded slices make batch composition
unobservable in the results.  The property test drives one request set
through randomized interleavings/windows/delays and compares every
response bitwise against a solo-served reference.
"""
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.serve import ContinuousBatcher, P2Quantile, RequestQueue, ServeRequest


# ---------------------------------------------------------------------------
# components
# ---------------------------------------------------------------------------


def test_request_queue_bounds_and_rejects():
    q = RequestQueue(2)
    assert q.push("a") and q.push("b")
    assert not q.push("c")  # full: explicit rejection, no side effect
    assert len(q) == 2
    assert q.pop() == "a"
    assert q.push("c")  # a pop frees a slot
    assert [q.pop(), q.pop(), q.pop()] == ["b", "c", None]
    with pytest.raises(ValueError):
        RequestQueue(0)


def test_batcher_size_and_delay_triggers():
    b = ContinuousBatcher(max_rows=10, max_delay_ms=50.0)
    b.add("r0", 4, now=0.0)
    assert not b.ready(now=0.0) and b.take(now=0.0) is None
    b.add("r1", 6, now=0.01)  # 10 rows: size trigger
    assert b.ready(now=0.01)
    assert b.take(now=0.01) == ["r0", "r1"] and len(b) == 0
    b.add("r2", 1, now=1.0)
    assert not b.ready(now=1.04)  # 40 ms: timer not yet fired
    assert b.ready(now=1.06)  # 60 ms: oldest waited out the delay
    assert b.take(now=1.06) == ["r2"]
    b.add("r3", 2, now=2.0)
    assert b.take(now=2.0, force=True) == ["r3"]  # force flushes a partial


def test_batcher_splits_at_budget_and_admits_oversized_head():
    b = ContinuousBatcher(max_rows=8, max_delay_ms=0.0)
    for i, rows in enumerate([5, 5, 99]):
        b.add(f"r{i}", rows, now=0.0)
    assert b.take(now=0.0) == ["r0"]  # r1 would spill the budget
    assert b.take(now=0.0) == ["r1"]
    assert b.take(now=0.0) == ["r2"]  # oversized head still ships alone


def test_serve_request_validation_and_ordering():
    req = ServeRequest.make(7, np.array([5, 3, 5, 9]), None, 0.0)
    np.testing.assert_array_equal(req.unique, [3, 5, 9])
    np.testing.assert_array_equal(req.vertices, [5, 3, 5, 9])
    assert req.deadline_at(100.0) == pytest.approx(0.1)
    assert req.deadline_at(None) is None
    with pytest.raises(ValueError):
        ServeRequest.make(0, np.array([]), None, 0.0)
    with pytest.raises(ValueError):
        ServeRequest.make(0, np.eye(2), None, 0.0)


def test_p2_quantile_tracks_exact_percentiles():
    rng = np.random.default_rng(3)
    xs = rng.gamma(2.0, 10.0, size=2000)
    for q in (0.5, 0.95, 0.99):
        est = P2Quantile(q)
        for x in xs:
            est.add(x)
        exact = float(np.percentile(xs, 100 * q))
        assert abs(est.value() - exact) <= 0.1 * exact + 1.0
    small = P2Quantile(0.5)
    for x in [3.0, 1.0, 2.0]:
        small.add(x)
    assert small.value() == 2.0  # exact below five samples


def test_config_serve_knobs_validate():
    from repro.api import GLISPConfig

    GLISPConfig().validate()
    for bad in (
        dict(serve_queue_depth=0),
        dict(serve_max_batch_delay_ms=-1.0),
        dict(serve_deadline_ms=0.0),
    ):
        with pytest.raises(ValueError):
            GLISPConfig(**bad).validate()
    GLISPConfig(serve_deadline_ms=None).validate()  # explicit no-deadline


# ---------------------------------------------------------------------------
# the served system
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_system(tmp_path_factory):
    import jax

    from repro.api import GLISPConfig, GLISPSystem
    from repro.graph import power_law_graph
    from repro.models.gnn import GNNModel

    g = power_law_graph(800, avg_degree=6, seed=3, feat_dim=16, num_classes=4)
    system = GLISPSystem.build(g, GLISPConfig(num_parts=2, fanouts=(6, 4)))
    model = GNNModel("sage", 16, hidden=8, num_layers=2)
    params = model.init(jax.random.PRNGKey(0))
    fns = [model.embed_layer_fn(params, k) for k in range(2)]
    wd = str(tmp_path_factory.mktemp("serve_emb"))
    system.infer_layerwise(fns, wd, out_dims=[8, 8], batch_size=256)
    return system


REQUESTS = None


def _requests(g):
    global REQUESTS
    if REQUESTS is None:
        rng = np.random.default_rng(11)
        REQUESTS = [
            rng.choice(g.num_vertices, size=int(rng.integers(1, 9)), replace=False)
            for _ in range(8)
        ]
    return REQUESTS


@pytest.fixture(scope="module")
def solo_reference(served_system):
    """Every request served alone — the bit-identity ground truth."""
    server = served_system.server(max_batch_delay_ms=0.0, deadline_ms=None)
    return [server.call(v).embeddings for v in _requests(served_system.graph)]


def test_server_requires_inference_artifact(served_system):
    from repro.api import GLISPConfig, GLISPSystem

    fresh = GLISPSystem.build(
        served_system.graph, GLISPConfig(num_parts=2, fanouts=(6, 4))
    )
    with pytest.raises(ValueError, match="infer_layerwise"):
        fresh.server()


@settings(max_examples=8, deadline=None)
@given(
    window=st.integers(min_value=1, max_value=8),
    queue_depth=st.integers(min_value=8, max_value=32),
    delay_ms=st.sampled_from([0.0, 0.5, 1e6]),
    steps_between=st.integers(min_value=0, max_value=3),
)
def test_batching_is_bit_identical_to_solo(
    served_system, solo_reference, window, queue_depth, delay_ms, steps_between
):
    """Any admission window / queue depth / flush-delay interleaving must
    return exactly the solo embeddings for every request id."""
    server = served_system.server(
        queue_depth=queue_depth, max_batch_delay_ms=delay_ms, deadline_ms=None
    )
    reqs = _requests(served_system.graph)
    rids, nxt = [], 0
    while nxt < len(reqs):
        for _ in range(window):
            if nxt < len(reqs):
                rids.append(server.submit(reqs[nxt]))
                nxt += 1
        for _ in range(steps_between):
            server.step()  # un-forced: flushes only if a trigger fired
    server.drain()
    for rid, want in zip(rids, solo_reference):
        resp = server.response(rid)
        assert resp is not None and resp.status == "ok"
        assert resp.embeddings.dtype == want.dtype
        assert np.array_equal(resp.embeddings, want), (
            f"request {rid} diverged under window={window} "
            f"delay={delay_ms} steps={steps_between}"
        )
    assert server.stats.completed == len(reqs)
    assert server.stats.rejected == 0


def test_batched_occupancy_beats_solo(served_system):
    reqs = _requests(served_system.graph)
    solo = served_system.server(max_batch_delay_ms=0.0, deadline_ms=None)
    for v in reqs:
        solo.call(v)
    batched = served_system.server(max_batch_delay_ms=0.0, deadline_ms=None)
    rids = [batched.submit(v) for v in reqs]
    batched.drain()
    assert all(batched.response(r).status == "ok" for r in rids)
    assert batched.stats.occupancy() > solo.stats.occupancy()
    assert batched.stats.mean_batch_requests() > 1.0


def test_queue_full_rejects_explicitly(served_system):
    server = served_system.server(queue_depth=2, max_batch_delay_ms=1e6)
    rids = [server.submit(np.array([i])) for i in range(5)]
    statuses = [
        server.response(r, pop=False) and server.response(r, pop=False).status
        for r in rids
    ]
    assert statuses == [None, None, "rejected", "rejected", "rejected"]
    assert server.stats.rejected == 3
    server.drain()  # the two admitted requests still complete
    assert server.response(rids[0]).status == "ok"
    assert server.response(rids[1]).status == "ok"


def test_missed_deadline_times_out_and_server_survives(served_system):
    """A request whose deadline passed completes with an explicit timeout
    response — and the serving loop keeps answering later requests."""
    server = served_system.server(max_batch_delay_ms=0.0, deadline_ms=1e-6)
    rid = server.submit(np.array([1, 2, 3]))
    server.drain()
    resp = server.response(rid)
    assert resp.status == "timeout" and resp.embeddings is None
    assert server.stats.timed_out == 1
    # per-request deadline override: the next request is generous and lands
    rid2 = server.submit(np.array([4, 5]), deadline_ms=60_000.0)
    server.drain()
    assert server.response(rid2).status == "ok"
    assert server.stats.completed == 2


def test_blocked_service_times_out_within_deadline(served_system):
    """Sampling stuck behind a held scheduler lock must surface as a
    timeout response in ~deadline time, not wedge the serving loop."""
    server = served_system.server(max_batch_delay_ms=0.0, deadline_ms=50.0)
    svc = served_system.service
    held = threading.Event()

    def hold():
        with svc._lock:
            held.set()
            time.sleep(0.4)

    th = threading.Thread(target=hold)
    th.start()
    held.wait()
    try:
        rid = server.submit(np.array([1, 2, 3]))
        t0 = time.monotonic()
        server.drain()
        elapsed = time.monotonic() - t0
    finally:
        th.join()
    resp = server.response(rid)
    assert resp.status == "timeout"
    assert elapsed < 0.3, f"deadline wait not deadline-aware: {elapsed:.3f}s"
    # the server is not wedged: the same vertices serve fine afterwards
    assert server.call(np.array([1, 2, 3])).status == "ok"


def test_ticket_result_timeout_is_deadline_aware(served_system):
    """Regression (PR 8): ``SampleTicket.result(timeout=0.01)`` returns
    within a small multiple of 10 ms even while another thread holds the
    service's scheduler lock mid-round."""
    from repro.api import SampleTimeout

    svc = served_system.service
    ticket = served_system.submit(np.arange(8), key=(0x9E8, 0))
    held = threading.Event()

    def hold():
        with svc._lock:
            held.set()
            time.sleep(0.4)

    th = threading.Thread(target=hold)
    th.start()
    held.wait()
    t0 = time.monotonic()
    with pytest.raises(SampleTimeout):
        ticket.result(timeout=0.01)
    elapsed = time.monotonic() - t0
    th.join()
    assert elapsed < 0.25, f"10 ms timeout took {elapsed * 1e3:.0f} ms"
    assert ticket.result(timeout=5.0).hops  # still completes afterwards


def test_degraded_sampling_yields_degraded_responses(served_system):
    """Under a fault plan that exhausts sampling retries, responses come
    back ``status="ok"`` with ``degraded=True`` — explicit, never silent."""
    import jax

    from repro.api import FaultPlan, FaultSpec, GLISPConfig, GLISPSystem, RetryPolicy
    from repro.models.gnn import GNNModel

    g = served_system.graph
    faulty = GLISPSystem.build(
        g,
        GLISPConfig(
            num_parts=2,
            fanouts=(6, 4),
            fault_plan=FaultPlan(seed=5, sites=(("server.*", FaultSpec(p=0.95)),)),
            retry_policy=RetryPolicy(max_attempts=1),
        ),
    )
    model = GNNModel("sage", 16, hidden=8, num_layers=2)
    params = model.init(jax.random.PRNGKey(0))
    fns = [model.embed_layer_fn(params, k) for k in range(2)]
    import tempfile

    faulty.infer_layerwise(
        fns, tempfile.mkdtemp(), out_dims=[8, 8], batch_size=256
    )
    server = faulty.server(deadline_ms=None)
    rids = [server.submit(v) for v in _requests(g)]
    server.drain()
    responses = [server.response(r) for r in rids]
    assert all(r.status == "ok" for r in responses)
    assert any(r.degraded for r in responses)
    assert server.stats.degraded == sum(r.degraded for r in responses)
