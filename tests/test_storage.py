"""Tiered storage subsystem: tier/policy semantics, HybridCache lifecycle,
legacy TwoLevelCache accounting parity, and the FeatureSource training path.
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal envs: deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.inference import (
    ChunkedEmbeddingStore,
    LayerwiseInferenceEngine,
    TwoLevelCache,
)
from repro.core.inference.cache import CachePolicy
from repro.core.storage import (
    CACHE_POLICIES,
    DFSTier,
    DiskTier,
    HybridCache,
    IOCost,
    LocalityPolicy,
    MemoryTier,
    StoreFeatureSource,
    as_feature_source,
    build_tiers,
    chunk_runs,
    resolve_policy,
)


def _store(path, rows=512, dim=4, chunk_rows=32, **kw) -> DFSTier:
    store = DFSTier(str(path), rows, dim, chunk_rows=chunk_rows, **kw)
    vals = (
        np.arange(rows * dim, dtype=np.float32).reshape(rows, dim)
        / (rows * dim)
    )
    store.write_rows(np.arange(rows), vals.astype(store.dtype))
    return store


def _two_tier(store, policy="fifo", capacity=2) -> HybridCache:
    tiers = [
        MemoryTier(store.chunk_rows, store.dim, capacity=capacity),
        DiskTier(store.chunk_rows, store.dim),
    ]
    return HybridCache(store, tiers, policy=policy)


def _chunk_reads(cache, chunks):
    """Read one row from each chunk id in sequence."""
    for c in chunks:
        cache.read_rows(np.asarray([c * cache.store.chunk_rows]))


# ---------------------------------------------------------------------------
# chunk_runs / store
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(n=st.integers(0, 200), seed=st.integers(0, 10_000))
def test_chunk_runs_assume_sorted_matches_general(n, seed):
    rng = np.random.default_rng(seed)
    rows = np.sort(rng.integers(0, 1000, size=n).astype(np.int64))
    got = [
        (c, pos.tolist(), crows.tolist())
        for c, pos, crows in chunk_runs(rows, 64, assume_sorted=True)
    ]
    want = [
        (c, pos.tolist(), crows.tolist())
        for c, pos, crows in chunk_runs(rows, 64)
    ]
    assert got == want


def test_write_rows_unsorted_input(tmp_path):
    """The single-argsort write path handles shuffled row ids."""
    store = DFSTier(str(tmp_path / "s"), 300, 4, chunk_rows=64)
    rng = np.random.default_rng(3)
    rows = rng.permutation(300)
    vals = rng.standard_normal((300, 4)).astype(np.float32)
    store.write_rows(rows, vals)
    got = store.read_rows(rows)
    np.testing.assert_array_equal(got, vals)


def test_compressed_store_roundtrip(tmp_path):
    """compress=True writes .npz chunks; full and partial writes roundtrip."""
    store = DFSTier(str(tmp_path / "z"), 200, 6, chunk_rows=64, compress=True)
    rng = np.random.default_rng(0)
    vals = rng.standard_normal((200, 6)).astype(np.float32)
    store.write_rows(np.arange(200), vals)
    files = sorted(os.listdir(store.path))
    assert files and all(f.endswith(".npz") for f in files)
    np.testing.assert_array_equal(store.read_rows(np.arange(200)), vals)
    patch = np.full((3, 6), 9.0, np.float32)
    store.write_rows(np.array([0, 70, 199]), patch)  # partial RMW per chunk
    got = store.read_rows(np.arange(200))
    assert (got[[0, 70, 199]] == 9.0).all()
    keep = np.setdiff1d(np.arange(200), [0, 70, 199])
    np.testing.assert_array_equal(got[keep], vals[keep])
    # the deprecation shim constructs the same store
    shim = ChunkedEmbeddingStore(
        str(tmp_path / "z"), 200, 6, chunk_rows=64, compress=True
    )
    np.testing.assert_array_equal(shim.read_rows_direct(np.arange(200)), got)


def test_disk_tier_spills_to_files(tmp_path):
    """DiskTier with a path actually writes chunk files and reloads them."""
    tier = DiskTier(32, 4, path=str(tmp_path / "d"))
    block = np.ones((32, 4), np.float32) * 5
    tier.write_chunk(3, block)
    assert 3 in tier and len(tier) == 1
    assert os.path.exists(os.path.join(tier.path, "tier_000003.npy"))
    np.testing.assert_array_equal(tier.read_chunk(3), block)
    rows = np.arange(3 * 32, 3 * 32 + 8)
    np.testing.assert_array_equal(tier.read_rows(rows), block[:8])
    tier.delete_chunk(3)
    assert 3 not in tier
    assert not os.listdir(tier.path)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


def test_lru_and_fifo_hit_counts_differ(tmp_path):
    """A reuse-heavy trace where LRU keeps the hot chunk FIFO ages out:
    A B A C A D A ... — LRU refreshes A on every touch, FIFO evicts it as
    the oldest whenever a new chunk streams in."""
    trace = [0, 1, 0, 2, 0, 3, 0, 4, 0, 5, 0]
    hits = {}
    for policy in ("fifo", "lru"):
        store = _store(tmp_path / policy)
        cache = _two_tier(store, policy=policy, capacity=2)
        cache.fill(cache.plan_fill(np.arange(store.num_rows)))
        _chunk_reads(cache, trace)
        hits[policy] = cache.stats.dynamic_hits
    # LRU: every touch of chunk 0 refreshes its age, so only the streaming
    # chunks age out and 0 hits on every revisit (5).  FIFO: 0 stays the
    # oldest resident, so each new chunk evicts it and half the revisits
    # miss (3).
    assert hits["lru"] > hits["fifo"], hits
    assert (hits["lru"], hits["fifo"]) == (5, 3), hits


def test_locality_policy_protects_fill_window(tmp_path):
    """Locality eviction drops far (boundary) chunks first, so the local
    working set survives one-shot far reads that would cycle FIFO out."""
    # chunks 0-3 are the active partition (focus); 8-15 are far neighbors
    local = [0, 1, 2, 3]
    far = [8, 9, 10, 11, 12, 13, 14, 15]
    trace = []
    for f in far:  # interleave: local sweep, then one far one-shot read
        trace += local + [f]
    trace += local
    hits, modeled = {}, {}
    for policy in ("fifo", "locality"):
        store = _store(tmp_path / policy, rows=512, chunk_rows=32)  # 16 chunks
        cache = _two_tier(store, policy=policy, capacity=5)
        cache.fill(
            cache.plan_fill(
                np.arange(store.num_rows),
                focus_rows=np.arange(4 * 32),  # chunks 0-3
            )
        )
        _chunk_reads(cache, trace)
        hits[policy] = cache.stats.dynamic_hits
        modeled[policy] = cache.stats.modeled_time_ms(IOCost())
    assert hits["locality"] > hits["fifo"], hits
    # identical fills and access counts, so more memory hits must lower the
    # modeled retrieval time
    assert modeled["locality"] < modeled["fifo"], modeled


def test_policy_resolution_forms():
    assert resolve_policy("fifo").name == "fifo"
    assert resolve_policy(CachePolicy.LRU).name == "lru"  # legacy str-enum
    assert resolve_policy(LocalityPolicy).name == "locality"
    pol = LocalityPolicy()
    assert resolve_policy(pol) is pol
    with pytest.raises(ValueError):
        CACHE_POLICIES.get("nope")


# ---------------------------------------------------------------------------
# HybridCache lifecycle + legacy parity
# ---------------------------------------------------------------------------


def test_plan_fill_and_evict_lifecycle(tmp_path):
    store = _store(tmp_path / "s")  # 16 chunks of 32 rows
    cache = _two_tier(store, capacity=3)
    plan = cache.plan_fill(np.arange(0, 256))  # chunks 0-7
    assert plan.chunks.tolist() == list(range(8))
    assert plan.fetch.tolist() == list(range(8))
    assert plan.modeled_ms(IOCost()) == 8 * IOCost().dfs_ms
    cache.fill(plan)
    assert cache.stats.fill_chunks == 8
    assert cache.contains(np.array([0, 255])).all()
    assert not cache.contains(np.array([256])).any()
    # incremental refill: already-resident chunks are not refetched
    plan2 = cache.plan_fill(np.arange(0, 288), reset=False)
    assert plan2.fetch.tolist() == [8]
    cache.fill(plan2)
    assert cache.stats.fill_chunks == 9
    # explicit eviction releases residency without touching the store
    writes_before = store.stats.chunk_writes
    assert cache.evict() > 0
    assert not cache.contains(np.arange(0, 288)).any()
    assert store.stats.chunk_writes == writes_before


def test_write_through_invalidates_cache(tmp_path):
    store = _store(tmp_path / "s")
    cache = _two_tier(store, capacity=4)
    cache.fill(cache.plan_fill(np.arange(64)))  # chunks 0-1
    cache.read_rows(np.arange(64))
    new = np.full((32, store.dim), 7.0, np.float32)
    cache.write_rows(np.arange(32), new)  # chunk 0 rewritten
    np.testing.assert_array_equal(cache.read_rows(np.arange(32)), new)


def test_hybrid_matches_legacy_two_level_accounting(tmp_path):
    """Acceptance: a memory+disk fifo HybridCache reproduces the historic
    fill_chunks/static_reads/dynamic_hits accounting, trace for trace."""
    trace = [0, 1, 2, 0, 1, 2, 3, 3, 0]
    store_a = _store(tmp_path / "a", rows=320, chunk_rows=32)  # 10 chunks
    store_b = _store(tmp_path / "b", rows=320, chunk_rows=32)
    legacy = TwoLevelCache(store_a, CachePolicy.FIFO, dynamic_frac=0.2)
    legacy.fill_static(np.arange(320))
    hybrid = HybridCache(
        store_b,
        build_tiers(("memory", "disk"), 32, store_b.dim),
        policy="fifo",
        dynamic_frac=0.2,
    )
    hybrid.fill(hybrid.plan_fill(np.arange(320)))
    for c in trace:
        rows = np.arange(c * 32, c * 32 + 16)
        np.testing.assert_array_equal(
            legacy.read_rows(rows), hybrid.read_rows(rows)
        )
    ls, hs = legacy.stats, hybrid.stats
    assert (ls.fill_chunks, ls.static_reads, ls.dynamic_hits, ls.rows_served) \
        == (hs.fill_chunks, hs.static_reads, hs.dynamic_hits, hs.rows_served)
    assert hs.fill_chunks == 10
    assert legacy.dynamic_capacity == 2
    assert ls.modeled_time_ms(IOCost()) == hs.modeled_time_ms(IOCost())


def test_fill_free_capacity_grows(tmp_path):
    """The historic bug: without fill_static, dynamic_capacity stayed 0 and
    the memory tier evicted on every insert, deadening LRU-vs-FIFO.  Now
    capacity tracks the chunks admitted below, so fill-free reuse hits."""
    store = _store(tmp_path / "s", rows=320, chunk_rows=32)
    cache = TwoLevelCache(store, CachePolicy.LRU, dynamic_frac=0.5)
    # no fill_static: demand-fault chunks 0-5, then re-read 4 and 5
    for c in [0, 1, 2, 3, 4, 5]:
        cache.read_rows(np.arange(c * 32, c * 32 + 4))
    assert cache.dynamic_capacity == 3  # grew with the 6 faulted chunks
    before = cache.stats.dynamic_hits
    cache.read_rows(np.arange(4 * 32, 6 * 32))  # repopulates chunks 4, 5
    cache.read_rows(np.arange(4 * 32, 6 * 32))  # both now memory hits
    assert cache.stats.dynamic_hits >= before + 3


def test_hybrid_single_memory_tier(tmp_path):
    """A one-tier stack (pure memory cache over DFS) works; demand faults
    count as static (non-memory) serves, never as tier hits, so the hit
    ratio stays honest on a cold trace."""
    store = _store(tmp_path / "s")
    cache = HybridCache(
        store,
        [MemoryTier(store.chunk_rows, store.dim, capacity=4)],
        policy="lru",
    )
    cache.read_rows(np.arange(0, 128))  # chunks 0-3 demand-faulted
    assert cache.stats.fill_chunks == 4
    assert cache.stats.demand_reads == 4
    assert cache.stats.static_reads == 4  # cold pass: all misses
    assert cache.stats.tiers[0].hits == 0
    got = cache.read_rows(np.arange(0, 128))  # warm pass: all memory hits
    np.testing.assert_array_equal(got, store.read_rows(np.arange(0, 128)))
    assert cache.stats.tiers[0].hits == 4
    assert cache.stats.dynamic_hit_ratio == 0.5  # 4 hits / 8 retrievals


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def _sum_layer(W):
    def layer(_k, h_self, h_nbr, seg):
        agg = np.zeros_like(h_self)
        if h_nbr.shape[0]:
            np.add.at(agg, seg, h_nbr)
        return np.tanh(np.concatenate([h_self, agg], axis=1) @ W)

    return layer


def test_engine_stores_identical_across_tier_configs(
    small_graph, sampling_client, tmp_path
):
    """Acceptance: the tier stack and policy change WHERE rows come from,
    never their values — final stores agree bit-for-bit across configs."""
    rng = np.random.default_rng(0)
    layers = [_sum_layer(rng.standard_normal((32, 16)).astype(np.float32) * 0.3)]
    BIG = 10**9
    results = {}
    configs = {
        "two_tier_fifo": dict(storage_tiers=("memory", "disk"), policy="fifo"),
        "two_tier_locality": dict(
            storage_tiers=("memory", "disk"), policy="locality"
        ),
        "disk_only": dict(storage_tiers=("disk",), policy="fifo"),
        "tiny_memory": dict(
            storage_tiers=("memory", "disk"),
            tier_capacities=(1, 0),
            policy="lru",
        ),
    }
    for name, kw in configs.items():
        res = LayerwiseInferenceEngine(
            small_graph, sampling_client, layers, small_graph.vertex_feats,
            str(tmp_path / name), fanouts=[BIG], chunk_rows=128,
            out_dims=[16], batch_size=512, **kw,
        ).run()
        ids = np.arange(small_graph.num_vertices)
        results[name] = res.final_store.read_rows(res.newid[ids])
    base = results.pop("two_tier_fifo")
    for name, got in results.items():
        # full fanout visits identical edges, but each run's sample order
        # permutes the float32 accumulation -> allclose, not bit equality
        np.testing.assert_allclose(
            base, got, rtol=1e-4, atol=1e-5, err_msg=name
        )


def test_engine_layer_stats_expose_tiers(
    small_graph, sampling_client, tmp_path
):
    rng = np.random.default_rng(0)
    layers = [_sum_layer(rng.standard_normal((32, 16)).astype(np.float32) * 0.3)]
    res = LayerwiseInferenceEngine(
        small_graph, sampling_client, layers, small_graph.vertex_feats,
        str(tmp_path), fanouts=[5], chunk_rows=128, out_dims=[16],
    ).run()
    tiers = res.layer_stats[0].tiers
    assert [t.kind for t in tiers] == ["memory", "disk"]
    # legacy CacheStats rollup mirrors the tier view (two-tier fifo config)
    assert res.layer_stats[0].cache.dynamic_hits == tiers[0].hits
    assert res.layer_stats[0].cache.static_reads == tiers[1].hits


# ---------------------------------------------------------------------------
# FeatureSource — the training path
# ---------------------------------------------------------------------------


def test_as_feature_source_shapes(small_graph):
    src = as_feature_source(small_graph.vertex_feats)
    assert src.shape == small_graph.vertex_feats.shape
    assert src is as_feature_source(src)
    rows = np.array([0, 5, 3])
    np.testing.assert_array_equal(
        src.gather(rows), small_graph.vertex_feats[rows]
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1_000), chunk_rows=st.sampled_from([64, 100, 256]))
def test_disk_backed_features_bit_identical_batches(
    seed, chunk_rows, small_graph, sampling_client, tmp_path_factory
):
    """Acceptance property: training batches built over a disk-backed
    feature store equal the in-memory ones bit for bit."""
    from repro.models.gnn.batching import subgraph_to_batch

    td = tmp_path_factory.mktemp(f"feats_{seed}_{chunk_rows}")
    rng = np.random.default_rng(seed)
    seeds = np.sort(
        rng.choice(small_graph.num_vertices, size=64, replace=False)
    )
    sub = sampling_client.sample_khop(seeds, [10, 5])
    src = StoreFeatureSource.from_array(
        small_graph.vertex_feats, str(td), chunk_rows=chunk_rows,
        policy="lru", dynamic_frac=0.3,
    )
    a = subgraph_to_batch(
        sub, small_graph.vertex_feats, small_graph.labels, 2,
        edge_types=small_graph.edge_types,
    )
    b = subgraph_to_batch(
        sub, src, small_graph.labels, 2, edge_types=small_graph.edge_types,
    )
    np.testing.assert_array_equal(a.feats, b.feats)
    np.testing.assert_array_equal(a.valid, b.valid)
    np.testing.assert_array_equal(a.seed_pos, b.seed_pos)
    np.testing.assert_array_equal(a.labels, b.labels)
    for da, db in zip(a.layer_dst, b.layer_dst):
        np.testing.assert_array_equal(da, db)
    assert src.stats.rows_served > 0  # the tiered path actually served


def test_pipeline_feature_source_end_to_end(
    small_graph, sampling_client, tmp_path
):
    """BatchPipeline with an out-of-core FeatureSource streams the same
    batches as the in-memory default (serial mode, same request keys)."""
    from repro.api.pipeline import BatchPipeline

    seeds = np.arange(128)
    BIG = 10**9  # full fanout: sampling is deterministic across pipelines
    kw = dict(
        fanouts=[BIG, BIG], num_layers=2, batch_size=64, prefetch=0, seed=0
    )
    mem = BatchPipeline(sampling_client, small_graph, seeds, **kw)
    src = StoreFeatureSource.from_array(
        small_graph.vertex_feats, str(tmp_path / "f"), chunk_rows=256
    )
    disk = BatchPipeline(
        sampling_client, small_graph, seeds, feature_source=src, **kw
    )
    for (sa, ba), (sb, bb) in zip(mem.batches(1), disk.batches(1)):
        np.testing.assert_array_equal(sa, sb)
        np.testing.assert_array_equal(np.asarray(ba.feats), np.asarray(bb.feats))
        np.testing.assert_array_equal(
            np.asarray(ba.labels), np.asarray(bb.labels)
        )
