"""Partitioner quality and invariants (paper Table II claims at small scale),
the ``Partitioner`` protocol / ``PartitionPlan`` scorecard, the lockstep-vs-
loop AdaDNE equivalence gate, and the cached partition pipeline."""
import functools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - minimal environments
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.partition import (
    PARTITIONERS,
    NEConfig,
    NeighborExpansionPartitioner,
    Partitioner,
    PartitionPipeline,
    PartitionPlan,
    adadne,
    distributed_ne,
    hash2d_partition,
    ldg_edge_cut,
    random_edge_partition,
)
from repro.core.partition.dne import _flush_sequence, _iteration_budgets
from repro.graph import power_law_graph
from repro.graph.metrics import (
    metrics_from_edge_assignment,
    metrics_from_vertex_assignment,
)


@pytest.fixture(scope="module")
def g():
    return power_law_graph(8000, avg_degree=10, seed=11)


def test_all_edges_assigned(g):
    for fn in (adadne, distributed_ne, hash2d_partition, random_edge_partition):
        ep = fn(g, 8, seed=0)
        assert ep.shape == (g.num_edges,)
        assert ep.min() >= 0 and ep.max() < 8


def test_adadne_balance(g):
    m = metrics_from_edge_assignment(g, adadne(g, 8, seed=0), 8)
    assert m["VB"] < 1.5, m
    assert m["EB"] < 1.4, m
    assert 1.0 <= m["RF"] < 4.0, m


def test_adadne_beats_random_rf(g):
    m_ada = metrics_from_edge_assignment(g, adadne(g, 8, seed=0), 8)
    m_rnd = metrics_from_edge_assignment(g, random_edge_partition(g, 8, 0), 8)
    assert m_ada["RF"] < m_rnd["RF"]


def test_adadne_vb_eb_vs_dne(g):
    """Paper Table II: AdaDNE suppresses VB/EB relative to DistributedNE
    (averaged over seeds to avoid flakiness)."""
    vb_a, eb_a, vb_d, eb_d = [], [], [], []
    for s in range(3):
        ma = metrics_from_edge_assignment(g, adadne(g, 8, seed=s), 8)
        md = metrics_from_edge_assignment(g, distributed_ne(g, 8, seed=s), 8)
        vb_a.append(ma["VB"]); eb_a.append(ma["EB"])
        vb_d.append(md["VB"]); eb_d.append(md["EB"])
    assert np.mean(vb_a) <= np.mean(vb_d) * 1.1
    assert np.mean(eb_a) <= np.mean(eb_d) * 1.1


def test_edge_cut_metrics(g):
    vp = ldg_edge_cut(g, 4, seed=0)
    assert vp.shape == (g.num_vertices,)
    m = metrics_from_vertex_assignment(g, vp, 4)
    assert m["RF"] >= 1.0


def test_hash2d_replication_bound(g):
    """2D hash: RF bounded by rows + cols - 1."""
    m = metrics_from_edge_assignment(g, hash2d_partition(g, 16, 0), 16)
    assert m["RF"] <= 4 + 4 - 1 + 0.01


# ---------------------------------------------------------------------------
# Partitioner protocol + PartitionPlan scorecard
# ---------------------------------------------------------------------------


def test_registry_entries_implement_protocol(g):
    expected = {"adadne", "adadne_loop", "dne", "dne_loop", "ldg", "hash2d", "random"}
    assert expected <= set(PARTITIONERS.names())
    for name in expected:
        entry = PARTITIONERS.get(name)
        assert isinstance(entry, Partitioner), name
        assert entry.name == name


def test_plan_scorecard_matches_metrics(g):
    for name in ("adadne", "ldg", "hash2d"):
        plan = PARTITIONERS.get(name).partition(g, 4, seed=0)
        assert isinstance(plan, PartitionPlan)
        assert plan.num_parts == 4 and plan.partitioner == name
        m = metrics_from_edge_assignment(g, plan.edge_parts, 4)
        assert plan.replication_factor == pytest.approx(m["RF"])
        assert plan.vertex_balance == pytest.approx(m["VB"])
        assert plan.edge_balance == pytest.approx(m["EB"])
        assert plan.edge_counts.tolist() == m["edges"]
        assert plan.vertex_counts.tolist() == m["vertices"]
        assert plan.metrics()["RF"] == plan.replication_factor
    # instances stay callable like the old registry functions
    plan = PARTITIONERS.get("random")(g, 4, seed=1)
    assert isinstance(plan, PartitionPlan)


def test_ldg_plan_has_vertex_owner_and_direction(g):
    plan = PARTITIONERS.get("ldg").partition(g, 4, seed=0, direction="out")
    assert plan.vertex_owner is not None
    np.testing.assert_array_equal(
        plan.edge_parts, plan.vertex_owner[g.src].astype(np.int16)
    )
    plan_in = PARTITIONERS.get("ldg").partition(g, 4, seed=0, direction="in")
    np.testing.assert_array_equal(
        plan_in.edge_parts, plan_in.vertex_owner[g.dst].astype(np.int16)
    )


def test_adadne_iteration_trace(g):
    plan = PARTITIONERS.get("adadne").partition(g, 4, seed=0)
    tr = plan.iteration_trace
    assert tr is not None
    iters = tr["remaining"].shape[0]
    assert iters > 1
    assert tr["edge_counts"].shape == (iters, 4)
    assert tr["lam"].shape == (iters, 4)
    # remaining decreases to 0 and edge counts grow monotonically
    assert tr["remaining"][-1] == 0 or tr["remaining"][-1] < tr["remaining"][0]
    assert (np.diff(tr["edge_counts"], axis=0) >= 0).all()
    assert tr["edge_counts"][-1].sum() <= g.num_edges


# ---------------------------------------------------------------------------
# lockstep vs loop: determinism + statistical equivalence gate
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _prop_graph():
    return power_law_graph(4000, avg_degree=8, seed=23)


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16), parts=st.integers(2, 8))
def test_property_adadne_quality_and_determinism(seed, parts):
    """Both implementations: all edges assigned, balance within the soft
    bounds, bit-identical across runs at a fixed seed — and the two
    implementations statistically equivalent (the refactor's gate)."""
    gg = _prop_graph()
    plans = {}
    for mode in ("lockstep", "loop"):
        part = NeighborExpansionPartitioner(adaptive=True, mode=mode)
        plan = part.partition(gg, parts, seed=seed)
        again = part.partition(gg, parts, seed=seed)
        np.testing.assert_array_equal(
            plan.edge_parts, again.edge_parts
        ), f"{mode} nondeterministic"
        assert plan.edge_parts.shape == (gg.num_edges,)
        assert plan.edge_parts.min() >= 0 and plan.edge_parts.max() < parts
        assert plan.vertex_balance < 1.8, (mode, plan.metrics())
        assert plan.edge_balance < 1.6, (mode, plan.metrics())
        assert 1.0 <= plan.replication_factor < parts
        plans[mode] = plan
    a, b = plans["lockstep"], plans["loop"]
    assert a.vertex_balance == pytest.approx(b.vertex_balance, abs=0.35)
    assert a.edge_balance == pytest.approx(b.edge_balance, abs=0.35)
    assert a.replication_factor == pytest.approx(b.replication_factor, rel=0.15)


def test_legacy_shims_match_registry(g):
    np.testing.assert_array_equal(
        adadne(g, 4, seed=3),
        PARTITIONERS.get("adadne").partition(g, 4, seed=3).edge_parts,
    )
    np.testing.assert_array_equal(
        distributed_ne(g, 4, seed=3, mode="loop"),
        PARTITIONERS.get("dne_loop").partition(g, 4, seed=3).edge_parts,
    )


def test_ne_config_legacy_call_style(g):
    """Old style — cfg carries num_parts/seed, partition(g) — still works."""
    part = NeighborExpansionPartitioner(NEConfig(num_parts=4, adaptive=True, seed=5))
    plan = part.partition(g)
    assert plan.num_parts == 4 and plan.seed == 5
    np.testing.assert_array_equal(plan.edge_parts, adadne(g, 4, seed=5))


# ---------------------------------------------------------------------------
# budgets fix + vectorized stall flush
# ---------------------------------------------------------------------------


def test_iteration_budgets_zero_for_terminated():
    lam = np.full(4, 0.1)
    bsize = np.array([10, 0, 500, 20], dtype=np.int64)
    term = np.array([False, True, True, False])
    budgets = _iteration_budgets(lam, bsize, term, E=100_000, budget_frac=0.01)
    assert (budgets[term] == 0).all()  # hard threshold honored exactly
    assert (budgets[~term] >= 16).all()
    # un-terminated vector reproduces the original proportional split
    none = np.zeros(4, dtype=bool)
    b2 = _iteration_budgets(lam, bsize, none, E=100_000, budget_frac=0.01)
    w = lam * np.maximum(bsize, 1.0)
    want = np.maximum(16, 0.01 * 100_000 * w / w.sum()).astype(np.int64)
    np.testing.assert_array_equal(b2, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), parts=st.integers(1, 12), k=st.integers(0, 400))
def test_flush_sequence_matches_naive_greedy(seed, parts, k):
    rng = np.random.default_rng(seed)
    nE = rng.integers(0, 50, size=parts).astype(np.int64)
    seq = _flush_sequence(nE.copy(), k)
    # naive replay: each edge to the current argmin (lowest index on ties)
    cur = nE.copy()
    want = np.empty(k, dtype=np.int16)
    for i in range(k):
        p = int(np.argmin(cur))
        want[i] = p
        cur[p] += 1
    np.testing.assert_array_equal(seq, want)
    if k:
        np.testing.assert_array_equal(
            np.bincount(seq, minlength=parts) + nE, cur
        )


# ---------------------------------------------------------------------------
# chunked LDG
# ---------------------------------------------------------------------------


def test_ldg_chunked_determinism_and_balance(g):
    a = ldg_edge_cut(g, 4, seed=9)
    b = ldg_edge_cut(g, 4, seed=9)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (g.num_vertices,)
    assert a.min() >= 0 and a.max() < 4
    sizes = np.bincount(a, minlength=4)
    cap = 1.05 * g.num_vertices / 4
    # within-chunk placements can't see each other, so the hard cap can
    # drift by at most one chunk
    assert sizes.max() <= cap + 256
    # locality objective: most neighbors co-located vs a random assignment
    same = (a[g.src] == a[g.dst]).mean()
    rng = np.random.default_rng(0)
    rand = rng.integers(0, 4, g.num_vertices).astype(np.int16)
    assert same > (rand[g.src] == rand[g.dst]).mean()


# ---------------------------------------------------------------------------
# the cached partition -> reorder -> materialize pipeline
# ---------------------------------------------------------------------------


def test_pipeline_stages_no_cache(g):
    pipe = PartitionPipeline("adadne", 4, reorder="pds", seed=0)
    res = pipe.run(g)
    assert not res.cache_hit and res.cache_key is None
    assert len(res.partitions) == 4
    assert sum(p.num_edges for p in res.partitions) == g.num_edges
    assert sorted(res.perm.tolist()) == list(range(g.num_vertices))
    assert set(res.seconds) == {"partition", "reorder", "materialize"}
    np.testing.assert_array_equal(
        res.plan.edge_parts, adadne(g, 4, seed=0)
    )


def test_pipeline_cache_roundtrip(g, tmp_path):
    cache = str(tmp_path / "pcache")
    pipe = PartitionPipeline("adadne", 4, reorder="pds", seed=0, cache_dir=cache)
    first = pipe.run(g)
    assert not first.cache_hit
    second = pipe.run(g)
    assert second.cache_hit and second.cache_key == first.cache_key
    np.testing.assert_array_equal(first.plan.edge_parts, second.plan.edge_parts)
    np.testing.assert_array_equal(first.perm, second.perm)
    assert second.plan.replication_factor == pytest.approx(
        first.plan.replication_factor
    )
    assert second.plan.edge_counts.tolist() == first.plan.edge_counts.tolist()
    # a config change must miss (different content address)
    other = PartitionPipeline("adadne", 4, reorder="pds", seed=1, cache_dir=cache)
    assert other.cache_key(g) != pipe.cache_key(g)
    assert not other.run(g).cache_hit


def test_pipeline_cache_key_covers_hyperparameters(g, tmp_path):
    """Differently-configured instances of one algorithm never share an
    artifact: the instance's cache_token (name + hyperparameters) is part
    of the content address."""
    cache = str(tmp_path / "pcache")
    default = PartitionPipeline("adadne", 4, seed=0, cache_dir=cache)
    default.run(g)
    custom = PartitionPipeline(
        NeighborExpansionPartitioner(adaptive=True, lam0=0.9, alpha=3.0),
        4,
        seed=0,
        cache_dir=cache,
    )
    assert custom.cache_key(g) != default.cache_key(g)
    assert not custom.run(g).cache_hit


def test_pipeline_corrupt_artifact_recomputes(g, tmp_path):
    cache = str(tmp_path / "pcache")
    pipe = PartitionPipeline("adadne", 4, seed=0, cache_dir=cache)
    first = pipe.run(g)
    path = pipe._cache_path(pipe.cache_key(g))
    with open(path, "r+b") as fh:
        fh.seek(10)
        fh.write(b"\xde\xad\xbe\xef" * 8)
    again = pipe.run(g)  # must not raise BadZipFile
    assert not again.cache_hit
    np.testing.assert_array_equal(first.plan.edge_parts, again.plan.edge_parts)
    assert pipe.run(g).cache_hit  # the recompute republished a good artifact


def test_pipeline_cache_keeps_vertex_owner(g, tmp_path):
    cache = str(tmp_path / "pcache")
    pipe = PartitionPipeline("ldg", 4, seed=0, cache_dir=cache)
    first = pipe.run(g)
    second = pipe.run(g)
    assert second.cache_hit
    np.testing.assert_array_equal(first.plan.vertex_owner, second.plan.vertex_owner)


def test_system_build_reports_cache_hit(g, tmp_path):
    from repro.api import GLISPConfig, GLISPSystem

    cfg = GLISPConfig(
        num_parts=4,
        fanouts=(4,),
        partition_cache_dir=str(tmp_path / "syscache"),
    ).validate()
    s1 = GLISPSystem.build(g, cfg)
    assert not s1.partition_cache_hit
    s2 = GLISPSystem.build(g, cfg)
    assert s2.partition_cache_hit
    # near-zero partition stage on the hit: loading beats repartitioning
    assert s2.partition_seconds < max(0.25, 0.5 * s1.partition_seconds)
    np.testing.assert_array_equal(s1.plan.edge_parts, s2.plan.edge_parts)
    np.testing.assert_array_equal(s1.reorder_perm, s2.reorder_perm)
    # identically-seeded systems sample identically whichever path built them
    a = s1.sample(np.arange(32), fanouts=[4])
    b = s2.sample(np.arange(32), fanouts=[4])
    for ha, hb in zip(a.hops, b.hops):
        np.testing.assert_array_equal(ha.src, hb.src)
        np.testing.assert_array_equal(ha.dst, hb.dst)


def test_config_validates_cache_dir():
    from repro.api import GLISPConfig

    with pytest.raises(ValueError, match="partition_cache_dir"):
        GLISPConfig(partition_cache_dir="").validate()
    GLISPConfig(partition_cache_dir=None).validate()
