"""Partitioner quality and invariants (paper Table II claims at small scale)."""
import numpy as np
import pytest

from repro.core.partition import (
    adadne,
    distributed_ne,
    hash2d_partition,
    ldg_edge_cut,
    random_edge_partition,
)
from repro.graph import power_law_graph
from repro.graph.metrics import (
    metrics_from_edge_assignment,
    metrics_from_vertex_assignment,
)


@pytest.fixture(scope="module")
def g():
    return power_law_graph(8000, avg_degree=10, seed=11)


def test_all_edges_assigned(g):
    for fn in (adadne, distributed_ne, hash2d_partition, random_edge_partition):
        ep = fn(g, 8, seed=0)
        assert ep.shape == (g.num_edges,)
        assert ep.min() >= 0 and ep.max() < 8


def test_adadne_balance(g):
    m = metrics_from_edge_assignment(g, adadne(g, 8, seed=0), 8)
    assert m["VB"] < 1.5, m
    assert m["EB"] < 1.4, m
    assert 1.0 <= m["RF"] < 4.0, m


def test_adadne_beats_random_rf(g):
    m_ada = metrics_from_edge_assignment(g, adadne(g, 8, seed=0), 8)
    m_rnd = metrics_from_edge_assignment(g, random_edge_partition(g, 8, 0), 8)
    assert m_ada["RF"] < m_rnd["RF"]


def test_adadne_vb_eb_vs_dne(g):
    """Paper Table II: AdaDNE suppresses VB/EB relative to DistributedNE
    (averaged over seeds to avoid flakiness)."""
    vb_a, eb_a, vb_d, eb_d = [], [], [], []
    for s in range(3):
        ma = metrics_from_edge_assignment(g, adadne(g, 8, seed=s), 8)
        md = metrics_from_edge_assignment(g, distributed_ne(g, 8, seed=s), 8)
        vb_a.append(ma["VB"]); eb_a.append(ma["EB"])
        vb_d.append(md["VB"]); eb_d.append(md["EB"])
    assert np.mean(vb_a) <= np.mean(vb_d) * 1.1
    assert np.mean(eb_a) <= np.mean(eb_d) * 1.1


def test_edge_cut_metrics(g):
    vp = ldg_edge_cut(g, 4, seed=0)
    assert vp.shape == (g.num_vertices,)
    m = metrics_from_vertex_assignment(g, vp, 4)
    assert m["RF"] >= 1.0


def test_hash2d_replication_bound(g):
    """2D hash: RF bounded by rows + cols - 1."""
    m = metrics_from_edge_assignment(g, hash2d_partition(g, 16, 0), 16)
    assert m["RF"] <= 4 + 4 - 1 + 0.01
